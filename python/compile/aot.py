"""AOT lowering: JAX (L2, calling L1 Pallas) -> HLO text artifacts for Rust.

Run once via ``make artifacts``. Python never runs on the request path; the
Rust runtime (rust/src/runtime/) loads the HLO text with
``HloModuleProto::from_text_file``, compiles it on the PJRT CPU client and
executes it.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects with
``proto.id() <= INT_MAX``. The text parser reassigns ids, so text
round-trips cleanly. (See /opt/xla-example/README.md.)

Artifacts are keyed by the paper's Table 2 dataset shapes; each line of
``artifacts/manifest.txt`` is::

    name entry task B D K filename

Usage: ``python -m compile.aot --out ../artifacts [--only tiny,diabetes]``
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, task, B, D, K, entries)
# B is the fixed minibatch the artifact is specialized for; the Rust side
# pads the final partial batch with zero rows (zero rows score w0 and are
# masked out of metrics).
SPECS = [
    ("tiny_reg", "regression", 8, 16, 4, ("score", "grad", "step")),
    ("tiny_clf", "classification", 8, 16, 4, ("score", "grad", "step")),
    ("diabetes", "classification", 256, 8, 4, ("score", "grad", "step")),
    ("housing", "regression", 256, 13, 4, ("score", "grad", "step")),
    ("ijcnn1", "classification", 256, 22, 4, ("score", "grad", "step")),
    # realsim is D=20,958; score is the artifact the evaluator needs on the
    # request path. grad/step at this width are built on demand (--full).
    ("realsim", "classification", 256, 20958, 16, ("score",)),
]

FULL_EXTRA = {"realsim": ("grad", "step")}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shapes(task, B, D, K):
    f32 = jnp.float32
    w0 = jax.ShapeDtypeStruct((), f32)
    w = jax.ShapeDtypeStruct((D,), f32)
    V = jax.ShapeDtypeStruct((D, K), f32)
    X = jax.ShapeDtypeStruct((B, D), f32)
    y = jax.ShapeDtypeStruct((B,), f32)
    s = jax.ShapeDtypeStruct((), f32)
    return w0, w, V, X, y, s


def lower_entry(entry, task, B, D, K):
    """Lower one entry point to HLO text."""
    w0, w, V, X, y, s = _shapes(task, B, D, K)
    if entry == "score":
        fn = model.score_batch
        args = (w0, w, V, X)
        lowered = jax.jit(fn).lower(*args)
    elif entry == "score_aux":
        fn = model.score_and_aux_batch
        args = (w0, w, V, X)
        lowered = jax.jit(fn).lower(*args)
    elif entry == "grad":
        fn = functools.partial(model.grad_batch, task=task)
        args = (w0, w, V, X, y)
        lowered = jax.jit(fn).lower(*args)
    elif entry == "step":
        fn = functools.partial(model.sgd_step_batch, task=task)
        args = (w0, w, V, X, y, s, s, s)
        # Donate the parameter buffers: the step graph aliases them in-place.
        lowered = jax.jit(fn, donate_argnums=(0, 1, 2)).lower(*args)
    else:
        raise ValueError(f"unknown entry {entry!r}")
    return to_hlo_text(lowered)


def _input_fingerprint() -> str:
    """Hash of the compile-path sources, for the no-op freshness check."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for fname in sorted(files):
            if fname.endswith(".py"):
                with open(os.path.join(root, fname), "rb") as fh:
                    h.update(fname.encode())
                    h.update(fh.read())
    return h.hexdigest()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default="", help="comma-separated spec names")
    ap.add_argument("--full", action="store_true",
                    help="also build the very wide realsim grad/step artifacts")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    only = {s for s in args.only.split(",") if s}
    fp = _input_fingerprint()
    fp_path = os.path.join(args.out, ".fingerprint")
    manifest_path = os.path.join(args.out, "manifest.txt")
    if not only and os.path.exists(fp_path) and os.path.exists(manifest_path):
        with open(fp_path) as fh:
            if fh.read().strip() == fp:
                print("artifacts up to date; nothing to do")
                return 0

    lines = ["# name entry task B D K filename"]
    for name, task, B, D, K, entries in SPECS:
        if only and name not in only:
            continue
        if args.full:
            entries = tuple(entries) + FULL_EXTRA.get(name, ())
        for entry in entries:
            fname = f"{name}_{entry}.hlo.txt"
            path = os.path.join(args.out, fname)
            print(f"lowering {name}/{entry}  (task={task} B={B} D={D} K={K})",
                  flush=True)
            text = lower_entry(entry, task, B, D, K)
            with open(path, "w") as fh:
                fh.write(text)
            lines.append(f"{name} {entry} {task} {B} {D} {K} {fname}")

    if not only:
        with open(manifest_path, "w") as fh:
            fh.write("\n".join(lines) + "\n")
        with open(fp_path, "w") as fh:
            fh.write(fp + "\n")
    print(f"wrote {len(lines) - 1} artifacts to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
