"""Pure-jnp reference oracle for the FM kernels.

This module is the correctness ground-truth for the Pallas kernels in
``fm_pallas.py``. Everything here is written in the most direct form of the
paper's equations (eqs. 4, 6-13) with no tiling, blocking or other kernel
machinery, so that a bug in the kernels cannot be masked by a shared
implementation detail.

Shapes (dense minibatch):
    X  : [B, D]   minibatch of examples
    w0 : []       global bias
    w  : [D]      linear weights
    V  : [D, K]   factor embeddings
    y  : [B]      labels (regression: reals; classification: +/-1)
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "fm_score_parts_ref",
    "fm_score_ref",
    "fm_score_naive_ref",
    "loss_ref",
    "multiplier_ref",
    "fm_grad_ref",
]


def fm_score_parts_ref(w, V, X):
    """The three synchronization quantities of the score function.

    Returns (A, xw, S2) where
        A[b, k]  = sum_d V[d, k] * X[b, d]          (paper eq. 10, batched)
        xw[b]    = sum_d w[d] * X[b, d]
        S2[b, k] = sum_d V[d, k]^2 * X[b, d]^2
    """
    A = X @ V
    xw = X @ w
    S2 = (X * X) @ (V * V)
    return A, xw, S2


def fm_score_ref(w0, w, V, X):
    """FM score via the O(KD) rewrite (paper eq. 4)."""
    A, xw, S2 = fm_score_parts_ref(w, V, X)
    return w0 + xw + 0.5 * jnp.sum(A * A - S2, axis=-1)


def fm_score_naive_ref(w0, w, V, X):
    """FM score via the O(K D^2) double loop (paper eq. 2).

    Deliberately naive: used only in tests to validate the eq. 3 rewrite.
    """
    B, D = X.shape
    pair = jnp.zeros((B,), X.dtype)
    gram = V @ V.T  # [D, D] of <v_j, v_j'>
    for j in range(D):
        for jp in range(j + 1, D):
            pair = pair + gram[j, jp] * X[:, j] * X[:, jp]
    return w0 + X @ w + pair


def loss_ref(f, y, task):
    """Per-example loss (paper eq. 5's l(.)).

    task: "regression" -> squared loss 0.5 (f - y)^2
          "classification" -> logistic loss log(1 + exp(-y f))
    """
    if task == "regression":
        return 0.5 * (f - y) ** 2
    if task == "classification":
        # log(1 + exp(-y f)) computed stably.
        return jnp.logaddexp(0.0, -y * f)
    raise ValueError(f"unknown task {task!r}")


def multiplier_ref(f, y, task):
    """The G_i multiplier dl/df (paper eq. 9)."""
    if task == "regression":
        return f - y
    if task == "classification":
        return -y / (1.0 + jnp.exp(y * f))
    raise ValueError(f"unknown task {task!r}")


def fm_grad_ref(w0, w, V, X, y, task):
    """Full-batch gradients of the mean loss (no regularizer).

    Returns (g0, gw, gV, mean_loss) with
        g0      = mean_i G_i
        gw[j]   = mean_i G_i x_ij                       (paper eq. 7)
        gV[j,k] = mean_i G_i (x_ij a_ik - v_jk x_ij^2)  (paper eq. 8)
    The regularizer terms are added by the caller (they are trivially
    separable and the Rust side owns the hyper-parameters).
    """
    B = X.shape[0]
    A, xw, S2 = fm_score_parts_ref(w, V, X)
    f = w0 + xw + 0.5 * jnp.sum(A * A - S2, axis=-1)
    g = multiplier_ref(f, y, task)  # [B]
    g0 = jnp.mean(g)
    gw = (X.T @ g) / B
    gA = g[:, None] * A  # [B, K]
    gV = (X.T @ gA - ((X * X).T @ g)[:, None] * V) / B
    mean_loss = jnp.mean(loss_ref(f, y, task))
    return g0, gw, gV, mean_loss
