"""Layer-1 Pallas kernels for Factorization Machines.

Two kernels cover the FM compute hot-spot for a dense minibatch tile:

* ``fm_score_parts``  — the forward synchronization quantities
  (A = X @ V, xw = X @ w, S2 = X^2 @ V^2), i.e. everything eq. 4 needs.
* ``fm_grad_parts``   — the backward matmuls given the per-example loss
  multipliers g (paper eq. 9): gw = X^T g, gA = X^T (g * A),
  gs = X^2^T g (so that gV = gA - gs[:, None] * V).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
"synchronization term" a_ik = sum_d v_dk x_id is exactly a [B,D] x [D,K]
matmul, so on TPU it maps onto the MXU systolic array. We tile the D axis
(the model-parallel axis the Rust coordinator partitions) with BlockSpec so
each grid step streams one X[B, Dt] tile and one V[Dt, K] slab HBM->VMEM and
accumulates the K-resident partial sums in the output block — the in-kernel
analogue of the paper's *incremental synchronization* with partial sums.

All pallas_call sites use interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and the AOT path (aot.py) must produce HLO that the
Rust runtime's CPU client executes. Real-TPU performance is estimated
structurally in DESIGN.md §Perf.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fm_score_parts", "fm_grad_parts", "DEFAULT_BLOCK_D", "pick_block_d"]

# Default D-tile. 512 f32 columns x B<=512 rows keeps an X tile <= 1 MiB and a
# V slab (512 x K<=64) <= 128 KiB: comfortably inside a 16 MiB VMEM budget
# with double-buffering headroom (see DESIGN.md §Perf).
DEFAULT_BLOCK_D = 512


def pick_block_d(d: int, block_d: int | None = None) -> int:
    """Choose a D-tile size: the default, shrunk for tiny models."""
    if block_d is not None:
        return min(block_d, d)
    return min(DEFAULT_BLOCK_D, d)


def _score_parts_kernel(x_ref, w_ref, v_ref, a_ref, xw_ref, s2_ref):
    """Grid step (d): accumulate partial sums over one D-tile.

    Blocks:  x_ref [B, Dt], w_ref [Dt], v_ref [Dt, K]
    Outputs: a_ref [B, K], xw_ref [B], s2_ref [B, K]  (same block every step,
             accumulated across the D grid axis).
    """
    d = pl.program_id(0)

    x = x_ref[...]
    v = v_ref[...]
    w = w_ref[...]

    a_part = jnp.dot(x, v, preferred_element_type=jnp.float32)
    xw_part = jnp.dot(x, w[:, None], preferred_element_type=jnp.float32)[:, 0]
    s2_part = jnp.dot(x * x, v * v, preferred_element_type=jnp.float32)

    @pl.when(d == 0)
    def _init():
        a_ref[...] = a_part
        xw_ref[...] = xw_part
        s2_ref[...] = s2_part

    @pl.when(d != 0)
    def _acc():
        a_ref[...] += a_part
        xw_ref[...] += xw_part
        s2_ref[...] += s2_part


def fm_score_parts(w, V, X, *, block_d: int | None = None):
    """Compute (A, xw, S2) for a dense minibatch with a D-tiled Pallas kernel.

    Args:
      w: [D] linear weights.
      V: [D, K] factor embeddings.
      X: [B, D] minibatch.
      block_d: optional D-tile override (testing / autotuning).

    Returns (A [B,K], xw [B], S2 [B,K]) in float32.
    """
    B, D = X.shape
    Dv, K = V.shape
    assert Dv == D and w.shape == (D,), (X.shape, V.shape, w.shape)

    bd = pick_block_d(D, block_d)
    # Zero-pad D to a tile multiple: out-of-bounds block reads are undefined
    # in interpret mode, and zeros contribute nothing to any of the sums.
    Dp = pl.cdiv(D, bd) * bd
    if Dp != D:
        pad = ((0, 0), (0, Dp - D))
        X = jnp.pad(X, pad)
        w = jnp.pad(w, ((0, Dp - D),))
        V = jnp.pad(V, ((0, Dp - D), (0, 0)))
    grid = (Dp // bd,)

    return pl.pallas_call(
        _score_parts_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bd), lambda d: (0, d)),
            pl.BlockSpec((bd,), lambda d: (d,)),
            pl.BlockSpec((bd, K), lambda d: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, K), lambda d: (0, 0)),
            pl.BlockSpec((B,), lambda d: (0,)),
            pl.BlockSpec((B, K), lambda d: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        interpret=True,
    )(X, w, V)


def _grad_parts_kernel(x_ref, g_ref, ga_in_ref, gw_ref, gacc_ref, gs_ref):
    """Grid step (d): backward matmuls for one D-tile.

    Blocks:  x_ref [B, Dt], g_ref [B], ga_in_ref [B, K] (g * A, precomputed)
    Outputs: gw_ref [Dt], gacc_ref [Dt, K], gs_ref [Dt]

    Each grid step owns a distinct D-tile of every output, so there is no
    cross-step accumulation: one pass, three transposed matmuls on the MXU.
    """
    x = x_ref[...]
    g = g_ref[...]
    ga = ga_in_ref[...]

    xt = x.T  # [Dt, B]
    gw_ref[...] = jnp.dot(xt, g[:, None], preferred_element_type=jnp.float32)[:, 0]
    gacc_ref[...] = jnp.dot(xt, ga, preferred_element_type=jnp.float32)
    x2t = (x * x).T
    gs_ref[...] = jnp.dot(x2t, g[:, None], preferred_element_type=jnp.float32)[:, 0]


def fm_grad_parts(X, g, A, *, block_d: int | None = None):
    """Backward matmuls: (gw, gA_acc, gs) from multipliers g and factor sums A.

    gw[j]      = sum_b g_b X[b, j]
    gA_acc[j,k]= sum_b g_b X[b, j] A[b, k]
    gs[j]      = sum_b g_b X[b, j]^2

    The caller finishes gV = gA_acc - gs[:, None] * V (an elementwise op the
    XLA fusion pass handles; keeping it out of the kernel lets the same
    artifact serve any V without re-streaming it).
    """
    B, D = X.shape
    K = A.shape[1]
    assert g.shape == (B,) and A.shape == (B, K)

    bd = pick_block_d(D, block_d)
    # Zero-pad D to a tile multiple (see fm_score_parts); padded output rows
    # are sliced away below.
    Dp = pl.cdiv(D, bd) * bd
    if Dp != D:
        X = jnp.pad(X, ((0, 0), (0, Dp - D)))
    grid = (Dp // bd,)
    ga = g[:, None] * A  # [B, K], tiny; fused by XLA outside the kernel

    gw, gacc, gs = pl.pallas_call(
        _grad_parts_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bd), lambda d: (0, d)),
            pl.BlockSpec((B,), lambda d: (0,)),
            pl.BlockSpec((B, K), lambda d: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bd,), lambda d: (d,)),
            pl.BlockSpec((bd, K), lambda d: (d, 0)),
            pl.BlockSpec((bd,), lambda d: (d,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Dp,), jnp.float32),
            jax.ShapeDtypeStruct((Dp, K), jnp.float32),
            jax.ShapeDtypeStruct((Dp,), jnp.float32),
        ],
        interpret=True,
    )(X, g, ga)
    if Dp != D:
        gw, gacc, gs = gw[:D], gacc[:D], gs[:D]
    return gw, gacc, gs
