"""Layer-2 JAX model: Factorization Machine forward / loss / gradient graphs.

These are the computations `aot.py` lowers to HLO text for the Rust runtime.
They call the Layer-1 Pallas kernels (`kernels.fm_pallas`) so that the kernel
lowers into the same HLO module; nothing here runs at serving/training time in
Python.

Conventions shared with the Rust side (rust/src/runtime/):
  * all arrays are float32;
  * classification labels are +/-1 floats;
  * regularization is applied on the Rust side (it is separable and the
    coordinator owns the hyper-parameters), so gradients here are pure
    data-loss gradients of the *mean* loss over the batch;
  * every entry point returns a flat tuple of arrays (lowered with
    return_tuple=True; the Rust side unwraps the tuple).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import fm_pallas
from .kernels import ref

TASKS = ("regression", "classification")


def score_batch(w0, w, V, X):
    """FM scores f(x_i) for a dense minibatch (paper eq. 4).

    Returns (f [B],).
    """
    A, xw, S2 = fm_pallas.fm_score_parts(w, V, X)
    f = w0 + xw + 0.5 * jnp.sum(A * A - S2, axis=-1)
    return (f,)


def score_and_aux_batch(w0, w, V, X):
    """Scores plus the synchronization quantities the coordinator caches.

    Returns (f [B], A [B,K]): A is the paper's a_ik (eq. 10) — the NOMAD
    engine's auxiliary variable — so the runtime can refresh worker-local
    caches from the same artifact that scores.
    """
    A, xw, S2 = fm_pallas.fm_score_parts(w, V, X)
    f = w0 + xw + 0.5 * jnp.sum(A * A - S2, axis=-1)
    return (f, A)


def loss_batch(w0, w, V, X, y, *, task):
    """Mean data loss over the batch. Returns (loss [],)."""
    (f,) = score_batch(w0, w, V, X)
    return (jnp.mean(ref.loss_ref(f, y, task)),)


def grad_batch(w0, w, V, X, y, *, task):
    """Mean-loss gradients via the L1 backward kernel.

    Returns (g0 [], gw [D], gV [D,K], loss []).
    """
    B = X.shape[0]
    A, xw, S2 = fm_pallas.fm_score_parts(w, V, X)
    f = w0 + xw + 0.5 * jnp.sum(A * A - S2, axis=-1)
    g = ref.multiplier_ref(f, y, task)  # [B]
    gw, gA_acc, gs = fm_pallas.fm_grad_parts(X, g, A)
    g0 = jnp.mean(g)
    gV = (gA_acc - gs[:, None] * V) / B
    loss = jnp.mean(ref.loss_ref(f, y, task))
    return (g0, gw / B, gV, loss)


def sgd_step_batch(w0, w, V, X, y, eta, lam_w, lam_v, *, task):
    """One dense-minibatch SGD step (the XLA-trainer variant's inner graph).

    Applies paper eqs. 6-8 with the batch-mean gradient plus L2 terms.
    Buffer donation for (w0, w, V) is declared at lowering time in aot.py.
    Returns (w0', w', V', loss).
    """
    g0, gw, gV, loss = grad_batch(w0, w, V, X, y, task=task)
    w0n = w0 - eta * g0
    wn = w - eta * (gw + lam_w * w)
    Vn = V - eta * (gV + lam_v * V)
    return (w0n, wn, Vn, loss)
