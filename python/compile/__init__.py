"""Build-time compile package: L2 model + L1 kernels + AOT lowering."""
