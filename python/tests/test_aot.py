"""AOT pipeline tests: HLO text generation, manifest format, freshness."""

import os

import numpy as np
import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_entry_produces_hlo_text():
    text = aot.lower_entry("score", "regression", 4, 8, 2)
    assert text.startswith("HloModule"), text[:80]
    # return_tuple=True: the root must be a tuple.
    assert "ROOT" in text and "tuple" in text


@pytest.mark.parametrize("entry", ["score", "score_aux", "grad", "step"])
def test_all_entries_lower(entry):
    text = aot.lower_entry(entry, "classification", 4, 8, 2)
    assert text.startswith("HloModule")
    # f32[4,8] minibatch parameter must appear.
    assert "f32[4,8]" in text


def test_entry_shapes_are_specialized():
    t1 = aot.lower_entry("score", "regression", 8, 16, 4)
    assert "f32[8,16]" in t1 and "f32[16,4]" in t1


def test_unknown_entry_rejected():
    with pytest.raises(ValueError):
        aot.lower_entry("nope", "regression", 2, 2, 2)


def test_tasks_change_grad_module():
    reg = aot.lower_entry("grad", "regression", 4, 8, 2)
    clf = aot.lower_entry("grad", "classification", 4, 8, 2)
    assert reg != clf  # logistic multiplier vs residual must differ


def test_fingerprint_is_stable():
    assert aot._input_fingerprint() == aot._input_fingerprint()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    def test_manifest_lines_well_formed(self):
        with open(os.path.join(ART, "manifest.txt")) as fh:
            lines = [l.split() for l in fh if l.strip() and not l.startswith("#")]
        assert lines, "empty manifest"
        for parts in lines:
            name, entry, task, B, D, K, fname = parts
            assert entry in ("score", "score_aux", "grad", "step")
            assert task in ("regression", "classification")
            assert int(B) > 0 and int(D) > 0 and int(K) > 0
            assert os.path.exists(os.path.join(ART, fname)), fname

    def test_every_table2_dataset_has_score_artifact(self):
        with open(os.path.join(ART, "manifest.txt")) as fh:
            names = {l.split()[0] for l in fh if l.strip() and not l.startswith("#")}
        for ds in ("diabetes", "housing", "ijcnn1", "realsim"):
            assert ds in names, f"missing {ds}"

    def test_artifacts_are_parseable_hlo(self):
        with open(os.path.join(ART, "tiny_reg_score.hlo.txt")) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule")
