"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: every artifact the
Rust runtime executes is built from these kernels, so a mismatch here would
poison every downstream number.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fm_pallas, ref

RNG = np.random.default_rng(1234)


def _rand_problem(B, D, K, scale=1.0, density=1.0, rng=RNG):
    X = rng.normal(size=(B, D)).astype(np.float32) * scale
    if density < 1.0:
        X *= (rng.random((B, D)) < density).astype(np.float32)
    w = rng.normal(size=(D,)).astype(np.float32)
    V = (rng.normal(size=(D, K)) * 0.1).astype(np.float32)
    return X, w, V


def _assert_score_parts_close(X, w, V, block_d=None, rtol=2e-4, atol=2e-4):
    A, xw, S2 = fm_pallas.fm_score_parts(w, V, X, block_d=block_d)
    Ar, xwr, S2r = ref.fm_score_parts_ref(w, V, X)
    np.testing.assert_allclose(A, Ar, rtol=rtol, atol=atol)
    np.testing.assert_allclose(xw, xwr, rtol=rtol, atol=atol)
    np.testing.assert_allclose(S2, S2r, rtol=rtol, atol=atol)


class TestScoreParts:
    @pytest.mark.parametrize(
        "B,D,K",
        [(1, 1, 1), (2, 3, 2), (8, 16, 4), (16, 37, 5), (32, 100, 8),
         (7, 513, 3), (256, 22, 4), (3, 1024, 16)],
    )
    def test_matches_ref(self, B, D, K):
        X, w, V = _rand_problem(B, D, K)
        _assert_score_parts_close(X, w, V)

    @pytest.mark.parametrize("block_d", [1, 2, 7, 16, 64, 512, 10_000])
    def test_any_tile_size(self, block_d):
        X, w, V = _rand_problem(16, 37, 5)
        _assert_score_parts_close(X, w, V, block_d=block_d)

    def test_zero_input(self):
        X = np.zeros((4, 9), np.float32)
        w = np.zeros((9,), np.float32)
        V = np.zeros((9, 3), np.float32)
        A, xw, S2 = fm_pallas.fm_score_parts(w, V, X)
        assert not np.any(A) and not np.any(xw) and not np.any(S2)

    def test_sparse_input(self):
        X, w, V = _rand_problem(64, 200, 8, density=0.05)
        _assert_score_parts_close(X, w, V, block_d=32)

    def test_large_magnitudes(self):
        X, w, V = _rand_problem(8, 32, 4, scale=100.0)
        _assert_score_parts_close(X, w, V, rtol=1e-3, atol=1e-1)

    def test_single_column_tiles(self):
        # Tiling at block_d=1 exercises the accumulate path maximally.
        X, w, V = _rand_problem(4, 5, 2)
        _assert_score_parts_close(X, w, V, block_d=1)


class TestGradParts:
    @pytest.mark.parametrize(
        "B,D,K",
        [(1, 1, 1), (2, 3, 2), (8, 16, 4), (16, 37, 5), (32, 100, 8), (7, 513, 3)],
    )
    def test_matches_dense_algebra(self, B, D, K):
        X, w, V = _rand_problem(B, D, K)
        A = np.asarray(ref.fm_score_parts_ref(w, V, X)[0])
        g = RNG.normal(size=(B,)).astype(np.float32)
        gw, gacc, gs = fm_pallas.fm_grad_parts(X, g, A)
        np.testing.assert_allclose(gw, X.T @ g, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(gacc, X.T @ (g[:, None] * A), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(gs, (X * X).T @ g, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("block_d", [1, 3, 16, 512])
    def test_any_tile_size(self, block_d):
        B, D, K = 8, 37, 4
        X, w, V = _rand_problem(B, D, K)
        A = np.asarray(ref.fm_score_parts_ref(w, V, X)[0])
        g = RNG.normal(size=(B,)).astype(np.float32)
        gw, gacc, gs = fm_pallas.fm_grad_parts(X, g, A, block_d=block_d)
        np.testing.assert_allclose(gw, X.T @ g, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(gacc, X.T @ (g[:, None] * A), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(gs, (X * X).T @ g, rtol=2e-4, atol=2e-4)

    def test_zero_multipliers(self):
        X, w, V = _rand_problem(8, 16, 4)
        A = np.asarray(ref.fm_score_parts_ref(w, V, X)[0])
        g = np.zeros((8,), np.float32)
        gw, gacc, gs = fm_pallas.fm_grad_parts(X, g, A)
        assert not np.any(gw) and not np.any(gacc) and not np.any(gs)


class TestRewriteIdentity:
    """Paper eq. 3: the O(KD) rewrite equals the naive O(KD^2) double sum."""

    @pytest.mark.parametrize("B,D,K", [(3, 4, 2), (5, 8, 4), (2, 12, 3)])
    def test_rewrite_equals_naive(self, B, D, K):
        X, w, V = _rand_problem(B, D, K)
        f_fast = ref.fm_score_ref(0.5, w, V, X)
        f_naive = ref.fm_score_naive_ref(0.5, w, V, X)
        np.testing.assert_allclose(f_fast, f_naive, rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    B=st.integers(1, 24),
    D=st.integers(1, 96),
    K=st.integers(1, 12),
    block_d=st.one_of(st.none(), st.integers(1, 128)),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_score_sweep(B, D, K, block_d, seed):
    """Property: kernel == oracle for arbitrary shapes and tilings."""
    rng = np.random.default_rng(seed)
    X, w, V = _rand_problem(B, D, K, rng=rng)
    _assert_score_parts_close(X, w, V, block_d=block_d)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 16),
    D=st.integers(1, 64),
    K=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_grad_sweep(B, D, K, seed):
    rng = np.random.default_rng(seed)
    X, w, V = _rand_problem(B, D, K, rng=rng)
    A = np.asarray(ref.fm_score_parts_ref(w, V, X)[0])
    g = rng.normal(size=(B,)).astype(np.float32)
    gw, gacc, gs = fm_pallas.fm_grad_parts(X, g, A)
    np.testing.assert_allclose(gw, X.T @ g, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(gacc, X.T @ (g[:, None] * A), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(gs, (X * X).T @ g, rtol=5e-4, atol=5e-4)
