"""L2 correctness: model graphs vs jax.grad and the analytic paper equations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def _problem(B, D, K, task):
    X = RNG.normal(size=(B, D)).astype(np.float32)
    w = RNG.normal(size=(D,)).astype(np.float32)
    V = (RNG.normal(size=(D, K)) * 0.1).astype(np.float32)
    w0 = np.float32(0.25)
    if task == "regression":
        y = RNG.normal(size=(B,)).astype(np.float32)
    else:
        y = np.where(RNG.random(B) > 0.5, 1.0, -1.0).astype(np.float32)
    return w0, w, V, X, y


@pytest.mark.parametrize("B,D,K", [(8, 16, 4), (5, 33, 7), (1, 2, 1)])
def test_score_batch_matches_ref(B, D, K):
    w0, w, V, X, _ = _problem(B, D, K, "regression")
    (f,) = model.score_batch(w0, w, V, X)
    np.testing.assert_allclose(f, ref.fm_score_ref(w0, w, V, X), rtol=2e-4, atol=2e-4)


def test_score_and_aux_returns_paper_a():
    w0, w, V, X, _ = _problem(6, 20, 4, "regression")
    f, A = model.score_and_aux_batch(w0, w, V, X)
    np.testing.assert_allclose(A, X @ V, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(f, ref.fm_score_ref(w0, w, V, X), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("task", ["regression", "classification"])
@pytest.mark.parametrize("B,D,K", [(8, 16, 4), (12, 40, 6)])
def test_grad_batch_matches_autodiff(task, B, D, K):
    w0, w, V, X, y = _problem(B, D, K, task)

    def mean_loss(w0_, w_, V_):
        f = ref.fm_score_ref(w0_, w_, V_, X)
        return jnp.mean(ref.loss_ref(f, y, task))

    g0r, gwr, gVr = jax.grad(mean_loss, argnums=(0, 1, 2))(w0, w, V)
    g0, gw, gV, loss = model.grad_batch(w0, w, V, X, y, task=task)
    np.testing.assert_allclose(g0, g0r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw, gwr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gV, gVr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(loss, mean_loss(w0, w, V), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("task", ["regression", "classification"])
def test_sgd_step_decreases_loss(task):
    w0, w, V, X, y = _problem(32, 24, 4, task)
    eta, lw, lv = np.float32(0.05), np.float32(0.0), np.float32(0.0)
    (_, l0) = model.loss_batch(w0, w, V, X, y, task=task), None
    loss0 = model.loss_batch(w0, w, V, X, y, task=task)[0]
    w0n, wn, Vn, reported = model.sgd_step_batch(w0, w, V, X, y, eta, lw, lv, task=task)
    loss1 = model.loss_batch(w0n, wn, Vn, X, y, task=task)[0]
    np.testing.assert_allclose(reported, loss0, rtol=1e-5, atol=1e-6)
    assert float(loss1) < float(loss0)


def test_sgd_step_applies_regularizer():
    w0, w, V, X, y = _problem(8, 10, 3, "regression")
    eta = np.float32(0.1)
    # With a huge lambda and zero-centred data loss contribution the shrink
    # direction must dominate: parameter norms decrease.
    w0a, wa, Va, _ = model.sgd_step_batch(
        w0, w, V, X, y, eta, np.float32(5.0), np.float32(5.0), task="regression"
    )
    assert float(jnp.linalg.norm(wa)) < float(jnp.linalg.norm(w))
    assert float(jnp.linalg.norm(Va)) < float(jnp.linalg.norm(V))


def test_multiplier_matches_loss_derivative():
    # G_i (paper eq. 9) is dl/df: check by finite differences.
    f = jnp.linspace(-3, 3, 13)
    y_reg = jnp.linspace(-1, 1, 13)
    y_clf = jnp.where(jnp.arange(13) % 2 == 0, 1.0, -1.0)
    eps = 1e-3
    for task, y in (("regression", y_reg), ("classification", y_clf)):
        g = ref.multiplier_ref(f, y, task)
        num = (ref.loss_ref(f + eps, y, task) - ref.loss_ref(f - eps, y, task)) / (2 * eps)
        np.testing.assert_allclose(g, num, rtol=1e-2, atol=1e-3)


def test_classification_loss_is_stable_at_extremes():
    f = jnp.array([1e4, -1e4], jnp.float32)
    y = jnp.array([-1.0, 1.0], jnp.float32)
    loss = ref.loss_ref(f, y, "classification")
    assert np.all(np.isfinite(loss))
    g = ref.multiplier_ref(f, y, "classification")
    assert np.all(np.isfinite(g))
