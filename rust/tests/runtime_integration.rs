//! Integration tests for the PJRT runtime: load real AOT artifacts, execute
//! them, and check them against the pure-Rust implementations of the same
//! equations.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built — CI runs `make artifacts` first.

use dsfacto::data::{synth, Dataset, Task};
use dsfacto::fm::{loss, FmModel};
use dsfacto::runtime::Runtime;
use dsfacto::util::rng::Pcg64;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("DSFACTO_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    Runtime::available(&dir).then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn random_model(d: usize, k: usize, seed: u64) -> FmModel {
    let mut rng = Pcg64::seeded(seed);
    let mut m = FmModel::init(d, k, 0.1, &mut rng);
    for x in m.w.iter_mut() {
        *x = rng.normal32(0.0, 0.3);
    }
    m.w0 = 0.2;
    m
}

fn random_batch(b: usize, d: usize, task: Task, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::seeded(seed);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal32(0.0, 1.0)).collect();
    let y: Vec<f32> = (0..b)
        .map(|_| match task {
            Task::Regression => rng.normal32(0.0, 1.0),
            Task::Classification => {
                if rng.chance(0.5) {
                    1.0
                } else {
                    -1.0
                }
            }
        })
        .collect();
    (x, y)
}

#[test]
fn score_artifact_matches_rust_scorer() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    for name in ["tiny_reg", "tiny_clf"] {
        let exec = rt.load(name, "score").unwrap();
        let (b, d, k) = (exec.spec.b, exec.spec.d, exec.spec.k);
        let model = random_model(d, k, 1);
        let (x, _) = random_batch(b, d, exec.spec.task, 2);
        let scores = exec.score_batch(&model, &x).unwrap();
        assert_eq!(scores.len(), b);
        for r in 0..b {
            let row = &x[r * d..(r + 1) * d];
            let idx: Vec<u32> = (0..d as u32).collect();
            let want = model.score_sparse(&idx, row);
            assert!(
                (scores[r] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "{name} row {r}: xla {} vs rust {want}",
                scores[r]
            );
        }
    }
}

#[test]
fn grad_artifact_matches_finite_differences() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    for name in ["tiny_reg", "tiny_clf"] {
        let exec = rt.load(name, "grad").unwrap();
        let (b, d, k) = (exec.spec.b, exec.spec.d, exec.spec.k);
        let task = exec.spec.task;
        let model = random_model(d, k, 3);
        let (x, y) = random_batch(b, d, task, 4);
        let (g0, gw, gv, loss_val) = exec.grad_batch(&model, &x, &y).unwrap();
        assert_eq!(gw.len(), d);
        assert_eq!(gv.len(), d * k);

        // Mean loss via the Rust scorer.
        let mean_loss = |m: &FmModel| -> f32 {
            let idx: Vec<u32> = (0..d as u32).collect();
            (0..b)
                .map(|r| loss::loss(m.score_sparse(&idx, &x[r * d..(r + 1) * d]), y[r], task))
                .sum::<f32>()
                / b as f32
        };
        assert!((loss_val - mean_loss(&model)).abs() < 1e-3);

        let eps = 1e-2f32;
        // Spot-check a few coordinates by central differences.
        for &j in &[0usize, d / 2, d - 1] {
            let mut mp = model.clone();
            mp.w[j] += eps;
            let mut mm = model.clone();
            mm.w[j] -= eps;
            let num = (mean_loss(&mp) - mean_loss(&mm)) / (2.0 * eps);
            assert!(
                (gw[j] - num).abs() < 2e-2 * (1.0 + num.abs()),
                "{name} gw[{j}]: xla {} vs fd {num}",
                gw[j]
            );
        }
        let p = (d / 2) * k;
        let mut mp = model.clone();
        mp.v[p] += eps;
        let mut mm = model.clone();
        mm.v[p] -= eps;
        let num = (mean_loss(&mp) - mean_loss(&mm)) / (2.0 * eps);
        assert!(
            (gv[p] - num).abs() < 2e-2 * (1.0 + num.abs()),
            "{name} gv[{p}]: xla {} vs fd {num}",
            gv[p]
        );
        let mut mp = model.clone();
        mp.w0 += eps;
        let mut mm = model.clone();
        mm.w0 -= eps;
        let num = (mean_loss(&mp) - mean_loss(&mm)) / (2.0 * eps);
        assert!((g0 - num).abs() < 2e-2 * (1.0 + num.abs()));
    }
}

#[test]
fn step_artifact_descends_and_matches_grad() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let grad = rt.load("tiny_reg", "grad").unwrap();
    let step = rt.load("tiny_reg", "step").unwrap();
    let (b, d, k) = (step.spec.b, step.spec.d, step.spec.k);
    let model = random_model(d, k, 5);
    let (x, y) = random_batch(b, d, Task::Regression, 6);

    let (g0, gw, gv, loss0) = grad.grad_batch(&model, &x, &y).unwrap();
    let mut stepped = model.clone();
    let eta = 0.05f32;
    let loss_reported = step.step_batch(&mut stepped, &x, &y, eta, 0.0, 0.0).unwrap();
    assert!((loss_reported - loss0).abs() < 1e-4);

    // step == model - eta * grad (lambda = 0).
    assert!((stepped.w0 - (model.w0 - eta * g0)).abs() < 1e-4);
    for j in 0..d {
        assert!((stepped.w[j] - (model.w[j] - eta * gw[j])).abs() < 1e-4);
    }
    for p in 0..d * k {
        assert!((stepped.v[p] - (model.v[p] - eta * gv[p])).abs() < 1e-4);
    }

    // And the loss actually decreased.
    let (_, _, _, loss1) = grad.grad_batch(&stepped, &x, &y).unwrap();
    assert!(loss1 < loss0, "{loss0} -> {loss1}");
}

#[test]
fn xla_evaluator_agrees_with_rust_evaluator() {
    let dir = require_artifacts!();
    // Use the real diabetes-twin artifact shape (B=256, D=8, K=4).
    let ds = synth::table2_dataset("diabetes", 7).unwrap();
    let model = random_model(ds.d(), 4, 8);
    let eval = dsfacto::coordinator::Evaluator::for_dataset(&dir, &ds).unwrap();
    let xla = eval.evaluate(&model, &ds).unwrap();
    let rust = dsfacto::metrics::evaluate(&model, &ds);
    assert!((xla.loss - rust.loss).abs() < 1e-4, "{} vs {}", xla.loss, rust.loss);
    assert!((xla.accuracy - rust.accuracy).abs() < 1e-9);
    assert!((xla.auc - rust.auc).abs() < 1e-6);
}

#[test]
fn score_dataset_handles_padding_tail() {
    let dir = require_artifacts!();
    // diabetes twin: 513 rows = 2 full batches of 256 + tail of 1.
    let ds = synth::table2_dataset("diabetes", 9).unwrap();
    assert_eq!(ds.n() % 256, 1, "want a ragged tail");
    let rt = Runtime::new(&dir).unwrap();
    let exec = rt.load("diabetes", "score").unwrap();
    let model = random_model(ds.d(), 4, 10);
    let scores = exec.score_dataset(&model, &ds).unwrap();
    assert_eq!(scores.len(), ds.n());
    // Tail row agrees with the Rust scorer.
    let (idx, val) = ds.rows.row(ds.n() - 1);
    let want = model.score_sparse(idx, val);
    let got = scores[ds.n() - 1];
    assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "{got} vs {want}");
}

#[test]
fn xla_dense_trainer_converges_on_tiny() {
    let dir = require_artifacts!();
    // Build a dataset matching the tiny_reg artifact (D=16, K=4).
    let spec = synth::SynthSpec {
        name: "tiny_reg".into(),
        task: Task::Regression,
        n: 160,
        d: 16,
        k: 4,
        density: 1.0,
        factor_scale: 0.3,
        noise: 0.2,
        skew: 0.0,
    };
    let ds = synth::generate(&spec, 11).dataset;
    let (train, test) = ds.split(0.8, 12);
    let mut cfg = dsfacto::config::ExperimentConfig::default();
    cfg.trainer = dsfacto::config::TrainerKind::XlaDense;
    cfg.artifacts_dir = dir;
    cfg.outer_iters = 30;
    cfg.eta = dsfacto::optim::LrSchedule::Constant(0.05);
    cfg.fm.k = 4;
    // Through the uniform Trainer API, like every other engine.
    let trainer = cfg.trainer.build(&cfg);
    assert_eq!(trainer.name(), "xla-dense");
    let out = trainer.fit(&train, Some(&test), &mut ()).unwrap();
    assert_eq!(out.trace.len(), 31);
    let first = out.trace.first().unwrap().objective;
    let last = out.trace.last().unwrap().objective;
    assert!(last < 0.6 * first, "XLA dense trainer: {first} -> {last}");
}

#[test]
fn predictor_trait_native_and_xla_agree() {
    // The acceptance check for the serving API: both scorer backends are
    // reachable through `Predictor`, and batch predictions agree within
    // tolerance on a Table-2 dataset.
    use dsfacto::train::{Predictor, XlaPredictor};
    let dir = require_artifacts!();
    let ds = synth::table2_dataset("diabetes", 7).unwrap();
    let model = random_model(ds.d(), 4, 21);

    let native: &dyn Predictor = &model;
    let native_scores = native.predict_dataset(&ds).unwrap();

    let xla = XlaPredictor::for_dataset(&dir, &ds, model.clone()).unwrap();
    let xla_pred: &dyn Predictor = &xla;
    let mut xla_scores = vec![0f32; ds.n()];
    xla_pred.predict_batch(&ds.rows, &mut xla_scores).unwrap();

    assert_eq!(native_scores.len(), xla_scores.len());
    for (i, (a, b)) in native_scores.iter().zip(&xla_scores).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + a.abs()),
            "row {i}: native {a} vs xla {b}"
        );
    }

    // Single-example entry point agrees too.
    let (idx, val) = ds.rows.row(0);
    let one_native = native.predict_one(idx, val).unwrap();
    let one_xla = xla_pred.predict_one(idx, val).unwrap();
    assert!(
        (one_native - one_xla).abs() < 1e-3 * (1.0 + one_native.abs()),
        "{one_native} vs {one_xla}"
    );
}

#[test]
fn evaluator_into_predictor_serves_the_trained_model() {
    let dir = require_artifacts!();
    let ds = synth::table2_dataset("housing", 23).unwrap();
    let model = random_model(ds.d(), 4, 24);
    let pred = dsfacto::coordinator::Evaluator::for_dataset(&dir, &ds)
        .unwrap()
        .into_predictor(model.clone())
        .unwrap();
    let scores = dsfacto::train::Predictor::predict_dataset(&pred, &ds).unwrap();
    let (idx, val) = ds.rows.row(0);
    let want = model.score_sparse(idx, val);
    assert!(
        (scores[0] - want).abs() < 1e-3 * (1.0 + want.abs()),
        "{} vs {want}",
        scores[0]
    );
}

#[test]
fn manifest_covers_all_table2_datasets() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    for ds in ["diabetes", "housing", "ijcnn1", "realsim"] {
        assert!(
            rt.manifest().find(ds, "score").is_some(),
            "missing score artifact for {ds}"
        );
    }
}

#[test]
fn nomad_model_scores_identically_through_xla() {
    // End-to-end L3 -> L2/L1 agreement: train with the NOMAD engine, then
    // verify the XLA request path scores its model like the Rust path.
    let dir = require_artifacts!();
    let ds = synth::table2_dataset("housing", 13).unwrap();
    let (train, test) = ds.split(0.8, 14);
    let fm = dsfacto::fm::FmHyper {
        k: 4,
        ..Default::default()
    };
    let cfg = dsfacto::nomad::NomadConfig {
        workers: 4,
        outer_iters: 10,
        ..Default::default()
    };
    let out = dsfacto::nomad::train(&train, Some(&test), &fm, &cfg).unwrap();
    let eval = dsfacto::coordinator::Evaluator::for_dataset(&dir, &test).unwrap();
    let xla = eval.evaluate(&out.model, &test).unwrap();
    let rust = dsfacto::metrics::evaluate(&out.model, &test);
    assert!((xla.rmse - rust.rmse).abs() < 1e-3, "{} vs {}", xla.rmse, rust.rmse);
}

fn _assert_dataset_traits(_: &Dataset) {}
