//! Conformance oracle for the token wire codec (`cluster::codec`): every
//! token the engine can circulate — column blocks of any width/K, the
//! bias token, both phases — must round-trip bit-exactly, report its
//! exact wire size, and be rejected under truncation, extension, or
//! header corruption. This is the contract the TCP transport's framing
//! relies on.

use dsfacto::cluster::codec::{decode_token, encode_token, token_wire_size};
use dsfacto::nomad::token::{Phase, Token, BIAS};
use dsfacto::util::prop::forall_res;
use dsfacto::util::rng::Pcg64;

fn random_token(rng: &mut Pcg64) -> Token {
    if rng.chance(0.2) {
        // Bias token: w = [w0], no factors.
        Token {
            j: BIAS,
            iter: rng.next_u32() % 1000,
            phase: if rng.chance(0.5) {
                Phase::Update
            } else {
                Phase::Recompute
            },
            visits: (rng.next_u32() % 64) as u16,
            w: Box::from([rng.normal32(0.0, 10.0)]),
            v: Box::from([]),
        }
    } else {
        let ncols = 1 + rng.below_usize(8);
        let k = rng.below_usize(17); // k = 0 included
        Token {
            j: rng.next_u32() % (1 << 24),
            iter: rng.next_u32() % 1000,
            phase: if rng.chance(0.5) {
                Phase::Update
            } else {
                Phase::Recompute
            },
            visits: (rng.next_u32() % 64) as u16,
            w: (0..ncols).map(|_| rng.normal32(0.0, 10.0)).collect(),
            v: (0..ncols * k).map(|_| rng.normal32(0.0, 1.0)).collect(),
        }
    }
}

/// Round-trip + exact wire-size accounting for arbitrary tokens
/// (including bias tokens and k = 0 blocks).
#[test]
fn prop_roundtrip_and_wire_size() {
    forall_res(
        "token codec roundtrip with exact size accounting",
        128,
        random_token,
        |tok| {
            let mut buf = Vec::new();
            encode_token(tok, &mut buf);
            if buf.len() != token_wire_size(tok) {
                return Err(format!(
                    "encoded {} bytes, token_wire_size says {}",
                    buf.len(),
                    token_wire_size(tok)
                ));
            }
            let back = decode_token(&buf).map_err(|e| format!("{e:#}"))?;
            if back == *tok {
                Ok(())
            } else {
                Err(format!("{back:?} != {tok:?}"))
            }
        },
    );
}

/// Both `Phase` variants survive the wire explicitly (not just by chance
/// of the random generator).
#[test]
fn all_phase_variants_roundtrip() {
    for phase in [Phase::Update, Phase::Recompute] {
        for (j, w_len, v_len) in [(BIAS, 1usize, 0usize), (0, 3, 12), (77, 1, 4)] {
            let tok = Token {
                j,
                iter: 41,
                phase,
                visits: 7,
                w: (0..w_len).map(|i| i as f32 - 0.5).collect(),
                v: (0..v_len).map(|i| -(i as f32) * 0.25).collect(),
            };
            let mut buf = Vec::new();
            encode_token(&tok, &mut buf);
            let back = decode_token(&buf).unwrap();
            assert_eq!(back, tok, "phase {phase:?}, j {j}");
        }
    }
}

/// Every strict prefix of a valid frame is rejected, as is any extension:
/// the framing layer can trust the codec to catch torn reads.
#[test]
fn prop_truncation_and_extension_rejected() {
    forall_res(
        "truncated/extended frames rejected",
        32,
        random_token,
        |tok| {
            let mut buf = Vec::new();
            encode_token(tok, &mut buf);
            for cut in 0..buf.len() {
                if decode_token(&buf[..cut]).is_ok() {
                    return Err(format!("prefix of {cut}/{} bytes accepted", buf.len()));
                }
            }
            let mut extended = buf.clone();
            extended.push(0);
            if decode_token(&extended).is_ok() {
                return Err("frame with trailing garbage accepted".to_string());
            }
            Ok(())
        },
    );
}

/// Header corruption (magic, phase byte) is rejected.
#[test]
fn corrupted_header_rejected() {
    let mut rng = Pcg64::seeded(5);
    let tok = random_token(&mut rng);
    let mut buf = Vec::new();
    encode_token(&tok, &mut buf);

    let mut bad_magic = buf.clone();
    bad_magic[0] ^= 0xff;
    assert!(decode_token(&bad_magic).is_err(), "bad magic accepted");

    let mut bad_phase = buf.clone();
    bad_phase[10] = 9;
    assert!(decode_token(&bad_phase).is_err(), "bad phase byte accepted");
}
