//! Cross-trainer parity (the Fig. 4/5 qualitative claims): DS-FACTO reaches
//! the same solution quality as the libFM baseline and the synchronous
//! variants on every Table-2 twin that fits in test time.

use dsfacto::baseline::{bulksync_train, dsgd_train, libfm_train, DsgdConfig, LibfmConfig};
use dsfacto::data::{synth, Task};
use dsfacto::fm::FmHyper;
use dsfacto::metrics::evaluate;
use dsfacto::nomad::{train as nomad_train, NomadConfig};
use dsfacto::optim::LrSchedule;

struct Quality {
    name: &'static str,
    headline: f64,
}

fn run_all(dataset: &str, seed: u64) -> (Task, Vec<Quality>) {
    let ds = synth::table2_dataset(dataset, seed).unwrap();
    let (train, test) = ds.split(0.8, seed + 1);
    let task = train.task;
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let mut out = Vec::new();

    let ncfg = NomadConfig {
        workers: 4,
        outer_iters: 60,
        eta: LrSchedule::Constant(0.5),
        ..Default::default()
    };
    let nomad = nomad_train(&train, None, &fm, &ncfg).unwrap();
    out.push(Quality {
        name: "ds-facto",
        headline: evaluate(&nomad.model, &test).headline(task),
    });

    let lcfg = LibfmConfig {
        epochs: 40,
        eta: LrSchedule::Constant(0.02),
        ..Default::default()
    };
    let libfm = libfm_train(&train, None, &fm, &lcfg);
    out.push(Quality {
        name: "libfm",
        headline: evaluate(&libfm.model, &test).headline(task),
    });

    let dcfg = DsgdConfig {
        epochs: 60,
        eta: LrSchedule::Constant(0.5),
        workers: 4,
        ..Default::default()
    };
    let dsgd = dsgd_train(&train, None, &fm, &dcfg);
    out.push(Quality {
        name: "dsgd",
        headline: evaluate(&dsgd.model, &test).headline(task),
    });

    let bulk = bulksync_train(&train, None, &fm, 60, LrSchedule::Constant(0.5), 4, seed);
    out.push(Quality {
        name: "bulksync",
        headline: evaluate(&bulk.model, &test).headline(task),
    });

    (task, out)
}

fn assert_parity(dataset: &str, seed: u64) {
    let (task, quals) = run_all(dataset, seed);
    let report: Vec<String> = quals
        .iter()
        .map(|q| format!("{}={:.4}", q.name, q.headline))
        .collect();
    eprintln!("{dataset}: {}", report.join(" "));
    match task {
        Task::Classification => {
            // Accuracy: every trainer within 6 points of the best.
            let best = quals.iter().map(|q| q.headline).fold(f64::MIN, f64::max);
            for q in &quals {
                assert!(
                    q.headline > best - 0.06,
                    "{dataset}: {} acc {:.4} too far below best {best:.4} ({report:?})",
                    q.name,
                    q.headline
                );
            }
        }
        Task::Regression => {
            // RMSE: every trainer within 20% of the best.
            let best = quals.iter().map(|q| q.headline).fold(f64::MAX, f64::min);
            for q in &quals {
                assert!(
                    q.headline < best * 1.2 + 0.02,
                    "{dataset}: {} rmse {:.4} too far above best {best:.4} ({report:?})",
                    q.name,
                    q.headline
                );
            }
        }
    }
}

#[test]
fn parity_on_diabetes_twin() {
    assert_parity("diabetes", 21);
}

#[test]
fn parity_on_housing_twin() {
    assert_parity("housing", 22);
}

#[test]
fn parity_on_ijcnn1_twin() {
    // ijcnn1 is 50k examples; keep budgets moderate.
    let ds = synth::table2_dataset("ijcnn1", 23).unwrap();
    let (train, test) = ds.split(0.8, 24);
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let ncfg = NomadConfig {
        workers: 4,
        outer_iters: 30,
        eta: LrSchedule::Constant(1.0),
        eval_every: usize::MAX,
        ..Default::default()
    };
    let nomad = nomad_train(&train, None, &fm, &ncfg).unwrap();
    let nomad_acc = evaluate(&nomad.model, &test).accuracy;

    let lcfg = LibfmConfig {
        epochs: 5,
        eta: LrSchedule::Constant(0.01),
        ..Default::default()
    };
    let libfm = libfm_train(&train, None, &fm, &lcfg);
    let libfm_acc = evaluate(&libfm.model, &test).accuracy;
    eprintln!("ijcnn1: nomad={nomad_acc:.4} libfm={libfm_acc:.4}");
    assert!(
        nomad_acc > libfm_acc - 0.06,
        "nomad {nomad_acc} vs libfm {libfm_acc}"
    );
    assert!(nomad_acc > 0.6, "nomad accuracy {nomad_acc}");
}

/// AdaGrad extension sanity: frequency-adaptive steps also converge.
#[test]
fn adagrad_extension_converges() {
    use dsfacto::optim::AdaGradState;
    let ds = synth::table2_dataset("diabetes", 30).unwrap();
    let (train, test) = ds.split(0.8, 31);
    let mut rng = dsfacto::util::rng::Pcg64::seeded(32);
    let mut model = dsfacto::fm::FmModel::init(train.d(), 4, 0.01, &mut rng);
    let mut st = AdaGradState::new(train.d(), 4);
    let mut a = vec![0f32; 4];
    for _ in 0..20 {
        for i in 0..train.n() {
            let (idx, val) = train.rows.row(i);
            st.update_example(
                &mut model,
                idx,
                val,
                train.labels[i],
                train.task,
                0.1,
                1e-4,
                1e-4,
                &mut a,
            );
        }
    }
    let acc = evaluate(&model, &test).accuracy;
    assert!(acc > 0.6, "adagrad accuracy {acc}");
}
