//! Cross-trainer parity (the Fig. 4/5 qualitative claims): DS-FACTO reaches
//! the same solution quality as the libFM baseline and the synchronous
//! variants on every Table-2 twin that fits in test time. All trainers run
//! through `TrainerKind::build` — the uniform session API.

use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::data::{synth, Task};
use dsfacto::fm::FmHyper;
use dsfacto::metrics::evaluate;
use dsfacto::optim::LrSchedule;

struct Quality {
    name: &'static str,
    headline: f64,
}

/// Per-trainer budget: (iterations, step size) at parity quality.
fn budget(kind: TrainerKind) -> (usize, f32) {
    match kind {
        TrainerKind::Nomad => (60, 0.5),
        TrainerKind::Libfm => (40, 0.02),
        TrainerKind::Dsgd => (60, 0.5),
        TrainerKind::BulkSync => (60, 0.5),
        TrainerKind::XlaDense => unreachable!("needs artifacts; not in this sweep"),
    }
}

fn run_all(dataset: &str, seed: u64) -> (Task, Vec<Quality>) {
    let ds = synth::table2_dataset(dataset, seed).unwrap();
    let (train, test) = ds.split(0.8, seed + 1);
    let task = train.task;
    let mut out = Vec::new();
    // Every kind except XlaDense, which needs AOT artifacts.
    for kind in TrainerKind::all()
        .into_iter()
        .filter(|&k| k != TrainerKind::XlaDense)
    {
        let (iters, eta) = budget(kind);
        let cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2(dataset.into()),
            trainer: kind,
            fm: FmHyper {
                k: 4,
                ..Default::default()
            },
            workers: 4,
            outer_iters: iters,
            eta: LrSchedule::Constant(eta),
            seed,
            ..Default::default()
        };
        let trainer = cfg.trainer.build(&cfg);
        let fitted = trainer.fit(&train, None, &mut ()).unwrap();
        out.push(Quality {
            name: kind.name(),
            headline: evaluate(&fitted.model, &test).headline(task),
        });
    }
    (task, out)
}

fn assert_parity(dataset: &str, seed: u64) {
    let (task, quals) = run_all(dataset, seed);
    let report: Vec<String> = quals
        .iter()
        .map(|q| format!("{}={:.4}", q.name, q.headline))
        .collect();
    eprintln!("{dataset}: {}", report.join(" "));
    match task {
        Task::Classification => {
            // Accuracy: every trainer within 6 points of the best.
            let best = quals.iter().map(|q| q.headline).fold(f64::MIN, f64::max);
            for q in &quals {
                assert!(
                    q.headline > best - 0.06,
                    "{dataset}: {} acc {:.4} too far below best {best:.4} ({report:?})",
                    q.name,
                    q.headline
                );
            }
        }
        Task::Regression => {
            // RMSE: every trainer within 20% of the best.
            let best = quals.iter().map(|q| q.headline).fold(f64::MAX, f64::min);
            for q in &quals {
                assert!(
                    q.headline < best * 1.2 + 0.02,
                    "{dataset}: {} rmse {:.4} too far above best {best:.4} ({report:?})",
                    q.name,
                    q.headline
                );
            }
        }
    }
}

#[test]
fn parity_on_diabetes_twin() {
    assert_parity("diabetes", 21);
}

#[test]
fn parity_on_housing_twin() {
    assert_parity("housing", 22);
}

#[test]
fn parity_on_ijcnn1_twin() {
    // ijcnn1 is 50k examples; keep budgets moderate.
    let ds = synth::table2_dataset("ijcnn1", 23).unwrap();
    let (train, test) = ds.split(0.8, 24);
    let mk_cfg = |kind, iters, eta| ExperimentConfig {
        dataset: DatasetSpec::Table2("ijcnn1".into()),
        trainer: kind,
        fm: FmHyper {
            k: 4,
            ..Default::default()
        },
        workers: 4,
        outer_iters: iters,
        eta: LrSchedule::Constant(eta),
        eval_every: usize::MAX,
        ..Default::default()
    };
    let ncfg = mk_cfg(TrainerKind::Nomad, 30, 1.0);
    let nomad = ncfg.trainer.build(&ncfg).fit(&train, None, &mut ()).unwrap();
    let nomad_acc = evaluate(&nomad.model, &test).accuracy;

    let lcfg = mk_cfg(TrainerKind::Libfm, 5, 0.01);
    let libfm = lcfg.trainer.build(&lcfg).fit(&train, None, &mut ()).unwrap();
    let libfm_acc = evaluate(&libfm.model, &test).accuracy;
    eprintln!("ijcnn1: nomad={nomad_acc:.4} libfm={libfm_acc:.4}");
    assert!(
        nomad_acc > libfm_acc - 0.06,
        "nomad {nomad_acc} vs libfm {libfm_acc}"
    );
    assert!(nomad_acc > 0.6, "nomad accuracy {nomad_acc}");
}

/// AdaGrad extension sanity: frequency-adaptive steps also converge.
#[test]
fn adagrad_extension_converges() {
    use dsfacto::optim::AdaGradState;
    let ds = synth::table2_dataset("diabetes", 30).unwrap();
    let (train, test) = ds.split(0.8, 31);
    let mut rng = dsfacto::util::rng::Pcg64::seeded(32);
    let mut model = dsfacto::fm::FmModel::init(train.d(), 4, 0.01, &mut rng);
    let mut st = AdaGradState::new(train.d(), 4);
    let mut a = vec![0f32; 4];
    for _ in 0..20 {
        for i in 0..train.n() {
            let (idx, val) = train.rows.row(i);
            st.update_example(
                &mut model,
                idx,
                val,
                train.labels[i],
                train.task,
                0.1,
                1e-4,
                1e-4,
                &mut a,
            );
        }
    }
    let acc = evaluate(&model, &test).accuracy;
    assert!(acc > 0.6, "adagrad accuracy {acc}");
}
