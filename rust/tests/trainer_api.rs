//! Trainer/observer API conformance: one parameterized suite that drives
//! every `TrainerKind` through the `Trainer` trait and asserts the session
//! contract — trace completeness, monotone clocks, objective descent,
//! observer delivery, seed determinism — plus unit coverage for the
//! `EarlyStop` and `Checkpointer` observers against a live trainer.

use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::optim::LrSchedule;
use dsfacto::train::{Checkpointer, EarlyStop, Observers, TraceRecorder};

/// The trainers that run without AOT artifacts. XlaDense conformance is in
/// rust/tests/runtime_integration.rs (it needs `make artifacts`).
const CPU_KINDS: [TrainerKind; 4] = [
    TrainerKind::Nomad,
    TrainerKind::Libfm,
    TrainerKind::Dsgd,
    TrainerKind::BulkSync,
];

fn housing_cfg(kind: TrainerKind, iters: usize, workers: usize) -> ExperimentConfig {
    // Distributed engines take batch-GD-scale steps; libFM takes
    // per-example SGD steps.
    let eta = match kind {
        TrainerKind::Libfm => 0.02,
        _ => 0.5,
    };
    ExperimentConfig {
        dataset: DatasetSpec::Table2("housing".into()),
        trainer: kind,
        outer_iters: iters,
        workers,
        eta: LrSchedule::Constant(eta),
        ..Default::default()
    }
}

#[test]
fn every_kind_satisfies_the_session_contract() {
    for kind in CPU_KINDS {
        let cfg = housing_cfg(kind, 8, 2);
        let ds = cfg.dataset.load(cfg.seed).unwrap();
        let (train, test) = ds.split(0.8, 9);

        let trainer = cfg.trainer.build(&cfg);
        assert_eq!(trainer.name(), kind.name());

        let mut rec = TraceRecorder::default();
        let out = trainer.fit(&train, Some(&test), &mut rec).unwrap();

        // Trace covers iteration 0 plus every outer iteration, in order.
        assert_eq!(out.trace.len(), 9, "{kind:?}");
        for (i, pt) in out.trace.iter().enumerate() {
            assert_eq!(pt.iter, i, "{kind:?}");
        }
        // Timestamps are monotone.
        assert!(
            out.trace.windows(2).all(|w| w[0].secs <= w[1].secs),
            "{kind:?}: non-monotone clock"
        );
        // The objective descends.
        let (first, last) = (out.trace[0].objective, out.trace[8].objective);
        assert!(last < first, "{kind:?}: objective {first} -> {last}");
        // The eval cadence produced held-out metrics on every point
        // (eval_every = 1 by default).
        assert!(out.trace.iter().all(|p| p.test.is_some()), "{kind:?}");
        // The observer saw exactly the recorded trace. (Field-wise check:
        // regression EvalMetrics carry NaN accuracy, so `==` on whole
        // points would be vacuously false.)
        assert_eq!(rec.trace.len(), out.trace.len(), "{kind:?}");
        for (a, b) in rec.trace.iter().zip(&out.trace) {
            assert_eq!(a.iter, b.iter, "{kind:?}");
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{kind:?}");
            assert_eq!(a.secs.to_bits(), b.secs.to_bits(), "{kind:?}");
        }
    }
}

#[test]
fn every_kind_is_seed_deterministic() {
    for kind in CPU_KINDS {
        // P=1 makes even the asynchronous engine deterministic; the
        // synchronous trainers are deterministic at any worker count.
        let workers = match kind {
            TrainerKind::Nomad => 1,
            _ => 2,
        };
        let cfg = housing_cfg(kind, 4, workers);
        let ds = cfg.dataset.load(cfg.seed).unwrap();
        let a = cfg.trainer.build(&cfg).fit(&ds, None, &mut ()).unwrap();
        let b = cfg.trainer.build(&cfg).fit(&ds, None, &mut ()).unwrap();
        assert_eq!(a.model, b.model, "{kind:?}: same seed, different model");
    }
}

#[test]
fn early_stop_observer_ends_sessions_early() {
    // eta = 0 trains in place: the objective never improves, so EarlyStop
    // fires after exactly `patience` non-improving points (iters 1..=3).
    let mut cfg = housing_cfg(TrainerKind::Libfm, 30, 1);
    cfg.eta = LrSchedule::Constant(0.0);
    let ds = cfg.dataset.load(cfg.seed).unwrap();
    let mut stop = EarlyStop::new(3, 1e-12);
    let out = cfg.trainer.build(&cfg).fit(&ds, None, &mut stop).unwrap();
    assert_eq!(stop.stopped_at, Some(3));
    assert_eq!(out.trace.len(), 4, "stopped after iters 0..=3");
}

#[test]
fn checkpointer_observer_saves_on_cadence() {
    let dir = std::env::temp_dir().join("dsfacto_trainer_api_ckpt");
    std::fs::remove_dir_all(&dir).ok();
    let cfg = housing_cfg(TrainerKind::Libfm, 5, 1);
    let ds = cfg.dataset.load(cfg.seed).unwrap();
    let mut ck = Checkpointer::new(&dir, 2);
    let out = cfg.trainer.build(&cfg).fit(&ds, None, &mut ck).unwrap();
    assert!(ck.error.is_none(), "{:?}", ck.error);
    // iters 2 and 4, plus the final model on completion.
    assert_eq!(ck.saved.len(), 3, "{:?}", ck.saved);
    assert!(ck.saved[0].ends_with("ckpt-00002.dsfm"));
    assert!(ck.saved[1].ends_with("ckpt-00004.dsfm"));
    assert!(ck.saved[2].ends_with("final.dsfm"));
    let last = dsfacto::fm::io::load(&ck.saved[2]).unwrap();
    assert_eq!(last, out.model, "final checkpoint is the returned model");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn composed_observers_all_see_the_session() {
    let cfg = housing_cfg(TrainerKind::Dsgd, 6, 2);
    let ds = cfg.dataset.load(cfg.seed).unwrap();
    let mut rec = TraceRecorder::default();
    let mut stop = EarlyStop::new(50, 1e-12); // never fires in 6 iters
    let out = {
        let mut obs = Observers::new();
        obs.push(&mut rec);
        obs.push(&mut stop);
        cfg.trainer.build(&cfg).fit(&ds, None, &mut obs).unwrap()
    };
    assert_eq!(rec.trace.len(), out.trace.len());
    assert!(stop.stopped_at.is_none());
}

#[test]
fn observer_stop_bounds_the_async_engine_overrun() {
    // The decentralized engine may overrun a Stop by its pipeline depth
    // (at most three outer iterations), never more.
    struct StopAt(usize);
    impl dsfacto::train::TrainObserver for StopAt {
        fn on_iter(
            &mut self,
            pt: &dsfacto::metrics::TracePoint,
            _m: Option<&dsfacto::fm::FmModel>,
        ) -> dsfacto::train::ControlFlow {
            if pt.iter >= self.0 {
                dsfacto::train::ControlFlow::Stop
            } else {
                dsfacto::train::ControlFlow::Continue
            }
        }
    }
    let cfg = housing_cfg(TrainerKind::Nomad, 30, 3);
    let ds = cfg.dataset.load(cfg.seed).unwrap();
    let out = cfg.trainer.build(&cfg).fit(&ds, None, &mut StopAt(4)).unwrap();
    let last = out.trace.last().unwrap().iter;
    assert!((4..=7).contains(&last), "stop at 4 ended at {last}");
    for (i, pt) in out.trace.iter().enumerate() {
        assert_eq!(pt.iter, i);
    }
}
