//! Chaos end-to-end tests: real `dsfacto driver` + `dsfacto worker` OS
//! processes under **deterministic scripted fault injection**
//! (`DSFACTO_CHAOS`), checked against the in-process engine.
//!
//! The recovery oracle is the same bitwise one the clean cluster suite
//! uses: under `update_mode = mean` the engine's deferred-sorted
//! recompute fold is arrival-order independent, so whatever the schedule
//! of drops, duplicates, kills and driver restarts, a run that *recovers*
//! must assemble the exact in-process model — not an approximation of it.
//!
//! Covered here: a duplicated and a dropped ring frame (dedup + stall
//! detection + checkpoint restart), a worker scripted to die mid-epoch, a
//! driver kill followed by `--resume` rejoin from its journal, and an
//! unauthenticated client knocking on a keyed control port.

use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dsfacto::config::{ExperimentConfig, TrainerKind};
use dsfacto::data::cache::{write_cache, ShardCacheSource};
use dsfacto::data::synth::table2_dataset;
use dsfacto::data::DataSource;
use dsfacto::partition::RowStrategy;
use dsfacto::train::Trainer;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_dsfacto")
}

/// A spawned dsfacto process, killed on drop so a failed assertion never
/// leaks children past the test run. Unlike the clean-cluster harness,
/// this one can carry per-process environment (the chaos schedule).
struct Proc {
    child: Child,
    name: String,
}

impl Proc {
    fn spawn(name: &str, args: &[&str], envs: &[(&str, &str)], capture_stdout: bool) -> Proc {
        let mut cmd = Command::new(bin());
        cmd.args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .stdin(Stdio::null())
            .stdout(if capture_stdout {
                Stdio::piped()
            } else {
                Stdio::null()
            });
        for (k, v) in envs {
            cmd.env(k, v);
        }
        let child = cmd.spawn().unwrap_or_else(|e| panic!("spawn {name}: {e}"));
        Proc {
            child,
            name: name.to_string(),
        }
    }

    /// Streams this process's stdout lines into a shared buffer from a
    /// background thread (so the pipe never fills and blocks the child).
    fn capture_lines(&mut self) -> Arc<Mutex<Vec<String>>> {
        let stdout = self.child.stdout.take().expect("stdout not piped");
        let lines = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&lines);
        std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                match line {
                    Ok(l) => sink.lock().unwrap().push(l),
                    Err(_) => break,
                }
            }
        });
        lines
    }

    /// Waits for exit within `timeout`; panics on timeout, returns the
    /// success flag otherwise.
    fn wait_ok(&mut self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(status) = self.child.try_wait().expect("try_wait") {
                return status.success();
            }
            assert!(
                Instant::now() < deadline,
                "{} did not exit within {timeout:?}",
                self.name
            );
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Blocks until some captured line satisfies `pred` (scanning new lines
/// as they stream in), returning the matching line.
fn wait_for_line(
    lines: &Arc<Mutex<Vec<String>>>,
    what: &str,
    timeout: Duration,
    pred: impl Fn(&str) -> bool,
) -> String {
    let deadline = Instant::now() + timeout;
    let mut scanned = 0usize;
    loop {
        {
            let buf = lines.lock().unwrap();
            while scanned < buf.len() {
                if pred(&buf[scanned]) {
                    return buf[scanned].clone();
                }
                scanned += 1;
            }
        }
        assert!(
            Instant::now() < deadline,
            "never saw {what}; driver output so far: {:#?}",
            lines.lock().unwrap()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Extracts the bound control address from the driver's
/// `dsfacto driver: control on ADDR` line.
fn control_addr(lines: &Arc<Mutex<Vec<String>>>) -> String {
    let line = wait_for_line(lines, "the control-on line", Duration::from_secs(60), |l| {
        l.contains("control on ")
    });
    line.split("control on ")
        .nth(1)
        .expect("address after 'control on '")
        .trim()
        .to_string()
}

/// Matches the driver's per-iteration progress line for iter >= `min`.
fn iter_line_at_least(min: u32) -> impl Fn(&str) -> bool {
    move |l: &str| {
        l.trim_start()
            .strip_prefix("iter")
            .and_then(|rest| rest.trim_start().split_whitespace().next())
            .and_then(|n| n.parse::<u32>().ok())
            .is_some_and(|n| n >= min)
    }
}

/// The in-process reference run at the exact schedule the driver ships to
/// its workers (same seed, eta, token width, partition — same everything).
fn inprocess_model(cache: &str, p: usize, iters: usize, seed: u64) -> dsfacto::fm::FmModel {
    let mut cfg = ExperimentConfig::default();
    for (key, val) in [
        ("dataset", format!("cache:{cache}")),
        ("data_cache", cache.to_string()),
        ("workers", p.to_string()),
        ("outer_iters", iters.to_string()),
        ("eta", "constant:0.5".to_string()),
        ("seed", seed.to_string()),
        ("cols_per_token", "5".to_string()),
        ("train_frac", "1".to_string()),
    ] {
        cfg.set(key, &val).unwrap();
    }
    let ds = ShardCacheSource::open(cache).unwrap().materialize().unwrap();
    let out = TrainerKind::Nomad
        .build(&cfg)
        .fit(&ds, None, &mut ())
        .unwrap();
    out.model
}

fn setup_cache(tag: &str, seed: u64, shards: usize) -> (std::path::PathBuf, String) {
    let base = std::env::temp_dir().join(format!("dsfacto_chaos_{tag}"));
    std::fs::remove_dir_all(&base).ok();
    std::fs::create_dir_all(&base).unwrap();
    let cache = base.join("cache");
    let ds = table2_dataset("housing", seed).unwrap();
    write_cache(&ds, RowStrategy::Contiguous, shards, &cache).unwrap();
    let cache_s = cache.to_str().unwrap().to_string();
    (base, cache_s)
}

/// Driver args shared by every scenario (schedule must match
/// [`inprocess_model`]).
fn driver_args<'a>(
    dataset: &'a str,
    iters: &'a str,
    seed: &'a str,
    ckpt: &'a str,
    model: &'a str,
) -> Vec<&'a str> {
    vec![
        "driver",
        "--dataset",
        dataset,
        "--workers",
        "2",
        "--outer-iters",
        iters,
        "--eta",
        "constant:0.5",
        "--seed",
        seed,
        "--cols-per-token",
        "5",
        "--train-frac",
        "1",
        "--ckpt-dir",
        ckpt,
        "--ckpt-every",
        "1",
        "--save-model",
        model,
    ]
}

fn assert_bitwise(model_path: &std::path::Path, cache: &str, iters: usize, seed: u64) {
    let cluster = dsfacto::fm::io::load(model_path).unwrap();
    let reference = inprocess_model(cache, 2, iters, seed);
    assert_eq!(
        cluster, reference,
        "faulted-but-recovered model differs from the in-process engine"
    );
}

/// One dropped and one duplicated ring frame. The duplicate is absorbed
/// by the envelope's sequence dedup (no restart); the drop starves the
/// ring of a token, heartbeats keep flowing, and only the driver's
/// *stall* detector can notice — it aborts the generation and restarts
/// from the newest complete block checkpoint. Either way the final model
/// must be bitwise the in-process one.
#[test]
fn dropped_and_duplicated_ring_frames_recover_bitwise() {
    let (base, cache) = setup_cache("dropdup", 23, 2);
    let ckpt_s = base.join("ckpt").to_str().unwrap().to_string();
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");

    let mut args = driver_args(&dataset, "4", "23", &ckpt_s, &model_s);
    args.extend_from_slice(&[
        "--addr",
        "127.0.0.1:0",
        "--stall-timeout",
        "3",
        "--max-restarts",
        "3",
    ]);
    let mut driver = Proc::spawn("driver", &args, &[], true);
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    let worker_args = [
        "worker",
        "--driver",
        addr.as_str(),
        "--ckpt-dir",
        ckpt_s.as_str(),
        "--ckpt-every",
        "1",
    ];
    // worker-a duplicates its 3rd remote ring frame; worker-b swallows
    // its 6th. Both schedules are deterministic per process.
    let chaos_dup = [("DSFACTO_CHAOS", "dup:ring:2")];
    let chaos_drop = [("DSFACTO_CHAOS", "drop:ring:5")];
    let mut worker_a = Proc::spawn("worker-a", &worker_args, &chaos_dup, false);
    let mut worker_b = Proc::spawn("worker-b", &worker_args, &chaos_drop, false);

    // The dropped token stalls the ring; the stall detector must restart
    // the generation (both workers survive and re-join).
    wait_for_line(
        &lines,
        "the stall-restart marker",
        Duration::from_secs(120),
        |l| l.contains("restarting from iteration"),
    );
    assert!(
        driver.wait_ok(Duration::from_secs(180)),
        "driver failed; output: {:#?}",
        lines.lock().unwrap()
    );
    assert!(worker_a.wait_ok(Duration::from_secs(60)), "worker-a failed");
    assert!(worker_b.wait_ok(Duration::from_secs(60)), "worker-b failed");

    assert_bitwise(&model_path, &cache, 4, 23);
    std::fs::remove_dir_all(&base).ok();
}

/// A worker scripted to exit(9) mid-epoch — after finalizing iteration 3
/// but *before* reporting it — so the driver sees a dead member and must
/// restart the generation from block checkpoints with a replacement.
#[test]
fn scripted_worker_kill_recovers_bitwise() {
    let (base, cache) = setup_cache("kill", 17, 2);
    let ckpt_s = base.join("ckpt").to_str().unwrap().to_string();
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");

    let mut args = driver_args(&dataset, "6", "17", &ckpt_s, &model_s);
    args.extend_from_slice(&[
        "--addr",
        "127.0.0.1:0",
        "--heartbeat-timeout",
        "2",
        "--max-restarts",
        "2",
    ]);
    let mut driver = Proc::spawn("driver", &args, &[], true);
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    let worker_args = [
        "worker",
        "--driver",
        addr.as_str(),
        "--ckpt-dir",
        ckpt_s.as_str(),
        "--ckpt-every",
        "1",
    ];
    let chaos_kill = [("DSFACTO_CHAOS", "kill:3")];
    let mut worker_a = Proc::spawn("worker-a", &worker_args, &[], false);
    let mut worker_b = Proc::spawn("worker-b", &worker_args, &chaos_kill, false);

    wait_for_line(
        &lines,
        "the generation-restart marker",
        Duration::from_secs(120),
        |l| l.contains("restarting from iteration"),
    );
    // The scripted kill really did exit with the chaos status, not a
    // clean shutdown.
    assert!(!worker_b.wait_ok(Duration::from_secs(10)), "worker-b should die");
    let mut worker_c = Proc::spawn("worker-c", &worker_args, &[], false);

    assert!(
        driver.wait_ok(Duration::from_secs(180)),
        "driver failed after recovery; output: {:#?}",
        lines.lock().unwrap()
    );
    assert!(worker_a.wait_ok(Duration::from_secs(60)), "survivor failed");
    assert!(worker_c.wait_ok(Duration::from_secs(60)), "replacement failed");

    assert_bitwise(&model_path, &cache, 6, 17);
    std::fs::remove_dir_all(&base).ok();
}

/// Kill the DRIVER mid-run, then bring up a new one on the same address
/// with `--resume`: it restores the trace from its journal, the orphaned
/// workers re-dial and re-join, and the run finishes from the newest
/// complete checkpoint epoch — bitwise the in-process model.
#[test]
fn driver_kill_and_resume_rejoins_bitwise() {
    let (base, cache) = setup_cache("resume", 7, 2);
    let ckpt_s = base.join("ckpt").to_str().unwrap().to_string();
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");

    let mut args = driver_args(&dataset, "8", "7", &ckpt_s, &model_s);
    args.extend_from_slice(&["--addr", "127.0.0.1:0"]);
    let mut driver = Proc::spawn("driver", &args, &[], true);
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    // Generous connect timeout: the workers must outlive the driver gap
    // and keep re-dialing until the resumed driver binds.
    let worker_args = [
        "worker",
        "--driver",
        addr.as_str(),
        "--ckpt-dir",
        ckpt_s.as_str(),
        "--ckpt-every",
        "1",
        "--connect-timeout",
        "60",
    ];
    let mut worker_a = Proc::spawn("worker-a", &worker_args, &[], false);
    let mut worker_b = Proc::spawn("worker-b", &worker_args, &[], false);

    // Let the journal accumulate some aggregated iterations, then kill
    // the driver outright (no Shutdown, no Abort — a real crash).
    wait_for_line(&lines, "iteration 3", Duration::from_secs(120), iter_line_at_least(3));
    driver.kill();

    // Same experiment, same (now free) address, --resume.
    let mut args2 = driver_args(&dataset, "8", "7", &ckpt_s, &model_s);
    args2.extend_from_slice(&["--addr", addr.as_str(), "--resume"]);
    let mut driver2 = Proc::spawn("driver-2", &args2, &[], true);
    let lines2 = driver2.capture_lines();
    wait_for_line(
        &lines2,
        "the journal-resume marker",
        Duration::from_secs(60),
        |l| l.contains("resuming from journal"),
    );

    assert!(
        driver2.wait_ok(Duration::from_secs(180)),
        "resumed driver failed; output: {:#?}",
        lines2.lock().unwrap()
    );
    assert!(worker_a.wait_ok(Duration::from_secs(120)), "worker-a failed");
    assert!(worker_b.wait_ok(Duration::from_secs(120)), "worker-b failed");

    assert_bitwise(&model_path, &cache, 8, 7);
    std::fs::remove_dir_all(&base).ok();
}

/// With `--cluster-secret` set, an unauthenticated client knocking on the
/// control port gets its frames rejected and its connection dropped — and
/// the keyed cluster around it completes bitwise, undisturbed.
#[test]
fn unauthenticated_knock_is_dropped_and_the_keyed_run_completes() {
    use std::io::{Read, Write};

    let (base, cache) = setup_cache("knock", 41, 2);
    let ckpt_s = base.join("ckpt").to_str().unwrap().to_string();
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");

    let mut args = driver_args(&dataset, "3", "41", &ckpt_s, &model_s);
    args.extend_from_slice(&["--addr", "127.0.0.1:0", "--cluster-secret", "rfc4231"]);
    let mut driver = Proc::spawn("driver", &args, &[], true);
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    // The knocker: a well-formed length prefix carrying an UNSIGNED
    // envelope (magic right, auth flag clear). A keyed driver must reject
    // it for the missing tag and hang up.
    let mut knock = std::net::TcpStream::connect(&addr).unwrap();
    let env = [0xfcu8, 0xd5, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1]; // magic|flags=0|seq=0|1 junk byte
    let mut msg = (env.len() as u32).to_le_bytes().to_vec();
    msg.extend_from_slice(&env);
    knock.write_all(&msg).unwrap();
    knock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut sink = [0u8; 16];
    match knock.read(&mut sink) {
        Ok(0) => {} // EOF: the driver hung up, as it must.
        Ok(n) => panic!("driver sent {n} bytes to an unauthenticated client"),
        // A reset is a hang-up too; only silence (a read timeout) fails.
        Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {}
        Err(e) => panic!("driver kept the unauthenticated connection open: {e}"),
    }
    drop(knock);

    let worker_args = [
        "worker",
        "--driver",
        addr.as_str(),
        "--cluster-secret",
        "rfc4231",
        "--ckpt-dir",
        ckpt_s.as_str(),
    ];
    let mut worker_a = Proc::spawn("worker-a", &worker_args, &[], false);
    let mut worker_b = Proc::spawn("worker-b", &worker_args, &[], false);

    assert!(
        driver.wait_ok(Duration::from_secs(180)),
        "keyed driver failed; output: {:#?}",
        lines.lock().unwrap()
    );
    assert!(worker_a.wait_ok(Duration::from_secs(60)), "worker-a failed");
    assert!(worker_b.wait_ok(Duration::from_secs(60)), "worker-b failed");

    assert_bitwise(&model_path, &cache, 3, 41);
    std::fs::remove_dir_all(&base).ok();
}
