//! Property tests for the data-substrate invariants: CSR <-> CSC <-> dense
//! round-trips, structure-preserving transforms (`select_rows`,
//! `slice_rows`, `scale_columns`), and the shared batch-densify path
//! (`densify_batch` / `Csr::densify_rows`) against the `Csr::row` oracle.

use dsfacto::data::{Csr, Dataset, Task};
use dsfacto::util::prop::{forall_res, random_csr};

/// Rebuilds a CSR from a CSC column view (duplicate-free by construction).
fn csc_to_csr(m: &Csr) -> Csr {
    let t = m.to_csc();
    let mut triplets = Vec::with_capacity(t.nnz());
    for j in 0..t.n_cols() {
        let (rows, vals) = t.col(j);
        for (r, v) in rows.iter().zip(vals) {
            triplets.push((*r as usize, j, *v));
        }
    }
    Csr::from_triplets(m.n_rows(), m.n_cols(), &triplets)
}

/// CSR -> CSC -> CSR is the identity (both are canonical forms).
#[test]
fn prop_csr_csc_roundtrip() {
    forall_res(
        "csr -> csc -> csr identity",
        64,
        |rng| random_csr(rng, 12, 12),
        |m| {
            let back = csc_to_csr(m);
            back.validate().map_err(|e| format!("{e:#}"))?;
            if back == *m {
                Ok(())
            } else {
                Err("roundtrip changed the matrix".to_string())
            }
        },
    );
}

/// CSR -> dense -> CSR preserves the dense image exactly.
#[test]
fn prop_dense_roundtrip() {
    forall_res(
        "csr -> dense -> csr preserves the dense image",
        48,
        |rng| random_csr(rng, 10, 10),
        |m| {
            let dense = m.to_dense();
            let (n, d) = (m.n_rows(), m.n_cols());
            let mut triplets = Vec::new();
            for i in 0..n {
                for j in 0..d {
                    let v = dense[i * d + j];
                    if v != 0.0 {
                        triplets.push((i, j, v));
                    }
                }
            }
            let back = Csr::from_triplets(n, d, &triplets);
            if back.to_dense() == dense {
                Ok(())
            } else {
                Err("dense image changed".to_string())
            }
        },
    );
}

/// `select_rows` / `slice_rows` / `scale_columns` all preserve
/// `validate()`, and scaling acts column-wise on the dense image.
#[test]
fn prop_transforms_preserve_invariants() {
    forall_res(
        "select/slice/scale preserve CSR invariants",
        48,
        |rng| {
            let m = random_csr(rng, 10, 10);
            let n = m.n_rows();
            // Selection with repetition allowed, arbitrary order.
            let sel: Vec<usize> = (0..rng.below_usize(2 * n + 1))
                .map(|_| rng.below_usize(n))
                .collect();
            let a = rng.below_usize(n + 1);
            let b = a + rng.below_usize(n - a + 1);
            let scale: Vec<f32> = (0..m.n_cols())
                .map(|_| rng.normal32(0.0, 2.0))
                .collect();
            (m, sel, a, b, scale)
        },
        |(m, sel, a, b, scale)| {
            let selected = m.select_rows(sel);
            selected.validate().map_err(|e| format!("select: {e:#}"))?;
            if selected.n_rows() != sel.len() {
                return Err("select_rows row count".into());
            }
            for (out_r, &src_r) in sel.iter().enumerate() {
                if selected.row(out_r) != m.row(src_r) {
                    return Err(format!("select_rows row {out_r} != source {src_r}"));
                }
            }

            let sliced = m.slice_rows(*a, *b);
            sliced.validate().map_err(|e| format!("slice: {e:#}"))?;
            let range: Vec<usize> = (*a..*b).collect();
            if sliced != m.select_rows(&range) {
                return Err("slice_rows != select_rows on the same range".into());
            }

            let mut scaled = m.clone();
            scaled.scale_columns(scale);
            scaled.validate().map_err(|e| format!("scale: {e:#}"))?;
            let dense = m.to_dense();
            let scaled_dense = scaled.to_dense();
            let d = m.n_cols();
            for (p, (&orig, &got)) in dense.iter().zip(&scaled_dense).enumerate() {
                let want = orig * scale[p % d];
                if (got - want).abs() > 1e-6 * (1.0 + want.abs()) {
                    return Err(format!("scale_columns at flat index {p}: {got} vs {want}"));
                }
            }
            Ok(())
        },
    );
}

/// `densify_batch` agrees with the `Csr::row` oracle entry-by-entry and
/// zero-fills the padding tail.
#[test]
fn prop_densify_batch_agrees_with_rows() {
    forall_res(
        "densify_batch equals row-wise densification",
        64,
        |rng| {
            let rows = random_csr(rng, 10, 8);
            let n = rows.n_rows();
            let labels = (0..n).map(|i| i as f32).collect();
            let ds = Dataset {
                name: "prop".into(),
                task: Task::Regression,
                rows,
                labels,
            };
            let start = rng.below_usize(n + 2); // may start past the end
            let b = 1 + rng.below_usize(n + 2);
            (ds, start, b)
        },
        |(ds, start, b)| {
            let d = ds.d();
            let mut buf = vec![f32::NAN; b * d];
            let real = ds.densify_batch(*start, *b, &mut buf);
            let want_real = (*b).min(ds.n().saturating_sub(*start));
            if real != want_real {
                return Err(format!("real {real} != {want_real}"));
            }
            for r in 0..*b {
                let row = &buf[r * d..(r + 1) * d];
                if r < real {
                    let (idx, val) = ds.rows.row(start + r);
                    let mut expect = vec![0f32; d];
                    for (j, v) in idx.iter().zip(val) {
                        expect[*j as usize] = *v;
                    }
                    if row != expect.as_slice() {
                        return Err(format!("row {r} mismatch"));
                    }
                } else if row.iter().any(|&x| x != 0.0) {
                    return Err(format!("padding row {r} not zero-filled"));
                }
            }
            Ok(())
        },
    );
}

/// The `Csr::densify_rows` width parameter (the XLA fixed-shape path)
/// zero-fills the columns past `n_cols`.
#[test]
fn densify_rows_wider_than_matrix() {
    let m = Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)]);
    let width = 5;
    let mut buf = vec![f32::NAN; 4 * width];
    let real = m.densify_rows(0, 4, width, &mut buf);
    assert_eq!(real, 2);
    assert_eq!(&buf[..width], &[1.0, 0.0, 2.0, 0.0, 0.0]);
    assert_eq!(&buf[width..2 * width], &[0.0, 0.0, 0.0, 3.0, 0.0][..]);
    assert!(buf[2 * width..].iter().all(|&x| x == 0.0));
}
