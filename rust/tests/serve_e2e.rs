//! End-to-end tests for `dsfacto serve` (src/serve/): real TCP
//! connections against an in-process server, pinning the three
//! properties the serving layer promises —
//!
//! * scores over the wire are **bitwise equal** to
//!   `Predictor::predict_batch`, concurrently, batched or unbatched,
//!   and regardless of `col_blocks`;
//! * the per-connection arenas stop growing once warm (**zero
//!   steady-state allocation**), observable through the stats frame's
//!   capacity watermarks;
//! * a **hot checkpoint swap** lands on live connections without
//!   dropping them, and request-level errors leave the connection
//!   scoring.

use std::path::PathBuf;
use std::time::Duration;

use dsfacto::data::{synth, Dataset};
use dsfacto::fm::{io as fm_io, FmModel};
use dsfacto::serve::{serve, ScoreClient, ServeHandle, ServeOptions};
use dsfacto::train::Predictor;
use dsfacto::util::rng::Pcg64;

fn test_model(d: usize, k: usize, seed: u64) -> FmModel {
    let mut rng = Pcg64::seeded(seed);
    let mut m = FmModel::init(d, k, 0.3, &mut rng);
    for x in m.w.iter_mut() {
        *x = rng.normal32(0.0, 0.5);
    }
    m.w0 = 0.25;
    m
}

fn test_rows() -> Dataset {
    synth::table2_dataset("housing", 11).unwrap()
}

/// Rows as the wire wants them: parallel (indices, values) slices.
fn wire_rows(ds: &Dataset) -> Vec<(&[u32], &[f32])> {
    (0..ds.n()).map(|i| ds.rows.row(i)).collect()
}

/// Reference scores straight off the kernel path the trainers use.
fn reference_scores(m: &FmModel, ds: &Dataset) -> Vec<f32> {
    Predictor::predict_dataset(m, ds).unwrap()
}

struct TestServer {
    handle: ServeHandle,
    model_path: PathBuf,
    dir: PathBuf,
}

impl TestServer {
    /// Saves `m` into a fresh temp dir and starts a server over it.
    fn start(name: &str, m: &FmModel, tweak: impl FnOnce(&mut ServeOptions)) -> TestServer {
        let dir = std::env::temp_dir().join(format!("dsfacto_serve_e2e_{name}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let model_path = dir.join("model.dsfm");
        fm_io::save(m, &model_path).unwrap();
        let mut opts = ServeOptions {
            addr: "127.0.0.1:0".into(),
            model_path: model_path.clone(),
            ..Default::default()
        };
        tweak(&mut opts);
        let handle = serve(&opts).unwrap();
        TestServer {
            handle,
            model_path,
            dir,
        }
    }

    fn connect(&self) -> ScoreClient {
        ScoreClient::connect(&self.handle.addr().to_string()).unwrap()
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.handle.shutdown();
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn concurrent_streams_score_bitwise_equal_to_predict_batch() {
    let ds = test_rows();
    let m = test_model(ds.d(), 4, 3);
    let want = reference_scores(&m, &ds);
    let server = TestServer::start("concurrent", &m, |_| {});

    // 8 concurrent client streams, each scoring its own interleaved row
    // subset over its own connection, all racing the same server.
    std::thread::scope(|scope| {
        for stream_id in 0..8usize {
            let server = &server;
            let ds = &ds;
            let want = &want;
            scope.spawn(move || {
                let mut client = server.connect();
                let picks: Vec<usize> = (0..ds.n()).filter(|i| i % 8 == stream_id).collect();
                let rows: Vec<(&[u32], &[f32])> = picks.iter().map(|&i| ds.rows.row(i)).collect();
                let got = client.score(&rows).unwrap();
                let expect: Vec<f32> = picks.iter().map(|&i| want[i]).collect();
                assert_eq!(
                    bits(&got),
                    bits(&expect),
                    "stream {stream_id}: served scores are not bitwise equal"
                );
            });
        }
    });
    assert_eq!(server.handle.requests(), 8);
}

#[test]
fn batched_pipelining_is_bitwise_equal_to_unbatched_and_coalesces() {
    let ds = test_rows();
    let m = test_model(ds.d(), 4, 5);
    let want = reference_scores(&m, &ds);
    let n_requests = 16usize;
    // A wide window so every pipelined request of the burst lands in one
    // gather even on a slow machine.
    let server = TestServer::start("batched", &m, |o| {
        o.max_batch = n_requests;
        o.batch_window = Duration::from_millis(200);
    });

    // Unbatched: one synchronous request per row — every score waits for
    // its reply, so each one is its own sweep.
    let mut sync_client = server.connect();
    let mut unbatched = Vec::new();
    for i in 0..n_requests {
        unbatched.extend(sync_client.score(&[ds.rows.row(i)]).unwrap());
    }
    assert_eq!(bits(&unbatched), bits(&want[..n_requests]));

    // Batched: fire the whole burst, then collect. The server gathers the
    // burst into fewer fused sweeps; scores must not change a bit.
    let mut pipelined = server.connect();
    let mut ids = Vec::new();
    for i in 0..n_requests {
        ids.push(pipelined.send_score_request(&[ds.rows.row(i)]).unwrap());
    }
    let mut batched = Vec::new();
    for &expect_id in &ids {
        match pipelined.recv().unwrap() {
            dsfacto::serve::Frame::ScoreResponse { req_id, mut scores } => {
                assert_eq!(req_id, expect_id, "responses must come back in order");
                assert_eq!(scores.len(), 1);
                batched.push(scores.pop().unwrap());
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(
        bits(&batched),
        bits(&unbatched),
        "batched scores diverge from unbatched"
    );

    let stats = pipelined.stats().unwrap();
    assert_eq!(stats.requests, 2 * n_requests as u64);
    assert!(
        stats.batches < stats.requests,
        "pipelined burst never coalesced: {} batches for {} requests",
        stats.batches,
        stats.requests
    );
}

#[test]
fn steady_state_arena_capacity_stops_growing() {
    let ds = test_rows();
    let m = test_model(ds.d(), 4, 7);
    let server = TestServer::start("zeroalloc", &m, |_| {});
    let mut client = server.connect();
    let rows = wire_rows(&ds);
    let batch = &rows[..32.min(rows.len())];

    // Warmup: let every grow-only arena see the working batch shape.
    for _ in 0..10 {
        client.score(batch).unwrap();
    }
    let warm = client.stats().unwrap();
    assert!(warm.staging_capacity > 0 && warm.scratch_capacity > 0);

    // Steady state: the same load must not move either watermark — the
    // capacities are exactly the connection's allocation history.
    for _ in 0..50 {
        client.score(batch).unwrap();
    }
    let after = client.stats().unwrap();
    assert_eq!(
        (after.staging_capacity, after.scratch_capacity),
        (warm.staging_capacity, warm.scratch_capacity),
        "steady-state load grew a per-connection arena"
    );
}

#[test]
fn hot_reload_swaps_models_without_dropping_the_connection() {
    let ds = test_rows();
    let m1 = test_model(ds.d(), 4, 21);
    let m2 = test_model(ds.d(), 4, 22);
    let want1 = reference_scores(&m1, &ds);
    let want2 = reference_scores(&m2, &ds);
    assert_ne!(bits(&want1), bits(&want2), "test models must differ");

    let server = TestServer::start("reload", &m1, |o| {
        o.reload_poll = Duration::from_millis(10);
    });
    let mut client = server.connect();
    let rows = wire_rows(&ds);

    let got = client.score(&rows).unwrap();
    assert_eq!(bits(&got), bits(&want1));
    let fp1 = client.stats().unwrap().fingerprint;

    // Push a new checkpoint; the atomic save renames a complete file into
    // place, so the watcher can never parse a torn write.
    fm_io::save(&m2, &server.model_path).unwrap();
    for _ in 0..500 {
        if client.stats().unwrap().generation >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.generation, 2, "hot reload never landed");
    assert_ne!(stats.fingerprint, fp1);

    // Same connection, no reconnect: the next batch scores the new model.
    let got = client.score(&rows).unwrap();
    assert_eq!(
        bits(&got),
        bits(&want2),
        "post-swap scores are not the new model's"
    );
}

#[test]
fn col_blocked_server_is_bitwise_equal_to_unblocked() {
    let ds = test_rows();
    let m = test_model(ds.d(), 7, 31);
    let rows = wire_rows(&ds);

    let unblocked = TestServer::start("blocks1", &m, |o| o.col_blocks = 1);
    let blocked = TestServer::start("blocks3", &m, |o| o.col_blocks = 3);
    let want = unblocked.connect().score(&rows).unwrap();
    assert_eq!(bits(&want), bits(&reference_scores(&m, &ds)));

    let mut client = blocked.connect();
    assert_eq!(client.stats().unwrap().col_blocks, 3);
    let got = client.score(&rows).unwrap();
    assert_eq!(
        bits(&got),
        bits(&want),
        "col_blocks=3 serving diverges from unblocked"
    );
}

#[test]
fn invalid_rows_get_error_frames_and_the_connection_survives() {
    let ds = test_rows();
    let m = test_model(ds.d(), 4, 41);
    let want = reference_scores(&m, &ds);
    let server = TestServer::start("badrows", &m, |_| {});
    let mut client = server.connect();

    // Out-of-range feature index: rejected with the row named, no score.
    let bad_idx = [ds.d() as u32 + 5];
    let bad_val = [1.0f32];
    let err = client
        .score(&[(&bad_idx[..], &bad_val[..])])
        .unwrap_err()
        .to_string();
    assert!(err.contains("out of range"), "{err}");

    // Non-ascending indices: also a request-level error.
    let dup_idx = [2u32, 2];
    let dup_val = [1.0f32, 2.0];
    let err = client
        .score(&[(&dup_idx[..], &dup_val[..])])
        .unwrap_err()
        .to_string();
    assert!(err.contains("strictly increasing"), "{err}");

    // The same connection still scores, bitwise-correctly.
    let got = client.score(&wire_rows(&ds)).unwrap();
    assert_eq!(bits(&got), bits(&want));
}
