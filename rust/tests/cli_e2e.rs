//! End-to-end CLI tests: drive the `dsfacto` binary as a user would.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_dsfacto")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn dsfacto");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_commands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for cmd in ["train", "evaluate", "inspect", "datasets", "artifacts"] {
        assert!(text.contains(cmd), "help missing {cmd}: {text}");
    }
}

#[test]
fn datasets_prints_table2() {
    let (ok, text) = run(&["datasets"]);
    assert!(ok, "{text}");
    for name in ["diabetes", "housing", "ijcnn1", "realsim"] {
        assert!(text.contains(name), "{text}");
    }
    assert!(text.contains("20958"), "realsim D missing: {text}");
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}

#[test]
fn unknown_flag_fails_cleanly() {
    let (ok, text) = run(&["train", "--dataset", "housing", "--not-a-flag", "1"]);
    assert!(!ok);
    assert!(text.contains("not-a-flag"), "{text}");
}

#[test]
fn train_save_inspect_evaluate_roundtrip() {
    let dir = std::env::temp_dir().join("dsfacto_cli_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("m.dsfm");
    let model_s = model.to_str().unwrap();
    let trace = dir.join("trace.csv");

    let (ok, text) = run(&[
        "train",
        "--dataset",
        "housing",
        "--trainer",
        "nomad",
        "--workers",
        "2",
        "--outer-iters",
        "10",
        "--eta",
        "constant:0.5",
        "--seed",
        "7",
        "--trace",
        trace.to_str().unwrap(),
        "--save-model",
        model_s,
        "--quiet",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("test RMSE"), "{text}");
    assert!(model.exists());
    assert!(trace.exists());

    let (ok, text) = run(&["inspect", "--model", model_s]);
    assert!(ok, "{text}");
    assert!(text.contains("d=13 k=4"), "{text}");

    let (ok, text) = run(&["evaluate", "--model", model_s, "--dataset", "housing", "--seed", "7"]);
    assert!(ok, "{text}");
    assert!(text.contains("rmse="), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_config_file() {
    let dir = std::env::temp_dir().join("dsfacto_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.conf");
    std::fs::write(
        &cfg,
        "dataset = housing\ntrainer = libfm\nouter_iters = 5\neta = constant:0.02\nseed = 3\n",
    )
    .unwrap();
    let (ok, text) = run(&["train", "--config", cfg.to_str().unwrap(), "--quiet"]);
    assert!(ok, "{text}");
    assert!(text.contains("trained libfm"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn artifacts_listing_when_built() {
    let manifest = format!("{}/artifacts/manifest.txt", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&manifest).exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (ok, text) = run(&["artifacts"]);
    assert!(ok, "{text}");
    assert!(text.contains("realsim"), "{text}");
    assert!(text.contains("PJRT platform"), "{text}");
}

#[test]
fn train_on_libsvm_file_dataset() {
    // Full user flow with a real LIBSVM file on disk: write the housing
    // twin out in LIBSVM format, then train on it via --dataset <path>.
    let dir = std::env::temp_dir().join("dsfacto_cli_libsvm");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("housing.svm");
    let ds = dsfacto::data::synth::table2_dataset("housing", 17).unwrap();
    dsfacto::data::libsvm::save(&ds, &path).unwrap();

    let (ok, text) = run(&[
        "train",
        "--dataset",
        path.to_str().unwrap(),
        "--dataset-task",
        "regression",
        "--trainer",
        "libfm",
        "--outer-iters",
        "5",
        "--eta",
        "constant:0.02",
        "--quiet",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("test RMSE"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ingest_then_train_from_cache() {
    // The out-of-core user flow: stream a LIBSVM file into a shard cache,
    // then train with every distributed worker loading only its own shard
    // file (--data-cache), on the cache-materialized dataset
    // (--dataset cache:DIR, --train-frac 1 keeps the cached row order).
    let dir = std::env::temp_dir().join("dsfacto_cli_ingest");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("housing.svm");
    let ds = dsfacto::data::synth::table2_dataset("housing", 19).unwrap();
    dsfacto::data::libsvm::save(&ds, &path).unwrap();
    let cache = dir.join("cache");
    let cache_s = cache.to_str().unwrap();

    let (ok, text) = run(&[
        "ingest",
        "--dataset",
        path.to_str().unwrap(),
        "--data-cache",
        cache_s,
        "--dataset-task",
        "regression",
        "--shards",
        "2",
        "--chunk-rows",
        "64",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("ingested"), "{text}");
    assert!(cache.join("manifest.dsfc").exists());
    assert!(cache.join("shard_00000.dsfs").exists());

    let dataset_arg = format!("cache:{cache_s}");
    let (ok, text) = run(&[
        "train",
        "--dataset",
        &dataset_arg,
        "--data-cache",
        cache_s,
        "--train-frac",
        "1",
        "--trainer",
        "dsgd",
        "--workers",
        "2",
        "--outer-iters",
        "5",
        "--eta",
        "constant:0.5",
        "--quiet",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("trained dsgd"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tcp_transport_from_cli() {
    let (ok, text) = run(&[
        "train",
        "--dataset",
        "housing",
        "--trainer",
        "nomad",
        "--workers",
        "2",
        "--outer-iters",
        "3",
        "--transport",
        "tcp",
        "--quiet",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("bytes"), "{text}");
}
