//! Multi-process cluster end-to-end tests: spawn a real `dsfacto driver`
//! and real `dsfacto worker` OS processes against a shared shard cache,
//! and check the distributed run against the in-process engine.
//!
//! The load-bearing assertion is *bitwise* model equality: under the
//! default `update_mode = mean` the lane-blocked engine folds deferred
//! recompute contributions in a canonical order, so the assembled model
//! must be bit-identical whether the P workers are threads in one process
//! or separate processes trading tokens over TCP.
//!
//! The `--wire-precision bf16` variant is the one deliberate exception:
//! each token hop rounds the payload to 8 significand bits, so that run
//! is pinned to the f32 reference by *tolerance* (relative L2 distance)
//! instead, and a ring that mixes precisions must be refused at `Join`.

use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dsfacto::config::{ExperimentConfig, TrainerKind};
use dsfacto::data::cache::{write_cache, ShardCacheSource};
use dsfacto::data::synth::table2_dataset;
use dsfacto::data::DataSource;
use dsfacto::partition::RowStrategy;
use dsfacto::train::Trainer;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_dsfacto")
}

/// A spawned dsfacto process, killed on drop so a failed assertion never
/// leaks children past the test run.
struct Proc {
    child: Child,
    name: String,
}

impl Proc {
    fn spawn(name: &str, args: &[&str], capture_stdout: bool) -> Proc {
        let mut cmd = Command::new(bin());
        cmd.args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .stdin(Stdio::null())
            .stdout(if capture_stdout {
                Stdio::piped()
            } else {
                Stdio::null()
            });
        let child = cmd.spawn().unwrap_or_else(|e| panic!("spawn {name}: {e}"));
        Proc {
            child,
            name: name.to_string(),
        }
    }

    /// Like `spawn`, but with stderr piped instead of stdout — for tests
    /// asserting on a process's error output.
    fn spawn_capturing_stderr(name: &str, args: &[&str]) -> Proc {
        let mut cmd = Command::new(bin());
        cmd.args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::piped());
        let child = cmd.spawn().unwrap_or_else(|e| panic!("spawn {name}: {e}"));
        Proc {
            child,
            name: name.to_string(),
        }
    }

    /// Streams this process's stderr lines like `capture_lines` does for
    /// stdout (requires `spawn_capturing_stderr`).
    fn capture_stderr_lines(&mut self) -> Arc<Mutex<Vec<String>>> {
        let stderr = self.child.stderr.take().expect("stderr not piped");
        let lines = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&lines);
        std::thread::spawn(move || {
            for line in std::io::BufReader::new(stderr).lines() {
                match line {
                    Ok(l) => sink.lock().unwrap().push(l),
                    Err(_) => break,
                }
            }
        });
        lines
    }

    /// Streams this process's stdout lines into a shared buffer from a
    /// background thread (so the pipe never fills and blocks the child).
    fn capture_lines(&mut self) -> Arc<Mutex<Vec<String>>> {
        let stdout = self.child.stdout.take().expect("stdout not piped");
        let lines = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&lines);
        std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                match line {
                    Ok(l) => sink.lock().unwrap().push(l),
                    Err(_) => break,
                }
            }
        });
        lines
    }

    /// Waits for exit within `timeout`; panics on timeout, returns the
    /// success flag otherwise.
    fn wait_ok(&mut self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(status) = self.child.try_wait().expect("try_wait") {
                return status.success();
            }
            assert!(
                Instant::now() < deadline,
                "{} did not exit within {timeout:?}",
                self.name
            );
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Blocks until some captured line satisfies `pred` (scanning new lines
/// as they stream in), returning the matching line.
fn wait_for_line(
    lines: &Arc<Mutex<Vec<String>>>,
    what: &str,
    timeout: Duration,
    pred: impl Fn(&str) -> bool,
) -> String {
    let deadline = Instant::now() + timeout;
    let mut scanned = 0usize;
    loop {
        {
            let buf = lines.lock().unwrap();
            while scanned < buf.len() {
                if pred(&buf[scanned]) {
                    return buf[scanned].clone();
                }
                scanned += 1;
            }
        }
        assert!(
            Instant::now() < deadline,
            "never saw {what}; driver output so far: {:#?}",
            lines.lock().unwrap()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Extracts the bound control address from the driver's
/// `dsfacto driver: control on ADDR` line.
fn control_addr(lines: &Arc<Mutex<Vec<String>>>) -> String {
    let line = wait_for_line(lines, "the control-on line", Duration::from_secs(60), |l| {
        l.contains("control on ")
    });
    line.split("control on ")
        .nth(1)
        .expect("address after 'control on '")
        .trim()
        .to_string()
}

/// The in-process reference run at the exact schedule the driver ships to
/// its workers (same seed, eta, token width, partition — same everything).
fn inprocess_model(cache: &str, p: usize, iters: usize, seed: u64) -> dsfacto::fm::FmModel {
    let mut cfg = ExperimentConfig::default();
    for (key, val) in [
        ("dataset", format!("cache:{cache}")),
        ("data_cache", cache.to_string()),
        ("workers", p.to_string()),
        ("outer_iters", iters.to_string()),
        ("eta", "constant:0.5".to_string()),
        ("seed", seed.to_string()),
        ("cols_per_token", "5".to_string()),
        ("train_frac", "1".to_string()),
    ] {
        cfg.set(key, &val).unwrap();
    }
    let ds = ShardCacheSource::open(cache).unwrap().materialize().unwrap();
    let out = TrainerKind::Nomad
        .build(&cfg)
        .fit(&ds, None, &mut ())
        .unwrap();
    out.model
}

fn setup_cache(tag: &str, seed: u64, shards: usize) -> (std::path::PathBuf, String) {
    let base = std::env::temp_dir().join(format!("dsfacto_cluster_{tag}"));
    std::fs::remove_dir_all(&base).ok();
    std::fs::create_dir_all(&base).unwrap();
    let cache = base.join("cache");
    let ds = table2_dataset("housing", seed).unwrap();
    write_cache(&ds, RowStrategy::Contiguous, shards, &cache).unwrap();
    let cache_s = cache.to_str().unwrap().to_string();
    (base, cache_s)
}

fn run_ring(tag: &str, p: usize, iters: usize, seed: u64) {
    let (base, cache) = setup_cache(tag, seed, p);
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");
    let (ps, iters_s, seed_s) = (p.to_string(), iters.to_string(), seed.to_string());

    let mut driver = Proc::spawn(
        "driver",
        &[
            "driver",
            "--dataset",
            &dataset,
            "--workers",
            &ps,
            "--outer-iters",
            &iters_s,
            "--eta",
            "constant:0.5",
            "--seed",
            &seed_s,
            "--cols-per-token",
            "5",
            "--train-frac",
            "1",
            "--addr",
            "127.0.0.1:0",
            "--save-model",
            &model_s,
            "--quiet",
        ],
        true,
    );
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    let mut workers: Vec<Proc> = (0..p)
        .map(|i| {
            Proc::spawn(
                &format!("worker-{i}"),
                &["worker", "--driver", &addr],
                false,
            )
        })
        .collect();

    assert!(
        driver.wait_ok(Duration::from_secs(180)),
        "driver failed; output: {:#?}",
        lines.lock().unwrap()
    );
    for w in &mut workers {
        assert!(w.wait_ok(Duration::from_secs(60)), "{} failed", w.name);
    }

    let cluster = dsfacto::fm::io::load(&model_path).unwrap();
    let reference = inprocess_model(&cache, p, iters, seed);
    assert_eq!(
        cluster, reference,
        "multi-process model differs from the in-process engine"
    );
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn driver_rejects_fractional_train_split() {
    // The driver must refuse `train_frac < 1` loudly (workers train on the
    // ingested shard files; a split would silently change the rows) —
    // before it binds a port or opens the cache.
    use dsfacto::cluster::runtime::{run_driver, ClusterSpec, DriverOptions};
    let mut cfg = ExperimentConfig::default();
    cfg.set("dataset", "cache:/nonexistent/dir").unwrap();
    cfg.set("train_frac", "0.5").unwrap();
    cfg.cluster = Some(ClusterSpec::Driver {
        addr: "127.0.0.1:0".to_string(),
        p: 2,
    });
    let err = run_driver(&DriverOptions {
        cfg,
        ckpt_dir: None,
        ckpt_every: 1,
        join_timeout: Duration::from_secs(1),
        heartbeat_timeout: Duration::from_secs(1),
        stall_timeout: Duration::from_secs(60),
        max_generations: 1,
        resume: false,
        chaos: None,
        quiet: true,
    })
    .expect_err("train_frac = 0.5 must be rejected");
    assert!(
        format!("{err:#}").contains("train_frac = 1"),
        "unhelpful error: {err:#}"
    );
}

/// Relative L2 distance between two models over all parameters
/// (`w0`, `w`, `V`), with `b` as the reference.
fn rel_l2_dist(a: &dsfacto::fm::FmModel, b: &dsfacto::fm::FmModel) -> f64 {
    let pairs = a
        .w
        .iter()
        .zip(b.w.iter())
        .chain(a.v.iter().zip(b.v.iter()))
        .chain(std::iter::once((&a.w0, &b.w0)));
    let (mut num, mut den) = (0f64, 0f64);
    for (x, y) in pairs {
        num += (*x as f64 - *y as f64).powi(2);
        den += (*y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[test]
fn two_process_ring_is_bitwise_in_process() {
    run_ring("p2", 2, 4, 23);
}

/// `--wire-precision bf16` on every process: the run completes and the
/// assembled model tracks the in-process f32 reference. Documented
/// tolerance (EXPERIMENTS.md §Cluster): every token hop rounds each
/// circulated value to 8 significand bits (2^-8 relative), so over 4
/// iterations the model stays within 5e-2 relative L2 of the exact run —
/// ample headroom over the drift seen in practice, while a mis-wired
/// decode (wrong half of the f32, swapped byte order) lands far outside.
#[test]
fn bf16_two_process_ring_tracks_in_process_f32() {
    let (base, cache) = setup_cache("bf16", 37, 2);
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");

    let mut driver = Proc::spawn(
        "driver",
        &[
            "driver",
            "--dataset",
            &dataset,
            "--workers",
            "2",
            "--outer-iters",
            "4",
            "--eta",
            "constant:0.5",
            "--seed",
            "37",
            "--cols-per-token",
            "5",
            "--train-frac",
            "1",
            "--addr",
            "127.0.0.1:0",
            "--wire-precision",
            "bf16",
            "--save-model",
            &model_s,
            "--quiet",
        ],
        true,
    );
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);
    wait_for_line(
        &lines,
        "the wire-precision banner",
        Duration::from_secs(10),
        |l| l.contains("token wire precision bf16"),
    );

    let mut workers: Vec<Proc> = (0..2)
        .map(|i| {
            Proc::spawn(
                &format!("worker-{i}"),
                &["worker", "--driver", &addr, "--wire-precision", "bf16"],
                false,
            )
        })
        .collect();

    assert!(
        driver.wait_ok(Duration::from_secs(180)),
        "driver failed; output: {:#?}",
        lines.lock().unwrap()
    );
    for w in &mut workers {
        assert!(w.wait_ok(Duration::from_secs(60)), "{} failed", w.name);
    }

    let cluster = dsfacto::fm::io::load(&model_path).unwrap();
    let reference = inprocess_model(&cache, 2, 4, 37);
    let dist = rel_l2_dist(&cluster, &reference);
    assert!(
        dist.is_finite() && dist < 5e-2,
        "bf16 ring drifted {dist:.4} relative L2 from the f32 reference"
    );
    assert!(
        dist > 0.0,
        "bf16 ring is bitwise f32 — is the wire precision actually applied?"
    );
    std::fs::remove_dir_all(&base).ok();
}

/// A worker whose `--wire-precision` differs from the driver's can never
/// be admitted: the driver answers its `Join` with `Reject` (a mixed ring
/// would corrupt every circulating token), and the worker exits
/// unsuccessfully with the reason instead of re-joining forever.
#[test]
fn mixed_wire_precision_worker_is_rejected() {
    let (base, cache) = setup_cache("mixprec", 41, 2);
    let dataset = format!("cache:{cache}");

    // Driver at the f32 default, expecting 2 workers; it holds the
    // membership round open while we probe it with a bf16 worker.
    let mut driver = Proc::spawn(
        "driver",
        &[
            "driver",
            "--dataset",
            &dataset,
            "--workers",
            "2",
            "--outer-iters",
            "2",
            "--eta",
            "constant:0.5",
            "--seed",
            "41",
            "--cols-per-token",
            "5",
            "--train-frac",
            "1",
            "--addr",
            "127.0.0.1:0",
            "--quiet",
        ],
        true,
    );
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    let mut worker = Proc::spawn_capturing_stderr(
        "worker-bf16",
        &["worker", "--driver", &addr, "--wire-precision", "bf16"],
    );
    let errs = worker.capture_stderr_lines();
    assert!(
        !worker.wait_ok(Duration::from_secs(60)),
        "a precision-mismatched worker must exit unsuccessfully"
    );
    wait_for_line(
        &errs,
        "the rejection reason on the worker's stderr",
        Duration::from_secs(10),
        |l| l.contains("wire_precision mismatch"),
    );

    driver.kill();
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn three_process_ring_is_bitwise_in_process() {
    run_ring("p3", 3, 3, 29);
}

#[test]
fn killed_worker_recovers_from_block_checkpoints() {
    let (base, cache) = setup_cache("recover", 31, 2);
    let ckpt = base.join("ckpt");
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let model_path = base.join("model.dsfm");
    let model_s = model_path.to_str().unwrap().to_string();
    let dataset = format!("cache:{cache}");

    // Not --quiet: the test steers off the driver's per-iteration progress
    // lines and its generation-restart marker.
    let mut driver = Proc::spawn(
        "driver",
        &[
            "driver",
            "--dataset",
            &dataset,
            "--workers",
            "2",
            "--outer-iters",
            "8",
            "--eta",
            "constant:0.5",
            "--seed",
            "7",
            "--cols-per-token",
            "5",
            "--train-frac",
            "1",
            "--addr",
            "127.0.0.1:0",
            "--ckpt-dir",
            &ckpt_s,
            "--ckpt-every",
            "1",
            "--heartbeat-timeout",
            "2",
            "--max-restarts",
            "2",
            "--save-model",
            &model_s,
        ],
        true,
    );
    let lines = driver.capture_lines();
    let addr = control_addr(&lines);

    let worker_args = [
        "worker",
        "--driver",
        addr.as_str(),
        "--ckpt-dir",
        ckpt_s.as_str(),
        "--ckpt-every",
        "1",
    ];
    let mut worker_a = Proc::spawn("worker-a", &worker_args, false);
    let mut worker_b = Proc::spawn("worker-b", &worker_args, false);

    // Let training make checkpointable progress, then kill one worker.
    wait_for_line(&lines, "iteration 3", Duration::from_secs(120), |l| {
        l.trim_start()
            .strip_prefix("iter")
            .and_then(|rest| rest.trim_start().split_whitespace().next())
            .and_then(|n| n.parse::<u32>().ok())
            .is_some_and(|n| n >= 3)
    });
    worker_b.kill();

    // The driver notices (closed control conn / heartbeat silence), aborts
    // the generation and opens the next membership round.
    wait_for_line(
        &lines,
        "the generation-restart marker",
        Duration::from_secs(60),
        |l| l.contains("restarting from iteration"),
    );
    let mut worker_c = Proc::spawn("worker-c", &worker_args, false);

    assert!(
        driver.wait_ok(Duration::from_secs(180)),
        "driver failed after recovery; output: {:#?}",
        lines.lock().unwrap()
    );
    assert!(worker_a.wait_ok(Duration::from_secs(60)), "survivor failed");
    assert!(worker_c.wait_ok(Duration::from_secs(60)), "replacement failed");

    // The run recovered: a restart happened, block checkpoints exist, and
    // the final model was assembled and saved.
    let restarted = lines
        .lock()
        .unwrap()
        .iter()
        .any(|l| l.contains("restarting from iteration"));
    assert!(restarted);
    let blocks = std::fs::read_dir(&ckpt)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy();
            name.starts_with("blocks-r") && name.ends_with(".dsfb")
        })
        .count();
    assert!(blocks > 0, "no block checkpoints were written");
    let model = dsfacto::fm::io::load(&model_path).unwrap();
    let src = ShardCacheSource::open(&cache).unwrap();
    assert_eq!(model.d, src.d());
    std::fs::remove_dir_all(&base).ok();
}
