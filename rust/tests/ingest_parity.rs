//! The out-of-core data layer's acceptance suite:
//!
//! * **Ingest parity** — `libsvm::stream_ingest` -> `ShardCacheSource`
//!   must be bitwise identical to `libsvm::parse` -> `InMemorySource` on
//!   the same file (shards, CSC, labels), for contiguous and nnz-balanced
//!   plans, on a synthetic-twin file round-tripped through `libsvm::save`.
//! * **End-to-end trainer parity** — nomad / dsgd / bulksync trained from
//!   a shard cache produce bit-identical models and traces to the same
//!   run trained from the in-memory dataset.
//! * **Corruption rejection** — truncation, bit flips, trailing bytes,
//!   version skew and missing shard files are all refused (mirroring
//!   `codec_conformance.rs` for the wire codec).
//! * **Bounded memory** — the streaming ingester's instrumented peaks
//!   stay below the full-CSR footprint, and per-worker shard loads read
//!   one shard file each, never the whole cache.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use dsfacto::baseline::{
    bulksync_train_with_stats, dsgd_train_with_stats, BulkSyncConfig, DsgdConfig,
};
use dsfacto::data::cache::{fnv1a, shard_file_name, ShardCacheSource, MANIFEST_FILE};
use dsfacto::data::libsvm::{self, IngestOptions};
use dsfacto::data::{synth, DataSource, Dataset, InMemorySource, ShardSource, Task};
use dsfacto::fm::{FmHyper, FmModel};
use dsfacto::metrics::TrainOutput;
use dsfacto::nomad::{self, NomadConfig};
use dsfacto::optim::LrSchedule;
use dsfacto::partition::{build_shards_from_source, RowStrategy};

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dsfacto_ingest_parity_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Saves a synthetic twin as LIBSVM text, parses it back (the in-memory
/// reference), and stream-ingests the same file (the cache under test).
fn twin_file_and_parsed(dir: &Path, name: &str, seed: u64) -> (PathBuf, Dataset) {
    let ds = synth::table2_dataset(name, seed).unwrap();
    let path = dir.join(format!("{name}.svm"));
    libsvm::save(&ds, &path).unwrap();
    // The reference is the *parsed file*, not the generator output: both
    // sides of every comparison then saw exactly the same text.
    let parsed = libsvm::load(&path, name, ds.task, Some(ds.d())).unwrap();
    (path, parsed)
}

fn assert_labels_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: label count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: label {i}");
    }
}

fn assert_models_bitwise(a: &FmModel, b: &FmModel, what: &str) {
    assert_eq!(a.w0.to_bits(), b.w0.to_bits(), "{what}: w0");
    assert_eq!(a.w.len(), b.w.len(), "{what}: w len");
    for (j, (x, y)) in a.w.iter().zip(&b.w).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: w[{j}]");
    }
    assert_eq!(a.v.len(), b.v.len(), "{what}: v len");
    for (q, (x, y)) in a.v.iter().zip(&b.v).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: v[{q}]");
    }
}

fn assert_traces_bitwise(a: &TrainOutput, b: &TrainOutput, what: &str) {
    assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
    for (pa, pb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(pa.iter, pb.iter, "{what}");
        assert_eq!(
            pa.objective.to_bits(),
            pb.objective.to_bits(),
            "{what}: objective at iter {}",
            pa.iter
        );
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{what}: train_loss at iter {}",
            pa.iter
        );
    }
}

// ---------------------------------------------------------------------
// Ingest parity: stream_ingest == parse, shard by shard, bit for bit.

#[test]
fn stream_ingest_matches_in_memory_source_bitwise() {
    let dir = scratch_dir("shards");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 3);
    for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
        let cache_dir = dir.join(format!("cache_{}", strat.spec()));
        let opts = IngestOptions {
            task: parsed.task,
            n_features: Some(parsed.d()),
            strategy: strat,
            shards: 4,
            chunk_rows: 37, // forces many chunks on n = 303
        };
        let report = libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();
        assert_eq!(
            (report.n, report.d, report.nnz),
            (parsed.n(), parsed.d(), parsed.nnz()),
            "{strat:?}"
        );
        assert!(report.chunks_flushed > 1, "{strat:?}: single chunk");

        let cache = ShardCacheSource::open(&cache_dir).unwrap();
        let mem = InMemorySource::new(&parsed);
        assert_eq!(cache.task(), mem.task());
        assert_eq!(cache.name(), "housing");

        // The cached plan is the plan the in-memory planner computes.
        let part = cache.plan(strat, 4).unwrap();
        assert_eq!(part, mem.plan(strat, 4).unwrap(), "{strat:?}");

        // Every shard: identical local CSR, CSC, labels, range, task.
        for id in 0..4 {
            let got = cache.shard(&part, id).unwrap();
            let want = mem.shard(&part, id).unwrap();
            assert_eq!(got.rows, want.rows, "{strat:?} shard {id}: CSR");
            assert_eq!(got.cols, want.cols, "{strat:?} shard {id}: CSC");
            assert_labels_bitwise(&got.labels, &want.labels, &format!("{strat:?} shard {id}"));
            assert_eq!((got.start, got.end), (want.start, want.end));
            assert_eq!(got.task, want.task);
        }

        // Whole-dataset access reconstructs the parsed dataset exactly.
        let back = cache.materialize().unwrap();
        assert_eq!(back.rows, parsed.rows, "{strat:?}");
        assert_labels_bitwise(&back.labels, &parsed.labels, &format!("{strat:?} materialize"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// End-to-end trainer parity: cache-fed training == in-memory training.

#[test]
fn trainers_from_cache_match_in_memory_bitwise() {
    let dir = scratch_dir("train");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 7);
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
        // DSGD and bulk-sync run P = 4 (deterministic: scoped joins merge
        // in shard order); the asynchronous NOMAD engine is only
        // run-to-run deterministic at P = 1, so its parity uses one
        // worker — the seam under test is identical at any P.
        for &(trainer, p) in &[("nomad", 1usize), ("dsgd", 4), ("bulksync", 4)] {
            let cache_dir = dir.join(format!("cache_{}_{trainer}", strat.spec()));
            let opts = IngestOptions {
                task: parsed.task,
                n_features: Some(parsed.d()),
                strategy: strat,
                shards: p,
                chunk_rows: 64,
            };
            libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();
            let cached = ShardSource::Cache(cache_dir.to_str().unwrap().to_string());
            let what = format!("{trainer} {} P={p}", strat.spec());
            match trainer {
                "nomad" => {
                    let run = |source: ShardSource| {
                        let cfg = NomadConfig {
                            workers: p,
                            outer_iters: 5,
                            eta: LrSchedule::Constant(0.5),
                            seed: 11,
                            eval_every: usize::MAX,
                            row_partition: strat,
                            source,
                            ..Default::default()
                        };
                        nomad::train_with_stats(&parsed, None, &fm, &cfg).unwrap()
                    };
                    let (mem, mem_stats) = run(ShardSource::InMemory);
                    let (cch, cch_stats) = run(cached.clone());
                    assert_models_bitwise(&mem.model, &cch.model, &what);
                    assert_traces_bitwise(&mem, &cch, &what);
                    assert_eq!(mem_stats.partition.shard_nnz, cch_stats.partition.shard_nnz);
                }
                "dsgd" => {
                    let run = |source: ShardSource| {
                        let cfg = DsgdConfig {
                            epochs: 5,
                            eta: LrSchedule::Constant(0.5),
                            workers: p,
                            seed: 11,
                            eval_every: usize::MAX,
                            row_partition: strat,
                            source,
                        };
                        dsgd_train_with_stats(&parsed, None, &fm, &cfg, &mut ()).unwrap()
                    };
                    let (mem, mem_stats) = run(ShardSource::InMemory);
                    let (cch, cch_stats) = run(cached.clone());
                    assert_models_bitwise(&mem.model, &cch.model, &what);
                    assert_traces_bitwise(&mem, &cch, &what);
                    assert_eq!(mem_stats.shard_nnz, cch_stats.shard_nnz);
                }
                _ => {
                    let run = |source: ShardSource| {
                        let cfg = BulkSyncConfig {
                            iters: 5,
                            eta: LrSchedule::Constant(0.05),
                            workers: p,
                            seed: 11,
                            eval_every: usize::MAX,
                            row_partition: strat,
                            source,
                        };
                        bulksync_train_with_stats(&parsed, None, &fm, &cfg, &mut ()).unwrap()
                    };
                    let (mem, mem_stats) = run(ShardSource::InMemory);
                    let (cch, cch_stats) = run(cached.clone());
                    assert_models_bitwise(&mem.model, &cch.model, &what);
                    assert_traces_bitwise(&mem, &cch, &what);
                    assert_eq!(mem_stats.shard_nnz, cch_stats.shard_nnz);
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn data_cache_config_key_reaches_every_distributed_trainer() {
    // The session-API wiring: `data_cache = <dir>` routes shard loads
    // through the cache for nomad, dsgd and bulksync via
    // TrainerKind::build, with identical results to the in-memory run.
    use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
    use dsfacto::train::Trainer;

    let dir = scratch_dir("cfg");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 9);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        strategy: RowStrategy::Contiguous,
        shards: 2,
        chunk_rows: 64,
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();

    for kind in [TrainerKind::Nomad, TrainerKind::Dsgd, TrainerKind::BulkSync] {
        // P = 1 determinism only matters for nomad; dsgd/bulksync are
        // deterministic at any worker count, but share the same cache.
        let workers = if kind == TrainerKind::Nomad { 1 } else { 2 };
        let shards = if kind == TrainerKind::Nomad { 1 } else { 2 };
        let cdir = dir.join(format!("cache_p{shards}"));
        let opts = IngestOptions {
            shards,
            ..opts.clone()
        };
        libsvm::stream_ingest(&path, "housing", &opts, &cdir).unwrap();
        let mut cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("housing".into()),
            trainer: kind,
            fm: FmHyper {
                k: 4,
                ..Default::default()
            },
            workers,
            outer_iters: 3,
            eta: LrSchedule::Constant(0.5),
            eval_every: usize::MAX,
            ..Default::default()
        };
        let from_memory = kind.build(&cfg).fit(&parsed, None, &mut ()).unwrap();
        cfg.set("data_cache", cdir.to_str().unwrap()).unwrap();
        let trainer = kind.build(&cfg);
        let from_cache = trainer.fit(&parsed, None, &mut ()).unwrap();
        assert_models_bitwise(
            &from_memory.model,
            &from_cache.model,
            &format!("{kind:?} via data_cache"),
        );
        assert!(trainer.partition_stats().is_some(), "{kind:?}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Rejection: corruption, truncation, version skew, plan/shape mismatch.

#[test]
fn manifest_corruption_truncation_and_version_skew_rejected() {
    let dir = scratch_dir("manifest_rej");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 13);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        ..Default::default()
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();
    assert!(ShardCacheSource::open(&cache_dir).is_ok());

    let manifest_path = cache_dir.join(MANIFEST_FILE);
    let pristine = std::fs::read(&manifest_path).unwrap();

    // Every strict prefix is rejected (footer hash or hard truncation).
    for cut in 0..pristine.len() {
        std::fs::write(&manifest_path, &pristine[..cut]).unwrap();
        assert!(
            ShardCacheSource::open(&cache_dir).is_err(),
            "manifest prefix of {cut}/{} bytes accepted",
            pristine.len()
        );
    }
    // Trailing garbage is rejected.
    let mut extended = pristine.clone();
    extended.push(0);
    std::fs::write(&manifest_path, &extended).unwrap();
    assert!(ShardCacheSource::open(&cache_dir).is_err(), "trailing byte accepted");

    // Any single bit flip in the body is caught by the footer hash.
    for &at in &[0usize, 4, 12, pristine.len() / 2, pristine.len() - 9] {
        let mut bad = pristine.clone();
        bad[at] ^= 0x40;
        std::fs::write(&manifest_path, &bad).unwrap();
        assert!(
            ShardCacheSource::open(&cache_dir).is_err(),
            "bit flip at {at} accepted"
        );
    }

    // Version skew with a *valid* footer hash must still be refused.
    let mut vskew = pristine.clone();
    vskew[4..8].copy_from_slice(&2u32.to_le_bytes());
    let body_len = vskew.len() - 8;
    let h = fnv1a(&vskew[..body_len]);
    vskew[body_len..].copy_from_slice(&h.to_le_bytes());
    std::fs::write(&manifest_path, &vskew).unwrap();
    let err = ShardCacheSource::open(&cache_dir).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");

    // Restore: the pristine manifest still opens.
    std::fs::write(&manifest_path, &pristine).unwrap();
    assert!(ShardCacheSource::open(&cache_dir).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_file_corruption_and_truncation_rejected() {
    let dir = scratch_dir("shard_rej");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 17);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        shards: 2,
        ..Default::default()
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();
    let src = ShardCacheSource::open(&cache_dir).unwrap();
    let part = src.plan(RowStrategy::Contiguous, 2).unwrap();
    assert!(src.shard(&part, 0).is_ok());

    let shard_path = cache_dir.join(shard_file_name(0));
    let pristine = std::fs::read(&shard_path).unwrap();

    // Truncated by one byte.
    std::fs::write(&shard_path, &pristine[..pristine.len() - 1]).unwrap();
    assert!(src.shard(&part, 0).is_err(), "truncated shard accepted");
    // Extended by one byte.
    let mut extended = pristine.clone();
    extended.push(7);
    std::fs::write(&shard_path, &extended).unwrap();
    assert!(src.shard(&part, 0).is_err(), "extended shard accepted");
    // A flipped value byte (header still plausible) is caught by the
    // manifest's file hash.
    let mut bad = pristine.clone();
    let at = pristine.len() - 3;
    bad[at] ^= 0x01;
    std::fs::write(&shard_path, &bad).unwrap();
    assert!(src.shard(&part, 0).is_err(), "bit-flipped shard accepted");
    // Missing file.
    std::fs::remove_file(&shard_path).unwrap();
    assert!(src.shard(&part, 0).is_err(), "missing shard accepted");
    // Shard 1 is untouched and still loads.
    assert!(src.shard(&part, 1).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_and_shape_mismatches_are_refused_at_fit_time() {
    let dir = scratch_dir("mismatch");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 19);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        shards: 4,
        strategy: RowStrategy::Contiguous,
        ..Default::default()
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();
    let cached = ShardSource::Cache(cache_dir.to_str().unwrap().to_string());
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };

    // Worker count differing from the cached shard count.
    let cfg = DsgdConfig {
        epochs: 2,
        workers: 3,
        row_partition: RowStrategy::Contiguous,
        source: cached.clone(),
        ..Default::default()
    };
    let err = dsgd_train_with_stats(&parsed, None, &fm, &cfg, &mut ()).unwrap_err();
    assert!(format!("{err:#}").contains("re-ingest"), "{err:#}");

    // Strategy differing from the cached plan.
    let cfg = DsgdConfig {
        epochs: 2,
        workers: 4,
        row_partition: RowStrategy::NnzBalanced,
        source: cached.clone(),
        ..Default::default()
    };
    assert!(dsgd_train_with_stats(&parsed, None, &fm, &cfg, &mut ()).is_err());

    // A training set that is not the cached rows (shape mismatch).
    let subset = parsed.subset(&(0..parsed.n() / 2).collect::<Vec<_>>(), "half");
    let cfg = BulkSyncConfig {
        iters: 2,
        workers: 4,
        source: cached,
        ..Default::default()
    };
    let err = bulksync_train_with_stats(&subset, None, &fm, &cfg, &mut ()).unwrap_err();
    assert!(format!("{err:#}").contains("does not describe"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Bounded memory.

/// In-memory footprint of the full training matrix (indptr + indices +
/// values + labels) — the thing the out-of-core path must never hold.
fn full_csr_bytes(ds: &Dataset) -> usize {
    8 * (ds.n() + 1) + (4 + 4) * ds.nnz() + 4 * ds.n()
}

#[test]
fn ingest_and_shard_loads_never_hold_the_full_csr() {
    let dir = scratch_dir("bounded");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 23);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        strategy: RowStrategy::Contiguous,
        shards: 4,
        chunk_rows: 32,
    };
    let report = libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();

    // The ingester streamed: many chunks, each bounded by chunk_rows.
    assert!(report.chunks_flushed >= 2, "{report:?}");
    assert!(report.peak_chunk_rows <= 32, "{report:?}");

    // Its instrumented peak (prefix + max(chunk, shard)) stays well under
    // the full CSR it never built.
    let full = full_csr_bytes(&parsed);
    assert!(
        report.peak_resident_bytes < full,
        "ingest peak {} >= full CSR {full}",
        report.peak_resident_bytes
    );
    // The dominant term is one shard (~ a quarter of the data here), not
    // the dataset.
    assert!(
        report.peak_shard_bytes < full * 2 / 3,
        "peak shard {} vs full {full}",
        report.peak_shard_bytes
    );

    // Per-worker loads: each worker reads one shard file; the source's
    // high-water mark is the largest single file, strictly below the
    // total cache size.
    let src = ShardCacheSource::open(&cache_dir).unwrap();
    let part = src.plan(RowStrategy::Contiguous, 4).unwrap();
    let shards = build_shards_from_source(&src, &part).unwrap();
    assert_eq!(shards.len(), 4);
    let total_cache_bytes: u64 = (0..4)
        .map(|id| std::fs::metadata(cache_dir.join(shard_file_name(id))).unwrap().len())
        .sum();
    assert_eq!(src.peak_load_bytes() as usize, src.max_shard_file_bytes());
    assert!(
        src.peak_load_bytes() < total_cache_bytes,
        "peak load {} vs total {total_cache_bytes}",
        src.peak_load_bytes()
    );
    // And each materialized shard holds exactly its slice.
    assert_eq!(shards.iter().map(|s| s.rows.nnz()).sum::<usize>(), parsed.nnz());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Streaming coordinator: `run_experiment` on a `cache:` dataset must be
// bitwise the in-memory run — trace, model and final metrics — while
// never holding more than the prefetch window (<= 2 shards).

#[test]
fn streaming_run_experiment_is_bitwise_in_memory() {
    use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
    use dsfacto::coordinator::run_experiment;
    use dsfacto::train::Trainer;

    let dir = scratch_dir("stream_coord");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 31);
    for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
        // NOMAD is run-to-run deterministic only at P = 1; the others are
        // deterministic at any width.
        for &(kind, p) in &[
            (TrainerKind::Nomad, 1usize),
            (TrainerKind::Libfm, 3),
            (TrainerKind::Dsgd, 3),
            (TrainerKind::BulkSync, 3),
        ] {
            let cache_dir = dir.join(format!("cache_{}_{}", strat.spec(), kind.name()));
            let opts = IngestOptions {
                task: parsed.task,
                n_features: Some(parsed.d()),
                strategy: strat,
                shards: p,
                chunk_rows: 64,
            };
            libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();

            let eta = match kind {
                TrainerKind::Libfm => LrSchedule::Constant(0.02),
                TrainerKind::BulkSync => LrSchedule::Constant(0.05),
                _ => LrSchedule::Constant(0.5),
            };
            let cfg = ExperimentConfig {
                dataset: DatasetSpec::Cache {
                    dir: cache_dir.to_str().unwrap().to_string(),
                },
                trainer: kind,
                fm: FmHyper {
                    k: 4,
                    ..Default::default()
                },
                workers: p,
                outer_iters: 4,
                eta,
                eval_every: usize::MAX,
                train_frac: 1.0,
                row_partition: strat,
                ..Default::default()
            };
            let what = format!("{} {} P={p}", kind.name(), strat.spec());

            // The in-memory reference: the same trainer build, fed the
            // parsed dataset directly (same shuffle gating, same RNG).
            let reference = kind.build(&cfg).fit(&parsed, None, &mut ()).unwrap();
            let summary = run_experiment(&cfg).unwrap_or_else(|e| panic!("{what}: {e:#}"));

            assert_models_bitwise(&reference.model, &summary.output.model, &what);
            assert_traces_bitwise(&reference, &summary.output, &what);

            // Final metrics stream shard by shard, bitwise the in-memory
            // evaluation of the same model over the same rows.
            assert!(summary.test.is_none(), "{what}: streaming runs hold no test set");
            let want = dsfacto::metrics::evaluate(&summary.output.model, &parsed);
            let got = summary.final_eval;
            assert_eq!(got.loss.to_bits(), want.loss.to_bits(), "{what}: loss");
            assert_eq!(got.rmse.to_bits(), want.rmse.to_bits(), "{what}: rmse");
            assert_eq!(got.accuracy.to_bits(), want.accuracy.to_bits(), "{what}: accuracy");
            assert_eq!(got.auc.to_bits(), want.auc.to_bits(), "{what}: auc");

            // The streaming run reports its residency meters.
            let residency = summary.residency.expect("streaming run reports residency");
            assert!(residency.peak_resident_shards >= 1, "{what}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_fed_run_experiment_never_holds_the_full_csr() {
    use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
    use dsfacto::coordinator::run_experiment;

    let dir = scratch_dir("stream_bounded");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 37);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        strategy: RowStrategy::Contiguous,
        shards: 4,
        chunk_rows: 64,
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();

    // libFM sweeps shards strictly in order, so the coordinator's prefetch
    // window is the whole working set: one shard in use + one in flight.
    let cfg = ExperimentConfig {
        dataset: DatasetSpec::Cache {
            dir: cache_dir.to_str().unwrap().to_string(),
        },
        trainer: TrainerKind::Libfm,
        fm: FmHyper {
            k: 4,
            ..Default::default()
        },
        outer_iters: 3,
        eta: LrSchedule::Constant(0.02),
        eval_every: usize::MAX,
        train_frac: 1.0,
        ..Default::default()
    };
    let summary = run_experiment(&cfg).unwrap();
    let residency = summary.residency.expect("streaming run reports residency");
    assert!(
        residency.peak_resident_shards <= 2,
        "prefetch window exceeded: {} shards resident",
        residency.peak_resident_shards
    );
    let full = full_csr_bytes(&parsed);
    assert!(
        residency.peak_resident_bytes < full,
        "coordinator resident {} >= full CSR {full}",
        residency.peak_resident_bytes
    );
    // Sequential sweeps actually used the double buffer.
    assert!(residency.prefetch_hits > 0, "{residency:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn failed_fit_removes_the_trace_csv() {
    use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
    use dsfacto::coordinator::run_experiment;

    let dir = scratch_dir("trace_abort");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 41);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        strategy: RowStrategy::Contiguous,
        shards: 2,
        chunk_rows: 64,
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();

    // workers = 3 against a 2-shard cache: the plan is refused at fit
    // time, after the trace CSV was created — the error path must not
    // leak a header-only file that looks like a finished series.
    let trace_path = dir.join("trace.csv");
    let cfg = ExperimentConfig {
        dataset: DatasetSpec::Cache {
            dir: cache_dir.to_str().unwrap().to_string(),
        },
        trainer: TrainerKind::Dsgd,
        workers: 3,
        outer_iters: 2,
        train_frac: 1.0,
        trace_path: Some(trace_path.to_str().unwrap().to_string()),
        ..Default::default()
    };
    let err = run_experiment(&cfg).expect_err("mismatched plan must fail");
    assert!(format!("{err:#}").contains("re-ingest"), "{err:#}");
    assert!(
        !trace_path.exists(),
        "failed run left a partial trace CSV behind"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// The seam accepts caller-provided sources (embedding surface).

#[derive(Debug)]
struct CountingSource {
    inner: ShardCacheSource,
    loads: std::sync::atomic::AtomicUsize,
}

impl DataSource for CountingSource {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn d(&self) -> usize {
        self.inner.d()
    }
    fn nnz(&self) -> usize {
        self.inner.nnz()
    }
    fn task(&self) -> Task {
        self.inner.task()
    }
    fn plan(
        &self,
        strategy: RowStrategy,
        p: usize,
    ) -> anyhow::Result<dsfacto::partition::RowPartition> {
        self.inner.plan(strategy, p)
    }
    fn shard(
        &self,
        part: &dsfacto::partition::RowPartition,
        id: usize,
    ) -> anyhow::Result<dsfacto::partition::Shard> {
        self.loads.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.shard(part, id)
    }
    fn materialize(&self) -> anyhow::Result<Dataset> {
        self.inner.materialize()
    }
}

#[test]
fn custom_source_sees_exactly_one_load_per_worker_shard() {
    let dir = scratch_dir("custom");
    let (path, parsed) = twin_file_and_parsed(&dir, "housing", 29);
    let cache_dir = dir.join("cache");
    let opts = IngestOptions {
        task: parsed.task,
        n_features: Some(parsed.d()),
        shards: 4,
        ..Default::default()
    };
    libsvm::stream_ingest(&path, "housing", &opts, &cache_dir).unwrap();
    let counting = Arc::new(CountingSource {
        inner: ShardCacheSource::open(&cache_dir).unwrap(),
        loads: std::sync::atomic::AtomicUsize::new(0),
    });
    let cfg = BulkSyncConfig {
        iters: 3,
        workers: 4,
        eta: LrSchedule::Constant(0.05),
        source: ShardSource::Custom(counting.clone()),
        ..Default::default()
    };
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let (out, stats) = bulksync_train_with_stats(&parsed, None, &fm, &cfg, &mut ()).unwrap();
    assert!(out.model.w0.is_finite());
    assert_eq!(stats.shard_nnz.len(), 4);
    // Shards are built once per run — one load per worker, not per iter.
    assert_eq!(counting.loads.load(std::sync::atomic::Ordering::Relaxed), 4);
    std::fs::remove_dir_all(&dir).ok();
}
