//! Conformance for the lane-padded in-memory token layout: the engine
//! circulates `ncols x padded_k(k)` factor payloads, but the **wire format
//! is the K-strided one and is unchanged** — `encode_token_padded` must
//! produce byte-identical frames to the plain codec on the unpadded twin,
//! and `decode_token_padded` must reconstruct the padded payload
//! losslessly (zero padding lanes included). `codec_conformance.rs`
//! continues to pin the plain K-strided codec itself, untouched.

use dsfacto::cluster::codec::{
    decode_token, decode_token_padded, encode_token, encode_token_padded,
    padded_token_wire_size, token_wire_size,
};
use dsfacto::kernel::{padded_k, LANES};
use dsfacto::nomad::token::{Phase, Token, BIAS};
use dsfacto::util::prop::forall_res;
use dsfacto::util::rng::Pcg64;

/// A random engine-style token in both layouts: lane-padded (as the
/// engine circulates it) and K-strided (its wire twin). Bias tokens are
/// identical in both layouts.
fn random_token_pair(rng: &mut Pcg64) -> (Token, Token, usize) {
    if rng.chance(0.2) {
        let bias = Token {
            j: BIAS,
            iter: rng.next_u32() % 1000,
            phase: if rng.chance(0.5) {
                Phase::Update
            } else {
                Phase::Recompute
            },
            visits: (rng.next_u32() % 64) as u16,
            w: Box::from([rng.normal32(0.0, 10.0)]),
            v: Box::from([]),
        };
        let k = 1 + rng.below_usize(16);
        return (bias.clone(), bias, k);
    }
    let ncols = 1 + rng.below_usize(8);
    let k = 1 + rng.below_usize(16);
    let kp = padded_k(k);
    let mut v_pad = vec![0f32; ncols * kp];
    let mut v_flat = vec![0f32; ncols * k];
    for bi in 0..ncols {
        for kk in 0..k {
            let x = rng.normal32(0.0, 1.0);
            v_pad[bi * kp + kk] = x;
            v_flat[bi * k + kk] = x;
        }
    }
    let padded = Token {
        j: rng.next_u32() % (1 << 24),
        iter: rng.next_u32() % 1000,
        phase: if rng.chance(0.5) {
            Phase::Update
        } else {
            Phase::Recompute
        },
        visits: (rng.next_u32() % 64) as u16,
        w: (0..ncols).map(|_| rng.normal32(0.0, 10.0)).collect(),
        v: v_pad.into_boxed_slice(),
    };
    let stripped = Token {
        v: v_flat.into_boxed_slice(),
        ..padded.clone()
    };
    (padded, stripped, k)
}

/// Acceptance criterion: padded in-memory tokens encode/decode through
/// the K-strided wire form losslessly, and that wire form is
/// byte-identical to the pre-padding codec on the stripped twin.
#[test]
fn prop_padded_tokens_roundtrip_through_k_strided_wire() {
    forall_res(
        "padded token wire roundtrip",
        128,
        random_token_pair,
        |(padded, stripped, k)| {
            let mut wire = Vec::new();
            encode_token_padded(padded, *k, &mut wire);
            // 1. The wire format is unchanged: identical bytes to the
            //    plain codec on the K-strided twin.
            let mut plain = Vec::new();
            encode_token(stripped, &mut plain);
            if wire != plain {
                return Err("padded encode changed the wire bytes".to_string());
            }
            if wire.len() != padded_token_wire_size(padded, *k) {
                return Err(format!(
                    "wire {} bytes, padded_token_wire_size says {}",
                    wire.len(),
                    padded_token_wire_size(padded, *k)
                ));
            }
            if wire.len() != token_wire_size(stripped) {
                return Err("padded wire size disagrees with the plain size".to_string());
            }
            // 2. Lossless round-trip back into the padded layout.
            let back = decode_token_padded(&wire).map_err(|e| format!("{e:#}"))?;
            if back != *padded {
                return Err(format!("padded roundtrip lost data: {back:?} != {padded:?}"));
            }
            // 3. The plain decoder still sees the K-strided token.
            let flat = decode_token(&wire).map_err(|e| format!("{e:#}"))?;
            if flat != *stripped {
                return Err("plain decode no longer matches the stripped twin".to_string());
            }
            Ok(())
        },
    );
}

/// When K is already a lane multiple the two layouts coincide and the
/// padded entry points must behave exactly like the plain codec.
#[test]
fn lane_multiple_k_is_identity() {
    for k in [LANES, 2 * LANES] {
        let tok = Token {
            j: 5,
            iter: 3,
            phase: Phase::Recompute,
            visits: 1,
            w: Box::from([1.0f32, -2.0]),
            v: (0..2 * k).map(|i| i as f32 * 0.5).collect(),
        };
        let mut a = Vec::new();
        encode_token_padded(&tok, k, &mut a);
        let mut b = Vec::new();
        encode_token(&tok, &mut b);
        assert_eq!(a, b, "k={k}");
        assert_eq!(decode_token_padded(&a).unwrap(), tok, "k={k}");
    }
}

/// Decoded padding lanes are exactly zero — the invariant every
/// lane-blocked kernel relies on survives a wire hop.
#[test]
fn decoded_padding_lanes_are_exactly_zero() {
    let mut rng = Pcg64::seeded(31);
    for _ in 0..50 {
        let (padded, _, k) = random_token_pair(&mut rng);
        if padded.is_bias() {
            continue;
        }
        let kp = padded_k(k);
        let mut wire = Vec::new();
        encode_token_padded(&padded, k, &mut wire);
        let back = decode_token_padded(&wire).unwrap();
        for bi in 0..back.ncols() {
            let row = back.vrow(bi, kp);
            assert!(
                row[k..].iter().all(|&x| x.to_bits() == 0),
                "non-zero padding after decode (k={k})"
            );
        }
    }
}
