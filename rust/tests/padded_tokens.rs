//! Conformance for the lane-padded in-memory token layout: the engine
//! circulates `ncols x padded_k(k)` factor payloads, but the **wire format
//! is the K-strided one and is unchanged** — `encode_token_padded` must
//! produce byte-identical frames to the plain codec on the unpadded twin,
//! and `decode_token_padded` must reconstruct the padded payload
//! losslessly (zero padding lanes included). `codec_conformance.rs`
//! continues to pin the plain K-strided codec itself, untouched.

use dsfacto::cluster::codec::{
    bf16_to_f32, decode_token, decode_token_bf16, decode_token_padded, encode_token,
    encode_token_bf16, encode_token_padded, f32_to_bf16, padded_token_wire_size,
    token_wire_size, token_wire_size_bf16,
};
use dsfacto::kernel::{padded_k, LANES};
use dsfacto::nomad::token::{Phase, Token, BIAS};
use dsfacto::util::prop::forall_res;
use dsfacto::util::rng::Pcg64;

/// A random engine-style token in both layouts: lane-padded (as the
/// engine circulates it) and K-strided (its wire twin). Bias tokens are
/// identical in both layouts.
fn random_token_pair(rng: &mut Pcg64) -> (Token, Token, usize) {
    if rng.chance(0.2) {
        let bias = Token {
            j: BIAS,
            iter: rng.next_u32() % 1000,
            phase: if rng.chance(0.5) {
                Phase::Update
            } else {
                Phase::Recompute
            },
            visits: (rng.next_u32() % 64) as u16,
            w: Box::from([rng.normal32(0.0, 10.0)]),
            v: Box::from([]),
        };
        let k = 1 + rng.below_usize(16);
        return (bias.clone(), bias, k);
    }
    let ncols = 1 + rng.below_usize(8);
    let k = 1 + rng.below_usize(16);
    let kp = padded_k(k);
    let mut v_pad = vec![0f32; ncols * kp];
    let mut v_flat = vec![0f32; ncols * k];
    for bi in 0..ncols {
        for kk in 0..k {
            let x = rng.normal32(0.0, 1.0);
            v_pad[bi * kp + kk] = x;
            v_flat[bi * k + kk] = x;
        }
    }
    let padded = Token {
        j: rng.next_u32() % (1 << 24),
        iter: rng.next_u32() % 1000,
        phase: if rng.chance(0.5) {
            Phase::Update
        } else {
            Phase::Recompute
        },
        visits: (rng.next_u32() % 64) as u16,
        w: (0..ncols).map(|_| rng.normal32(0.0, 10.0)).collect(),
        v: v_pad.into_boxed_slice(),
    };
    let stripped = Token {
        v: v_flat.into_boxed_slice(),
        ..padded.clone()
    };
    (padded, stripped, k)
}

/// Acceptance criterion: padded in-memory tokens encode/decode through
/// the K-strided wire form losslessly, and that wire form is
/// byte-identical to the pre-padding codec on the stripped twin.
#[test]
fn prop_padded_tokens_roundtrip_through_k_strided_wire() {
    forall_res(
        "padded token wire roundtrip",
        128,
        random_token_pair,
        |(padded, stripped, k)| {
            let mut wire = Vec::new();
            encode_token_padded(padded, *k, &mut wire);
            // 1. The wire format is unchanged: identical bytes to the
            //    plain codec on the K-strided twin.
            let mut plain = Vec::new();
            encode_token(stripped, &mut plain);
            if wire != plain {
                return Err("padded encode changed the wire bytes".to_string());
            }
            if wire.len() != padded_token_wire_size(padded, *k) {
                return Err(format!(
                    "wire {} bytes, padded_token_wire_size says {}",
                    wire.len(),
                    padded_token_wire_size(padded, *k)
                ));
            }
            if wire.len() != token_wire_size(stripped) {
                return Err("padded wire size disagrees with the plain size".to_string());
            }
            // 2. Lossless round-trip back into the padded layout.
            let back = decode_token_padded(&wire).map_err(|e| format!("{e:#}"))?;
            if back != *padded {
                return Err(format!("padded roundtrip lost data: {back:?} != {padded:?}"));
            }
            // 3. The plain decoder still sees the K-strided token.
            let flat = decode_token(&wire).map_err(|e| format!("{e:#}"))?;
            if flat != *stripped {
                return Err("plain decode no longer matches the stripped twin".to_string());
            }
            Ok(())
        },
    );
}

/// When K is already a lane multiple the two layouts coincide and the
/// padded entry points must behave exactly like the plain codec.
#[test]
fn lane_multiple_k_is_identity() {
    for k in [LANES, 2 * LANES] {
        let tok = Token {
            j: 5,
            iter: 3,
            phase: Phase::Recompute,
            visits: 1,
            w: Box::from([1.0f32, -2.0]),
            v: (0..2 * k).map(|i| i as f32 * 0.5).collect(),
        };
        let mut a = Vec::new();
        encode_token_padded(&tok, k, &mut a);
        let mut b = Vec::new();
        encode_token(&tok, &mut b);
        assert_eq!(a, b, "k={k}");
        assert_eq!(decode_token_padded(&a).unwrap(), tok, "k={k}");
    }
}

/// Acceptance criterion for the bf16 wire (`wire_precision = bf16`):
/// every circulated value comes back as exactly
/// `bf16_to_f32(f32_to_bf16(x))` — i.e. the wire adds *only* the RNE
/// rounding to 8 significand bits, never extra drift — which for the
/// generator's finite values bounds the relative error by 2^-8. Headers,
/// lengths, and the zero-padding invariant survive unchanged, and the
/// frame is the size `token_wire_size_bf16` promises.
#[test]
fn prop_bf16_tokens_roundtrip_within_bf16_rounding() {
    forall_res(
        "bf16 token wire roundtrip",
        128,
        random_token_pair,
        |(padded, _stripped, k)| {
            let mut wire = Vec::new();
            encode_token_bf16(padded, *k, &mut wire);
            if wire.len() != token_wire_size_bf16(padded, *k) {
                return Err(format!(
                    "wire {} bytes, token_wire_size_bf16 says {}",
                    wire.len(),
                    token_wire_size_bf16(padded, *k)
                ));
            }
            let back = decode_token_bf16(&wire).map_err(|e| format!("{e:#}"))?;
            if (back.j, back.iter, back.phase, back.visits)
                != (padded.j, padded.iter, padded.phase, padded.visits)
            {
                return Err("bf16 roundtrip corrupted the header".to_string());
            }
            if back.w.len() != padded.w.len() || back.v.len() != padded.v.len() {
                return Err("bf16 roundtrip changed the payload shape".to_string());
            }
            let w_pairs = back.w.iter().zip(padded.w.iter());
            let v_pairs = back.v.iter().zip(padded.v.iter());
            for (got, want) in w_pairs.chain(v_pairs) {
                let expect = bf16_to_f32(f32_to_bf16(*want));
                if got.to_bits() != expect.to_bits() {
                    return Err(format!(
                        "bf16 wire is not pure RNE rounding: {want} -> {got}, expected {expect}"
                    ));
                }
                if (got - want).abs() > want.abs() / 256.0 {
                    return Err(format!("bf16 relative error above 2^-8: {want} -> {got}"));
                }
            }
            if !back.is_bias() {
                let kp = padded_k(*k);
                for bi in 0..back.ncols() {
                    if back.vrow(bi, kp)[*k..].iter().any(|&x| x.to_bits() != 0) {
                        return Err(format!("non-zero padding after bf16 decode (k={k})"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Values whose significand already fits in bf16's 8 bits — plus the
/// signed zeros and infinities — cross the bf16 wire bit-exactly, and a
/// NaN stays a NaN (its payload truncates to the top 7 mantissa bits, it
/// never collapses to a number).
#[test]
fn bf16_exact_values_and_specials_survive_the_wire() {
    let exact = [
        0.0f32,
        -0.0,
        1.0,
        -1.0,
        0.5,
        -0.375,
        2.0,
        96.0,
        -65536.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
    ];
    let k = 5usize; // not a lane multiple, so padding is exercised
    let kp = padded_k(k);
    let ncols = exact.len().div_ceil(k);
    let mut v = vec![0f32; ncols * kp];
    for (i, &x) in exact.iter().enumerate() {
        v[(i / k) * kp + i % k] = x;
    }
    let tok = Token {
        j: 9,
        iter: 2,
        phase: Phase::Update,
        visits: 1,
        w: (0..ncols).map(|c| exact[c % exact.len()]).collect(),
        v: v.into_boxed_slice(),
    };
    let mut wire = Vec::new();
    encode_token_bf16(&tok, k, &mut wire);
    let back = decode_token_bf16(&wire).unwrap();
    for (got, want) in back.w.iter().zip(tok.w.iter()).chain(back.v.iter().zip(tok.v.iter())) {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "bf16-exact value {want} changed on the wire"
        );
    }

    let nan_tok = Token {
        j: BIAS,
        iter: 0,
        phase: Phase::Recompute,
        visits: 0,
        w: Box::from([f32::NAN]),
        v: Box::from([]),
    };
    let mut wire = Vec::new();
    encode_token_bf16(&nan_tok, k, &mut wire);
    let back = decode_token_bf16(&wire).unwrap();
    assert!(back.w[0].is_nan(), "NaN collapsed to {} on the bf16 wire", back.w[0]);
}

/// Decoded padding lanes are exactly zero — the invariant every
/// lane-blocked kernel relies on survives a wire hop.
#[test]
fn decoded_padding_lanes_are_exactly_zero() {
    let mut rng = Pcg64::seeded(31);
    for _ in 0..50 {
        let (padded, _, k) = random_token_pair(&mut rng);
        if padded.is_bias() {
            continue;
        }
        let kp = padded_k(k);
        let mut wire = Vec::new();
        encode_token_padded(&padded, k, &mut wire);
        let back = decode_token_padded(&wire).unwrap();
        for bi in 0..back.ncols() {
            let row = back.vrow(bi, kp);
            assert!(
                row[k..].iter().all(|&x| x.to_bits() == 0),
                "non-zero padding after decode (k={k})"
            );
        }
    }
}
