//! Property tests on the partition subsystem (`dsfacto::partition`) and
//! its contract with the three distributed trainers:
//!
//! * structural invariants — every row / column covered exactly once
//!   under both strategies, the nnz-balance bound, the `GridPlan`
//!   stratum schedule;
//! * **contiguous-default bitwise parity** — with
//!   `row_partition = contiguous` DSGD and bulk-sync reproduce
//!   pre-refactor reference implementations bit for bit (the NOMAD
//!   engine's parity is pinned by
//!   `engine_properties::padded_engine_matches_scalar_reference_bitwise`,
//!   which replays the P = 1 schedule independently of the partition
//!   layer; here we additionally pin that P = 1 balanced degenerates to
//!   the identical single-shard run);
//! * `row_partition = balanced` — convergence-quality properties on
//!   nnz-skewed data, including the realsim synthetic twin.

use dsfacto::baseline::bulksync::{partial_gradient_rows, GradBuf};
use dsfacto::baseline::{
    bulksync_train_with_stats, dsgd_train_with_stats, BulkSyncConfig, DsgdConfig,
};
use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::data::{synth, Dataset};
use dsfacto::fm::{loss, FmHyper, FmModel};
use dsfacto::kernel::{visit, FmKernel, Scratch};
use dsfacto::nomad::{train_with_stats, NomadConfig};
use dsfacto::optim::LrSchedule;
use dsfacto::partition::{ColPartition, GridPlan, PartitionStats, RowPartition, RowStrategy};
use dsfacto::train::Trainer;
use dsfacto::util::prop::{forall_res, random_csr};
use dsfacto::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Structural invariants.

#[test]
fn prop_every_row_in_exactly_one_shard() {
    forall_res(
        "both strategies tile the rows",
        48,
        |rng| {
            let m = random_csr(rng, 48, 12);
            let p = 1 + rng.below_usize(8);
            (m, p)
        },
        |(m, p)| {
            for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
                let part = RowPartition::new(strat, m, *p);
                part.validate().map_err(|e| format!("{strat:?}: {e:#}"))?;
                let mut covered = 0usize;
                for (b, &(s, e)) in part.bounds().iter().enumerate() {
                    if b > 0 && part.bounds()[b - 1].1 != s {
                        return Err(format!("{strat:?}: shard {b} not contiguous"));
                    }
                    covered += e - s;
                }
                if covered != m.n_rows() {
                    return Err(format!("{strat:?}: covered {covered} of {}", m.n_rows()));
                }
                let nnz: usize = part.shard_nnz(m).iter().sum();
                if nnz != m.nnz() {
                    return Err(format!("{strat:?}: shard nnz sums to {nnz} != {}", m.nnz()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_balanced_max_shard_nnz_bounded_by_contiguous() {
    forall_res(
        "balanced max shard nnz <= contiguous max",
        48,
        |rng| {
            let m = random_csr(rng, 48, 12);
            let p = 1 + rng.below_usize(8);
            (m, p)
        },
        |(m, p)| {
            let max = |part: &RowPartition| part.shard_nnz(m).into_iter().max().unwrap_or(0);
            let mc = max(&RowPartition::contiguous(m.n_rows(), *p));
            let mb = max(&RowPartition::nnz_balanced(m, *p));
            if mb > mc {
                return Err(format!("balanced {mb} > contiguous {mc}"));
            }
            Ok(())
        },
    );
}

#[test]
fn grid_plan_covers_every_cell_once_per_epoch() {
    // DSGD's grid (shards == blocks) plus ragged grids.
    for (shards, blocks) in [(4usize, 4usize), (3, 3), (2, 5), (5, 2), (1, 1)] {
        let plan = GridPlan::new(shards, blocks);
        assert_eq!(plan.n_subepochs(), blocks);
        let mut hits = vec![vec![0u32; blocks]; shards];
        for sub in 0..plan.n_subepochs() {
            let mut in_flight = vec![false; blocks];
            for w in 0..shards {
                let b = plan.block_for(w, sub);
                hits[w][b] += 1;
                // Block-diagonal within a sub-epoch (no two shards on the
                // same block) whenever shards <= blocks — DSGD's case.
                if shards <= blocks {
                    assert!(!in_flight[b], "collision at sub {sub} block {b}");
                    in_flight[b] = true;
                }
            }
        }
        for row in &hits {
            assert!(row.iter().all(|&c| c == 1), "{shards}x{blocks}: {hits:?}");
        }
    }
    // The column side tiles D exactly (absorbs dsgd's column_bounds and
    // the engine's token blocks).
    for (d, nb) in [(13usize, 4usize), (5, 8), (1, 1)] {
        let cp = ColPartition::with_n_blocks(d, nb);
        let mut covered = vec![0u32; d];
        for b in 0..cp.n_blocks() {
            let (lo, hi) = cp.block_range(b);
            for cnt in &mut covered[lo..hi] {
                *cnt += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "d={d} nb={nb}");
    }
}

// ---------------------------------------------------------------------
// Contiguous-default bitwise parity: pre-refactor references.

/// The pre-refactor DSGD loop, replayed sequentially: contiguous row
/// chunks, `column_bounds` blocks, exact G/A barrier per sub-epoch
/// through the fused kernel (K-strided), per-column updates through the
/// K-strided scalar oracle `visit::scalar::col_update` — byte-for-byte
/// the operations `baseline::dsgd` ran before the partition layer.
fn dsgd_reference(train: &Dataset, fm: &FmHyper, cfg: &DsgdConfig) -> FmModel {
    let p = cfg.workers.max(1).min(train.d().max(1));
    let n = train.n();
    let d = train.d();
    let k = fm.k;
    let mut rng = Pcg64::new(cfg.seed, 0xd5fd);
    let mut model = FmModel::init(d, k, fm.init_std, &mut rng);

    let row_chunk = n.div_ceil(p);
    let blocks: Vec<(usize, usize, dsfacto::data::Csc)> = (0..p)
        .map(|b| {
            let start = (b * row_chunk).min(n);
            let end = ((b + 1) * row_chunk).min(n);
            (start, end, train.rows.slice_rows(start, end).to_csc())
        })
        .collect();
    let col_chunk = d.div_ceil(p);
    let bounds: Vec<usize> = (0..=p).map(|b| (b * col_chunk).min(d)).collect();

    for epoch in 0..cfg.epochs {
        let eta = cfg.eta.at(epoch);
        for sub in 0..p {
            // Barrier: exact multipliers + factor sums of this iterate.
            let kern = FmKernel::from_model(&model);
            let mut scratch = Scratch::for_k(k);
            let mut g_all = vec![0f32; n];
            let mut a_all = vec![0f32; n * k];
            for i in 0..n {
                let (idx, val) = train.rows.row(i);
                let ai = &mut a_all[i * k..(i + 1) * k];
                let f = kern.score_with_sums(idx, val, ai, &mut scratch);
                g_all[i] = loss::multiplier(f, train.labels[i], train.task);
            }
            // Block-diagonal updates against the frozen G/A.
            let mut deltas = Vec::with_capacity(p);
            let mut gv = vec![0f32; k];
            for (wid, (start, end, cols)) in blocks.iter().enumerate() {
                let cb = (wid + sub) % p;
                let (lo, hi) = (bounds[cb], bounds[cb + 1]);
                let mut w = model.w[lo..hi].to_vec();
                let mut v = model.v[lo * k..hi * k].to_vec();
                let h = visit::VisitHyper {
                    eta,
                    inv_n: 1.0 / n.max(1) as f32,
                    lambda_w: fm.lambda_w,
                    lambda_v: fm.lambda_v,
                    reg_split: 1.0 / p.max(1) as f32,
                };
                for j in lo..hi {
                    let (rows, xs) = cols.col(j);
                    visit::scalar::col_update(
                        rows,
                        xs,
                        &g_all[*start..*end],
                        &a_all[start * k..end * k],
                        k,
                        &mut w[j - lo],
                        &mut v[(j - lo) * k..(j - lo + 1) * k],
                        h,
                        &mut gv,
                    );
                }
                let mut g_sum = 0f64;
                for &gi in &g_all[*start..*end] {
                    g_sum += gi as f64;
                }
                deltas.push((cb, w, v, g_sum, end - start));
            }
            let mut g_total = 0f64;
            let mut rows_total = 0usize;
            for (cb, w, v, g_sum, nr) in deltas {
                let (lo, hi) = (bounds[cb], bounds[cb + 1]);
                model.w[lo..hi].copy_from_slice(&w);
                model.v[lo * k..hi * k].copy_from_slice(&v);
                g_total += g_sum;
                rows_total += nr;
            }
            if rows_total > 0 {
                model.w0 -= eta * (g_total / rows_total as f64) as f32;
            }
        }
    }
    model
}

fn assert_models_bitwise(a: &FmModel, b: &FmModel, what: &str) {
    assert_eq!(a.w0.to_bits(), b.w0.to_bits(), "{what}: w0");
    for (j, (x, y)) in a.w.iter().zip(&b.w).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: w[{j}]");
    }
    for (q, (x, y)) in a.v.iter().zip(&b.v).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: v[{q}]");
    }
}

#[test]
fn dsgd_contiguous_matches_prerefactor_reference_bitwise() {
    let ds = synth::table2_dataset("housing", 21).unwrap(); // d = 13
    for &(k, workers) in &[(4usize, 3usize), (5, 4), (8, 1)] {
        let fm = FmHyper {
            k,
            ..Default::default()
        };
        let cfg = DsgdConfig {
            epochs: 4,
            eta: LrSchedule::Constant(0.5),
            workers,
            seed: 77,
            eval_every: usize::MAX,
            row_partition: RowStrategy::Contiguous,
            ..Default::default()
        };
        let (out, stats) = dsgd_train_with_stats(&ds, None, &fm, &cfg, &mut ()).unwrap();
        let reference = dsgd_reference(&ds, &fm, &cfg);
        assert_models_bitwise(&out.model, &reference, &format!("dsgd k={k} p={workers}"));
        assert_eq!(stats.shard_nnz.iter().sum::<usize>(), ds.nnz());
    }
}

/// The pre-refactor bulk-sync loop: row-major per-worker partial
/// gradients over `n.div_ceil(workers)` chunks (the oracle
/// `partial_gradient_rows`), merged in worker order, one deterministic
/// step per iteration.
fn bulksync_reference(train: &Dataset, fm: &FmHyper, cfg: &BulkSyncConfig) -> FmModel {
    let workers = cfg.workers.max(1).min(train.n().max(1));
    let mut rng = Pcg64::new(cfg.seed, 0xb51c);
    let mut model = FmModel::init(train.d(), fm.k, fm.init_std, &mut rng);
    let n = train.n();
    let chunk = n.div_ceil(workers);
    for t in 0..cfg.iters {
        let kern = FmKernel::from_model(&model);
        let mut total = GradBuf::zeros(model.d, model.k);
        for p in 0..workers {
            let start = (p * chunk).min(n);
            let end = ((p + 1) * chunk).min(n);
            total.merge(&partial_gradient_rows(&kern, train, start, end));
        }
        let lr = cfg.eta.at(t);
        let inv_n = 1.0 / n as f64;
        model.w0 -= lr * (total.g0 * inv_n) as f32;
        for j in 0..model.d {
            let g = (total.gw[j] * inv_n) as f32 + fm.lambda_w * model.w[j];
            model.w[j] -= lr * g;
        }
        for q in 0..model.v.len() {
            let g = (total.gv[q] * inv_n) as f32 + fm.lambda_v * model.v[q];
            model.v[q] -= lr * g;
        }
    }
    model
}

#[test]
fn bulksync_contiguous_matches_prerefactor_reference_bitwise() {
    let ds = synth::table2_dataset("housing", 31).unwrap();
    for &(k, workers) in &[(4usize, 4usize), (7, 3), (2, 1)] {
        let fm = FmHyper {
            k,
            ..Default::default()
        };
        let cfg = BulkSyncConfig {
            iters: 5,
            eta: LrSchedule::Constant(0.05),
            workers,
            seed: 13,
            eval_every: usize::MAX,
            row_partition: RowStrategy::Contiguous,
            ..Default::default()
        };
        let (out, _) = bulksync_train_with_stats(&ds, None, &fm, &cfg, &mut ()).unwrap();
        let reference = bulksync_reference(&ds, &fm, &cfg);
        assert_models_bitwise(&out.model, &reference, &format!("bulksync k={k} p={workers}"));
    }
}

#[test]
fn nomad_single_worker_balanced_degenerates_to_contiguous_bitwise() {
    // With P = 1 every strategy yields the same single shard, so the
    // (deterministic) engine run must be bit-identical across strategies.
    let ds = synth::table2_dataset("housing", 41).unwrap();
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let run = |strat| {
        let cfg = NomadConfig {
            workers: 1,
            outer_iters: 4,
            eta: LrSchedule::Constant(0.5),
            seed: 7,
            eval_every: usize::MAX,
            cols_per_token: 3,
            row_partition: strat,
            ..Default::default()
        };
        train_with_stats(&ds, None, &fm, &cfg).unwrap().0.model
    };
    let cont = run(RowStrategy::Contiguous);
    let bal = run(RowStrategy::NnzBalanced);
    assert_models_bitwise(&cont, &bal, "nomad P=1");
}

// ---------------------------------------------------------------------
// Balanced mode: quality properties on nnz-skewed data.

/// Rows reordered by descending nnz: a front-loaded dataset on which the
/// contiguous split is maximally imbalanced while the greedy prefix
/// split equalizes.
fn front_loaded(ds: &Dataset) -> Dataset {
    let mut idx: Vec<usize> = (0..ds.n()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(ds.rows.row_nnz(i)));
    ds.subset(&idx, "skewed")
}

fn mini_skewed() -> Dataset {
    let spec = synth::SynthSpec {
        name: "realsim-mini".into(),
        task: dsfacto::data::Task::Classification,
        n: 400,
        d: 600,
        k: 4,
        density: 0.03,
        factor_scale: 0.2,
        noise: 0.4,
        skew: 1.1,
    };
    front_loaded(&synth::generate(&spec, 4242).dataset)
}

#[test]
fn balanced_reduces_imbalance_on_skewed_rows() {
    let ds = mini_skewed();
    let cont = PartitionStats::from_plan(&RowPartition::contiguous(ds.n(), 4), &ds.rows);
    let bal = PartitionStats::from_plan(&RowPartition::nnz_balanced(&ds.rows, 4), &ds.rows);
    assert!(bal.imbalance >= 1.0 - 1e-12);
    assert!(
        bal.imbalance <= cont.imbalance + 1e-12,
        "balanced {} vs contiguous {}",
        bal.imbalance,
        cont.imbalance
    );
    // Front-loaded rows: contiguous must be measurably imbalanced and
    // the greedy split must actually help (unless already perfect).
    assert!(
        bal.imbalance < cont.imbalance || (cont.imbalance - 1.0).abs() < 1e-6,
        "balanced {} did not improve on contiguous {}",
        bal.imbalance,
        cont.imbalance
    );
}

#[test]
fn balanced_dsgd_reaches_contiguous_quality_on_skewed_rows() {
    let ds = mini_skewed();
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let run = |strat| {
        let cfg = DsgdConfig {
            epochs: 15,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            seed: 5,
            eval_every: usize::MAX,
            row_partition: strat,
            ..Default::default()
        };
        dsgd_train_with_stats(&ds, None, &fm, &cfg, &mut ()).unwrap().0
    };
    let cont = run(RowStrategy::Contiguous);
    let bal = run(RowStrategy::NnzBalanced);
    let (c0, c1) = (cont.trace[0].objective, cont.trace.last().unwrap().objective);
    let (b0, b1) = (bal.trace[0].objective, bal.trace.last().unwrap().objective);
    assert!(b1.is_finite() && b1 < 0.95 * b0, "balanced dsgd: {b0} -> {b1}");
    assert!(c1 < 0.95 * c0, "contiguous dsgd: {c0} -> {c1}");
    // Different stratum boundaries, same optimization problem: final
    // quality must land in the same basin.
    assert!(
        (b1 - c1).abs() < 0.35 * c1.max(0.05),
        "balanced {b1} vs contiguous {c1}"
    );
}

#[test]
fn balanced_bulksync_matches_contiguous_gradient() {
    // Bulk-sync computes the exact batch gradient; the partition only
    // changes f64 merge grouping, so results must agree very tightly.
    let ds = mini_skewed();
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let run = |strat| {
        let cfg = BulkSyncConfig {
            iters: 10,
            eta: LrSchedule::Constant(0.1),
            workers: 4,
            seed: 6,
            eval_every: usize::MAX,
            row_partition: strat,
            ..Default::default()
        };
        bulksync_train_with_stats(&ds, None, &fm, &cfg, &mut ()).unwrap().0
    };
    let cont = run(RowStrategy::Contiguous);
    let bal = run(RowStrategy::NnzBalanced);
    let (c, b) = (
        cont.trace.last().unwrap().objective,
        bal.trace.last().unwrap().objective,
    );
    assert!(c.is_finite() && b.is_finite());
    assert!((c - b).abs() < 1e-4 * (1.0 + c.abs()), "{c} vs {b}");
}

#[test]
fn balanced_nomad_reaches_contiguous_quality_on_skewed_rows() {
    let ds = mini_skewed();
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let run = |strat| {
        let cfg = NomadConfig {
            workers: 4,
            outer_iters: 15,
            eta: LrSchedule::Constant(0.5),
            seed: 9,
            eval_every: usize::MAX,
            row_partition: strat,
            ..Default::default()
        };
        train_with_stats(&ds, None, &fm, &cfg).unwrap()
    };
    let (cont, cstats) = run(RowStrategy::Contiguous);
    let (bal, bstats) = run(RowStrategy::NnzBalanced);
    assert!(bstats.partition.imbalance <= cstats.partition.imbalance + 1e-12);
    assert_eq!(bstats.partition.shard_nnz.iter().sum::<usize>(), ds.nnz());
    let (c0, c1) = (cont.trace[0].objective, cont.trace.last().unwrap().objective);
    let (b0, b1) = (bal.trace[0].objective, bal.trace.last().unwrap().objective);
    assert!(c1 < 0.9 * c0, "contiguous nomad: {c0} -> {c1}");
    assert!(b1.is_finite() && b1 < 0.9 * b0, "balanced nomad: {b0} -> {b1}");
    assert!(
        (b1 - c1).abs() < 0.35 * c1.max(0.05),
        "balanced {b1} vs contiguous {c1}"
    );
}

#[test]
fn balanced_nomad_runs_on_realsim_twin() {
    // The acceptance target: the skewed realsim synthetic twin (Zipf-1.1
    // feature popularity, D = 20,958), shrunk to a testable row count.
    let spec = synth::SynthSpec {
        n: 1200,
        ..synth::SynthSpec::table2("realsim").unwrap()
    };
    let ds = synth::generate(&spec, 99).dataset;
    let fm = FmHyper {
        k: 16,
        init_std: 0.05,
        ..Default::default()
    };
    let cfg = NomadConfig {
        workers: 4,
        outer_iters: 2,
        eta: LrSchedule::Constant(0.5),
        seed: 3,
        eval_every: usize::MAX,
        row_partition: RowStrategy::NnzBalanced,
        ..Default::default()
    };
    let (out, stats) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
    assert_eq!(out.trace.len(), 3);
    assert!(out.model.w0.is_finite());
    assert!(out.model.v.iter().all(|x| x.is_finite()));
    assert_eq!(stats.partition.shard_nnz.len(), 4);
    assert_eq!(stats.partition.shard_nnz.iter().sum::<usize>(), ds.nnz());
    assert!(stats.partition.imbalance >= 1.0 - 1e-12);
    let cont = PartitionStats::from_plan(&RowPartition::contiguous(ds.n(), 4), &ds.rows);
    assert!(stats.partition.imbalance <= cont.imbalance + 1e-12);
}

// ---------------------------------------------------------------------
// Config / session-API wiring.

#[test]
fn row_partition_key_reaches_trainers() {
    let ds = mini_skewed();
    let mut cfg = ExperimentConfig {
        dataset: DatasetSpec::Table2("housing".into()),
        trainer: TrainerKind::Dsgd,
        fm: FmHyper {
            k: 4,
            ..Default::default()
        },
        workers: 4,
        outer_iters: 2,
        eval_every: usize::MAX,
        ..Default::default()
    };
    cfg.set("row_partition", "balanced").unwrap();
    let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
    assert_eq!(back.row_partition, RowStrategy::NnzBalanced);

    let expected = RowPartition::nnz_balanced(&ds.rows, 4).shard_nnz(&ds.rows);
    for kind in [TrainerKind::Dsgd, TrainerKind::BulkSync, TrainerKind::Nomad] {
        cfg.trainer = kind;
        let trainer = cfg.trainer.build(&cfg);
        assert!(trainer.partition_stats().is_none(), "{kind:?} before fit");
        trainer.fit(&ds, None, &mut ()).unwrap();
        let pstats = trainer
            .partition_stats()
            .unwrap_or_else(|| panic!("{kind:?} reports no partition stats"));
        assert_eq!(pstats.shard_nnz, expected, "{kind:?}");
    }
    // Single-machine trainers have no row shards.
    cfg.trainer = TrainerKind::Libfm;
    let libfm = cfg.trainer.build(&cfg);
    libfm.fit(&ds, None, &mut ()).unwrap();
    assert!(libfm.partition_stats().is_none());
}
