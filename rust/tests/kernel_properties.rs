//! Oracle-backed property suite for the fused lane-blocked kernel
//! (`dsfacto::kernel`): scoring parity against the paper-literal eq. 2
//! double sum (`FmModel::score_naive`), update parity against the scalar
//! reference (`optim::sgd_update_example`), finite-difference gradient
//! checks, and scratch-arena reuse — across random K in 1..=64 (covering
//! both the scalar scorer's stack path, K <= 32, and its heap path),
//! random nnz including empty rows, and permuted index orders.
//!
//! Also holds the engine's column-visit kernels (`kernel::visit`) to the
//! scalar K-strided oracles in `kernel::visit::scalar` **bit for bit**
//! across the lane-boundary K grid, empty columns included.

use dsfacto::data::Task;
use dsfacto::fm::{loss, FmModel};
use dsfacto::kernel::visit::{self, VisitHyper};
use dsfacto::kernel::{padded_k, AdaGradLanes, FmKernel, Scratch, LANES};
use dsfacto::optim::{sgd_update_example, AdaGradState};
use dsfacto::util::prop::{forall_res, pad_rows, sparse_row};
use dsfacto::util::rng::Pcg64;

/// The K grid the engine visit-kernel parity suite sweeps: both sides of
/// every lane boundary that matters (1, 7 | 8 | 9, 31 | 32, 64).
const VISIT_KS: [usize; 7] = [1, 7, 8, 9, 31, 32, 64];

fn random_model(rng: &mut Pcg64, d: usize, k: usize) -> FmModel {
    let mut m = FmModel::init(d, k, 0.3, rng);
    for x in m.w.iter_mut() {
        *x = rng.normal32(0.0, 0.5);
    }
    m.w0 = rng.normal32(0.0, 0.5);
    m
}

/// Max relative parameter discrepancy between two same-shape models.
fn model_distance(a: &FmModel, b: &FmModel) -> f32 {
    let mut worst = (a.w0 - b.w0).abs() / (1.0 + b.w0.abs());
    for (x, y) in a.w.iter().zip(&b.w) {
        worst = worst.max((x - y).abs() / (1.0 + y.abs()));
    }
    for (x, y) in a.v.iter().zip(&b.v) {
        worst = worst.max((x - y).abs() / (1.0 + y.abs()));
    }
    worst
}

/// Acceptance criterion: fused scores match the eq. 2 oracle within 1e-4
/// relative error for K across 1..=64 (stack and heap scalar paths alike),
/// arbitrary nnz (including empty rows), duplicate-free sorted indices.
#[test]
fn prop_kernel_score_matches_naive_all_k() {
    forall_res(
        "fused kernel score equals naive pairwise oracle",
        96,
        |rng| {
            let d = 2 + rng.below_usize(22);
            let k = 1 + rng.below_usize(64);
            let m = random_model(rng, d, k);
            let nnz = rng.below_usize(d + 1); // 0 included: empty rows
            let (idx, val) = sparse_row(rng, d, nnz);
            (m, idx, val)
        },
        |(m, idx, val)| {
            let kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            let fused = kern.score(idx, val, &mut scratch);
            let naive = m.score_naive(idx, val);
            let scalar = m.score_sparse(idx, val);
            let tol = 1e-4 * (1.0 + naive.abs());
            if (fused - naive).abs() >= tol {
                return Err(format!("k={}: fused {fused} vs naive {naive}", m.k));
            }
            if (fused - scalar).abs() >= tol {
                return Err(format!("k={}: fused {fused} vs scalar {scalar}", m.k));
            }
            Ok(())
        },
    );
}

/// The score is invariant (to accumulation noise) under joint permutation
/// of the (index, value) pairs — the kernel must not rely on sortedness.
#[test]
fn prop_kernel_score_permutation_invariant() {
    forall_res(
        "kernel score invariant under index permutation",
        64,
        |rng| {
            let d = 2 + rng.below_usize(16);
            let k = 1 + rng.below_usize(24);
            let m = random_model(rng, d, k);
            let nnz = 1 + rng.below_usize(d);
            let (idx, val) = sparse_row(rng, d, nnz);
            // A joint shuffle of the pairs.
            let mut pairs: Vec<(u32, f32)> = idx.iter().cloned().zip(val.iter().cloned()).collect();
            rng.shuffle(&mut pairs);
            let (pidx, pval): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
            (m, idx, val, pidx, pval)
        },
        |(m, idx, val, pidx, pval)| {
            let kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            let sorted = kern.score(idx, val, &mut scratch);
            let shuffled = kern.score(pidx, pval, &mut scratch);
            let tol = 1e-4 * (1.0 + sorted.abs());
            if (sorted - shuffled).abs() < tol {
                Ok(())
            } else {
                Err(format!("sorted {sorted} vs shuffled {shuffled}"))
            }
        },
    );
}

/// The fused score+gradient+update step lands on the same parameters (and
/// loss) as the scalar three-pass reference, for random shapes, tasks,
/// step sizes and regularization.
#[test]
fn prop_fused_step_matches_scalar_update() {
    forall_res(
        "fused score_grad_step equals scalar sgd_update_example",
        96,
        |rng| {
            let d = 2 + rng.below_usize(16);
            let k = 1 + rng.below_usize(64);
            let m = random_model(rng, d, k);
            let nnz = 1 + rng.below_usize(d);
            let (idx, val) = sparse_row(rng, d, nnz);
            let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
            let task = if rng.chance(0.5) {
                Task::Classification
            } else {
                Task::Regression
            };
            let eta = 10f32.powf(-1.0 - 2.0 * rng.f32());
            let lw = if rng.chance(0.5) { 0.0 } else { 1e-3 };
            let lv = if rng.chance(0.5) { 0.0 } else { 1e-3 };
            (m, idx, val, y, task, eta, lw, lv)
        },
        |(m, idx, val, y, task, eta, lw, lv)| {
            let mut scalar = m.clone();
            let mut a = vec![0f32; m.k];
            let mut s2 = vec![0f32; m.k];
            let scalar_loss = sgd_update_example(
                &mut scalar, idx, val, *y, *task, *eta, *lw, *lv, &mut a, &mut s2,
            );

            let mut kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            let fused_loss =
                kern.score_grad_step(idx, val, *y, *task, *eta, *lw, *lv, &mut scratch);
            let fused = kern.to_model();

            if (fused_loss - scalar_loss).abs() >= 1e-4 * (1.0 + scalar_loss.abs()) {
                return Err(format!("loss {fused_loss} vs {scalar_loss}"));
            }
            let dist = model_distance(&fused, &scalar);
            if dist < 1e-5 {
                Ok(())
            } else {
                Err(format!("post-update parameter distance {dist}"))
            }
        },
    );
}

/// Finite-difference check of the fused step's implied gradient: with
/// eta = 1 and no regularizer, `old - new` is the stochastic gradient.
#[test]
fn prop_fused_step_matches_finite_differences() {
    forall_res(
        "fused step direction matches central differences",
        48,
        |rng| {
            let d = 2 + rng.below_usize(8);
            let k = 1 + rng.below_usize(8);
            let m = random_model(rng, d, k);
            let nnz = 1 + rng.below_usize(d);
            let (idx, val) = sparse_row(rng, d, nnz);
            let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
            // Probe one w coordinate and one v coordinate on the support.
            let probe = idx[rng.below_usize(idx.len())] as usize;
            let kk = rng.below_usize(k);
            (m, idx, val, y, probe, kk)
        },
        |(m, idx, val, y, probe, kk)| {
            let task = Task::Classification;
            let mut kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            kern.score_grad_step(idx, val, *y, task, 1.0, 0.0, 0.0, &mut scratch);
            let stepped = kern.to_model();

            let eps = 1e-3f32;
            let loss_of = |mm: &FmModel| loss::loss(mm.score_sparse(idx, val), *y, task);
            let check = |ana: f32, bump: &dyn Fn(&mut FmModel, f32), what: &str| {
                let mut mp = m.clone();
                bump(&mut mp, eps);
                let mut mn = m.clone();
                bump(&mut mn, -eps);
                let num = (loss_of(&mp) - loss_of(&mn)) / (2.0 * eps);
                if (num - ana).abs() < 5e-3 {
                    Ok(())
                } else {
                    Err(format!("{what}: numeric {num} vs analytic {ana}"))
                }
            };
            let j = *probe;
            let p = j * m.k + *kk;
            check(m.w0 - stepped.w0, &|mm, e| mm.w0 += e, "w0")?;
            check(m.w[j] - stepped.w[j], &move |mm, e| mm.w[j] += e, "w")?;
            check(m.v[p] - stepped.v[p], &move |mm, e| mm.v[p] += e, "v")?;
            Ok(())
        },
    );
}

/// The lane-blocked AdaGrad variant matches the scalar AdaGrad state over
/// a multi-step trajectory.
#[test]
fn prop_adagrad_lanes_match_scalar_state() {
    forall_res(
        "fused AdaGrad equals scalar AdaGradState",
        32,
        |rng| {
            let d = 2 + rng.below_usize(10);
            let k = 1 + rng.below_usize(24);
            let m = random_model(rng, d, k);
            let steps: Vec<(Vec<u32>, Vec<f32>, f32)> = (0..5)
                .map(|_| {
                    let nnz = 1 + rng.below_usize(d);
                    let (idx, val) = sparse_row(rng, d, nnz);
                    let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
                    (idx, val, y)
                })
                .collect();
            (m, steps)
        },
        |(m, steps)| {
            let task = Task::Classification;
            let mut scalar = m.clone();
            let mut st = AdaGradState::new(m.d, m.k);
            let mut a = vec![0f32; m.k];

            let mut kern = FmKernel::from_model(m);
            let mut lanes = AdaGradLanes::new(m.d, m.k);
            let mut scratch = Scratch::for_k(m.k);

            for (idx, val, y) in steps {
                st.update_example(&mut scalar, idx, val, *y, task, 0.1, 1e-3, 1e-3, &mut a);
                kern.score_grad_step_adagrad(
                    idx,
                    val,
                    *y,
                    task,
                    0.1,
                    1e-3,
                    1e-3,
                    &mut lanes,
                    &mut scratch,
                );
            }
            let fused = kern.to_model();
            let dist = model_distance(&fused, &scalar);
            if dist < 1e-4 {
                Ok(())
            } else {
                Err(format!("AdaGrad trajectories diverged: distance {dist}"))
            }
        },
    );
}

/// Engine visit-kernel parity: the lane-blocked column kernels
/// (`visit::col_update` / `col_recompute` / `finalize_rows`) must be
/// **bitwise identical** to the scalar K-strided loops the engine ran
/// before lane-blocking (kept as oracles in `visit::scalar`), across the
/// full K grid, empty columns included, with the padding lanes pinned at
/// exactly zero throughout. (Bias tokens carry no factor payload — their
/// path is covered by the engine-level bitwise test in
/// `engine_properties.rs` and the padded-token codec suite.)
#[test]
fn visit_kernels_match_scalar_oracles_bitwise() {
    for &k in &VISIT_KS {
        let kp = padded_k(k);
        let mut rng = Pcg64::seeded(0x71f + k as u64);
        let nloc = 11;
        for nnz in [0usize, 1, 4, nloc] {
            // One CSC column over `nloc` local rows (empty at nnz = 0),
            // plus the frozen multipliers G and factor-sum cache A.
            let (rows, xs) = sparse_row(&mut rng, nloc, nnz);
            let g: Vec<f32> = (0..nloc).map(|_| rng.normal32(0.0, 1.0)).collect();
            let aa: Vec<f32> = (0..nloc * k).map(|_| rng.normal32(0.0, 0.7)).collect();
            let aa_p = pad_rows(&aa, nloc, k, kp);
            let w0col = rng.normal32(0.0, 0.5);
            let vcol: Vec<f32> = (0..k).map(|_| rng.normal32(0.0, 0.5)).collect();
            let h = VisitHyper {
                eta: 0.3,
                inv_n: 1.0 / 17.0,
                lambda_w: 1e-3,
                lambda_v: 1e-3,
                reg_split: 0.25,
            };

            // -- col_update parity.
            let mut w_s = w0col;
            let mut v_s = vcol.clone();
            let mut gv = vec![0f32; k];
            visit::scalar::col_update(&rows, &xs, &g, &aa, k, &mut w_s, &mut v_s, h, &mut gv);
            let mut w_l = w0col;
            let mut v_l = pad_rows(&vcol, 1, k, kp);
            let mut scratch = Scratch::new();
            visit::col_update(&rows, &xs, &g, &aa_p, kp, &mut w_l, &mut v_l, h, &mut scratch);
            assert_eq!(w_l.to_bits(), w_s.to_bits(), "k={k} nnz={nnz}: w");
            for kk in 0..k {
                assert_eq!(
                    v_l[kk].to_bits(),
                    v_s[kk].to_bits(),
                    "k={k} nnz={nnz}: v[{kk}]"
                );
            }
            assert!(
                v_l[k..].iter().all(|&x| x.to_bits() == 0),
                "k={k} nnz={nnz}: update un-zeroed the padding"
            );

            // -- col_update_stochastic parity (identical RNG streams).
            let mut w_ss = w0col;
            let mut v_ss = vcol.clone();
            let mut rng_s = Pcg64::seeded(900 + k as u64);
            let n_s = visit::scalar::col_update_stochastic(
                &rows, &xs, &g, &aa, k, &mut w_ss, &mut v_ss, 0.02, 1e-3, 1e-3, 3, &mut rng_s,
            );
            let mut w_sl = w0col;
            let mut v_sl = pad_rows(&vcol, 1, k, kp);
            let mut rng_l = Pcg64::seeded(900 + k as u64);
            let n_l = visit::col_update_stochastic(
                &rows, &xs, &g, &aa_p, kp, &mut w_sl, &mut v_sl, 0.02, 1e-3, 1e-3, 3, &mut rng_l,
            );
            assert_eq!(n_s, n_l, "k={k} nnz={nnz}: stochastic coord counts");
            assert_eq!(w_sl.to_bits(), w_ss.to_bits(), "k={k} nnz={nnz}: stoch w");
            for kk in 0..k {
                assert_eq!(
                    v_sl[kk].to_bits(),
                    v_ss[kk].to_bits(),
                    "k={k} nnz={nnz}: stoch v[{kk}]"
                );
            }
            assert!(v_sl[k..].iter().all(|&x| x.to_bits() == 0));

            // -- col_recompute parity (fold the updated column).
            let mut xw_s = vec![0f32; nloc];
            let mut a_s = vec![0f32; nloc * k];
            let mut s2_s = vec![0f32; nloc * k];
            visit::scalar::col_recompute(&rows, &xs, w_s, &v_s, k, &mut xw_s, &mut a_s, &mut s2_s);
            let mut xw_l = vec![0f32; nloc];
            let mut a_l = vec![0f32; nloc * kp];
            let mut s2_l = vec![0f32; nloc * kp];
            visit::col_recompute(&rows, &xs, w_l, &v_l, kp, &mut xw_l, &mut a_l, &mut s2_l);
            assert_eq!(xw_l, xw_s, "k={k} nnz={nnz}: acc_xw");
            for r in 0..nloc {
                for kk in 0..k {
                    assert_eq!(
                        a_l[r * kp + kk].to_bits(),
                        a_s[r * k + kk].to_bits(),
                        "k={k} nnz={nnz}: acc_a[{r},{kk}]"
                    );
                    assert_eq!(
                        s2_l[r * kp + kk].to_bits(),
                        s2_s[r * k + kk].to_bits(),
                        "k={k} nnz={nnz}: acc_s2[{r},{kk}]"
                    );
                }
                assert!(a_l[r * kp + k..(r + 1) * kp].iter().all(|&x| x.to_bits() == 0));
                assert!(s2_l[r * kp + k..(r + 1) * kp].iter().all(|&x| x.to_bits() == 0));
            }

            // -- finalize_rows parity: same loss sum, same refreshed G.
            for task in [Task::Regression, Task::Classification] {
                let labels: Vec<f32> = (0..nloc)
                    .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
                    .collect();
                let mut g_s = vec![0f32; nloc];
                let loss_s = visit::scalar::finalize_rows(
                    0.2, &xw_s, &a_s, &s2_s, k, &labels, task, &mut g_s,
                );
                let mut g_l = vec![0f32; nloc];
                let loss_l =
                    visit::finalize_rows(0.2, &xw_l, &a_l, &s2_l, kp, &labels, task, &mut g_l);
                assert_eq!(
                    loss_l.to_bits(),
                    loss_s.to_bits(),
                    "k={k} nnz={nnz} {task:?}: loss sum"
                );
                for r in 0..nloc {
                    assert_eq!(
                        g_l[r].to_bits(),
                        g_s[r].to_bits(),
                        "k={k} nnz={nnz} {task:?}: g[{r}]"
                    );
                }
            }
        }
    }
}

/// One scratch arena serves models of different K (grow-only reuse), and
/// padding stays exact across the K = 32 stack/heap boundary.
#[test]
fn scratch_reuse_across_k_and_lane_boundaries() {
    let mut rng = Pcg64::seeded(77);
    let mut scratch = Scratch::new();
    for &k in &[3, 40, 7, 64, 1, LANES, LANES + 1, 33] {
        let d = 10;
        let m = random_model(&mut rng, d, k);
        let kern = FmKernel::from_model(&m);
        assert_eq!(kern.padded(), padded_k(k));
        for nnz in [0, 1, d / 2, d] {
            let (idx, val) = sparse_row(&mut rng, d, nnz);
            let fused = kern.score(&idx, &val, &mut scratch);
            let naive = m.score_naive(&idx, &val);
            assert!(
                (fused - naive).abs() < 1e-4 * (1.0 + naive.abs()),
                "k={k} nnz={nnz}: {fused} vs {naive}"
            );
        }
    }
}

/// Long fused-SGD trajectories keep the kernel and the scalar reference in
/// the same place (error accumulates but stays bounded), and the model
/// round-trip after training is exact.
#[test]
fn fused_training_trajectory_tracks_scalar() {
    let mut rng = Pcg64::seeded(99);
    let d = 12;
    let k = 6;
    let m = random_model(&mut rng, d, k);
    let mut scalar = m.clone();
    let mut kern = FmKernel::from_model(&m);
    let mut scratch = Scratch::for_k(k);
    let mut a = vec![0f32; k];
    let mut s2 = vec![0f32; k];
    for step in 0..200 {
        let nnz = 1 + rng.below_usize(d);
        let (idx, val) = sparse_row(&mut rng, d, nnz);
        let y = if step % 3 == 0 { 1.0 } else { -1.0 };
        sgd_update_example(
            &mut scalar,
            &idx,
            &val,
            y,
            Task::Classification,
            0.05,
            1e-4,
            1e-4,
            &mut a,
            &mut s2,
        );
        kern.score_grad_step(
            &idx,
            &val,
            y,
            Task::Classification,
            0.05,
            1e-4,
            1e-4,
            &mut scratch,
        );
    }
    let fused = kern.to_model();
    let dist = model_distance(&fused, &scalar);
    assert!(dist < 1e-3, "200-step trajectory distance {dist}");
    // Round-trip stays loss-free after training.
    assert_eq!(FmKernel::from_model(&fused).to_model(), fused);
}
