//! Oracle-backed property suite for the fused lane-blocked kernel
//! (`dsfacto::kernel`): scoring parity against the paper-literal eq. 2
//! double sum (`FmModel::score_naive`), update parity against the scalar
//! reference (`optim::sgd_update_example`), finite-difference gradient
//! checks, and scratch-arena reuse — across random K in 1..=64 (covering
//! both the scalar scorer's stack path, K <= 32, and its heap path),
//! random nnz including empty rows, and permuted index orders.

use dsfacto::data::Task;
use dsfacto::fm::{loss, FmModel};
use dsfacto::kernel::{padded_k, AdaGradLanes, FmKernel, Scratch, LANES};
use dsfacto::optim::{sgd_update_example, AdaGradState};
use dsfacto::util::prop::{forall_res, sparse_row};
use dsfacto::util::rng::Pcg64;

fn random_model(rng: &mut Pcg64, d: usize, k: usize) -> FmModel {
    let mut m = FmModel::init(d, k, 0.3, rng);
    for x in m.w.iter_mut() {
        *x = rng.normal32(0.0, 0.5);
    }
    m.w0 = rng.normal32(0.0, 0.5);
    m
}

/// Max relative parameter discrepancy between two same-shape models.
fn model_distance(a: &FmModel, b: &FmModel) -> f32 {
    let mut worst = (a.w0 - b.w0).abs() / (1.0 + b.w0.abs());
    for (x, y) in a.w.iter().zip(&b.w) {
        worst = worst.max((x - y).abs() / (1.0 + y.abs()));
    }
    for (x, y) in a.v.iter().zip(&b.v) {
        worst = worst.max((x - y).abs() / (1.0 + y.abs()));
    }
    worst
}

/// Acceptance criterion: fused scores match the eq. 2 oracle within 1e-4
/// relative error for K across 1..=64 (stack and heap scalar paths alike),
/// arbitrary nnz (including empty rows), duplicate-free sorted indices.
#[test]
fn prop_kernel_score_matches_naive_all_k() {
    forall_res(
        "fused kernel score equals naive pairwise oracle",
        96,
        |rng| {
            let d = 2 + rng.below_usize(22);
            let k = 1 + rng.below_usize(64);
            let m = random_model(rng, d, k);
            let nnz = rng.below_usize(d + 1); // 0 included: empty rows
            let (idx, val) = sparse_row(rng, d, nnz);
            (m, idx, val)
        },
        |(m, idx, val)| {
            let kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            let fused = kern.score(idx, val, &mut scratch);
            let naive = m.score_naive(idx, val);
            let scalar = m.score_sparse(idx, val);
            let tol = 1e-4 * (1.0 + naive.abs());
            if (fused - naive).abs() >= tol {
                return Err(format!("k={}: fused {fused} vs naive {naive}", m.k));
            }
            if (fused - scalar).abs() >= tol {
                return Err(format!("k={}: fused {fused} vs scalar {scalar}", m.k));
            }
            Ok(())
        },
    );
}

/// The score is invariant (to accumulation noise) under joint permutation
/// of the (index, value) pairs — the kernel must not rely on sortedness.
#[test]
fn prop_kernel_score_permutation_invariant() {
    forall_res(
        "kernel score invariant under index permutation",
        64,
        |rng| {
            let d = 2 + rng.below_usize(16);
            let k = 1 + rng.below_usize(24);
            let m = random_model(rng, d, k);
            let nnz = 1 + rng.below_usize(d);
            let (idx, val) = sparse_row(rng, d, nnz);
            // A joint shuffle of the pairs.
            let mut pairs: Vec<(u32, f32)> = idx.iter().cloned().zip(val.iter().cloned()).collect();
            rng.shuffle(&mut pairs);
            let (pidx, pval): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
            (m, idx, val, pidx, pval)
        },
        |(m, idx, val, pidx, pval)| {
            let kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            let sorted = kern.score(idx, val, &mut scratch);
            let shuffled = kern.score(pidx, pval, &mut scratch);
            let tol = 1e-4 * (1.0 + sorted.abs());
            if (sorted - shuffled).abs() < tol {
                Ok(())
            } else {
                Err(format!("sorted {sorted} vs shuffled {shuffled}"))
            }
        },
    );
}

/// The fused score+gradient+update step lands on the same parameters (and
/// loss) as the scalar three-pass reference, for random shapes, tasks,
/// step sizes and regularization.
#[test]
fn prop_fused_step_matches_scalar_update() {
    forall_res(
        "fused score_grad_step equals scalar sgd_update_example",
        96,
        |rng| {
            let d = 2 + rng.below_usize(16);
            let k = 1 + rng.below_usize(64);
            let m = random_model(rng, d, k);
            let nnz = 1 + rng.below_usize(d);
            let (idx, val) = sparse_row(rng, d, nnz);
            let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
            let task = if rng.chance(0.5) {
                Task::Classification
            } else {
                Task::Regression
            };
            let eta = 10f32.powf(-1.0 - 2.0 * rng.f32());
            let lw = if rng.chance(0.5) { 0.0 } else { 1e-3 };
            let lv = if rng.chance(0.5) { 0.0 } else { 1e-3 };
            (m, idx, val, y, task, eta, lw, lv)
        },
        |(m, idx, val, y, task, eta, lw, lv)| {
            let mut scalar = m.clone();
            let mut a = vec![0f32; m.k];
            let mut s2 = vec![0f32; m.k];
            let scalar_loss = sgd_update_example(
                &mut scalar, idx, val, *y, *task, *eta, *lw, *lv, &mut a, &mut s2,
            );

            let mut kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            let fused_loss =
                kern.score_grad_step(idx, val, *y, *task, *eta, *lw, *lv, &mut scratch);
            let fused = kern.to_model();

            if (fused_loss - scalar_loss).abs() >= 1e-4 * (1.0 + scalar_loss.abs()) {
                return Err(format!("loss {fused_loss} vs {scalar_loss}"));
            }
            let dist = model_distance(&fused, &scalar);
            if dist < 1e-5 {
                Ok(())
            } else {
                Err(format!("post-update parameter distance {dist}"))
            }
        },
    );
}

/// Finite-difference check of the fused step's implied gradient: with
/// eta = 1 and no regularizer, `old - new` is the stochastic gradient.
#[test]
fn prop_fused_step_matches_finite_differences() {
    forall_res(
        "fused step direction matches central differences",
        48,
        |rng| {
            let d = 2 + rng.below_usize(8);
            let k = 1 + rng.below_usize(8);
            let m = random_model(rng, d, k);
            let nnz = 1 + rng.below_usize(d);
            let (idx, val) = sparse_row(rng, d, nnz);
            let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
            // Probe one w coordinate and one v coordinate on the support.
            let probe = idx[rng.below_usize(idx.len())] as usize;
            let kk = rng.below_usize(k);
            (m, idx, val, y, probe, kk)
        },
        |(m, idx, val, y, probe, kk)| {
            let task = Task::Classification;
            let mut kern = FmKernel::from_model(m);
            let mut scratch = Scratch::for_k(m.k);
            kern.score_grad_step(idx, val, *y, task, 1.0, 0.0, 0.0, &mut scratch);
            let stepped = kern.to_model();

            let eps = 1e-3f32;
            let loss_of = |mm: &FmModel| loss::loss(mm.score_sparse(idx, val), *y, task);
            let check = |ana: f32, bump: &dyn Fn(&mut FmModel, f32), what: &str| {
                let mut mp = m.clone();
                bump(&mut mp, eps);
                let mut mn = m.clone();
                bump(&mut mn, -eps);
                let num = (loss_of(&mp) - loss_of(&mn)) / (2.0 * eps);
                if (num - ana).abs() < 5e-3 {
                    Ok(())
                } else {
                    Err(format!("{what}: numeric {num} vs analytic {ana}"))
                }
            };
            let j = *probe;
            let p = j * m.k + *kk;
            check(m.w0 - stepped.w0, &|mm, e| mm.w0 += e, "w0")?;
            check(m.w[j] - stepped.w[j], &move |mm, e| mm.w[j] += e, "w")?;
            check(m.v[p] - stepped.v[p], &move |mm, e| mm.v[p] += e, "v")?;
            Ok(())
        },
    );
}

/// The lane-blocked AdaGrad variant matches the scalar AdaGrad state over
/// a multi-step trajectory.
#[test]
fn prop_adagrad_lanes_match_scalar_state() {
    forall_res(
        "fused AdaGrad equals scalar AdaGradState",
        32,
        |rng| {
            let d = 2 + rng.below_usize(10);
            let k = 1 + rng.below_usize(24);
            let m = random_model(rng, d, k);
            let steps: Vec<(Vec<u32>, Vec<f32>, f32)> = (0..5)
                .map(|_| {
                    let nnz = 1 + rng.below_usize(d);
                    let (idx, val) = sparse_row(rng, d, nnz);
                    let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
                    (idx, val, y)
                })
                .collect();
            (m, steps)
        },
        |(m, steps)| {
            let task = Task::Classification;
            let mut scalar = m.clone();
            let mut st = AdaGradState::new(m.d, m.k);
            let mut a = vec![0f32; m.k];

            let mut kern = FmKernel::from_model(m);
            let mut lanes = AdaGradLanes::new(m.d, m.k);
            let mut scratch = Scratch::for_k(m.k);

            for (idx, val, y) in steps {
                st.update_example(&mut scalar, idx, val, *y, task, 0.1, 1e-3, 1e-3, &mut a);
                kern.score_grad_step_adagrad(
                    idx,
                    val,
                    *y,
                    task,
                    0.1,
                    1e-3,
                    1e-3,
                    &mut lanes,
                    &mut scratch,
                );
            }
            let fused = kern.to_model();
            let dist = model_distance(&fused, &scalar);
            if dist < 1e-4 {
                Ok(())
            } else {
                Err(format!("AdaGrad trajectories diverged: distance {dist}"))
            }
        },
    );
}

/// One scratch arena serves models of different K (grow-only reuse), and
/// padding stays exact across the K = 32 stack/heap boundary.
#[test]
fn scratch_reuse_across_k_and_lane_boundaries() {
    let mut rng = Pcg64::seeded(77);
    let mut scratch = Scratch::new();
    for &k in &[3, 40, 7, 64, 1, LANES, LANES + 1, 33] {
        let d = 10;
        let m = random_model(&mut rng, d, k);
        let kern = FmKernel::from_model(&m);
        assert_eq!(kern.padded(), padded_k(k));
        for nnz in [0, 1, d / 2, d] {
            let (idx, val) = sparse_row(&mut rng, d, nnz);
            let fused = kern.score(&idx, &val, &mut scratch);
            let naive = m.score_naive(&idx, &val);
            assert!(
                (fused - naive).abs() < 1e-4 * (1.0 + naive.abs()),
                "k={k} nnz={nnz}: {fused} vs {naive}"
            );
        }
    }
}

/// Long fused-SGD trajectories keep the kernel and the scalar reference in
/// the same place (error accumulates but stays bounded), and the model
/// round-trip after training is exact.
#[test]
fn fused_training_trajectory_tracks_scalar() {
    let mut rng = Pcg64::seeded(99);
    let d = 12;
    let k = 6;
    let m = random_model(&mut rng, d, k);
    let mut scalar = m.clone();
    let mut kern = FmKernel::from_model(&m);
    let mut scratch = Scratch::for_k(k);
    let mut a = vec![0f32; k];
    let mut s2 = vec![0f32; k];
    for step in 0..200 {
        let nnz = 1 + rng.below_usize(d);
        let (idx, val) = sparse_row(&mut rng, d, nnz);
        let y = if step % 3 == 0 { 1.0 } else { -1.0 };
        sgd_update_example(
            &mut scalar,
            &idx,
            &val,
            y,
            Task::Classification,
            0.05,
            1e-4,
            1e-4,
            &mut a,
            &mut s2,
        );
        kern.score_grad_step(
            &idx,
            &val,
            y,
            Task::Classification,
            0.05,
            1e-4,
            1e-4,
            &mut scratch,
        );
    }
    let fused = kern.to_model();
    let dist = model_distance(&fused, &scalar);
    assert!(dist < 1e-3, "200-step trajectory distance {dist}");
    // Round-trip stays loss-free after training.
    assert_eq!(FmKernel::from_model(&fused).to_model(), fused);
}
