//! Property tests on the DS-FACTO engine's protocol invariants
//! (see `nomad::engine` docs): token conservation, visit accounting,
//! convergence sanity across worker counts / transports / shapes.

use dsfacto::data::{synth, Dataset, Task};
use dsfacto::fm::{loss, FmHyper, FmModel};
use dsfacto::kernel::{visit, FmKernel, Scratch};
use dsfacto::nomad::{train_with_stats, NomadConfig, TransportKind};
use dsfacto::optim::LrSchedule;
use dsfacto::util::prop::{default_cases, forall_res};
use dsfacto::util::rng::Pcg64;

fn small_dataset(rng: &mut dsfacto::util::rng::Pcg64) -> Dataset {
    let task = if rng.chance(0.5) {
        Task::Regression
    } else {
        Task::Classification
    };
    let spec = synth::SynthSpec {
        name: "prop".into(),
        task,
        n: 8 + rng.below_usize(60),
        d: 1 + rng.below_usize(24),
        k: 1 + rng.below_usize(6),
        density: if rng.chance(0.5) { 1.0 } else { 0.4 },
        factor_scale: 0.3,
        noise: 0.3,
        skew: 0.0,
    };
    synth::generate(&spec, rng.next_u64()).dataset
}

/// Conservation + accounting: for arbitrary (dataset, P, T) the engine
/// returns a complete model and the exact expected hop/visit counts.
#[test]
fn prop_token_conservation_and_accounting() {
    forall_res(
        "token conservation across random configs",
        default_cases().min(24),
        |rng| {
            let ds = small_dataset(rng);
            let p = 1 + rng.below_usize(6);
            let t = 1 + rng.below_usize(4);
            let seed = rng.next_u64();
            (ds, p, t, seed)
        },
        |(ds, p, t, seed)| {
            let fm = FmHyper {
                k: ds.rows.n_cols().min(4).max(1),
                ..Default::default()
            };
            let cfg = NomadConfig {
                workers: *p,
                outer_iters: *t,
                eta: LrSchedule::Constant(0.1),
                seed: *seed,
                eval_every: usize::MAX, // no eval: pure engine exercise
                transport: TransportKind::Local,
                update_mode: dsfacto::nomad::UpdateMode::MeanGradient,
                cols_per_token: 1,
                ..Default::default()
            };
            let (out, stats) =
                train_with_stats(ds, None, &fm, &cfg).map_err(|e| format!("{e:#}"))?;
            let ntok = (ds.d() + 1) as u64;
            let expect_msgs = ntok + ntok * (*p as u64) * 2 * (*t as u64);
            if stats.messages != expect_msgs {
                return Err(format!(
                    "messages {} != expected {expect_msgs} (conservation violated)",
                    stats.messages
                ));
            }
            if stats.update_visits != ntok * *p as u64 * *t as u64 {
                return Err(format!("update visits {}", stats.update_visits));
            }
            // Model must be complete and finite.
            if out.model.d != ds.d() {
                return Err("model dimension mismatch".into());
            }
            if !out.model.w0.is_finite()
                || out.model.w.iter().any(|x| !x.is_finite())
                || out.model.v.iter().any(|x| !x.is_finite())
            {
                return Err("non-finite parameters".into());
            }
            // Trace covers every iteration exactly once, in order.
            if out.trace.len() != *t + 1 {
                return Err(format!("trace len {} != {}", out.trace.len(), t + 1));
            }
            for (i, pt) in out.trace.iter().enumerate() {
                if pt.iter != i {
                    return Err(format!("trace order broken at {i}"));
                }
            }
            Ok(())
        },
    );
}

/// The engine's objective must descend on well-conditioned planted data
/// for any worker count (the Fig. 4 qualitative claim).
#[test]
fn prop_descends_for_any_worker_count() {
    for p in [1, 2, 3, 5, 8] {
        let ds = synth::table2_dataset("housing", 100 + p as u64).unwrap();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: p,
            outer_iters: 25,
            eta: LrSchedule::Constant(0.5),
            ..Default::default()
        };
        let (out, _) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
        let first = out.trace.first().unwrap().objective;
        let last = out.trace.last().unwrap().objective;
        assert!(
            last < 0.7 * first,
            "P={p}: objective {first} -> {last} did not descend"
        );
    }
}

/// Worker count must not change the *final quality* materially (the paper's
/// central claim: hybrid parallelism preserves convergence).
#[test]
fn prop_quality_invariant_to_worker_count() {
    let ds = synth::table2_dataset("housing", 55).unwrap();
    let (train, test) = ds.split(0.8, 56);
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let mut rmses = Vec::new();
    for p in [1, 4, 8] {
        let cfg = NomadConfig {
            workers: p,
            outer_iters: 40,
            eta: LrSchedule::Constant(0.5),
            ..Default::default()
        };
        let (out, _) = train_with_stats(&train, Some(&test), &fm, &cfg).unwrap();
        rmses.push(dsfacto::metrics::evaluate(&out.model, &test).rmse);
    }
    let max = rmses.iter().cloned().fold(f64::MIN, f64::max);
    let min = rmses.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        max - min < 0.15 * min.max(0.1),
        "final test RMSE varies too much across P: {rmses:?}"
    );
}

/// All three transports implement the same protocol: identical message
/// counts and comparable final quality on the same seed.
#[test]
fn prop_transports_are_equivalent() {
    let ds = synth::table2_dataset("housing", 77).unwrap();
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    let mk_cfg = |transport| NomadConfig {
        workers: 3,
        outer_iters: 8,
        eta: LrSchedule::Constant(0.5),
        transport,
        ..Default::default()
    };
    let (out_local, st_local) =
        train_with_stats(&ds, None, &fm, &mk_cfg(TransportKind::Local)).unwrap();
    let sim = TransportKind::SimNet(dsfacto::cluster::NetModel {
        latency: std::time::Duration::from_micros(20),
        bandwidth_bps: 1e9,
        workers_per_machine: 1,
    });
    let (out_sim, st_sim) = train_with_stats(&ds, None, &fm, &mk_cfg(sim)).unwrap();
    let (out_tcp, st_tcp) = train_with_stats(&ds, None, &fm, &mk_cfg(TransportKind::Tcp)).unwrap();

    assert_eq!(st_local.messages, st_sim.messages);
    assert_eq!(st_local.messages, st_tcp.messages);
    let obj = |o: &dsfacto::metrics::TrainOutput| o.trace.last().unwrap().objective;
    let (a, b, c) = (obj(&out_local), obj(&out_sim), obj(&out_tcp));
    // Async schedules differ, but all must land in the same basin.
    assert!((a - b).abs() < 0.3 * a.max(0.05), "local {a} vs simnet {b}");
    assert!((a - c).abs() < 0.3 * a.max(0.05), "local {a} vs tcp {c}");
}

/// Kernel-era regression: the engine's fused-kernel G/A initialization and
/// scratch-arena column visits must hold across the K = 32 boundary that
/// separates the scalar scorer's stack and heap paths (wide factor models
/// exercise the grown-scratch path end to end).
#[test]
fn prop_engine_handles_wide_factor_models() {
    for k in [1usize, 8, 33, 48] {
        let spec = synth::SynthSpec {
            name: "widek".into(),
            task: Task::Regression,
            n: 30,
            d: 10,
            k: 4,
            density: 1.0,
            factor_scale: 0.2,
            noise: 0.2,
            skew: 0.0,
        };
        let ds = synth::generate(&spec, 1000 + k as u64).dataset;
        let fm = FmHyper {
            k,
            init_std: 0.05,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: 3,
            outer_iters: 3,
            eta: LrSchedule::Constant(0.1),
            eval_every: usize::MAX,
            ..Default::default()
        };
        let (out, stats) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
        assert_eq!(out.trace.len(), 4, "k={k}");
        assert_eq!(out.model.k, k, "k={k}");
        assert!(
            out.model.w0.is_finite()
                && out.model.w.iter().all(|x| x.is_finite())
                && out.model.v.iter().all(|x| x.is_finite()),
            "k={k}: non-finite parameters"
        );
        assert!(stats.coordinate_updates > 0, "k={k}");
    }
}

/// A step-by-step scalar replay of the P = 1 engine schedule using the
/// K-strided `visit::scalar` oracles: with one worker the protocol is
/// fully deterministic (tokens are processed in deal order — all column
/// blocks, then the bias — once per phase), so the engine's lane-blocked,
/// padded-token run must reproduce it **bit for bit**.
fn scalar_reference_run(ds: &Dataset, fm: &FmHyper, cfg: &NomadConfig) -> FmModel {
    assert_eq!(cfg.workers, 1, "the scalar replay models the P=1 schedule");
    let (d, k, n) = (ds.d(), fm.k, ds.n());
    let c = cfg.cols_per_token;
    assert!(c > 0, "replay needs an explicit block size");
    let nblocks = d.div_ceil(c);

    // Same init stream as the engine.
    let mut rng = Pcg64::new(cfg.seed, 0x0ad);
    let init = FmModel::init(d, k, fm.init_std, &mut rng);
    // Initial G/A exactly as the worker computes them (through the fused
    // kernel), but stored K-strided.
    let kern0 = FmKernel::from_model(&init);
    let mut scratch = Scratch::for_k(k);
    let mut g = vec![0f32; n];
    let mut aa = vec![0f32; n * k];
    for r in 0..n {
        let (idx, val) = ds.rows.row(r);
        let f = kern0.score_with_sums(idx, val, &mut aa[r * k..(r + 1) * k], &mut scratch);
        g[r] = loss::multiplier(f, ds.labels[r], ds.task);
    }
    let cols = ds.rows.to_csc();

    let mut w0 = init.w0;
    let mut w = init.w.clone();
    let mut v = init.v.clone();
    let mut acc_xw = vec![0f32; n];
    let mut acc_a = vec![0f32; n * k];
    let mut acc_s2 = vec![0f32; n * k];
    let mut gv = vec![0f32; k];
    let inv_n = 1.0 / n.max(1) as f32;
    for iter in 0..cfg.outer_iters {
        let eta = cfg.eta.at(iter);
        let h = visit::VisitHyper {
            eta,
            inv_n,
            lambda_w: fm.lambda_w,
            lambda_v: fm.lambda_v,
            reg_split: 1.0, // P = 1
        };
        // Update pass: column blocks in deal order, bias token last.
        for b in 0..nblocks {
            let (lo, hi) = (b * c, (b * c + c).min(d));
            for j in lo..hi {
                let (rows, xs) = cols.col(j);
                visit::scalar::col_update(
                    rows,
                    xs,
                    &g,
                    &aa,
                    k,
                    &mut w[j],
                    &mut v[j * k..(j + 1) * k],
                    h,
                    &mut gv,
                );
            }
        }
        let gsum: f32 = g.iter().sum();
        w0 -= eta * gsum * inv_n;
        // Recompute pass in the same order (the bias visit only refreshes
        // the worker's local w0 copy, which this replay holds directly).
        for b in 0..nblocks {
            let (lo, hi) = (b * c, (b * c + c).min(d));
            for j in lo..hi {
                let (rows, xs) = cols.col(j);
                visit::scalar::col_recompute(
                    rows,
                    xs,
                    w[j],
                    &v[j * k..(j + 1) * k],
                    k,
                    &mut acc_xw,
                    &mut acc_a,
                    &mut acc_s2,
                );
            }
        }
        // End of the recompute pass: finalize.
        visit::scalar::finalize_rows(w0, &acc_xw, &acc_a, &acc_s2, k, &ds.labels, ds.task, &mut g);
        aa.copy_from_slice(&acc_a);
        acc_xw.fill(0.0);
        acc_a.fill(0.0);
        acc_s2.fill(0.0);
    }
    FmModel { d, k, w0, w, v }
}

/// The tentpole acceptance property: a padded-token, lane-blocked engine
/// run is **bitwise identical** to the scalar K-strided reference at a
/// fixed seed — the AoSoA layout changes how the arithmetic is laid out,
/// never what is computed. Covers K on both sides of a lane boundary and
/// a ragged final column block, with the bias token in the ring.
#[test]
fn padded_engine_matches_scalar_reference_bitwise() {
    let ds = synth::table2_dataset("housing", 21).unwrap(); // d = 13
    for &(k, c) in &[(4usize, 5usize), (7, 3), (8, 13)] {
        let fm = FmHyper {
            k,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: 1,
            outer_iters: 5,
            eta: LrSchedule::Constant(0.5),
            seed: 77,
            eval_every: usize::MAX,
            cols_per_token: c,
            ..Default::default()
        };
        let (out, _) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
        let reference = scalar_reference_run(&ds, &fm, &cfg);
        assert_eq!(
            out.model.w0.to_bits(),
            reference.w0.to_bits(),
            "k={k} c={c}: w0"
        );
        for (j, (a, b)) in out.model.w.iter().zip(&reference.w).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "k={k} c={c}: w[{j}]");
        }
        for (p, (a, b)) in out.model.v.iter().zip(&reference.v).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "k={k} c={c}: v[{p}]");
        }
    }
}

/// Degenerate shapes must not wedge the engine.
#[test]
fn prop_degenerate_shapes() {
    // One feature; one example; P > D; P > N.
    for (n, d, p) in [(1usize, 1usize, 1usize), (1, 3, 2), (5, 1, 4), (3, 2, 8)] {
        let spec = synth::SynthSpec {
            name: "degen".into(),
            task: Task::Regression,
            n,
            d,
            k: 1,
            density: 1.0,
            factor_scale: 0.1,
            noise: 0.1,
            skew: 0.0,
        };
        let ds = synth::generate(&spec, 1).dataset;
        let fm = FmHyper {
            k: 1,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: p,
            outer_iters: 2,
            ..Default::default()
        };
        let (out, _) = train_with_stats(&ds, None, &fm, &cfg)
            .unwrap_or_else(|e| panic!("n={n} d={d} p={p}: {e:#}"));
        assert_eq!(out.trace.len(), 3, "n={n} d={d} p={p}");
    }
}
