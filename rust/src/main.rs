//! `dsfacto` CLI — train / evaluate / inspect factorization machines with
//! the DS-FACTO engine and its baselines.
//!
//! Every trainer runs through the uniform session API
//! (`TrainerKind::build -> Trainer::fit`); the CLI itself only parses a
//! config and prints the summary.
//!
//! ```text
//! dsfacto train --dataset diabetes --trainer nomad --workers 4 --outer-iters 50
//! dsfacto train --trainer nomad --transport simnet:50us,1e9,2 --update-mode stochastic:4
//! dsfacto train --config configs/fig4_diabetes.conf --trace /tmp/trace.csv
//! dsfacto evaluate --model /tmp/model.dsfm --dataset diabetes
//! dsfacto inspect --model /tmp/model.dsfm
//! dsfacto datasets
//! ```

use anyhow::{bail, Context, Result};

use dsfacto::config::ExperimentConfig;
use dsfacto::coordinator::{run_experiment, Evaluator};
use dsfacto::data::synth::SynthSpec;
use dsfacto::data::Task;
use dsfacto::fm;
use dsfacto::runtime::Runtime;
use dsfacto::util::cli::Args;
use dsfacto::util::human_secs;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args
        .positionals()
        .first()
        .cloned()
        .unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "train" => cmd_train(args),
        "driver" => cmd_driver(args),
        "worker" => cmd_worker(args),
        "ingest" => cmd_ingest(args),
        "serve" => cmd_serve(args),
        "score" => cmd_score(args),
        "evaluate" => cmd_evaluate(args),
        "inspect" => cmd_inspect(args),
        "datasets" => cmd_datasets(args),
        "artifacts" => cmd_artifacts(args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?} (see `dsfacto help`)"),
    }
}

const HELP: &str = "\
dsfacto — Doubly Separable Factorization Machines

Training is uniform across engines: pick a trainer, every other flag is a
config key. All five trainers (nomad = DS-FACTO, libfm, dsgd, bulksync,
xla-dense) implement the same Trainer interface and accept the same
session options (trace streaming, eval cadence, checkpoints via the
library's observer API).

USAGE:
  dsfacto train      [--config FILE] [--dataset NAME|FILE] [--dataset-task TASK]
                     [--trainer nomad|libfm|dsgd|bulksync|xla]
                     [--workers P] [--outer-iters T] [--eta SPEC] [--k K]
                     [--lambda-w L] [--lambda-v L] [--seed S] [--eval-every E]
                     [--train-frac F]
                     [--transport local|tcp|simnet[:LAT,BW,WPM]]
                     [--update-mode mean|stochastic[:N]] [--cols-per-token C]
                     [--row-partition contiguous|balanced]
                     [--data-cache DIR]
                     [--trace FILE] [--save-model FILE]
                     [--xla-eval] [--artifacts DIR] [--quiet]
  dsfacto driver     [--config FILE] [--addr HOST:PORT] [--workers P]
                     [--ckpt-dir DIR] [--ckpt-every E] [--max-restarts R]
                     [--join-timeout SECS] [--heartbeat-timeout SECS]
                     [--stall-timeout SECS] [--resume]
                     [--cluster-secret S] [--wire-precision f32|bf16]
                     [--chaos SPEC]
                     [--save-model FILE] [--quiet] [train flags...]
  dsfacto worker     --driver HOST:PORT [--data-cache DIR]
                     [--ckpt-dir DIR] [--ckpt-every E] [--connect-timeout SECS]
                     [--cluster-secret S] [--wire-precision f32|bf16]
                     [--chaos SPEC]
  dsfacto ingest     --dataset FILE --data-cache DIR [--shards P]
                     [--row-partition contiguous|balanced]
                     [--dataset-task TASK] [--n-features D] [--chunk-rows N]
  dsfacto serve      --model FILE [--config FILE] [--addr HOST:PORT]
                     [--col-blocks B] [--max-batch N] [--batch-window-us US]
                     [--reload-poll-ms MS]
  dsfacto score      --data FILE [--addr HOST:PORT] [--stats]
  dsfacto evaluate   --model FILE --dataset NAME|FILE [--xla] [--artifacts DIR]
  dsfacto inspect    --model FILE
  dsfacto datasets                      # list Table-2 synthetic twins
  dsfacto artifacts  [--artifacts DIR]  # list AOT artifacts

SPECS:
  eta        constant:0.05 | inv:0.1,0.01 | exp:0.1,0.99
  transport  local | tcp | simnet:50us,1e9,2
             (latency[us|ms|s], bandwidth bytes/s, workers per machine;
              applies to the nomad trainer)
  update-mode  mean | stochastic:4   (nomad update-visit semantics)
  row-partition  contiguous | balanced   (row shards by count or by nnz;
             applies to the nomad, dsgd and bulksync trainers)

OUT-OF-CORE DATA:
  `dsfacto ingest` streams a LIBSVM file into a binary shard cache in one
  bounded-memory pass (never holding the full matrix). Training with
  `--dataset cache:DIR --train-frac 1` is then bounded-memory END TO END:
  the coordinator streams shards through a double-buffered prefetcher (one
  shard in use + at most one in flight, never the full CSR), the
  per-iteration trace and the final metrics are computed shard by shard,
  and the run prints its measured peak residency. The numbers are bitwise
  identical to the in-memory run of the same config. `--data-cache DIR`
  (config key `data_cache`) additionally makes every distributed worker
  load only its own shard file. The cache bakes in its row-partition plan
  and shard count, so ingest with the `--shards` / `--row-partition` you
  will train with. Caches are pre-split at ingest: every trainer —
  single-process and cluster alike — rejects `cache:` datasets with
  train_frac != 1, so pre-split held-out rows into their own file before
  ingesting.

CLUSTER (multi-process DS-FACTO):
  `dsfacto driver` + P x `dsfacto worker` run the NOMAD token ring across
  OS processes: the driver owns membership, rank/shard assignment, epoch
  aggregation and the convergence trace; each worker loads only its own
  shard from the shared cache (`--dataset cache:DIR`, so every process
  must see the same directory) and trades parameter tokens with its ring
  neighbors over TCP. `--addr HOST:PORT` (port 0 picks a free port — the
  bound address is printed as `dsfacto driver: control on ADDR`) is
  shorthand for the config key `cluster = driver:HOST:PORT,p=<workers>`.
  With `--ckpt-dir`, workers write per-epoch block checkpoints (pruned to
  the newest two complete epochs) and the driver restarts a generation
  from the newest complete epoch when a worker dies (detected by
  heartbeat silence) or the ring stalls without progress for
  `--stall-timeout` seconds (a lost frame); up to `--max-restarts`
  restarts. With `update-mode mean` (the default) the assembled model is
  bitwise identical to a single-process `dsfacto train` run at the same
  config — including across restarts.

CLUSTER FAULT TOLERANCE:
  --resume           Restart a crashed DRIVER: with `--ckpt-dir`, the
                     driver journals its control state (trace, generation
                     count, config hash) to DIR/driver.dsfj after every
                     aggregated iteration; a new driver started with
                     `--resume` and the same config re-opens membership,
                     restores the trace, and continues from the newest
                     complete block-checkpoint epoch. Workers that lost
                     the old driver keep re-dialing for
                     `--connect-timeout` seconds and re-join.
  --cluster-secret S (config key `cluster_secret`) tags every control and
                     ring frame with HMAC-SHA256 keyed by S; frames with
                     missing/wrong tags are rejected and the connection
                     dropped, so an unauthenticated or wrong-secret
                     client cannot join or corrupt a run. All processes
                     must agree on S; the driver never ships it over the
                     wire.
  --wire-precision   (config key `wire_precision`) numeric format of the
                     token payloads on the ring: `f32` (default, exact)
                     or `bf16` (top half of each f32; halves the factor
                     bytes per hop at ~3 significant decimal digits).
                     Every process must pass the same value — workers
                     declare theirs when joining and the driver rejects a
                     mismatch, since a mixed ring would corrupt tokens.
                     Control frames, checkpoints and the final collected
                     blocks stay f32 regardless.
  --chaos SPEC       (or env DSFACTO_CHAOS) deterministic fault injection
                     for tests/benches, applied to this process only.
                     SPEC is `;`-separated directives:
                       drop:ring:N | drop:ctrl:N   swallow the Nth frame
                       dup:ring:N  | dup:ctrl:N    send the Nth frame twice
                       delay:ring:N:MS | delay:ctrl:N:MS  stall the Nth send
                       kill:E                      exit(9) at epoch E
                       refuse:MS                   refuse conns for MS ms
                     e.g. --chaos 'drop:ring:7;kill:3'.

SERVE (zero-alloc batched scoring):
  `dsfacto serve` loads a checkpoint and answers scoring requests over a
  length-prefixed TCP frame protocol (magic 0xD5FE; EXPERIMENTS.md §Serve
  documents the wire layout). The request path allocates nothing in the
  steady state: per-connection grow-only arenas absorb decode and
  scoring, and pipelined requests arriving within `--batch-window-us`
  (config key `serve_batch_window_us`; up to `--max-batch` requests) are
  gathered into one fused scoring sweep. Scores are bitwise identical to
  `dsfacto evaluate`'s rust scorer, batched or not, and independent of
  `--col-blocks` (which slices the factor matrix into B column blocks
  for a bounded working set per sweep). The server polls the checkpoint
  file every `--reload-poll-ms` and hot-swaps a changed model behind an
  Arc — in-flight connections finish their batch on the old model and
  pick up the new one at the next batch, without reconnecting. Corrupt
  or partial checkpoint writes are ignored (saves are atomic tmp+rename,
  and a failed parse keeps the current model). `dsfacto score --data
  FILE` is the matching client: it scores a LIBSVM file against a
  running server and prints one score per line; `--stats` prints the
  server's stats snapshot (model generation/fingerprint, arena
  capacities, request counters) instead. Config keys: serve_addr,
  serve_model, serve_max_batch, serve_batch_window_us, serve_col_blocks,
  serve_reload_poll_ms. Latency/throughput numbers land in
  BENCH_serve.json via `cargo bench --bench serve_bench`.

KERNEL BACKEND:
  The per-example and column-visit kernels dispatch at startup to
  hand-written AVX2 SIMD on x86_64 CPUs that support it, with the
  portable lane-blocked code as the fallback. Set DSFACTO_NO_SIMD=1 to
  force the fallback (e.g. to bisect a suspected kernel discrepancy);
  every result except the FMA-contracted SGD v-update is bitwise
  identical either way.

Config files use the same keys with underscores (transport, update_mode,
cols_per_token, data_cache, cluster, wire_precision, ...); `--config`
values are overridden by explicit flags.
";

fn apply_cli_overrides(cfg: &mut ExperimentConfig, args: &mut Args) -> Result<()> {
    // CLI flag -> config key; values share one parser with config files.
    for (flag, key) in [
        ("dataset", "dataset"),
        ("dataset-task", "dataset_task"),
        ("trainer", "trainer"),
        ("workers", "workers"),
        ("outer-iters", "outer_iters"),
        ("eta", "eta"),
        ("k", "k"),
        ("lambda-w", "lambda_w"),
        ("lambda-v", "lambda_v"),
        ("seed", "seed"),
        ("train-frac", "train_frac"),
        ("eval-every", "eval_every"),
        ("trace", "trace"),
        ("artifacts", "artifacts"),
        ("transport", "transport"),
        ("update-mode", "update_mode"),
        ("cols-per-token", "cols_per_token"),
        ("row-partition", "row_partition"),
        ("data-cache", "data_cache"),
        ("cluster", "cluster"),
        ("cluster-secret", "cluster_secret"),
        ("wire-precision", "wire_precision"),
    ] {
        if let Some(v) = args.get(flag) {
            cfg.set(key, &v).with_context(|| format!("--{flag}"))?;
        }
    }
    if args.has("xla-eval") {
        cfg.xla_eval = true;
    }
    Ok(())
}

fn cmd_train(mut args: Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(&path)?,
        None => ExperimentConfig::default(),
    };
    apply_cli_overrides(&mut cfg, &mut args)?;
    let quiet = args.has("quiet");
    let save_model = args.get("save-model");
    args.finish()?;

    if !quiet {
        println!("== dsfacto train ==");
        println!("{}", cfg.dump());
    }

    let summary = run_experiment(&cfg)?;

    let out = &summary.output;
    if !quiet {
        for pt in &out.trace {
            let test_str = match &pt.test {
                Some(m) => match summary.task {
                    Task::Regression => format!(" test_rmse={:.5}", m.rmse),
                    Task::Classification => format!(" test_acc={:.4}", m.accuracy),
                },
                None => String::new(),
            };
            println!(
                "iter {:>4}  t={:>9}  objective={:.6}  train_loss={:.6}{}",
                pt.iter,
                human_secs(pt.secs),
                pt.objective,
                pt.train_loss,
                test_str
            );
        }
    }
    println!(
        "trained {} on {} ({} examples, {} features) in {} — final objective {:.6}",
        cfg.trainer.name(),
        cfg.dataset.name(),
        summary.train_n,
        summary.train_d,
        human_secs(out.wall_secs),
        out.trace.last().map(|p| p.objective).unwrap_or(f64::NAN),
    );
    // Streaming (`cache:` + train_frac = 1) runs have no held-out set:
    // the final metrics cover the cached training rows.
    let eval_label = if summary.test.is_some() {
        "test"
    } else {
        "train (train_frac = 1)"
    };
    match summary.task {
        Task::Regression => println!("{eval_label} RMSE {:.5}", summary.final_eval.rmse),
        Task::Classification => println!(
            "{eval_label} accuracy {:.4} (AUC {:.4})",
            summary.final_eval.accuracy, summary.final_eval.auc
        ),
    }
    if let Some(x) = &summary.final_eval_xla {
        println!(
            "XLA request-path eval: loss={:.6} headline={:.5}",
            x.loss,
            x.headline(summary.task)
        );
    }
    if let Some(r) = &summary.residency {
        println!(
            "streaming: peak resident {} shard(s) / {} bytes; prefetch {} hit(s), {} miss(es)",
            r.peak_resident_shards, r.peak_resident_bytes, r.prefetch_hits, r.prefetch_misses
        );
    }
    if let Some(stats) = &summary.stats {
        println!(
            "engine: {} messages, {} bytes, {} update visits, {} coordinate updates, holdback peak {}, shard imbalance {:.3}",
            stats.messages, stats.bytes, stats.update_visits, stats.coordinate_updates,
            stats.holdback_peak, stats.partition.imbalance
        );
    }
    if let Some(path) = save_model {
        fm::io::save(&out.model, &path)?;
        println!("model saved to {path}");
    }
    Ok(())
}

fn cmd_driver(mut args: Args) -> Result<()> {
    use dsfacto::cluster::runtime::{run_driver, ClusterSpec, DriverOptions};
    use std::time::Duration;

    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(&path)?,
        None => ExperimentConfig::default(),
    };
    apply_cli_overrides(&mut cfg, &mut args)?;
    // `--addr HOST:PORT` is the short form of `--cluster driver:HOST:PORT,p=<workers>`.
    if let Some(addr) = args.get("addr") {
        cfg.cluster = Some(ClusterSpec::Driver {
            addr,
            p: cfg.workers.max(1),
        });
    }
    let quiet = args.has("quiet");
    let save_model = args.get("save-model");
    let ckpt_dir = args.get("ckpt-dir").map(std::path::PathBuf::from);
    let ckpt_every: u32 = args.get_or("ckpt-every", 1)?;
    let join_timeout: u64 = args.get_or("join-timeout", 30)?;
    let heartbeat_timeout: u64 = args.get_or("heartbeat-timeout", 10)?;
    let stall_timeout: u64 = args.get_or("stall-timeout", 60)?;
    let max_restarts: u32 = args.get_or("max-restarts", 3)?;
    let resume = args.has("resume");
    let chaos = dsfacto::cluster::chaos::ChaosPlan::from_flag_or_env(args.get("chaos").as_deref())?;
    args.finish()?;

    if !quiet {
        println!("== dsfacto driver ==");
        println!("{}", cfg.dump());
    }
    let report = run_driver(&DriverOptions {
        cfg,
        ckpt_dir,
        ckpt_every,
        join_timeout: Duration::from_secs(join_timeout),
        heartbeat_timeout: Duration::from_secs(heartbeat_timeout),
        stall_timeout: Duration::from_secs(stall_timeout),
        max_generations: max_restarts.saturating_add(1),
        resume,
        chaos,
        quiet,
    })?;
    println!(
        "cluster run done in {}: {} iterations, {} generation(s), {} messages, {} bytes — final objective {:.6}",
        human_secs(report.wall_secs),
        report.trace.last().map(|p| p.iter).unwrap_or(0),
        report.generations,
        report.messages,
        report.bytes,
        report.trace.last().map(|p| p.objective).unwrap_or(f64::NAN),
    );
    if let Some(path) = save_model {
        fm::io::save(&report.model, &path)?;
        println!("model saved to {path}");
    }
    Ok(())
}

fn cmd_worker(mut args: Args) -> Result<()> {
    use dsfacto::cluster::runtime::{run_worker, WorkerOptions};
    use std::time::Duration;

    let driver_addr: String = args.require("driver")?;
    let data_cache = args.get("data-cache");
    let ckpt_dir = args.get("ckpt-dir").map(std::path::PathBuf::from);
    let ckpt_every: u32 = args.get_or("ckpt-every", 1)?;
    let connect_timeout: u64 = args.get_or("connect-timeout", 30)?;
    let cluster_secret = args.get("cluster-secret");
    let wire_precision = match args.get("wire-precision") {
        Some(v) => dsfacto::cluster::codec::WirePrecision::parse(&v)
            .context("--wire-precision")?,
        None => dsfacto::cluster::codec::WirePrecision::F32,
    };
    let chaos = dsfacto::cluster::chaos::ChaosPlan::from_flag_or_env(args.get("chaos").as_deref())?;
    args.finish()?;

    run_worker(&WorkerOptions {
        driver_addr,
        data_cache,
        ckpt_dir,
        ckpt_every,
        connect_timeout: Duration::from_secs(connect_timeout),
        cluster_secret,
        wire_precision,
        chaos,
    })
}

fn cmd_ingest(mut args: Args) -> Result<()> {
    use dsfacto::data::libsvm::{stream_ingest, IngestOptions};
    use dsfacto::partition::RowStrategy;

    let input: String = args.require("dataset")?;
    let out_dir: String = args.require("data-cache")?;
    let task = match args.get("dataset-task") {
        Some(t) => Task::parse(&t)?,
        None => Task::Classification,
    };
    let strategy = match args.get("row-partition") {
        Some(s) => RowStrategy::parse(&s)?,
        None => RowStrategy::Contiguous,
    };
    let shards: usize = args.get_or("shards", 4)?;
    let chunk_rows: usize = args.get_or("chunk-rows", 4096)?;
    let n_features = match args.get("n-features") {
        Some(v) => Some(v.parse::<usize>().context("--n-features")?),
        None => None,
    };
    args.finish()?;

    let name = std::path::Path::new(&input)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or(input.as_str())
        .to_string();
    let opts = IngestOptions {
        task,
        n_features,
        strategy,
        shards,
        chunk_rows,
    };
    let report = stream_ingest(&input, &name, &opts, &out_dir)?;
    println!(
        "ingested {input} -> {out_dir}: {} rows, {} features, {} nnz ({} indices)",
        report.n,
        report.d,
        report.nnz,
        if report.one_based { "1-based" } else { "0-based" }
    );
    println!(
        "  plan: {} x {shards} shards; {} chunks flushed (peak {} rows / {} bytes); \
         peak shard {} bytes; peak resident {} bytes",
        strategy.spec(),
        report.chunks_flushed,
        report.peak_chunk_rows,
        report.peak_chunk_bytes,
        report.peak_shard_bytes,
        report.peak_resident_bytes,
    );
    println!(
        "  train with: dsfacto train --dataset cache:{out_dir} --data-cache {out_dir} \
         --workers {shards} --row-partition {} --train-frac 1",
        strategy.spec()
    );
    Ok(())
}

fn cmd_serve(mut args: Args) -> Result<()> {
    use dsfacto::serve::{serve, ServeOptions};
    use std::time::Duration;

    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(&path)?,
        None => ExperimentConfig::default(),
    };
    // Serve flags map onto config keys like the train flags do.
    for (flag, key) in [
        ("addr", "serve_addr"),
        ("model", "serve_model"),
        ("max-batch", "serve_max_batch"),
        ("batch-window-us", "serve_batch_window_us"),
        ("col-blocks", "serve_col_blocks"),
        ("reload-poll-ms", "serve_reload_poll_ms"),
    ] {
        if let Some(v) = args.get(flag) {
            cfg.set(key, &v).with_context(|| format!("--{flag}"))?;
        }
    }
    args.finish()?;
    let model_path = match &cfg.serve_model {
        Some(p) => std::path::PathBuf::from(p),
        None => bail!("serve needs a checkpoint: --model FILE (config key serve_model)"),
    };

    let handle = serve(&ServeOptions {
        addr: cfg.serve_addr.clone(),
        model_path,
        col_blocks: cfg.serve_col_blocks,
        max_batch: cfg.serve_max_batch,
        batch_window: Duration::from_micros(cfg.serve_batch_window_us),
        reload_poll: Duration::from_millis(cfg.serve_reload_poll_ms),
    })?;
    println!("dsfacto serve: scoring on {}", handle.addr());
    // Serve until killed; the watcher and acceptor threads do the work.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_score(mut args: Args) -> Result<()> {
    use dsfacto::data::libsvm;
    use dsfacto::serve::ScoreClient;

    let addr = args
        .get("addr")
        .unwrap_or_else(|| ExperimentConfig::default().serve_addr);
    let data = args.get("data");
    let want_stats = args.has("stats");
    args.finish()?;

    let mut client = ScoreClient::connect(&addr)?;
    if want_stats {
        let s = client.stats()?;
        println!(
            "model: d={} k={} col_blocks={} generation={} fingerprint={:016x}",
            s.d, s.k, s.col_blocks, s.generation, s.fingerprint
        );
        println!(
            "served: {} requests, {} rows, {} batches; connection arenas: staging {} B, scratch {} B",
            s.requests, s.rows, s.batches, s.staging_capacity, s.scratch_capacity
        );
        return Ok(());
    }
    let path = match data {
        Some(p) => p,
        None => bail!("score needs --data FILE (LIBSVM rows) or --stats"),
    };
    // Labels in the file are ignored; only the features are scored.
    let ds = libsvm::load(&path, "score-input", Task::Regression, None)?;
    let rows: Vec<(&[u32], &[f32])> = (0..ds.n()).map(|i| ds.rows.row(i)).collect();
    let scores = client.score(&rows)?;
    for s in scores {
        println!("{s}");
    }
    Ok(())
}

fn cmd_evaluate(mut args: Args) -> Result<()> {
    let model_path: String = args.require("model")?;
    let dataset: String = args.require("dataset")?;
    let use_xla = args.has("xla");
    let artifacts = args.get("artifacts").unwrap_or_else(|| "artifacts".into());
    let task = args.get("dataset-task");
    let seed: u64 = args.get_or("seed", 42)?;
    args.finish()?;

    let model = fm::io::load(&model_path)?;
    let mut cfg = ExperimentConfig::default();
    cfg.set("dataset", &dataset)?;
    if let Some(t) = task {
        cfg.set("dataset_task", &t)?;
    }
    let ds = cfg.dataset.load(seed)?;
    anyhow::ensure!(
        ds.d() == model.d,
        "model d={} but dataset d={}",
        model.d,
        ds.d()
    );

    let metrics = if use_xla {
        Evaluator::for_dataset(&artifacts, &ds)
            .context("load score artifact")?
            .evaluate(&model, &ds)?
    } else {
        dsfacto::metrics::evaluate(&model, &ds)
    };
    println!(
        "n={} loss={:.6} rmse={:.5} accuracy={:.4} auc={:.4} ({})",
        ds.n(),
        metrics.loss,
        metrics.rmse,
        metrics.accuracy,
        metrics.auc,
        if use_xla { "XLA scorer" } else { "rust scorer" }
    );
    Ok(())
}

fn cmd_inspect(mut args: Args) -> Result<()> {
    let model_path: String = args.require("model")?;
    args.finish()?;
    let m = fm::io::load(&model_path)?;
    let wnorm: f64 = m.w.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let vnorm: f64 = m.v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    println!("DSFM model {model_path}");
    println!("  d={} k={} params={}", m.d, m.k, m.n_params());
    println!("  w0={:.6} |w|={wnorm:.6} |V|={vnorm:.6}", m.w0);
    Ok(())
}

fn cmd_datasets(args: Args) -> Result<()> {
    args.finish()?;
    println!("{:<10} {:>8} {:>8} {:>4}  task            density", "name", "N", "D", "K");
    for name in SynthSpec::table2_names() {
        let spec = SynthSpec::table2(name)?;
        println!(
            "{:<10} {:>8} {:>8} {:>4}  {:<15} {:.4}",
            spec.name,
            spec.n,
            spec.d,
            spec.k,
            spec.task.name(),
            spec.density
        );
    }
    Ok(())
}

fn cmd_artifacts(mut args: Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or_else(|| "artifacts".into());
    args.finish()?;
    if !Runtime::available(&dir) {
        bail!("no manifest in {dir:?}; run `make artifacts`");
    }
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("{:<10} {:<10} {:<15} {:>4} {:>6} {:>3}  file", "name", "entry", "task", "B", "D", "K");
    for e in rt.manifest().entries() {
        println!(
            "{:<10} {:<10} {:<15} {:>4} {:>6} {:>3}  {}",
            e.name, e.entry, e.task.name(), e.b, e.d, e.k, e.filename
        );
    }
    Ok(())
}
