//! The per-thread scratch arena behind the fused kernels, plus the
//! 32-byte-aligned f32 buffer both the arena and the kernel's factor
//! matrix live in.
//!
//! Every fused kernel entry point ([`FmKernel::score`],
//! [`FmKernel::score_grad_step`], …) takes a `&mut Scratch` instead of
//! allocating: the arena owns the lane-padded accumulator buffers (factor
//! sums `a`, squared sums `s2`, and a generic per-column gradient buffer
//! `gv`) and grows them on first use, so the steady state performs **zero
//! heap allocation** per example.
//!
//! ## Contract
//!
//! * One `Scratch` per thread. The arena is plain data (no interior
//!   mutability); sharing one across threads is prevented by `&mut`.
//! * A `Scratch` is not tied to one model: [`Scratch::ensure`] grows the
//!   buffers monotonically, so the same arena can serve models of
//!   different K (capacity never shrinks).
//! * After a scoring call, [`Scratch::factor_sums`] exposes the factor
//!   sums `a_k` (paper eq. 10) of the **most recent** example scored with
//!   this arena — the cache the per-example update (eq. 13) needs.
//!
//! [`FmKernel::score`]: super::FmKernel::score
//! [`FmKernel::score_grad_step`]: super::FmKernel::score_grad_step

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

use super::fused::padded_k;

/// 32-byte alignment: one AVX2 register row.
const ALIGN: usize = 32;

/// A growable f32 buffer whose storage is 32-byte aligned, so every
/// lane-block row of the kernel-owned accumulators and the AoSoA factor
/// matrix starts on an AVX2 register boundary (`kp` is a multiple of
/// [`LANES`](super::LANES), so row offsets are multiples of 32 bytes).
///
/// The explicit SIMD kernels in [`super::simd`] use unaligned-load
/// instructions for safety on caller-provided slices; this alignment
/// guarantees those instructions run at full aligned speed on the
/// kernel-owned buffers. Derefs to `[f32]`, so call sites read like a
/// `Vec<f32>`. (A dedicated type rather than an over-aligned `Box`,
/// because a `Box<[f32]>` with a stricter-than-natural alignment would be
/// undefined behavior to drop.)
pub struct AlignedF32 {
    ptr: NonNull<f32>,
    len: usize,
}

impl AlignedF32 {
    /// An empty buffer (no allocation).
    pub fn new() -> Self {
        AlignedF32 {
            ptr: NonNull::dangling(),
            len: 0,
        }
    }

    /// A zero-initialized buffer of `n` floats.
    pub fn zeroed(n: usize) -> Self {
        let mut b = AlignedF32::new();
        b.resize_zeroed(n);
        b
    }

    fn layout(n: usize) -> Layout {
        Layout::array::<f32>(n)
            .and_then(|l| l.align_to(ALIGN))
            .expect("AlignedF32 layout overflow")
    }

    /// Resizes to exactly `n` floats: existing values are preserved up to
    /// `min(len, n)` and any new tail is zero.
    pub fn resize_zeroed(&mut self, n: usize) {
        if n == self.len {
            return;
        }
        let fresh = if n == 0 {
            NonNull::dangling()
        } else {
            let layout = Self::layout(n);
            // SAFETY: `layout` has non-zero size here.
            let raw = unsafe { alloc_zeroed(layout) };
            let Some(p) = NonNull::new(raw.cast::<f32>()) else {
                handle_alloc_error(layout)
            };
            let keep = self.len.min(n);
            // SAFETY: both allocations are live, disjoint and at least
            // `keep` floats long.
            unsafe { p.as_ptr().copy_from_nonoverlapping(self.ptr.as_ptr(), keep) };
            p
        };
        self.release();
        self.ptr = fresh;
        self.len = n;
    }

    fn release(&mut self) {
        if self.len > 0 {
            // SAFETY: `ptr` was allocated with exactly this layout.
            unsafe { dealloc(self.ptr.as_ptr().cast(), Self::layout(self.len)) };
            self.ptr = NonNull::dangling();
            self.len = 0;
        }
    }
}

impl Drop for AlignedF32 {
    fn drop(&mut self) {
        self.release();
    }
}

impl Deref for AlignedF32 {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        // SAFETY: `ptr` covers `len` initialized floats (dangling-but-
        // aligned is valid for a zero-length slice).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedF32 {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in `deref`, and `&mut self` guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Clone for AlignedF32 {
    fn clone(&self) -> Self {
        let mut b = AlignedF32::zeroed(self.len);
        b.copy_from_slice(self);
        b
    }
}

impl Default for AlignedF32 {
    fn default() -> Self {
        AlignedF32::new()
    }
}

impl std::fmt::Debug for AlignedF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

impl PartialEq for AlignedF32 {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

// SAFETY: plain owned f32 storage with no interior mutability — moving or
// sharing it across threads is as safe as a Vec<f32>.
unsafe impl Send for AlignedF32 {}
unsafe impl Sync for AlignedF32 {}

/// Reusable lane-padded accumulator buffers for the fused kernels.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    /// Factor sums `a_k` (padded to a lane multiple).
    pub(super) a: AlignedF32,
    /// Squared factor sums `s2_k` (padded to a lane multiple).
    pub(super) s2: AlignedF32,
    /// Generic per-column gradient buffer (padded); used by the engine's
    /// column-visit updates so they need no per-visit allocation.
    pub gv: AlignedF32,
}

impl Scratch {
    /// An empty arena; buffers grow on first use.
    pub fn new() -> Self {
        Scratch::default()
    }

    /// An arena pre-sized for models with up to `k` factors.
    pub fn for_k(k: usize) -> Self {
        let mut s = Scratch::default();
        s.ensure(padded_k(k));
        s
    }

    /// Grows the buffers to at least `kp` floats (`kp` must be the padded
    /// factor width). Monotone: never shrinks, so reuse across models of
    /// different K is allocation-free once the largest has been seen.
    #[inline]
    pub fn ensure(&mut self, kp: usize) {
        if self.a.len() < kp {
            self.a.resize_zeroed(kp);
            self.s2.resize_zeroed(kp);
            self.gv.resize_zeroed(kp);
        }
    }

    /// The `(a, s2)` accumulator pair, sized to `kp` floats.
    #[inline]
    pub(super) fn sums(&mut self, kp: usize) -> (&mut [f32], &mut [f32]) {
        self.ensure(kp);
        (&mut self.a[..kp], &mut self.s2[..kp])
    }

    /// Factor sums `a_k` of the most recent example scored through this
    /// arena (first `k` entries; the padding lanes beyond are zero).
    #[inline]
    pub fn factor_sums(&self, k: usize) -> &[f32] {
        &self.a[..k]
    }

    /// Current accumulator capacity in floats (the grow-only watermark).
    /// Because [`ensure`](Scratch::ensure) only ever grows, a steady
    /// workload leaves this constant — the zero-steady-state-allocation
    /// tests sample it before and after a load phase and assert equality.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.a.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_monotonically() {
        let mut s = Scratch::new();
        s.ensure(8);
        assert_eq!(s.a.len(), 8);
        s.ensure(4); // no shrink
        assert_eq!(s.a.len(), 8);
        s.ensure(24);
        assert_eq!(s.a.len(), 24);
        assert_eq!(s.s2.len(), 24);
        assert_eq!(s.gv.len(), 24);
    }

    #[test]
    fn for_k_pads_to_lane_multiple() {
        let s = Scratch::for_k(5);
        assert_eq!(s.a.len(), super::super::LANES);
        let s = Scratch::for_k(9);
        assert_eq!(s.a.len(), 2 * super::super::LANES);
    }

    #[test]
    fn sums_are_distinct_buffers() {
        let mut s = Scratch::for_k(3);
        let (a, s2) = s.sums(8);
        a[0] = 1.0;
        s2[0] = 2.0;
        assert_eq!(s.a[0], 1.0);
        assert_eq!(s.s2[0], 2.0);
    }

    #[test]
    fn aligned_buffer_is_32_byte_aligned_and_grows() {
        for n in [1usize, 8, 24, 1024] {
            let b = AlignedF32::zeroed(n);
            assert_eq!(b.as_ptr() as usize % 32, 0, "n={n}");
            assert_eq!(b.len(), n);
            assert!(b.iter().all(|&x| x == 0.0));
        }
        let mut b = AlignedF32::zeroed(8);
        b[3] = 7.5;
        b.resize_zeroed(64);
        assert_eq!(b.as_ptr() as usize % 32, 0);
        assert_eq!(b[3], 7.5, "grow must preserve contents");
        assert!(b[8..].iter().all(|&x| x == 0.0), "grown tail must be zero");
        let c = b.clone();
        assert_eq!(c, b);
        assert_eq!(c[3], 7.5);
        b.resize_zeroed(4);
        assert_eq!(b.len(), 4);
        assert_eq!(b[3], 7.5);
        let empty = AlignedF32::new();
        assert!(empty.is_empty());
    }
}
