//! The per-thread scratch arena behind the fused kernels.
//!
//! Every fused kernel entry point ([`FmKernel::score`],
//! [`FmKernel::score_grad_step`], …) takes a `&mut Scratch` instead of
//! allocating: the arena owns the lane-padded accumulator buffers (factor
//! sums `a`, squared sums `s2`, and a generic per-column gradient buffer
//! `gv`) and grows them on first use, so the steady state performs **zero
//! heap allocation** per example.
//!
//! ## Contract
//!
//! * One `Scratch` per thread. The arena is plain data (no interior
//!   mutability); sharing one across threads is prevented by `&mut`.
//! * A `Scratch` is not tied to one model: [`Scratch::ensure`] grows the
//!   buffers monotonically, so the same arena can serve models of
//!   different K (capacity never shrinks).
//! * After a scoring call, [`Scratch::factor_sums`] exposes the factor
//!   sums `a_k` (paper eq. 10) of the **most recent** example scored with
//!   this arena — the cache the per-example update (eq. 13) needs.
//!
//! [`FmKernel::score`]: super::FmKernel::score
//! [`FmKernel::score_grad_step`]: super::FmKernel::score_grad_step

use super::fused::padded_k;

/// Reusable lane-padded accumulator buffers for the fused kernels.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    /// Factor sums `a_k` (padded to a lane multiple).
    pub(super) a: Vec<f32>,
    /// Squared factor sums `s2_k` (padded to a lane multiple).
    pub(super) s2: Vec<f32>,
    /// Generic per-column gradient buffer (padded); used by the engine's
    /// column-visit updates so they need no per-visit allocation.
    pub gv: Vec<f32>,
}

impl Scratch {
    /// An empty arena; buffers grow on first use.
    pub fn new() -> Self {
        Scratch::default()
    }

    /// An arena pre-sized for models with up to `k` factors.
    pub fn for_k(k: usize) -> Self {
        let mut s = Scratch::default();
        s.ensure(padded_k(k));
        s
    }

    /// Grows the buffers to at least `kp` floats (`kp` must be the padded
    /// factor width). Monotone: never shrinks, so reuse across models of
    /// different K is allocation-free once the largest has been seen.
    #[inline]
    pub fn ensure(&mut self, kp: usize) {
        if self.a.len() < kp {
            self.a.resize(kp, 0.0);
            self.s2.resize(kp, 0.0);
            self.gv.resize(kp, 0.0);
        }
    }

    /// The `(a, s2)` accumulator pair, sized to `kp` floats.
    #[inline]
    pub(super) fn sums(&mut self, kp: usize) -> (&mut [f32], &mut [f32]) {
        self.ensure(kp);
        (&mut self.a[..kp], &mut self.s2[..kp])
    }

    /// Factor sums `a_k` of the most recent example scored through this
    /// arena (first `k` entries; the padding lanes beyond are zero).
    #[inline]
    pub fn factor_sums(&self, k: usize) -> &[f32] {
        &self.a[..k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_monotonically() {
        let mut s = Scratch::new();
        s.ensure(8);
        assert_eq!(s.a.len(), 8);
        s.ensure(4); // no shrink
        assert_eq!(s.a.len(), 8);
        s.ensure(24);
        assert_eq!(s.a.len(), 24);
        assert_eq!(s.s2.len(), 24);
        assert_eq!(s.gv.len(), 24);
    }

    #[test]
    fn for_k_pads_to_lane_multiple() {
        let s = Scratch::for_k(5);
        assert_eq!(s.a.len(), super::super::LANES);
        let s = Scratch::for_k(9);
        assert_eq!(s.a.len(), 2 * super::super::LANES);
    }

    #[test]
    fn sums_are_distinct_buffers() {
        let mut s = Scratch::for_k(3);
        let (a, s2) = s.sums(8);
        a[0] = 1.0;
        s2[0] = 2.0;
        assert_eq!(s.a[0], 1.0);
        assert_eq!(s.s2[0], 2.0);
    }
}
