//! The fused lane-blocked FM kernels.
//!
//! [`FmKernel`] holds the model parameters with `V` in an AoSoA
//! ("array-of-structures-of-arrays") layout: each feature's factor row is
//! padded to a multiple of [`LANES`] (8) f32 values, so every inner loop
//! runs over fixed-width 8-lane blocks with no remainder — the shape LLVM
//! auto-vectorizes into full-width SIMD on every x86-64/aarch64 target.
//! Padding lanes are identically zero and stay zero under every update
//! (their gradients vanish because `v = 0` and `a = 0` there), so the
//! kernels never mask.
//!
//! The three fused entry points replace the crate's former multi-pass
//! scalar hot path:
//!
//! * [`FmKernel::score`] — linear term, factor sums `a` and squared sums
//!   `s2` (paper eq. 4 / eq. 10) accumulated in **one pass** over the
//!   non-zeros (the scalar path made two).
//! * [`FmKernel::score_grad_step`] — score + loss multiplier + the full
//!   eq. 11-13 SGD update in two passes total (score pass + update pass);
//!   the scalar `sgd_update_example` needed three. An AdaGrad variant
//!   ([`FmKernel::score_grad_step_adagrad`]) keeps its accumulators in the
//!   same lane-blocked layout.
//! * [`FmKernel::score_batch`] — scores every row of a CSR block; backs
//!   [`Predictor::predict_batch`](crate::train::Predictor::predict_batch)
//!   and [`FmModel::objective`](crate::fm::FmModel::objective).
//!
//! All of them take a per-thread [`Scratch`] arena, so the steady state
//! allocates nothing. `FmModel::score_naive` (paper eq. 2) remains the
//! independent test oracle; `rust/tests/kernel_properties.rs` holds the
//! parity suite.
//!
//! Every entry point dispatches once per call on
//! [`simd::backend`](super::simd::backend): the lane-blocked loops below
//! are the portable fallback (and the parity oracle), and on x86_64 CPUs
//! with AVX2+FMA the explicit intrinsics in [`super::simd`] run instead —
//! bitwise-identical for scoring, ULP-bounded for the FMA-contracted
//! eq. 13 update (see the `simd` module docs for the contract).
//! [`FmKernel::score_backend`] lets benchmarks force a specific backend.

use crate::data::{Csr, Dataset, Task};
use crate::fm::{loss, FmModel};

use super::scratch::{AlignedF32, Scratch};
use super::simd::{self, KernelBackend};

/// f32 lanes per block: 8 matches one AVX2 register (and two NEON ones).
pub const LANES: usize = 8;

/// The padded factor width for `k` factors (smallest lane multiple >= k).
#[inline]
pub fn padded_k(k: usize) -> usize {
    k.div_ceil(LANES) * LANES
}

/// FM parameters with `V` lane-blocked: row `j` occupies
/// `v[j*kp .. (j+1)*kp]` where `kp = padded_k(k)`; entries past `k` are
/// zero padding. Build one from an [`FmModel`] with
/// [`from_model`](FmKernel::from_model), train through the fused kernels,
/// and copy back with [`write_model`](FmKernel::write_model).
#[derive(Debug, Clone)]
pub struct FmKernel {
    d: usize,
    k: usize,
    /// Padded factor width (`padded_k(k)`).
    kp: usize,
    /// Global bias `w0`.
    pub w0: f32,
    /// Linear weights (length D).
    pub w: Vec<f32>,
    /// Lane-blocked factors, `D x kp` row-major (padding lanes zero),
    /// 32-byte aligned for the explicit SIMD kernels.
    v: AlignedF32,
}

impl FmKernel {
    /// Builds the lane-blocked view of a model (copies the parameters).
    pub fn from_model(m: &FmModel) -> Self {
        let kp = padded_k(m.k);
        let mut v = AlignedF32::zeroed(m.d * kp);
        for j in 0..m.d {
            v[j * kp..j * kp + m.k].copy_from_slice(&m.v[j * m.k..(j + 1) * m.k]);
        }
        FmKernel {
            d: m.d,
            k: m.k,
            kp,
            w0: m.w0,
            w: m.w.clone(),
            v,
        }
    }

    /// Copies the parameters back into a same-shape model (strips padding).
    pub fn write_model(&self, m: &mut FmModel) {
        assert_eq!(
            (m.d, m.k),
            (self.d, self.k),
            "kernel/model shape mismatch: kernel ({}, {}) vs model ({}, {})",
            self.d,
            self.k,
            m.d,
            m.k
        );
        m.w0 = self.w0;
        m.w.copy_from_slice(&self.w);
        for j in 0..self.d {
            m.v[j * self.k..(j + 1) * self.k]
                .copy_from_slice(&self.v[j * self.kp..j * self.kp + self.k]);
        }
    }

    /// The parameters as a fresh [`FmModel`].
    pub fn to_model(&self) -> FmModel {
        let mut m = FmModel::zeros(self.d, self.k);
        self.write_model(&mut m);
        m
    }

    /// Number of features D.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of factors K.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// The padded factor width (`padded_k(k)`).
    #[inline]
    pub fn padded(&self) -> usize {
        self.kp
    }

    /// Factor row `v_j` (length K; the padding lanes are not exposed).
    #[inline]
    pub fn vrow(&self, j: usize) -> &[f32] {
        &self.v[j * self.kp..j * self.kp + self.k]
    }

    /// Mutable factor row `v_j` (length K; padding stays private so it
    /// cannot be un-zeroed).
    #[inline]
    pub fn vrow_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.v[j * self.kp..j * self.kp + self.k]
    }

    /// The lane-padded factor rows `[lo, hi)` as one contiguous
    /// `(hi - lo) x padded_k(k)` slice, padding lanes (invariantly zero)
    /// included. This read-only view is what the NOMAD engine deals its
    /// lane-blocked token payloads from.
    #[inline]
    pub fn vrows_padded(&self, lo: usize, hi: usize) -> &[f32] {
        &self.v[lo * self.kp..hi * self.kp]
    }

    /// The fused accumulation pass through an explicit backend.
    #[inline]
    fn accumulate_with(
        &self,
        b: KernelBackend,
        idx: &[u32],
        val: &[f32],
        a: &mut [f32],
        s2: &mut [f32],
    ) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if b == KernelBackend::Avx2 {
            // SAFETY: `Avx2` is only selected (or force-accepted) when
            // the CPU supports avx2+fma.
            return unsafe { simd::accumulate(self.w0, &self.w, &self.v, self.kp, idx, val, a, s2) };
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = b;
        self.accumulate_lanes(idx, val, a, s2)
    }

    /// The fused accumulation pass: linear term plus lane-blocked factor
    /// sums `a` and squared sums `s2`, one sweep over the non-zeros.
    /// Returns the linear term `w0 + sum_j w_j x_j`. This lane-blocked
    /// loop is the portable fallback and the bitwise oracle for
    /// [`simd::accumulate`].
    #[inline]
    fn accumulate_lanes(&self, idx: &[u32], val: &[f32], a: &mut [f32], s2: &mut [f32]) -> f32 {
        debug_assert_eq!(a.len(), self.kp);
        debug_assert_eq!(s2.len(), self.kp);
        a.fill(0.0);
        s2.fill(0.0);
        let mut linear = self.w0;
        for (j, &x) in idx.iter().zip(val) {
            let j = *j as usize;
            linear += self.w[j] * x;
            let vj = &self.v[j * self.kp..(j + 1) * self.kp];
            for ((ab, sb), vb) in a
                .chunks_exact_mut(LANES)
                .zip(s2.chunks_exact_mut(LANES))
                .zip(vj.chunks_exact(LANES))
            {
                for l in 0..LANES {
                    let vx = vb[l] * x;
                    ab[l] += vx;
                    sb[l] += vx * vx;
                }
            }
        }
        linear
    }

    /// The pairwise term through an explicit backend. `pub(crate)` so the
    /// column-blocked scorer ([`super::blocked`]) finalizes its per-row
    /// accumulators through the exact reduction the fused path uses —
    /// the bitwise-parity contract between the two depends on it.
    #[inline]
    pub(crate) fn pair_term_with(b: KernelBackend, a: &[f32], s2: &[f32]) -> f32 {
        #[cfg(target_arch = "x86_64")]
        if b == KernelBackend::Avx2 {
            // SAFETY: as in `accumulate_with`.
            return 0.5 * unsafe { simd::pair_sum(a, s2) };
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = b;
        Self::pair_term_lanes(a, s2)
    }

    /// The pairwise term `0.5 * sum_k (a_k^2 - s2_k)` over padded lanes
    /// (padding contributes exactly zero). Portable fallback and bitwise
    /// oracle for [`simd::pair_sum`].
    #[inline]
    fn pair_term_lanes(a: &[f32], s2: &[f32]) -> f32 {
        let mut pair = 0f32;
        for (ab, sb) in a.chunks_exact(LANES).zip(s2.chunks_exact(LANES)) {
            for l in 0..LANES {
                pair += ab[l] * ab[l] - sb[l];
            }
        }
        0.5 * pair
    }

    /// FM score of one sparse example (paper eq. 4) in a single fused
    /// pass through the process-wide [`simd::backend`]. The factor sums
    /// remain readable via [`Scratch::factor_sums`] until the arena's
    /// next scoring call.
    #[inline]
    pub fn score(&self, idx: &[u32], val: &[f32], scratch: &mut Scratch) -> f32 {
        let b = simd::backend();
        let (a, s2) = scratch.sums(self.kp);
        let linear = self.accumulate_with(b, idx, val, a, s2);
        linear + Self::pair_term_with(b, a, s2)
    }

    /// [`score`](FmKernel::score) through an explicitly chosen backend —
    /// the benchmark harness forces the lanes/AVX2 variants side by side
    /// with this. Panics if `b` cannot run on this CPU.
    pub fn score_backend(
        &self,
        b: KernelBackend,
        idx: &[u32],
        val: &[f32],
        scratch: &mut Scratch,
    ) -> f32 {
        assert!(
            b.available(),
            "kernel backend {:?} is not available on this CPU",
            b.name()
        );
        let (a, s2) = scratch.sums(self.kp);
        let linear = self.accumulate_with(b, idx, val, a, s2);
        linear + Self::pair_term_with(b, a, s2)
    }

    /// Score plus an explicit copy of the factor sums `a` (eq. 10) into
    /// `a_out` (length K) — the form the G/A synchronization paths need.
    pub fn score_with_sums(
        &self,
        idx: &[u32],
        val: &[f32],
        a_out: &mut [f32],
        scratch: &mut Scratch,
    ) -> f32 {
        debug_assert_eq!(a_out.len(), self.k);
        let f = self.score(idx, val, scratch);
        a_out.copy_from_slice(scratch.factor_sums(self.k));
        f
    }

    /// Scores every row of a sparse block into `out`
    /// (`out.len() == rows.n_rows()`).
    pub fn score_batch(&self, rows: &Csr, out: &mut [f32], scratch: &mut Scratch) {
        assert_eq!(
            out.len(),
            rows.n_rows(),
            "output buffer {} != rows {}",
            out.len(),
            rows.n_rows()
        );
        let (indptr, indices, values) = rows.raw_parts();
        self.score_rows(indptr, indices, values, out, scratch);
    }

    /// [`score_batch`](FmKernel::score_batch) over raw CSR parts: row `i`
    /// is `indices[indptr[i]..indptr[i+1]]` / `values[..]`. This is the
    /// zero-alloc serving entry — a caller that stages rows in reusable
    /// grow-only buffers (the scoring server's request path) scores them
    /// without ever building a [`Csr`], whose constructor takes owned
    /// `Vec`s and would force a fresh allocation per batch.
    pub fn score_rows(
        &self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        assert_eq!(
            indptr.len(),
            out.len() + 1,
            "indptr length {} != rows {} + 1",
            indptr.len(),
            out.len()
        );
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        for (i, o) in out.iter_mut().enumerate() {
            let (lo, hi) = (indptr[i], indptr[i + 1]);
            *o = self.score(&indices[lo..hi], &values[lo..hi], scratch);
        }
    }

    /// Mean data loss over a dataset (no regularizer).
    pub fn data_loss(&self, ds: &Dataset, scratch: &mut Scratch) -> f64 {
        let mut total = 0f64;
        for i in 0..ds.n() {
            let (idx, val) = ds.rows.row(i);
            total += loss::loss(self.score(idx, val, scratch), ds.labels[i], ds.task) as f64;
        }
        total / ds.n().max(1) as f64
    }

    /// The regularized objective (paper eq. 5) over a dataset. Padding
    /// lanes are zero, so summing the padded `v` is exact.
    pub fn objective(
        &self,
        ds: &Dataset,
        lambda_w: f32,
        lambda_v: f32,
        scratch: &mut Scratch,
    ) -> f64 {
        let data = self.data_loss(ds, scratch);
        let rw: f64 = self.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let rv: f64 = self.v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        data + 0.5 * lambda_w as f64 * rw + 0.5 * lambda_v as f64 * rv
    }

    /// Fused score + gradient + SGD update (paper eqs. 11-13) for one
    /// example; returns the example's pre-update loss. Two sweeps over the
    /// non-zeros total (the scalar `sgd_update_example` made three), zero
    /// allocation, and the eq. 13 update uses the pre-update factor sums —
    /// the exact semantics of the scalar reference it replaces.
    ///
    /// The eq. 13 v-update is the one kernel where the AVX2 backend uses
    /// FMA contraction, so under it this step tracks the lane fallback to
    /// a documented ULP bound rather than bitwise (this per-example path
    /// feeds only tolerance-tested trainers; the engine's bitwise column
    /// path goes through [`super::visit`]).
    #[allow(clippy::too_many_arguments)]
    pub fn score_grad_step(
        &mut self,
        idx: &[u32],
        val: &[f32],
        y: f32,
        task: Task,
        eta: f32,
        lambda_w: f32,
        lambda_v: f32,
        scratch: &mut Scratch,
    ) -> f32 {
        let b = simd::backend();
        let kp = self.kp;
        let (a, s2) = scratch.sums(kp);
        let linear = self.accumulate_with(b, idx, val, a, s2);
        let f = linear + Self::pair_term_with(b, a, s2);
        let g = loss::multiplier(f, y, task);
        let l = loss::loss(f, y, task);

        #[cfg(target_arch = "x86_64")]
        let use_avx2 = b == KernelBackend::Avx2;
        // eq. 11 (stochastic form).
        self.w0 -= eta * g;
        for (j, &x) in idx.iter().zip(val) {
            let j = *j as usize;
            // eq. 12.
            let wj = &mut self.w[j];
            *wj -= eta * (g * x + lambda_w * *wj);
            // eq. 13, lane-blocked; padding lanes have v = a = 0 and thus a
            // zero update, so they remain zero.
            let vj = &mut self.v[j * kp..(j + 1) * kp];
            #[cfg(target_arch = "x86_64")]
            if use_avx2 {
                // SAFETY: as in `accumulate_with`.
                unsafe { simd::vrow_step(vj, a, x, g, eta, lambda_v) };
                continue;
            }
            let x2 = x * x;
            for (vb, ab) in vj.chunks_exact_mut(LANES).zip(a.chunks_exact(LANES)) {
                for l in 0..LANES {
                    let vl = vb[l];
                    vb[l] = vl - eta * (g * (x * ab[l] - vl * x2) + lambda_v * vl);
                }
            }
        }
        l
    }

    /// AdaGrad variant of [`score_grad_step`](FmKernel::score_grad_step)
    /// with lane-blocked accumulators; returns the example's loss.
    #[allow(clippy::too_many_arguments)]
    pub fn score_grad_step_adagrad(
        &mut self,
        idx: &[u32],
        val: &[f32],
        y: f32,
        task: Task,
        eta: f32,
        lambda_w: f32,
        lambda_v: f32,
        state: &mut AdaGradLanes,
        scratch: &mut Scratch,
    ) -> f32 {
        assert_eq!(
            (state.gw2.len(), state.kp),
            (self.d, self.kp),
            "AdaGrad state shape mismatch"
        );
        let b = simd::backend();
        let kp = self.kp;
        let (a, s2) = scratch.sums(kp);
        let linear = self.accumulate_with(b, idx, val, a, s2);
        let f = linear + Self::pair_term_with(b, a, s2);
        let g = loss::multiplier(f, y, task);
        let l = loss::loss(f, y, task);

        state.g02 += g * g;
        self.w0 -= eta * g / (state.g02.sqrt() + state.eps);
        for (j, &x) in idx.iter().zip(val) {
            let j = *j as usize;
            let gw = g * x + lambda_w * self.w[j];
            state.gw2[j] += gw * gw;
            self.w[j] -= eta * gw / (state.gw2[j].sqrt() + state.eps);

            let x2 = x * x;
            let vj = &mut self.v[j * kp..(j + 1) * kp];
            let gj = &mut state.gv2[j * kp..(j + 1) * kp];
            for ((vb, gb), ab) in vj
                .chunks_exact_mut(LANES)
                .zip(gj.chunks_exact_mut(LANES))
                .zip(a.chunks_exact(LANES))
            {
                for l in 0..LANES {
                    let vl = vb[l];
                    let gv = g * (x * ab[l] - vl * x2) + lambda_v * vl;
                    gb[l] += gv * gv;
                    vb[l] = vl - eta * gv / (gb[l].sqrt() + state.eps);
                }
            }
        }
        l
    }
}

/// Per-coordinate AdaGrad accumulators in the kernel's lane-blocked
/// layout (the DiFacto-style adaptivity of
/// [`crate::optim::AdaGradState`], fused).
#[derive(Debug, Clone)]
pub struct AdaGradLanes {
    /// Accumulated squared gradients for w (length D).
    pub gw2: Vec<f32>,
    /// Accumulated squared gradients for V (length `D * padded_k(K)`).
    pub gv2: Vec<f32>,
    /// Accumulated squared gradient for w0.
    pub g02: f32,
    /// Numerical floor.
    pub eps: f32,
    kp: usize,
}

impl AdaGradLanes {
    /// Fresh state for a `d x k` kernel.
    pub fn new(d: usize, k: usize) -> Self {
        let kp = padded_k(k);
        AdaGradLanes {
            gw2: vec![0.0; d],
            gv2: vec![0.0; d * kp],
            g02: 0.0,
            eps: 1e-8,
            kp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_model(d: usize, k: usize, seed: u64) -> FmModel {
        let mut rng = Pcg64::seeded(seed);
        let mut m = FmModel::init(d, k, 0.3, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.5);
        }
        m.w0 = 0.4;
        m
    }

    #[test]
    fn padding_rounds_up_to_lanes() {
        assert_eq!(padded_k(1), LANES);
        assert_eq!(padded_k(8), 8);
        assert_eq!(padded_k(9), 16);
        assert_eq!(padded_k(0), 0);
    }

    #[test]
    fn model_roundtrip_is_exact() {
        for k in [1, 3, 8, 11, 33] {
            let m = random_model(7, k, k as u64);
            let kern = FmKernel::from_model(&m);
            assert_eq!(kern.to_model(), m, "k={k}");
            assert_eq!(kern.vrow(3), m.vrow(3));
        }
    }

    #[test]
    fn fused_score_matches_scalar() {
        for k in [1, 4, 7, 16, 40] {
            let m = random_model(12, k, 100 + k as u64);
            let kern = FmKernel::from_model(&m);
            let mut scratch = Scratch::for_k(k);
            let idx = [0u32, 3, 5, 11];
            let val = [0.5f32, -1.5, 2.0, 0.25];
            let fused = kern.score(&idx, &val, &mut scratch);
            let scalar = m.score_sparse(&idx, &val);
            assert!(
                (fused - scalar).abs() < 1e-5 * (1.0 + scalar.abs()),
                "k={k}: {fused} vs {scalar}"
            );
        }
    }

    #[test]
    fn empty_row_scores_bias() {
        let m = random_model(4, 6, 2);
        let kern = FmKernel::from_model(&m);
        let mut scratch = Scratch::new();
        assert_eq!(kern.score(&[], &[], &mut scratch), m.w0);
    }

    #[test]
    fn score_with_sums_exposes_eq10() {
        let m = random_model(6, 3, 3);
        let kern = FmKernel::from_model(&m);
        let mut scratch = Scratch::for_k(3);
        let idx = [1u32, 4];
        let val = [2.0f32, -0.5];
        let mut a = vec![0f32; 3];
        let f = kern.score_with_sums(&idx, &val, &mut a, &mut scratch);
        assert!((f - m.score_sparse(&idx, &val)).abs() < 1e-6);
        for kk in 0..3 {
            let want = m.vrow(1)[kk] * 2.0 + m.vrow(4)[kk] * -0.5;
            assert!((a[kk] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn step_keeps_padding_zero() {
        // After many fused steps, converting back and forth must be
        // loss-free — i.e. nothing leaked into the padding lanes.
        let m = random_model(8, 5, 4);
        let mut kern = FmKernel::from_model(&m);
        let mut scratch = Scratch::for_k(5);
        let idx = [0u32, 2, 7];
        let val = [1.0f32, -2.0, 0.5];
        for step in 0..50 {
            kern.score_grad_step(
                &idx,
                &val,
                if step % 2 == 0 { 1.0 } else { -1.0 },
                Task::Classification,
                0.05,
                1e-3,
                1e-3,
                &mut scratch,
            );
        }
        let back = kern.to_model();
        let rebuilt = FmKernel::from_model(&back);
        assert_eq!(rebuilt.v, kern.v, "padding lanes drifted away from zero");
    }

    #[test]
    fn batch_matches_single_scores() {
        let ds = crate::data::synth::table2_dataset("housing", 8).unwrap();
        let m = random_model(ds.d(), 4, 9);
        let kern = FmKernel::from_model(&m);
        let mut scratch = Scratch::for_k(4);
        let mut out = vec![0f32; ds.n()];
        kern.score_batch(&ds.rows, &mut out, &mut scratch);
        for i in (0..ds.n()).step_by(41) {
            let (idx, val) = ds.rows.row(i);
            assert_eq!(out[i], kern.score(idx, val, &mut scratch));
        }
    }

    #[test]
    fn objective_matches_model_objective_shape() {
        let ds = crate::data::synth::table2_dataset("housing", 10).unwrap();
        let m = random_model(ds.d(), 4, 11);
        let kern = FmKernel::from_model(&m);
        let mut scratch = Scratch::for_k(4);
        let o0 = kern.objective(&ds, 0.0, 0.0, &mut scratch);
        let o1 = kern.objective(&ds, 1.0, 1.0, &mut scratch);
        let rw: f64 = m.w.iter().map(|&x| (x as f64).powi(2)).sum();
        let rv: f64 = m.v.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((o1 - o0 - 0.5 * (rw + rv)).abs() < 1e-6);
    }
}
