//! Explicit AVX2+FMA kernels with one-time runtime dispatch.
//!
//! The lane-blocked loops in [`super::fused`] and [`super::visit`] were
//! laid out for SIMD (AoSoA, 8-lane blocks, no remainders) but still
//! compile as whatever LLVM auto-vectorizes. This module adds the
//! explicit `std::arch` x86_64 intrinsics variants and the
//! [`KernelBackend`] selector that picks between them **once** at
//! startup:
//!
//! * [`backend`] probes the CPU via `is_x86_feature_detected!` on first
//!   call and caches the answer in a `OnceLock`. Setting
//!   `DSFACTO_NO_SIMD=1` in the environment forces the portable
//!   lane-blocked fallback regardless of what the CPU supports.
//! * The lane-blocked code paths stay in-tree as the portable fallback
//!   on non-x86_64 targets **and** as the parity oracle the AVX2
//!   variants are held to.
//!
//! ## Parity contract
//!
//! Every kernel here except [`vrow_step`] applies its floating-point
//! operations in the exact per-lane order of the lane-blocked loop it
//! mirrors — vectorized per-lane products, scalar-sequential horizontal
//! reductions through an 8-float spill buffer — so it is **bitwise
//! identical** to the lane oracle (and therefore preserves the engine's
//! scalar-bitwise end-to-end guarantee in
//! `rust/tests/engine_properties.rs`). [`vrow_step`] (the eq. 13
//! per-example v-update inside `score_grad_step`, a tolerance-tested
//! trainer path) is the one place FMA contraction is allowed: three
//! fused multiply-adds merge one rounding each, so it matches the lane
//! oracle to a documented ULP bound
//! ([`crate::util::prop::assert_ulp_close`]) rather than bitwise.
//!
//! All loads/stores are `loadu`/`storeu`: the kernel-owned buffers
//! ([`Scratch`](super::Scratch), `FmKernel`'s factor matrix) are 32-byte
//! aligned via [`super::scratch::AlignedF32`] — on which unaligned-load
//! instructions run at full aligned speed on every AVX2 CPU — while
//! caller-provided token payloads and worker arenas carry no alignment
//! guarantee, so aligned-only instructions would be undefined behavior
//! there.

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
use crate::data::Task;

/// Which implementation of the hot-path kernels this process runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// The portable lane-blocked loops (auto-vectorized; the parity
    /// oracle and the only backend on non-x86_64 targets).
    Lanes,
    /// Explicit AVX2+FMA intrinsics (x86_64 with `avx2` + `fma`).
    Avx2,
}

impl KernelBackend {
    /// Stable lowercase name (used in bench entry labels and logs).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Lanes => "lanes",
            KernelBackend::Avx2 => "avx2",
        }
    }

    /// Whether this backend can run on the current CPU.
    pub fn available(self) -> bool {
        match self {
            KernelBackend::Lanes => true,
            KernelBackend::Avx2 => avx2_available(),
        }
    }
}

/// True when the current CPU supports the AVX2+FMA kernel variants.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The pure selection rule, separated from environment/CPU probing so it
/// is testable on any machine: the escape hatch wins, then hardware.
pub fn select(no_simd: bool, avx2: bool) -> KernelBackend {
    if !no_simd && avx2 {
        KernelBackend::Avx2
    } else {
        KernelBackend::Lanes
    }
}

/// The process-wide kernel backend, chosen once on first call:
/// `DSFACTO_NO_SIMD=1` forces [`KernelBackend::Lanes`]; otherwise AVX2 is
/// used whenever the CPU supports `avx2` and `fma`.
pub fn backend() -> KernelBackend {
    static CHOICE: OnceLock<KernelBackend> = OnceLock::new();
    *CHOICE.get_or_init(|| {
        let no_simd = std::env::var("DSFACTO_NO_SIMD").is_ok_and(|v| v == "1");
        select(no_simd, avx2_available())
    })
}

// ---------------------------------------------------------------------------
// AVX2 kernel bodies (x86_64 only). Callers dispatch through
// `KernelBackend` and must have verified `avx2_available()` — encoded in
// the `# Safety` contract of each function.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod body {
    use super::Task;
    use crate::fm::loss;
    use std::arch::x86_64::*;

    use super::super::fused::LANES;
    use super::super::visit::VisitHyper;

    /// AVX2 variant of `FmKernel::accumulate` (bitwise-identical to the
    /// lane loop: per-lane `mul`/`add` only, no FMA, no reduction).
    ///
    /// # Safety
    /// CPU must support `avx2` and `fma`; `a.len() == s2.len() == kp`,
    /// `kp % LANES == 0`, every `idx` entry `< w.len()` with
    /// `v.len() >= (idx+1) * kp`.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn accumulate(
        w0: f32,
        w: &[f32],
        v: &[f32],
        kp: usize,
        idx: &[u32],
        val: &[f32],
        a: &mut [f32],
        s2: &mut [f32],
    ) -> f32 {
        debug_assert_eq!(a.len(), kp);
        debug_assert_eq!(s2.len(), kp);
        debug_assert_eq!(kp % LANES, 0);
        a.fill(0.0);
        s2.fill(0.0);
        let ap = a.as_mut_ptr();
        let sp = s2.as_mut_ptr();
        let mut linear = w0;
        for (j, &x) in idx.iter().zip(val) {
            let j = *j as usize;
            linear += w[j] * x;
            let vp = v.as_ptr().add(j * kp);
            let xs = _mm256_set1_ps(x);
            let mut o = 0;
            while o < kp {
                let vb = _mm256_loadu_ps(vp.add(o));
                let vx = _mm256_mul_ps(vb, xs);
                let ab = _mm256_loadu_ps(ap.add(o));
                _mm256_storeu_ps(ap.add(o), _mm256_add_ps(ab, vx));
                let sb = _mm256_loadu_ps(sp.add(o));
                _mm256_storeu_ps(sp.add(o), _mm256_add_ps(sb, _mm256_mul_ps(vx, vx)));
                o += LANES;
            }
        }
        linear
    }

    /// The raw pairwise sum `sum_k (a_k^2 - s2_k)` in the exact scalar
    /// order: per-block vector `a*a - s2` spilled to a stack buffer, then
    /// summed lane 0..8 sequentially (bitwise-identical to the lane loop).
    ///
    /// # Safety
    /// CPU must support `avx2` and `fma`; `a.len() == s2.len()` and both
    /// are a multiple of `LANES`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn pair_sum(a: &[f32], s2: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), s2.len());
        debug_assert_eq!(a.len() % LANES, 0);
        let mut pair = 0f32;
        let mut t = [0f32; LANES];
        let mut o = 0;
        while o < a.len() {
            let ab = _mm256_loadu_ps(a.as_ptr().add(o));
            let sb = _mm256_loadu_ps(s2.as_ptr().add(o));
            let tv = _mm256_sub_ps(_mm256_mul_ps(ab, ab), sb);
            _mm256_storeu_ps(t.as_mut_ptr(), tv);
            for &tl in &t {
                pair += tl;
            }
            o += LANES;
        }
        pair
    }

    /// The eq. 13 v-row update of `score_grad_step`, **FMA-contracted**:
    /// `v <- v - eta * (g * (x*a - v*x^2) + lambda_v * v)` with
    /// `fmsub`/`fmadd`/`fnmadd` merging one rounding each. ULP-bounded
    /// (not bitwise) against the lane oracle — see the module docs.
    ///
    /// # Safety
    /// CPU must support `avx2` and `fma`; `vj.len() <= a.len()` and
    /// `vj.len() % LANES == 0`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn vrow_step(vj: &mut [f32], a: &[f32], x: f32, g: f32, eta: f32, lambda_v: f32) {
        debug_assert!(vj.len() <= a.len());
        debug_assert_eq!(vj.len() % LANES, 0);
        let xs = _mm256_set1_ps(x);
        let x2s = _mm256_set1_ps(x * x);
        let gs = _mm256_set1_ps(g);
        let es = _mm256_set1_ps(eta);
        let ls = _mm256_set1_ps(lambda_v);
        let vp = vj.as_mut_ptr();
        let ap = a.as_ptr();
        let mut o = 0;
        while o < vj.len() {
            let vl = _mm256_loadu_ps(vp.add(o));
            let ab = _mm256_loadu_ps(ap.add(o));
            let inner = _mm256_fmsub_ps(xs, ab, _mm256_mul_ps(vl, x2s));
            let grad = _mm256_fmadd_ps(gs, inner, _mm256_mul_ps(ls, vl));
            _mm256_storeu_ps(vp.add(o), _mm256_fnmadd_ps(es, grad, vl));
            o += LANES;
        }
    }

    /// AVX2 variant of `visit::col_update` (bitwise-identical: vectorized
    /// per-lane products, same operation order as the lane loop).
    ///
    /// # Safety
    /// CPU must support `avx2` and `fma`; same shape contract as
    /// `visit::col_update` with `gv.len() == kp`.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn col_update(
        rows: &[u32],
        xs: &[f32],
        g: &[f32],
        aa: &[f32],
        kp: usize,
        wj: &mut f32,
        vj: &mut [f32],
        h: VisitHyper,
        gv: &mut [f32],
    ) {
        debug_assert_eq!(vj.len(), kp);
        debug_assert_eq!(gv.len(), kp);
        debug_assert_eq!(kp % LANES, 0);
        gv.fill(0.0);
        let gp = gv.as_mut_ptr();
        let mut gw = 0f32;
        for (r, x) in rows.iter().zip(xs) {
            let r = *r as usize;
            let gi = g[r];
            let x = *x;
            gw += gi * x;
            let xsv = _mm256_set1_ps(x);
            let x2v = _mm256_set1_ps(x * x);
            let giv = _mm256_set1_ps(gi);
            let ap = aa.as_ptr().add(r * kp);
            let vp = vj.as_ptr();
            let mut o = 0;
            while o < kp {
                let ab = _mm256_loadu_ps(ap.add(o));
                let vb = _mm256_loadu_ps(vp.add(o));
                let d = _mm256_sub_ps(_mm256_mul_ps(xsv, ab), _mm256_mul_ps(vb, x2v));
                let gb = _mm256_loadu_ps(gp.add(o));
                _mm256_storeu_ps(gp.add(o), _mm256_add_ps(gb, _mm256_mul_ps(giv, d)));
                o += LANES;
            }
        }
        *wj -= h.eta * (gw * h.inv_n + h.lambda_w * h.reg_split * *wj);
        let ev = _mm256_set1_ps(h.eta);
        let iv = _mm256_set1_ps(h.inv_n);
        // Same two-operand product the scalar loop evaluates per lane.
        let lv = _mm256_set1_ps(h.lambda_v * h.reg_split);
        let vp = vj.as_mut_ptr();
        let mut o = 0;
        while o < kp {
            let vb = _mm256_loadu_ps(vp.add(o));
            let gb = _mm256_loadu_ps(gp.add(o));
            let s = _mm256_add_ps(_mm256_mul_ps(gb, iv), _mm256_mul_ps(lv, vb));
            _mm256_storeu_ps(vp.add(o), _mm256_sub_ps(vb, _mm256_mul_ps(ev, s)));
            o += LANES;
        }
    }

    /// AVX2 variant of `visit::col_recompute` (bitwise-identical).
    ///
    /// # Safety
    /// CPU must support `avx2` and `fma`; same shape contract as
    /// `visit::col_recompute`.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn col_recompute(
        rows: &[u32],
        xs: &[f32],
        wj: f32,
        vj: &[f32],
        kp: usize,
        acc_xw: &mut [f32],
        acc_a: &mut [f32],
        acc_s2: &mut [f32],
    ) {
        debug_assert_eq!(vj.len(), kp);
        debug_assert_eq!(kp % LANES, 0);
        let vp = vj.as_ptr();
        for (r, x) in rows.iter().zip(xs) {
            let r = *r as usize;
            let x = *x;
            acc_xw[r] += wj * x;
            let xv = _mm256_set1_ps(x);
            let ap = acc_a.as_mut_ptr().add(r * kp);
            let sp = acc_s2.as_mut_ptr().add(r * kp);
            let mut o = 0;
            while o < kp {
                let vb = _mm256_loadu_ps(vp.add(o));
                let vx = _mm256_mul_ps(vb, xv);
                let ab = _mm256_loadu_ps(ap.add(o));
                _mm256_storeu_ps(ap.add(o), _mm256_add_ps(ab, vx));
                let sb = _mm256_loadu_ps(sp.add(o));
                _mm256_storeu_ps(sp.add(o), _mm256_add_ps(sb, _mm256_mul_ps(vx, vx)));
                o += LANES;
            }
        }
    }

    /// AVX2 variant of `visit::finalize_rows` (bitwise-identical: the
    /// per-row pairwise reduction spills per-block vectors and sums them
    /// in scalar lane order, exactly like [`pair_sum`]).
    ///
    /// # Safety
    /// CPU must support `avx2` and `fma`; same shape contract as
    /// `visit::finalize_rows`.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn finalize_rows(
        w0: f32,
        acc_xw: &[f32],
        acc_a: &[f32],
        acc_s2: &[f32],
        kp: usize,
        labels: &[f32],
        task: Task,
        g: &mut [f32],
    ) -> f64 {
        let nloc = g.len();
        debug_assert_eq!(labels.len(), nloc);
        debug_assert_eq!(acc_xw.len(), nloc);
        debug_assert_eq!(kp % LANES, 0);
        let mut loss_sum = 0f64;
        for r in 0..nloc {
            let pair = pair_sum(&acc_a[r * kp..(r + 1) * kp], &acc_s2[r * kp..(r + 1) * kp]);
            let f = w0 + acc_xw[r] + 0.5 * pair;
            g[r] = loss::multiplier(f, labels[r], task);
            loss_sum += loss::loss(f, labels[r], task) as f64;
        }
        loss_sum
    }
}

#[cfg(target_arch = "x86_64")]
pub(super) use body::{accumulate, col_recompute, col_update, finalize_rows, pair_sum, vrow_step};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_rule_honours_escape_hatch() {
        assert_eq!(select(false, true), KernelBackend::Avx2);
        assert_eq!(select(true, true), KernelBackend::Lanes);
        assert_eq!(select(false, false), KernelBackend::Lanes);
        assert_eq!(select(true, false), KernelBackend::Lanes);
    }

    #[test]
    fn backend_is_available_and_stable() {
        let b = backend();
        assert!(b.available());
        assert_eq!(backend(), b, "backend selection must be one-time");
        assert!(KernelBackend::Lanes.available());
        assert!(matches!(b.name(), "lanes" | "avx2"));
    }

    #[cfg(target_arch = "x86_64")]
    mod avx2_parity {
        use super::super::super::fused::{padded_k, LANES};
        use super::super::super::visit::{self, VisitHyper};
        use super::super::super::Scratch;
        use super::super::*;
        use crate::data::Task;
        use crate::util::prop::assert_ulp_close;
        use crate::util::rng::Pcg64;

        /// Random lane-padded column fixture: `n` rows, CSC column with
        /// every other row populated, padded `aa` arena.
        #[allow(clippy::type_complexity)]
        fn fixture(k: usize, n: usize, seed: u64) -> (Vec<u32>, Vec<f32>, Vec<f32>, Vec<f32>) {
            let kp = padded_k(k);
            let mut rng = Pcg64::seeded(seed);
            let rows: Vec<u32> = (0..n as u32).step_by(2).collect();
            let xs: Vec<f32> = rows.iter().map(|_| rng.normal32(0.0, 1.5)).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.normal32(0.0, 0.8)).collect();
            let mut aa = vec![0f32; n * kp];
            for r in 0..n {
                for kk in 0..k {
                    aa[r * kp + kk] = rng.normal32(0.0, 1.0);
                }
            }
            (rows, xs, g, aa)
        }

        fn padded_row(k: usize, rng: &mut Pcg64) -> Vec<f32> {
            let kp = padded_k(k);
            let mut v = vec![0f32; kp];
            for x in v.iter_mut().take(k) {
                *x = rng.normal32(0.0, 0.5);
            }
            v
        }

        #[test]
        fn accumulate_and_pair_sum_are_bitwise() {
            if !avx2_available() {
                eprintln!("skipping: no AVX2+FMA on this CPU");
                return;
            }
            for k in [1usize, 7, 8, 9, 16, 40] {
                let kp = padded_k(k);
                let d = 13;
                let mut rng = Pcg64::seeded(77 + k as u64);
                let mut v = vec![0f32; d * kp];
                for j in 0..d {
                    for kk in 0..k {
                        v[j * kp + kk] = rng.normal32(0.0, 0.6);
                    }
                }
                let w: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 0.4)).collect();
                let idx = [0u32, 3, 5, 11, 12];
                let val = [0.5f32, -1.5, 2.0, 0.25, -0.125];

                // Lane oracle.
                let mut a_l = vec![0f32; kp];
                let mut s_l = vec![0f32; kp];
                let mut lin_l = 0.7f32;
                for (j, &x) in idx.iter().zip(val.iter()) {
                    let j = *j as usize;
                    lin_l += w[j] * x;
                    for ((ab, sb), vb) in a_l
                        .chunks_exact_mut(LANES)
                        .zip(s_l.chunks_exact_mut(LANES))
                        .zip(v[j * kp..(j + 1) * kp].chunks_exact(LANES))
                    {
                        for l in 0..LANES {
                            let vx = vb[l] * x;
                            ab[l] += vx;
                            sb[l] += vx * vx;
                        }
                    }
                }
                let mut pair_l = 0f32;
                for (ab, sb) in a_l.chunks_exact(LANES).zip(s_l.chunks_exact(LANES)) {
                    for l in 0..LANES {
                        pair_l += ab[l] * ab[l] - sb[l];
                    }
                }

                let mut a_v = vec![0f32; kp];
                let mut s_v = vec![0f32; kp];
                let (lin_v, pair_v) = unsafe {
                    let lin = accumulate(0.7, &w, &v, kp, &idx, &val, &mut a_v, &mut s_v);
                    (lin, pair_sum(&a_v, &s_v))
                };
                assert_eq!(lin_v.to_bits(), lin_l.to_bits(), "k={k}: linear term");
                assert_eq!(pair_v.to_bits(), pair_l.to_bits(), "k={k}: pair sum");
                for kk in 0..kp {
                    assert_eq!(a_v[kk].to_bits(), a_l[kk].to_bits(), "k={k} a[{kk}]");
                    assert_eq!(s_v[kk].to_bits(), s_l[kk].to_bits(), "k={k} s2[{kk}]");
                }
            }
        }

        #[test]
        fn visit_kernels_are_bitwise_vs_lanes() {
            if !avx2_available() {
                eprintln!("skipping: no AVX2+FMA on this CPU");
                return;
            }
            for k in [1usize, 7, 8, 9, 16, 40] {
                let kp = padded_k(k);
                let n = 9;
                let (rows, xs, g, aa) = fixture(k, n, 1000 + k as u64);
                let mut rng = Pcg64::seeded(2000 + k as u64);
                let v0 = padded_row(k, &mut rng);
                let h = VisitHyper {
                    eta: 0.07,
                    inv_n: 1.0 / n as f32,
                    lambda_w: 1e-3,
                    lambda_v: 2e-3,
                    reg_split: 0.5,
                };

                // col_update: lanes vs avx2.
                let mut w_l = 0.3f32;
                let mut v_l = v0.clone();
                let mut scratch = Scratch::new();
                visit::col_update_backend(
                    KernelBackend::Lanes,
                    &rows,
                    &xs,
                    &g,
                    &aa,
                    kp,
                    &mut w_l,
                    &mut v_l,
                    h,
                    &mut scratch,
                );
                let mut w_a = 0.3f32;
                let mut v_a = v0.clone();
                visit::col_update_backend(
                    KernelBackend::Avx2,
                    &rows,
                    &xs,
                    &g,
                    &aa,
                    kp,
                    &mut w_a,
                    &mut v_a,
                    h,
                    &mut scratch,
                );
                assert_eq!(w_a.to_bits(), w_l.to_bits(), "k={k}: w after col_update");
                for kk in 0..kp {
                    assert_eq!(v_a[kk].to_bits(), v_l[kk].to_bits(), "k={k} v[{kk}]");
                }

                // col_recompute: lanes vs avx2.
                let mut xw_l = vec![0f32; n];
                let mut a_l = aa.clone();
                let mut s_l = vec![0.25f32; n * kp];
                visit::col_recompute_backend(
                    KernelBackend::Lanes,
                    &rows,
                    &xs,
                    0.4,
                    &v0,
                    kp,
                    &mut xw_l,
                    &mut a_l,
                    &mut s_l,
                );
                let mut xw_a = vec![0f32; n];
                let mut a_a = aa.clone();
                let mut s_a = vec![0.25f32; n * kp];
                visit::col_recompute_backend(
                    KernelBackend::Avx2,
                    &rows,
                    &xs,
                    0.4,
                    &v0,
                    kp,
                    &mut xw_a,
                    &mut a_a,
                    &mut s_a,
                );
                assert_eq!(
                    xw_a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    xw_l.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "k={k}: acc_xw"
                );
                for i in 0..n * kp {
                    assert_eq!(a_a[i].to_bits(), a_l[i].to_bits(), "k={k} acc_a[{i}]");
                    assert_eq!(s_a[i].to_bits(), s_l[i].to_bits(), "k={k} acc_s2[{i}]");
                }

                // finalize_rows: lanes vs avx2.
                let labels: Vec<f32> =
                    (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
                let mut g_l = vec![0f32; n];
                let loss_l = visit::finalize_rows_backend(
                    KernelBackend::Lanes,
                    0.15,
                    &xw_l,
                    &a_l,
                    &s_l,
                    kp,
                    &labels,
                    Task::Classification,
                    &mut g_l,
                );
                let mut g_a = vec![0f32; n];
                let loss_a = visit::finalize_rows_backend(
                    KernelBackend::Avx2,
                    0.15,
                    &xw_a,
                    &a_a,
                    &s_a,
                    kp,
                    &labels,
                    Task::Classification,
                    &mut g_a,
                );
                assert_eq!(loss_a.to_bits(), loss_l.to_bits(), "k={k}: finalize loss");
                for r in 0..n {
                    assert_eq!(g_a[r].to_bits(), g_l[r].to_bits(), "k={k} g[{r}]");
                }
            }
        }

        #[test]
        fn fma_vrow_step_is_ulp_close_to_lanes() {
            if !avx2_available() {
                eprintln!("skipping: no AVX2+FMA on this CPU");
                return;
            }
            for k in [1usize, 8, 16, 40] {
                let kp = padded_k(k);
                let mut rng = Pcg64::seeded(3000 + k as u64);
                let v0 = padded_row(k, &mut rng);
                let mut a = vec![0f32; kp];
                for x in a.iter_mut().take(k) {
                    *x = rng.normal32(0.0, 1.2);
                }
                let (x, g, eta, lambda_v) = (1.75f32, -0.6f32, 0.05f32, 1e-3f32);

                // Lane oracle (the exact eq. 13 loop in score_grad_step).
                let mut v_l = v0.clone();
                let x2 = x * x;
                for (vb, ab) in v_l.chunks_exact_mut(LANES).zip(a.chunks_exact(LANES)) {
                    for l in 0..LANES {
                        let vl = vb[l];
                        vb[l] = vl - eta * (g * (x * ab[l] - vl * x2) + lambda_v * vl);
                    }
                }

                let mut v_a = v0.clone();
                unsafe { vrow_step(&mut v_a, &a, x, g, eta, lambda_v) };
                // Three FMA contractions merge one rounding each: 4 ULPs
                // is the documented bound (EXPERIMENTS.md §Perf).
                for kk in 0..kp {
                    assert_ulp_close(v_a[kk], v_l[kk], 4, &format!("k={k} v[{kk}]"));
                }
                assert!(v_a[k..].iter().all(|&z| z == 0.0), "padding drifted");
            }
        }
    }
}
