//! Fused lane-blocked FM kernels — the per-example hot path every trainer
//! executes millions of times.
//!
//! DS-FACTO's premise is that the per-example FM work (the eq. 4 rewrite
//! and the eq. 10-13 updates) is the unit of work whose constant factor
//! bounds cluster throughput. This module is that unit, tuned:
//!
//! * **Layout** ([`FmKernel`]) — the factor matrix `V` is stored AoSoA:
//!   each feature row padded to a multiple of [`LANES`] (8) f32 values, so
//!   the inner loops are fixed-width 8-lane blocks LLVM turns into full
//!   SIMD registers. Padding lanes are invariantly zero.
//! * **Fusion** — scoring computes the linear term, the factor sums `a`
//!   and the squared sums `s2` in one sweep over the non-zeros;
//!   [`FmKernel::score_grad_step`] fuses score, loss multiplier and the
//!   eq. 11-13 update into two sweeps total (the scalar path made three).
//! * **Zero allocation** ([`Scratch`]) — every kernel call borrows a
//!   per-thread arena; nothing on the steady-state path touches the heap.
//! * **Explicit SIMD with runtime dispatch** ([`simd`]) — on x86_64 CPUs
//!   with AVX2+FMA the hot kernels run hand-written `std::arch`
//!   intrinsics, selected once at startup via [`simd::backend`]
//!   (`DSFACTO_NO_SIMD=1` forces the portable lane fallback). The
//!   lane-blocked loops stay in-tree as the fallback and the parity
//!   oracle: every SIMD kernel except the FMA-contracted
//!   `score_grad_step` v-update is bitwise-identical to them. Kernel-owned
//!   buffers ([`Scratch`], the factor matrix) live in 32-byte-aligned
//!   [`AlignedF32`] storage so every lane block sits on an AVX2 register
//!   boundary.
//!
//! Alongside the per-example (row-major) kernels, [`visit`] holds the
//! **column-visit kernels** the NOMAD engine drives: the eq. 12-13
//! update-phase step, the Algorithm 1 recompute fold and the per-row
//! finalize reduction, all over the same `kp = padded_k(k)`-strided
//! lane-blocked buffers (token payloads, worker `aa`/`acc_a`/`acc_s2`
//! arenas) with the identical zero-padding invariant.
//!
//! The scalar implementations (`FmModel::score_sparse`,
//! `optim::sgd_update_example`, and the K-strided column loops in
//! [`visit::scalar`]) remain in-tree as the semantic reference
//! and the benchmark baseline; `FmModel::score_naive` (paper eq. 2, the
//! O(K nnz^2) double sum) is the independent oracle the property suite in
//! `rust/tests/kernel_properties.rs` checks both against. The measured
//! fused-vs-scalar gap lands in `BENCH_hotpath.json` (see EXPERIMENTS.md
//! §Perf) via `cargo bench --bench hotpath_micro`.

// Hot-path module: lint-clean regardless of the workflow-level gate (CI
// additionally runs a clippy pass scoped to kernel + nomad).
#![deny(clippy::all)]

pub mod blocked;
mod fused;
mod scratch;
pub mod simd;
pub mod visit;

pub use blocked::{BlockScratch, BlockedFm};
pub use fused::{padded_k, AdaGradLanes, FmKernel, LANES};
pub use scratch::{AlignedF32, Scratch};
pub use simd::{backend, KernelBackend};
