//! Column-visit kernels — the DS-FACTO engine's per-visit hot path,
//! lane-blocked.
//!
//! Where the fused kernels in [`super::fused`] cover the *row-major*
//! per-example work every single-machine trainer executes, these cover
//! the *column-major* unit of the decentralized engine (paper Algorithm
//! 1): one circulating parameter column applied to, or folded over, a
//! worker's local CSC column. The entry points mirror the trainers'
//! column-major inner loops:
//!
//! * [`col_update`] — the eq. 12/13 mean-gradient step of one update-phase
//!   visit (Algorithm 1 lines 12-17, 1/N-normalized with the L2 term split
//!   across the P visits); DSGD's block updates run on it too;
//! * [`col_update_stochastic`] — the paper-literal line 14 variant:
//!   sampled per-example eq. 12/13 updates with frozen multipliers;
//! * [`col_grad`] — the fold without the step: one column's eq. 7/8
//!   partial gradient in f64, the bulk-sync all-reduce payload;
//! * [`col_recompute`] — one recompute-phase visit (lines 18-21): fold the
//!   column into the partial sums for G and A;
//! * [`finalize_rows`] — end of a recompute pass: the pairwise-term
//!   reduction, loss and fresh loss multiplier G for every local row.
//!
//! All four operate on `kp = padded_k(k)`-strided buffers sharing the
//! [`FmKernel`](super::FmKernel) zero-padding invariant: entries past `k`
//! in every row of `aa` / `acc_a` / `acc_s2` and in every `v_j` are
//! identically zero, their gradients and factor sums vanish, and the inner
//! loops run over fixed-width [`LANES`]-wide blocks with no remainder or
//! masking. [`col_update`] draws its gradient buffer from the caller's
//! [`Scratch`] arena, so no visit allocates at any K.
//!
//! Every kernel applies its floating-point operations in the exact
//! per-coordinate order of the scalar loops it replaced — padding lanes
//! only ever contribute exact `+0.0` terms — so a lane-blocked engine run
//! is **bitwise identical** to a scalar one (asserted end-to-end by
//! `rust/tests/engine_properties.rs`). The pre-lane-blocking scalar loops
//! live on, K-strided, in [`scalar`]: the oracle for the parity suite in
//! `rust/tests/kernel_properties.rs` and the baseline side of the
//! `engine_visit_*` entries in `BENCH_hotpath.json`.
//!
//! [`col_update`], [`col_recompute`] and [`finalize_rows`] dispatch on
//! the process-wide [`simd::backend`](super::simd::backend): the AVX2
//! variants in [`super::simd`] vectorize the per-lane products but keep
//! every reduction in scalar lane order, so **all three stay bitwise
//! identical** to the lane loops — and therefore the engine's
//! scalar-bitwise guarantee holds under either backend. The `*_backend`
//! entry points let benchmarks and parity tests force a specific
//! backend; [`col_update_stochastic`] and [`col_grad`] (f64 all-reduce
//! payload) remain lane-only.

use crate::data::Task;
use crate::fm::loss;
use crate::util::rng::Pcg64;

use super::fused::LANES;
use super::scratch::Scratch;
use super::simd::{self, KernelBackend};

/// Hyper-parameters of one mean-gradient update-phase column visit.
#[derive(Debug, Clone, Copy)]
pub struct VisitHyper {
    /// Step size for this outer iteration.
    pub eta: f32,
    /// `1/N` normalization of the mean-gradient fold (N = total examples).
    pub inv_n: f32,
    /// L2 penalty on the linear weight.
    pub lambda_w: f32,
    /// L2 penalty on the factor row.
    pub lambda_v: f32,
    /// The L2 term's share per visit (`1/P`): the penalty is split across
    /// the P visits of an outer iteration.
    pub reg_split: f32,
}

/// One update-phase visit of a parameter column (paper eqs. 12-13 as the
/// engine's incremental mean-gradient step): accumulate the local partial
/// gradient over the CSC column `(rows, xs)` against the frozen
/// multipliers `g` and the lane-blocked factor-sum cache `aa`
/// (`nloc x kp` row-major), then step `w_j` and the `kp`-strided factor
/// row `v_j`. The gradient buffer comes from `scratch`, so the visit
/// allocates nothing. Padding lanes of `v_j` stay exactly zero.
#[allow(clippy::too_many_arguments)]
pub fn col_update(
    rows: &[u32],
    xs: &[f32],
    g: &[f32],
    aa: &[f32],
    kp: usize,
    wj: &mut f32,
    vj: &mut [f32],
    h: VisitHyper,
    scratch: &mut Scratch,
) {
    col_update_backend(simd::backend(), rows, xs, g, aa, kp, wj, vj, h, scratch)
}

/// [`col_update`] through an explicitly chosen backend (benchmarks and
/// the SIMD parity tests force lanes/AVX2 side by side). Panics if `b`
/// cannot run on this CPU.
#[allow(clippy::too_many_arguments)]
pub fn col_update_backend(
    b: KernelBackend,
    rows: &[u32],
    xs: &[f32],
    g: &[f32],
    aa: &[f32],
    kp: usize,
    wj: &mut f32,
    vj: &mut [f32],
    h: VisitHyper,
    scratch: &mut Scratch,
) {
    assert!(
        b.available(),
        "kernel backend {:?} is not available on this CPU",
        b.name()
    );
    debug_assert_eq!(vj.len(), kp);
    debug_assert_eq!(kp % LANES, 0);
    scratch.ensure(kp);
    let gv = &mut scratch.gv[..kp];
    #[cfg(target_arch = "x86_64")]
    if b == KernelBackend::Avx2 {
        // SAFETY: availability asserted above.
        unsafe { simd::col_update(rows, xs, g, aa, kp, wj, vj, h, gv) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = b;
    col_update_lanes(rows, xs, g, aa, kp, wj, vj, h, gv)
}

/// The portable lane-blocked body of [`col_update`] — the bitwise oracle
/// the AVX2 variant is held to.
#[allow(clippy::too_many_arguments)]
fn col_update_lanes(
    rows: &[u32],
    xs: &[f32],
    g: &[f32],
    aa: &[f32],
    kp: usize,
    wj: &mut f32,
    vj: &mut [f32],
    h: VisitHyper,
    gv: &mut [f32],
) {
    gv.fill(0.0);
    let mut gw = 0f32;
    for (r, x) in rows.iter().zip(xs) {
        let r = *r as usize;
        let gi = g[r];
        let x = *x;
        gw += gi * x;
        let x2 = x * x;
        let ai = &aa[r * kp..(r + 1) * kp];
        for ((gb, ab), vb) in gv
            .chunks_exact_mut(LANES)
            .zip(ai.chunks_exact(LANES))
            .zip(vj.chunks_exact(LANES))
        {
            for l in 0..LANES {
                gb[l] += gi * (x * ab[l] - vb[l] * x2);
            }
        }
    }
    *wj -= h.eta * (gw * h.inv_n + h.lambda_w * h.reg_split * *wj);
    for (vb, gb) in vj.chunks_exact_mut(LANES).zip(gv.chunks_exact(LANES)) {
        for l in 0..LANES {
            let vl = vb[l];
            vb[l] = vl - h.eta * (gb[l] * h.inv_n + h.lambda_v * h.reg_split * vl);
        }
    }
}

/// One paper-literal stochastic update visit (Algorithm 1 line 14):
/// sample `samples` local examples from the column and apply the
/// per-example eq. 12/13 updates with the frozen multipliers. Returns the
/// number of coordinate updates applied (0 for an empty column, which
/// draws nothing from `rng`).
#[allow(clippy::too_many_arguments)]
pub fn col_update_stochastic(
    rows: &[u32],
    xs: &[f32],
    g: &[f32],
    aa: &[f32],
    kp: usize,
    wj: &mut f32,
    vj: &mut [f32],
    eta: f32,
    lambda_w: f32,
    lambda_v: f32,
    samples: usize,
    rng: &mut Pcg64,
) -> u64 {
    debug_assert_eq!(vj.len(), kp);
    if rows.is_empty() {
        return 0;
    }
    for _ in 0..samples {
        let t = rng.below_usize(rows.len());
        let r = rows[t] as usize;
        let x = xs[t];
        let gi = g[r];
        // eq. 12
        *wj -= eta * (gi * x + lambda_w * *wj);
        // eq. 13 with the cached a_ik, lane-blocked.
        let x2 = x * x;
        let ai = &aa[r * kp..(r + 1) * kp];
        for (vb, ab) in vj.chunks_exact_mut(LANES).zip(ai.chunks_exact(LANES)) {
            for l in 0..LANES {
                let vl = vb[l];
                vb[l] = vl - eta * (gi * (x * ab[l] - vl * x2) + lambda_v * vl);
            }
        }
    }
    samples as u64
}

/// Mean-gradient fold of one column *without* the parameter step: the
/// eq. 7/8 partial sums `(gw, gv)` of the bulk-sync all-reduce payload,
/// accumulated in **f64** over the lane-blocked `kp`-strided inputs.
/// `gv` must be at least `kp` long; it is zeroed here, and its first K
/// entries hold the factor gradient on return (padding lanes accumulate
/// exact zeros). Returns `gw`.
///
/// For a fixed column, the row-major per-example fold it replaces adds
/// exactly these terms in increasing row order — the order a CSC column
/// stores its rows — with the same f64 casts, so a column-major shard
/// sweep through this kernel reproduces the legacy row-major partial
/// gradient **bitwise** (asserted by `rust/tests/partition_properties.rs`).
pub fn col_grad(
    rows: &[u32],
    xs: &[f32],
    g: &[f32],
    aa: &[f32],
    kp: usize,
    vj: &[f32],
    gv: &mut [f64],
) -> f64 {
    debug_assert_eq!(vj.len(), kp);
    debug_assert!(gv.len() >= kp);
    let gv = &mut gv[..kp];
    gv.fill(0.0);
    let mut gw = 0f64;
    for (r, x) in rows.iter().zip(xs) {
        let r = *r as usize;
        let gi = g[r] as f64;
        let x = *x as f64;
        gw += gi * x;
        let x2 = x * x;
        let ai = &aa[r * kp..(r + 1) * kp];
        for ((gb, ab), vb) in gv
            .chunks_exact_mut(LANES)
            .zip(ai.chunks_exact(LANES))
            .zip(vj.chunks_exact(LANES))
        {
            for l in 0..LANES {
                gb[l] += gi * (x * ab[l] as f64 - vb[l] as f64 * x2);
            }
        }
    }
    gw
}

/// One recompute-phase visit (Algorithm 1 lines 18-21): fold the column's
/// fresh `(w_j, v_j)` into the lane-blocked partial sums `acc_a` /
/// `acc_s2` (`nloc x kp` row-major) and the linear partial sums `acc_xw`.
#[allow(clippy::too_many_arguments)]
pub fn col_recompute(
    rows: &[u32],
    xs: &[f32],
    wj: f32,
    vj: &[f32],
    kp: usize,
    acc_xw: &mut [f32],
    acc_a: &mut [f32],
    acc_s2: &mut [f32],
) {
    col_recompute_backend(
        simd::backend(),
        rows,
        xs,
        wj,
        vj,
        kp,
        acc_xw,
        acc_a,
        acc_s2,
    )
}

/// [`col_recompute`] through an explicitly chosen backend. Panics if `b`
/// cannot run on this CPU.
#[allow(clippy::too_many_arguments)]
pub fn col_recompute_backend(
    b: KernelBackend,
    rows: &[u32],
    xs: &[f32],
    wj: f32,
    vj: &[f32],
    kp: usize,
    acc_xw: &mut [f32],
    acc_a: &mut [f32],
    acc_s2: &mut [f32],
) {
    assert!(
        b.available(),
        "kernel backend {:?} is not available on this CPU",
        b.name()
    );
    debug_assert_eq!(vj.len(), kp);
    #[cfg(target_arch = "x86_64")]
    if b == KernelBackend::Avx2 {
        // SAFETY: availability asserted above.
        unsafe { simd::col_recompute(rows, xs, wj, vj, kp, acc_xw, acc_a, acc_s2) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = b;
    col_recompute_lanes(rows, xs, wj, vj, kp, acc_xw, acc_a, acc_s2)
}

/// The portable lane-blocked body of [`col_recompute`] — the bitwise
/// oracle the AVX2 variant is held to.
#[allow(clippy::too_many_arguments)]
fn col_recompute_lanes(
    rows: &[u32],
    xs: &[f32],
    wj: f32,
    vj: &[f32],
    kp: usize,
    acc_xw: &mut [f32],
    acc_a: &mut [f32],
    acc_s2: &mut [f32],
) {
    for (r, x) in rows.iter().zip(xs) {
        let r = *r as usize;
        let x = *x;
        acc_xw[r] += wj * x;
        let ar = &mut acc_a[r * kp..(r + 1) * kp];
        let sr = &mut acc_s2[r * kp..(r + 1) * kp];
        for ((ab, sb), vb) in ar
            .chunks_exact_mut(LANES)
            .zip(sr.chunks_exact_mut(LANES))
            .zip(vj.chunks_exact(LANES))
        {
            for l in 0..LANES {
                let vx = vb[l] * x;
                ab[l] += vx;
                sb[l] += vx * vx;
            }
        }
    }
}

/// End of a recompute pass: for every local row, reduce the lane-blocked
/// partial sums into the pairwise term (padding contributes exactly
/// `+0.0`), score `f = w0 + <x, w> + 0.5 * sum_k (a_k^2 - s2_k)`, refresh
/// the loss multiplier into `g` and return the summed loss. `g.len()`
/// determines the row count.
#[allow(clippy::too_many_arguments)]
pub fn finalize_rows(
    w0: f32,
    acc_xw: &[f32],
    acc_a: &[f32],
    acc_s2: &[f32],
    kp: usize,
    labels: &[f32],
    task: Task,
    g: &mut [f32],
) -> f64 {
    finalize_rows_backend(simd::backend(), w0, acc_xw, acc_a, acc_s2, kp, labels, task, g)
}

/// [`finalize_rows`] through an explicitly chosen backend. Panics if `b`
/// cannot run on this CPU.
#[allow(clippy::too_many_arguments)]
pub fn finalize_rows_backend(
    b: KernelBackend,
    w0: f32,
    acc_xw: &[f32],
    acc_a: &[f32],
    acc_s2: &[f32],
    kp: usize,
    labels: &[f32],
    task: Task,
    g: &mut [f32],
) -> f64 {
    assert!(
        b.available(),
        "kernel backend {:?} is not available on this CPU",
        b.name()
    );
    #[cfg(target_arch = "x86_64")]
    if b == KernelBackend::Avx2 {
        // SAFETY: availability asserted above.
        return unsafe { simd::finalize_rows(w0, acc_xw, acc_a, acc_s2, kp, labels, task, g) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = b;
    finalize_rows_lanes(w0, acc_xw, acc_a, acc_s2, kp, labels, task, g)
}

/// The portable lane-blocked body of [`finalize_rows`] — the bitwise
/// oracle the AVX2 variant is held to.
#[allow(clippy::too_many_arguments)]
fn finalize_rows_lanes(
    w0: f32,
    acc_xw: &[f32],
    acc_a: &[f32],
    acc_s2: &[f32],
    kp: usize,
    labels: &[f32],
    task: Task,
    g: &mut [f32],
) -> f64 {
    let nloc = g.len();
    debug_assert_eq!(labels.len(), nloc);
    debug_assert_eq!(acc_xw.len(), nloc);
    let mut loss_sum = 0f64;
    for r in 0..nloc {
        let ar = &acc_a[r * kp..(r + 1) * kp];
        let sr = &acc_s2[r * kp..(r + 1) * kp];
        let mut pair = 0f32;
        for (ab, sb) in ar.chunks_exact(LANES).zip(sr.chunks_exact(LANES)) {
            for l in 0..LANES {
                pair += ab[l] * ab[l] - sb[l];
            }
        }
        let f = w0 + acc_xw[r] + 0.5 * pair;
        g[r] = loss::multiplier(f, labels[r], task);
        loss_sum += loss::loss(f, labels[r], task) as f64;
    }
    loss_sum
}

/// Scalar K-strided reference implementations of the column-visit kernels
/// — byte-for-byte the loops `nomad::engine` ran before lane-blocking.
/// They stay in-tree as the oracle the parity suite
/// (`rust/tests/kernel_properties.rs`) holds the lane-blocked kernels to,
/// and as the baseline side of the `engine_visit_*` benchmark pairs in
/// `BENCH_hotpath.json`. Buffers here are unpadded: `aa`/`acc_a`/`acc_s2`
/// are `nloc x k` and `v_j` has length `k`.
pub mod scalar {
    use super::{loss, Pcg64, Task, VisitHyper};

    /// Scalar reference of [`super::col_update`] (`gv` is the caller's
    /// K-length gradient buffer).
    #[allow(clippy::too_many_arguments)]
    pub fn col_update(
        rows: &[u32],
        xs: &[f32],
        g: &[f32],
        aa: &[f32],
        k: usize,
        wj: &mut f32,
        vj: &mut [f32],
        h: VisitHyper,
        gv: &mut [f32],
    ) {
        debug_assert_eq!(vj.len(), k);
        let gv = &mut gv[..k];
        gv.fill(0.0);
        let mut gw = 0f32;
        for (r, x) in rows.iter().zip(xs) {
            let r = *r as usize;
            let gi = g[r];
            let x = *x;
            gw += gi * x;
            let x2 = x * x;
            let ai = &aa[r * k..(r + 1) * k];
            for kk in 0..k {
                gv[kk] += gi * (x * ai[kk] - vj[kk] * x2);
            }
        }
        *wj -= h.eta * (gw * h.inv_n + h.lambda_w * h.reg_split * *wj);
        for kk in 0..k {
            vj[kk] -= h.eta * (gv[kk] * h.inv_n + h.lambda_v * h.reg_split * vj[kk]);
        }
    }

    /// Scalar reference of [`super::col_update_stochastic`].
    #[allow(clippy::too_many_arguments)]
    pub fn col_update_stochastic(
        rows: &[u32],
        xs: &[f32],
        g: &[f32],
        aa: &[f32],
        k: usize,
        wj: &mut f32,
        vj: &mut [f32],
        eta: f32,
        lambda_w: f32,
        lambda_v: f32,
        samples: usize,
        rng: &mut Pcg64,
    ) -> u64 {
        debug_assert_eq!(vj.len(), k);
        if rows.is_empty() {
            return 0;
        }
        for _ in 0..samples {
            let t = rng.below_usize(rows.len());
            let r = rows[t] as usize;
            let x = xs[t];
            let gi = g[r];
            *wj -= eta * (gi * x + lambda_w * *wj);
            let x2 = x * x;
            let ai = &aa[r * k..(r + 1) * k];
            for kk in 0..k {
                let vjk = vj[kk];
                vj[kk] = vjk - eta * (gi * (x * ai[kk] - vjk * x2) + lambda_v * vjk);
            }
        }
        samples as u64
    }

    /// Scalar reference of [`super::col_grad`] (K-strided inputs).
    #[allow(clippy::too_many_arguments)]
    pub fn col_grad(
        rows: &[u32],
        xs: &[f32],
        g: &[f32],
        aa: &[f32],
        k: usize,
        vj: &[f32],
        gv: &mut [f64],
    ) -> f64 {
        debug_assert_eq!(vj.len(), k);
        let gv = &mut gv[..k];
        gv.fill(0.0);
        let mut gw = 0f64;
        for (r, x) in rows.iter().zip(xs) {
            let r = *r as usize;
            let gi = g[r] as f64;
            let x = *x as f64;
            gw += gi * x;
            let x2 = x * x;
            let ai = &aa[r * k..(r + 1) * k];
            for kk in 0..k {
                gv[kk] += gi * (x * ai[kk] as f64 - vj[kk] as f64 * x2);
            }
        }
        gw
    }

    /// Scalar reference of [`super::col_recompute`].
    #[allow(clippy::too_many_arguments)]
    pub fn col_recompute(
        rows: &[u32],
        xs: &[f32],
        wj: f32,
        vj: &[f32],
        k: usize,
        acc_xw: &mut [f32],
        acc_a: &mut [f32],
        acc_s2: &mut [f32],
    ) {
        debug_assert_eq!(vj.len(), k);
        for (r, x) in rows.iter().zip(xs) {
            let r = *r as usize;
            let x = *x;
            acc_xw[r] += wj * x;
            let ar = &mut acc_a[r * k..(r + 1) * k];
            let sr = &mut acc_s2[r * k..(r + 1) * k];
            for kk in 0..k {
                let vx = vj[kk] * x;
                ar[kk] += vx;
                sr[kk] += vx * vx;
            }
        }
    }

    /// Scalar reference of [`super::finalize_rows`].
    #[allow(clippy::too_many_arguments)]
    pub fn finalize_rows(
        w0: f32,
        acc_xw: &[f32],
        acc_a: &[f32],
        acc_s2: &[f32],
        k: usize,
        labels: &[f32],
        task: Task,
        g: &mut [f32],
    ) -> f64 {
        let nloc = g.len();
        debug_assert_eq!(labels.len(), nloc);
        let mut loss_sum = 0f64;
        for r in 0..nloc {
            let mut pair = 0f32;
            for kk in 0..k {
                let a = acc_a[r * k + kk];
                pair += a * a - acc_s2[r * k + kk];
            }
            let f = w0 + acc_xw[r] + 0.5 * pair;
            g[r] = loss::multiplier(f, labels[r], task);
            loss_sum += loss::loss(f, labels[r], task) as f64;
        }
        loss_sum
    }
}

#[cfg(test)]
mod tests {
    use super::super::fused::padded_k;
    use super::*;
    use crate::util::prop::pad_rows;

    #[test]
    fn update_matches_scalar_bitwise_small() {
        let k = 3;
        let kp = padded_k(k);
        let rows = [0u32, 2];
        let xs = [1.5f32, -0.5];
        let g = [0.2f32, -0.1, 0.7];
        let aa = [0.1f32, 0.2, 0.3, 0.0, -0.4, 0.5, 0.6, 0.7, -0.8];
        let aa_p = pad_rows(&aa, 3, k, kp);
        let h = VisitHyper {
            eta: 0.3,
            inv_n: 0.25,
            lambda_w: 1e-3,
            lambda_v: 1e-3,
            reg_split: 0.5,
        };
        let mut w_s = 0.4f32;
        let mut v_s = vec![0.3f32, -0.2, 0.1];
        let mut gv = vec![0f32; k];
        scalar::col_update(&rows, &xs, &g, &aa, k, &mut w_s, &mut v_s, h, &mut gv);

        let mut w_l = 0.4f32;
        let mut v_l = vec![0f32; kp];
        v_l[..k].copy_from_slice(&[0.3, -0.2, 0.1]);
        let mut scratch = Scratch::new();
        col_update(&rows, &xs, &g, &aa_p, kp, &mut w_l, &mut v_l, h, &mut scratch);

        assert_eq!(w_l.to_bits(), w_s.to_bits());
        for kk in 0..k {
            assert_eq!(v_l[kk].to_bits(), v_s[kk].to_bits(), "kk={kk}");
        }
        assert!(v_l[k..].iter().all(|&x| x == 0.0), "padding drifted");
    }

    #[test]
    fn empty_column_is_regularizer_only() {
        let k = 2;
        let kp = padded_k(k);
        let h = VisitHyper {
            eta: 0.1,
            inv_n: 1.0,
            lambda_w: 0.5,
            lambda_v: 0.5,
            reg_split: 1.0,
        };
        let mut w = 1.0f32;
        let mut v = vec![0f32; kp];
        v[0] = 2.0;
        let mut scratch = Scratch::new();
        col_update(&[], &[], &[], &[], kp, &mut w, &mut v, h, &mut scratch);
        assert_eq!(w, 1.0 - 0.1 * 0.5);
        assert_eq!(v[0], 2.0 - 0.1 * 0.5 * 2.0);
    }

    #[test]
    fn finalize_reduces_pairwise_term() {
        let k = 2;
        let kp = padded_k(k);
        // One row: a = (1, 2), s2 = (0.5, 1), xw = 0.25, w0 = 0.1.
        let mut acc_a = vec![0f32; kp];
        acc_a[0] = 1.0;
        acc_a[1] = 2.0;
        let mut acc_s2 = vec![0f32; kp];
        acc_s2[0] = 0.5;
        acc_s2[1] = 1.0;
        let acc_xw = [0.25f32];
        let labels = [2.0f32];
        let mut g = [0f32];
        let loss_sum = finalize_rows(
            0.1,
            &acc_xw,
            &acc_a,
            &acc_s2,
            kp,
            &labels,
            Task::Regression,
            &mut g,
        );
        let f = 0.1 + 0.25 + 0.5 * ((1.0 - 0.5) + (4.0 - 1.0));
        assert!((g[0] - loss::multiplier(f, 2.0, Task::Regression)).abs() < 1e-7);
        assert!((loss_sum - loss::loss(f, 2.0, Task::Regression) as f64).abs() < 1e-7);
    }

    #[test]
    fn grad_matches_scalar_bitwise() {
        let k = 5;
        let kp = padded_k(k);
        let rows = [0u32, 1, 3];
        let xs = [1.25f32, -0.75, 2.0];
        let g = [0.4f32, -0.3, 0.0, 0.8];
        let aa: Vec<f32> = (0..4 * k).map(|i| (i as f32) * 0.07 - 0.5).collect();
        let aa_p = pad_rows(&aa, 4, k, kp);
        let vj: Vec<f32> = (0..k).map(|i| 0.2 - 0.1 * i as f32).collect();
        let vj_p = pad_rows(&vj, 1, k, kp);

        let mut gv_s = vec![0f64; k];
        let gw_s = scalar::col_grad(&rows, &xs, &g, &aa, k, &vj, &mut gv_s);
        let mut gv_l = vec![0f64; kp];
        let gw_l = col_grad(&rows, &xs, &g, &aa_p, kp, &vj_p, &mut gv_l);

        assert_eq!(gw_l.to_bits(), gw_s.to_bits());
        for kk in 0..k {
            assert_eq!(gv_l[kk].to_bits(), gv_s[kk].to_bits(), "kk={kk}");
        }
        assert!(gv_l[k..].iter().all(|&x| x == 0.0), "padding drifted");
        // Empty column: zero gradient, gv cleared.
        let mut gv_e = vec![9f64; kp];
        let gw_e = col_grad(&[], &[], &g, &aa_p, kp, &vj_p, &mut gv_e);
        assert_eq!(gw_e, 0.0);
        assert!(gv_e.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn stochastic_matches_scalar_bitwise() {
        let k = 5;
        let kp = padded_k(k);
        let rows = [0u32, 1, 2, 3];
        let xs = [1.0f32, -2.0, 0.5, 0.25];
        let g = [0.3f32, -0.2, 0.9, 0.0];
        let aa: Vec<f32> = (0..4 * k).map(|i| (i as f32) * 0.1 - 1.0).collect();
        let aa_p = pad_rows(&aa, 4, k, kp);

        let init_v: Vec<f32> = (0..k).map(|i| 0.1 * i as f32).collect();
        let mut w_s = -0.2f32;
        let mut v_s = init_v.clone();
        let mut rng_s = Pcg64::seeded(9);
        let n_s = scalar::col_update_stochastic(
            &rows, &xs, &g, &aa, k, &mut w_s, &mut v_s, 0.05, 1e-3, 1e-3, 3, &mut rng_s,
        );

        let mut w_l = -0.2f32;
        let mut v_l = vec![0f32; kp];
        v_l[..k].copy_from_slice(&init_v);
        let mut rng_l = Pcg64::seeded(9);
        let n_l = col_update_stochastic(
            &rows, &xs, &g, &aa_p, kp, &mut w_l, &mut v_l, 0.05, 1e-3, 1e-3, 3, &mut rng_l,
        );
        assert_eq!(n_s, n_l);
        assert_eq!(w_l.to_bits(), w_s.to_bits());
        for kk in 0..k {
            assert_eq!(v_l[kk].to_bits(), v_s[kk].to_bits(), "kk={kk}");
        }
        assert!(v_l[k..].iter().all(|&x| x == 0.0));
    }
}
