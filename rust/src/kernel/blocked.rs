//! Column-blocked FM scoring over `ColPartition`-sliced factor blocks.
//!
//! [`BlockedFm`] holds `V` as one lane-padded slab **per column block**
//! of a [`ColPartition`] instead of a single `D x kp` matrix — the
//! memory-efficient serving layout: a block-wise score sweep touches one
//! block slab at a time, so for models whose factor matrix dwarfs RAM
//! the blocks can come from anywhere (today: resident slices; the seam
//! the ROADMAP's bigger-than-RAM serving item needs).
//!
//! ## Bitwise parity contract
//!
//! `BlockedFm::score_rows` is **bitwise identical** to
//! [`FmKernel::score_rows`](super::FmKernel::score_rows) on the same
//! rows, under either kernel backend. That holds by construction, not by
//! tolerance:
//!
//! * CSR rows keep strictly-ascending column indices (a validated
//!   invariant), so sweeping blocks `lo..hi` in ascending order with a
//!   per-row cursor visits every row's non-zeros in exactly the storage
//!   order the fused per-row kernel uses.
//! * Each non-zero is folded through [`visit::col_recompute`] — whose
//!   lane body is the same `vx = v*x; a += vx; s2 += vx*vx` /
//!   `linear += w_j*x` sequence as the fused accumulate pass, and whose
//!   AVX2 variant is held bitwise to the lane oracle.
//! * Per-row `linear` is **seeded with `w0`** before the sweep (the
//!   fused pass starts its accumulator at `w0`), and the final reduction
//!   goes through the same
//!   [`FmKernel::pair_term_with`](super::FmKernel::pair_term_with).
//!
//! `rust/tests/kernel_properties.rs`-style parity pins live in the unit
//! tests below and in `rust/tests/serve_e2e.rs` end to end.

use crate::fm::FmModel;
use crate::partition::ColPartition;

use super::fused::{padded_k, FmKernel};
use super::scratch::AlignedF32;
use super::simd;
use super::visit;

/// FM parameters with the factor matrix sliced into `ColPartition`
/// column blocks (each block lane-padded like the fused kernel's AoSoA
/// layout). Build with [`from_model`](BlockedFm::from_model); score
/// through [`score_rows`](BlockedFm::score_rows).
#[derive(Debug, Clone)]
pub struct BlockedFm {
    d: usize,
    k: usize,
    kp: usize,
    w0: f32,
    w: Vec<f32>,
    part: ColPartition,
    /// Block `b` holds factor rows `[lo, hi)` as a `(hi - lo) x kp`
    /// row-major slab, padding lanes zero.
    blocks: Vec<AlignedF32>,
}

impl BlockedFm {
    /// Slices a model's factors along `part` (which must cover the
    /// model's `d` features).
    pub fn from_model(m: &FmModel, part: ColPartition) -> Self {
        assert_eq!(
            part.d(),
            m.d,
            "column partition covers {} features, model has {}",
            part.d(),
            m.d
        );
        let kp = padded_k(m.k);
        let mut blocks = Vec::with_capacity(part.n_blocks());
        for b in 0..part.n_blocks() {
            let (lo, hi) = part.block_range(b);
            let mut slab = AlignedF32::zeroed((hi - lo) * kp);
            for (local, j) in (lo..hi).enumerate() {
                slab[local * kp..local * kp + m.k]
                    .copy_from_slice(&m.v[j * m.k..(j + 1) * m.k]);
            }
            blocks.push(slab);
        }
        BlockedFm {
            d: m.d,
            k: m.k,
            kp,
            w0: m.w0,
            w: m.w.clone(),
            part,
            blocks,
        }
    }

    /// Number of features D.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of factors K.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of column blocks.
    pub fn n_blocks(&self) -> usize {
        self.part.n_blocks()
    }

    /// Largest resident block slab in bytes (the peak per-sweep factor
    /// residency a block-wise scorer touches at a time).
    pub fn max_block_bytes(&self) -> usize {
        self.blocks.iter().map(|s| 4 * s.len()).max().unwrap_or(0)
    }

    /// Scores rows given as raw CSR parts (row `i` is
    /// `indices[indptr[i]..indptr[i+1]]`, strictly-ascending in-range
    /// columns) into `out`, sweeping the column blocks in ascending
    /// order. Bitwise identical to [`FmKernel::score_rows`] on the same
    /// rows (see the module docs for why). Zero steady-state allocation:
    /// `scratch` grows monotonically with the batch row count.
    pub fn score_rows(
        &self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut BlockScratch,
    ) {
        let n = out.len();
        assert_eq!(
            indptr.len(),
            n + 1,
            "indptr length {} != rows {} + 1",
            indptr.len(),
            n
        );
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        let kp = self.kp;
        let b = simd::backend();
        scratch.ensure(n, kp);
        let (linear, a, s2, cursor) = scratch.parts(n, kp);
        // Per-row state: the fused accumulate pass starts `linear` at w0
        // and zero-filled (a, s2); the block sweep must match exactly.
        linear.fill(self.w0);
        a.fill(0.0);
        s2.fill(0.0);
        for (r, c) in cursor.iter_mut().enumerate() {
            *c = indptr[r];
        }
        for (blk, slab) in self.blocks.iter().enumerate() {
            let (lo, hi) = self.part.block_range(blk);
            for r in 0..n {
                let end = indptr[r + 1];
                let mut c = cursor[r];
                // Ascending row indices: this block's non-zeros are the
                // cursor run with lo <= j < hi.
                while c < end && (indices[c] as usize) < hi {
                    let j = indices[c] as usize;
                    debug_assert!(j >= lo, "row {r}: unsorted column index {j}");
                    let x = values[c];
                    // One-row, one-column fold through the engine's
                    // column-visit kernel: identical per-non-zero FP ops
                    // (and backend dispatch) to the fused accumulate.
                    visit::col_recompute_backend(
                        b,
                        &[0u32],
                        &[x],
                        self.w[j],
                        &slab[(j - lo) * kp..(j - lo + 1) * kp],
                        kp,
                        &mut linear[r..r + 1],
                        &mut a[r * kp..(r + 1) * kp],
                        &mut s2[r * kp..(r + 1) * kp],
                    );
                    c += 1;
                }
                cursor[r] = c;
            }
        }
        for (r, o) in out.iter_mut().enumerate() {
            *o = linear[r]
                + FmKernel::pair_term_with(b, &a[r * kp..(r + 1) * kp], &s2[r * kp..(r + 1) * kp]);
        }
    }
}

/// Grow-only per-batch accumulators for [`BlockedFm::score_rows`]: the
/// per-row linear terms, the `n x kp` factor-sum slabs and the per-row
/// non-zero cursors. One per connection/thread, like
/// [`Scratch`](super::Scratch); capacity never shrinks, so a steady
/// request load allocates nothing after the largest batch has been seen.
#[derive(Debug, Clone, Default)]
pub struct BlockScratch {
    linear: Vec<f32>,
    a: AlignedF32,
    s2: AlignedF32,
    cursor: Vec<usize>,
}

impl BlockScratch {
    /// An empty arena; buffers grow on first use.
    pub fn new() -> Self {
        BlockScratch::default()
    }

    fn ensure(&mut self, n: usize, kp: usize) {
        if self.linear.len() < n {
            self.linear.resize(n, 0.0);
            self.cursor.resize(n, 0);
        }
        if self.a.len() < n * kp {
            self.a.resize_zeroed(n * kp);
            self.s2.resize_zeroed(n * kp);
        }
    }

    fn parts(
        &mut self,
        n: usize,
        kp: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [usize]) {
        (
            &mut self.linear[..n],
            &mut self.a[..n * kp],
            &mut self.s2[..n * kp],
            &mut self.cursor[..n],
        )
    }

    /// Current accumulator capacity in floats (grow-only watermark; see
    /// [`Scratch::capacity`](super::Scratch::capacity)).
    pub fn capacity(&self) -> usize {
        self.a.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Scratch;
    use crate::util::rng::Pcg64;

    fn random_model(d: usize, k: usize, seed: u64) -> FmModel {
        let mut rng = Pcg64::seeded(seed);
        let mut m = FmModel::init(d, k, 0.3, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.5);
        }
        m.w0 = 0.25;
        m
    }

    #[test]
    fn blocked_score_is_bitwise_equal_to_fused() {
        let ds = crate::data::synth::table2_dataset("housing", 5).unwrap();
        let (indptr, indices, values) = ds.rows.raw_parts();
        for k in [1usize, 4, 7, 16] {
            let m = random_model(ds.d(), k, 40 + k as u64);
            let kern = FmKernel::from_model(&m);
            let mut want = vec![0f32; ds.n()];
            kern.score_rows(indptr, indices, values, &mut want, &mut Scratch::for_k(k));
            for nb in [1usize, 2, 3, 5, ds.d()] {
                let blocked = BlockedFm::from_model(&m, ColPartition::with_n_blocks(ds.d(), nb));
                let mut got = vec![0f32; ds.n()];
                blocked.score_rows(indptr, indices, values, &mut got, &mut BlockScratch::new());
                assert_eq!(got, want, "k={k} nb={nb}");
            }
        }
    }

    #[test]
    fn empty_rows_score_bias_and_scratch_grows_monotonically() {
        let m = random_model(6, 3, 9);
        let blocked = BlockedFm::from_model(&m, ColPartition::with_n_blocks(6, 2));
        let mut scratch = BlockScratch::new();
        let mut out = vec![0f32; 2];
        blocked.score_rows(&[0, 0, 0], &[], &[], &mut out, &mut scratch);
        assert_eq!(out, vec![m.w0; 2]);
        let cap = scratch.capacity();
        blocked.score_rows(&[0, 0], &[], &[], &mut out[..1], &mut scratch);
        assert_eq!(scratch.capacity(), cap, "capacity must never shrink");
    }

    #[test]
    #[should_panic(expected = "column partition covers")]
    fn partition_shape_mismatch_panics() {
        let m = random_model(6, 3, 11);
        BlockedFm::from_model(&m, ColPartition::with_n_blocks(7, 2));
    }
}
