//! Synchronous DSGD-style hybrid-parallel baseline.
//!
//! The bulk-synchronous counterpart to DS-FACTO (paper §4.2, "DSGD style
//! communication (synchronous)"): workers own disjoint row shards; the
//! parameter columns are split into P blocks; an epoch is P sub-epochs.
//! In sub-epoch s, worker p updates column block (p + s) mod P against its
//! row shard — the block-diagonal stratum schedule of
//! [`GridPlan`](crate::partition::GridPlan) — so no two workers touch the
//! same parameters. The synchronization terms G and A are recomputed
//! exactly at a **barrier before every sub-epoch** (this is precisely the
//! bulk synchronization whose cost DS-FACTO's incremental scheme removes).
//!
//! The (row x column) grid comes from [`crate::partition`]: row shards
//! through a [`crate::partition::RowPartition`] (contiguous by default;
//! nnz-balanced via
//! [`DsgdConfig::row_partition`]) materialized through the
//! [`crate::data::DataSource`] seam ([`DsgdConfig::source`]; in-memory
//! slices by default, per-worker shard-cache files under
//! `data_cache = <dir>`), column blocks through [`ColPartition`]. The
//! per-column update runs on the
//! lane-blocked [`kernel::visit::col_update`](crate::kernel::visit::col_update)
//! kernel over a `kp`-strided auxiliary cache — the same hot path as the
//! NOMAD engine's update visits, with identical per-coordinate operation
//! order to the scalar loop it replaced (so contiguous-default runs are
//! bitwise unchanged; `rust/tests/partition_properties.rs` pins this
//! against a pre-refactor reference).
//!
//! The session-facing entry point is [`crate::train::DsgdTrainer`].

use crate::data::{Dataset, ShardSource};
use crate::fm::{loss, FmHyper, FmModel};
use crate::kernel::{padded_k, visit, FmKernel, Scratch};
use crate::metrics::TrainOutput;
use crate::optim::LrSchedule;
use crate::partition::{
    build_shards_from_source, ColPartition, GridPlan, PartitionStats, RowStrategy, Shard,
};
use crate::train::{Probe, TrainObserver};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// DSGD configuration.
#[derive(Debug, Clone)]
pub struct DsgdConfig {
    pub epochs: usize,
    pub eta: LrSchedule,
    pub workers: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// Row-shard strategy (contiguous = legacy default).
    pub row_partition: RowStrategy,
    /// Where workers pull their row shards from (in-memory slices by
    /// default; a shard cache under `data_cache = <dir>`).
    pub source: ShardSource,
}

impl Default for DsgdConfig {
    fn default() -> Self {
        DsgdConfig {
            epochs: 50,
            // Column-batch update semantics (see update_block): batch-GD
            // scale steps.
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            seed: 42,
            eval_every: 1,
            row_partition: RowStrategy::Contiguous,
            source: ShardSource::InMemory,
        }
    }
}

/// The per-sub-epoch scalars a block update needs (bundled so the worker
/// call stays readable).
#[derive(Clone, Copy)]
struct BlockArgs {
    /// Padded factor stride of the barrier's A cache.
    kp: usize,
    /// The column-block grid.
    col_plan: ColPartition,
    /// Column block this worker updates this sub-epoch.
    col_block: usize,
    /// Step size for this epoch.
    eta: f32,
    /// Total example count N (the 1/N normalization).
    n_total: usize,
    /// Sub-epochs per epoch P (the L2 split).
    p_total: usize,
}

/// A worker's updates to one column block (applied after the join).
struct ColumnDelta {
    /// Column block id.
    block: usize,
    /// New values for w in the block (block-local order).
    w: Vec<f32>,
    /// New values for v rows in the block (block-local, K-strided).
    v: Vec<f32>,
    /// Sum of G_i over the worker's rows (for the shared w0 step).
    g_sum: f64,
    n_rows: usize,
}

/// Trains with synchronous block-cyclic DSGD, reporting each epoch to
/// `obs` (which may stop the run at an epoch boundary).
pub fn dsgd_train(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &DsgdConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<TrainOutput> {
    Ok(dsgd_train_with_stats(train, test, fm, cfg, obs)?.0)
}

/// Like [`dsgd_train`], also returning the row-shard load summary.
pub fn dsgd_train_with_stats(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &DsgdConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<(TrainOutput, PartitionStats)> {
    let p = cfg.workers.max(1).min(train.d().max(1));
    let mut rng = Pcg64::new(cfg.seed, 0xd5fd);
    let model = FmModel::init(train.d(), fm.k, fm.init_std, &mut rng);
    let probe = Probe::new(train, test, fm.lambda_w, fm.lambda_v, cfg.eval_every);

    // The (row-shard x column-block) grid, built once, with the shards
    // pulled through the data seam (in-memory by default — bit-identical
    // to the legacy slice build; shard-cache files when configured).
    let resolved = cfg.source.resolve(train)?;
    let source = resolved.as_dyn();
    let row_plan = source.plan(cfg.row_partition, p)?;
    let pstats = PartitionStats::from_plan(&row_plan, &train.rows);
    let shards = build_shards_from_source(source, &row_plan)?;
    let out = dsgd_core(&shards, train.n(), train.d(), p, fm, cfg, model, probe, obs)?;
    Ok((out, pstats))
}

/// [`dsgd_train_with_stats`] off a [`DataSource`] — no caller-held full
/// matrix. Each simulated worker still holds its own row shard for the
/// whole session (that *is* the paper's distributed memory model: the
/// data is resident across workers, never in one coordinator), and the
/// convergence probe folds over those resident shards, so no step of the
/// run materializes the full CSR. Model and trace are bitwise identical
/// to the in-memory run of the same config (same RNG stream, same visit
/// order, same probe fold).
///
/// [`DataSource`]: crate::data::DataSource
pub fn dsgd_train_from_source(
    src: &dyn crate::data::DataSource,
    fm: &FmHyper,
    cfg: &DsgdConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<(TrainOutput, PartitionStats)> {
    let p = cfg.workers.max(1).min(src.d().max(1));
    let mut rng = Pcg64::new(cfg.seed, 0xd5fd);
    let model = FmModel::init(src.d(), fm.k, fm.init_std, &mut rng);
    let row_plan = src.plan(cfg.row_partition, p)?;
    let shards = build_shards_from_source(src, &row_plan)?;
    let pstats =
        PartitionStats::from_shard_nnz(shards.iter().map(|s| s.rows.nnz()).collect());
    let probe = Probe::from_shards(&shards, src.n(), fm.lambda_w, fm.lambda_v, cfg.eval_every);
    let out = dsgd_core(&shards, src.n(), src.d(), p, fm, cfg, model, probe, obs)?;
    Ok((out, pstats))
}

/// The shared epoch loop behind both entry points: block-cyclic
/// sub-epochs over already-built shards, recording through the probe the
/// caller chose (in-memory trace fold or resident-shard fold).
#[allow(clippy::too_many_arguments)]
fn dsgd_core(
    shards: &[Shard],
    n: usize,
    d: usize,
    p: usize,
    fm: &FmHyper,
    cfg: &DsgdConfig,
    mut model: FmModel,
    mut probe: Probe<'_>,
    obs: &mut dyn TrainObserver,
) -> crate::Result<TrainOutput> {
    let k = fm.k;
    let kp = padded_k(k);
    let col_plan = ColPartition::with_n_blocks(d, p);
    let plan = GridPlan::new(p, col_plan.n_blocks());

    let mut sw = Stopwatch::start();
    let mut clock = 0f64;
    let mut stopped = probe.try_record(0, 0.0, &model, obs)?.is_stop();
    sw.lap();

    for epoch in 0..cfg.epochs {
        if stopped {
            break;
        }
        let eta = cfg.eta.at(epoch);
        for sub in 0..plan.n_subepochs() {
            // --- Barrier: recompute G and A exactly (the bulk sync step).
            let (g_all, a_all) = compute_aux(&model, &shards, n, kp);

            // --- Parallel block-diagonal updates.
            let deltas = std::thread::scope(|scope| {
                let model_ref = &model;
                let g_ref = &g_all;
                let a_ref = &a_all;
                let handles: Vec<_> = shards
                    .iter()
                    .map(|shard| {
                        let col_block = plan.block_for(shard.id, sub);
                        let args = BlockArgs {
                            kp,
                            col_plan,
                            col_block,
                            eta,
                            n_total: n,
                            p_total: p,
                        };
                        scope.spawn(move || update_block(model_ref, shard, g_ref, a_ref, fm, args))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("dsgd worker panicked"))
                    .collect::<Vec<ColumnDelta>>()
            });

            // --- Apply deltas (disjoint column blocks; safe sequential write).
            let mut g_total = 0f64;
            let mut rows_total = 0usize;
            for delta in deltas {
                let (lo, hi) = col_plan.block_range(delta.block);
                model.w[lo..hi].copy_from_slice(&delta.w);
                model.v[lo * k..hi * k].copy_from_slice(&delta.v);
                g_total += delta.g_sum;
                rows_total += delta.n_rows;
            }
            // Shared bias step with the merged multiplier mean (eq. 11).
            if rows_total > 0 {
                model.w0 -= eta * (g_total / rows_total as f64) as f32;
            }
        }
        clock += sw.lap();
        stopped = probe.try_record(epoch + 1, clock, &model, obs)?.is_stop();
        sw.lap();
    }

    Ok(TrainOutput {
        model,
        trace: probe.into_trace(),
        wall_secs: clock,
    })
}

/// Exact G (multipliers) and lane-blocked A (factor sums, `n x kp` with
/// zero padding) for all rows, in parallel over the shards. Each barrier
/// builds the lane-blocked kernel view once (O(D K) copy) and the workers
/// score through per-thread scratch arenas — zero per-example allocation.
fn compute_aux(model: &FmModel, shards: &[Shard], n: usize, kp: usize) -> (Vec<f32>, Vec<f32>) {
    let k = model.k;
    let mut g = vec![0f32; n];
    let mut a = vec![0f32; n * kp];
    let kern = FmKernel::from_model(model);
    std::thread::scope(|scope| {
        let kern_ref = &kern;
        let mut g_rest: &mut [f32] = &mut g;
        let mut a_rest: &mut [f32] = &mut a;
        for shard in shards {
            let take = shard.nloc();
            let (g_blk, g_next) = g_rest.split_at_mut(take);
            let (a_blk, a_next) = a_rest.split_at_mut(take * kp);
            g_rest = g_next;
            a_rest = a_next;
            scope.spawn(move || {
                let mut scratch = Scratch::for_k(k);
                for r in 0..take {
                    let (idx, val) = shard.rows.row(r);
                    let f = kern_ref.score_with_sums(
                        idx,
                        val,
                        &mut a_blk[r * kp..r * kp + k],
                        &mut scratch,
                    );
                    g_blk[r] = loss::multiplier(f, shard.labels[r], shard.task);
                }
            });
        }
    });
    (g, a)
}

/// One worker's sub-epoch: updates of column block `col_block` against its
/// row shard, with the (stale within the sub-epoch) G/A, through the
/// lane-blocked column-update kernel.
///
/// Column-batch semantics matching the NOMAD engine (see
/// `nomad::engine::Worker::update_visit`): with G frozen for the
/// sub-epoch, per-nonzero application of eqs. 12-13 compounds into an
/// unnormalized batch step; instead each sub-epoch applies the 1/N-scaled
/// local partial gradient with the L2 term split across the P sub-epochs
/// that touch a column per epoch.
fn update_block(
    model: &FmModel,
    shard: &Shard,
    g_all: &[f32],
    a_all: &[f32],
    fm: &FmHyper,
    args: BlockArgs,
) -> ColumnDelta {
    let BlockArgs {
        kp,
        col_plan,
        col_block,
        eta,
        n_total,
        p_total,
    } = args;
    let k = model.k;
    let (lo, hi) = col_plan.block_range(col_block);
    let nb = hi - lo;
    let mut w = model.w[lo..hi].to_vec();
    // Lane-pad the block's factor rows (padding lanes stay exactly zero
    // under the kernel's update, so stripping them back below is lossless).
    let mut v = vec![0f32; nb * kp];
    for (bi, j) in (lo..hi).enumerate() {
        v[bi * kp..bi * kp + k].copy_from_slice(&model.v[j * k..(j + 1) * k]);
    }
    // Shard-local views of the global auxiliary arrays: the CSC carries
    // local row indices.
    let g = &g_all[shard.start..shard.end];
    let aa = &a_all[shard.start * kp..shard.end * kp];
    let h = visit::VisitHyper {
        eta,
        inv_n: 1.0 / n_total.max(1) as f32,
        lambda_w: fm.lambda_w,
        lambda_v: fm.lambda_v,
        reg_split: 1.0 / p_total.max(1) as f32,
    };
    let mut scratch = Scratch::for_k(k);
    for (bi, j) in (lo..hi).enumerate() {
        let (rows, xs) = shard.cols.col(j);
        visit::col_update(
            rows,
            xs,
            g,
            aa,
            kp,
            &mut w[bi],
            &mut v[bi * kp..(bi + 1) * kp],
            h,
            &mut scratch,
        );
    }
    let mut g_sum = 0f64;
    for &gi in g {
        g_sum += gi as f64;
    }
    // Strip the padding back to the K-strided model layout.
    let mut v_out = vec![0f32; nb * k];
    for bi in 0..nb {
        v_out[bi * k..(bi + 1) * k].copy_from_slice(&v[bi * kp..bi * kp + k]);
    }
    ColumnDelta {
        block: col_block,
        w,
        v: v_out,
        g_sum,
        n_rows: shard.nloc(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::partition::{build_shards, RowPartition};

    #[test]
    fn aux_matches_sequential() {
        let ds = synth::table2_dataset("housing", 1).unwrap();
        let mut rng = Pcg64::seeded(2);
        let m = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let kp = padded_k(4);
        let part = RowPartition::contiguous(ds.n(), 3);
        let shards = build_shards(&ds, &part);
        let (g, a) = compute_aux(&m, &shards, ds.n(), kp);
        let mut ak = vec![0f32; 4];
        let mut s2 = vec![0f32; 4];
        for i in 0..ds.n() {
            let (idx, val) = ds.rows.row(i);
            let f = m.score_with_sums(idx, val, &mut ak, &mut s2);
            assert!((g[i] - loss::multiplier(f, ds.labels[i], ds.task)).abs() < 1e-6);
            for kk in 0..4 {
                assert!((a[i * kp + kk] - ak[kk]).abs() < 1e-6);
            }
            // Padding lanes stay zero.
            for kk in 4..kp {
                assert_eq!(a[i * kp + kk], 0.0);
            }
        }
    }

    #[test]
    fn dsgd_converges_on_housing() {
        let ds = synth::table2_dataset("housing", 2).unwrap();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = DsgdConfig {
            epochs: 20,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            ..Default::default()
        };
        let out = dsgd_train(&ds, None, &fm, &cfg, &mut ()).unwrap();
        let first = out.trace.first().unwrap().objective;
        let last = out.trace.last().unwrap().objective;
        assert!(last < 0.5 * first, "{first} -> {last}");
    }

    #[test]
    fn dsgd_matches_quality_of_sequential_sgd() {
        let ds = synth::table2_dataset("diabetes", 3).unwrap();
        let (train, test) = ds.split(0.8, 1);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = DsgdConfig {
            epochs: 30,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            ..Default::default()
        };
        let out = dsgd_train(&train, Some(&test), &fm, &cfg, &mut ()).unwrap();
        let acc = out.trace.last().unwrap().test.unwrap().accuracy;
        assert!(acc > 0.6, "accuracy {acc}");
    }

    #[test]
    fn single_worker_dsgd_reduces_objective() {
        let ds = synth::table2_dataset("housing", 4).unwrap();
        let fm = FmHyper::default();
        let cfg = DsgdConfig {
            epochs: 10,
            workers: 1,
            eta: LrSchedule::Constant(0.5),
            ..Default::default()
        };
        let out = dsgd_train(&ds, None, &fm, &cfg, &mut ()).unwrap();
        assert!(out.trace.last().unwrap().objective < 0.7 * out.trace[0].objective);
    }

    #[test]
    fn stats_report_shard_load() {
        let ds = synth::table2_dataset("housing", 6).unwrap();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = DsgdConfig {
            epochs: 2,
            workers: 4,
            ..Default::default()
        };
        let (_, stats) = dsgd_train_with_stats(&ds, None, &fm, &cfg, &mut ()).unwrap();
        assert_eq!(stats.shard_nnz.len(), 4);
        assert_eq!(stats.shard_nnz.iter().sum::<usize>(), ds.nnz());
        assert!(stats.imbalance >= 1.0 - 1e-12);
    }
}
