//! Synchronous DSGD-style hybrid-parallel baseline.
//!
//! The bulk-synchronous counterpart to DS-FACTO (paper §4.2, "DSGD style
//! communication (synchronous)"): workers own disjoint row blocks; the
//! parameter columns are split into P blocks; an epoch is P sub-epochs.
//! In sub-epoch s, worker p updates column block (p + s) mod P against its
//! row block — a block-diagonal schedule, so no two workers touch the same
//! parameters. The synchronization terms G and A are recomputed exactly at
//! a **barrier before every sub-epoch** (this is precisely the bulk
//! synchronization whose cost DS-FACTO's incremental scheme removes).
//!
//! The session-facing entry point is [`crate::train::DsgdTrainer`].

use crate::data::{Csc, Dataset};
use crate::fm::{loss, FmHyper, FmModel};
use crate::kernel::{FmKernel, Scratch};
use crate::metrics::TrainOutput;
use crate::optim::LrSchedule;
use crate::train::{Probe, TrainObserver};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// DSGD configuration.
#[derive(Debug, Clone)]
pub struct DsgdConfig {
    pub epochs: usize,
    pub eta: LrSchedule,
    pub workers: usize,
    pub seed: u64,
    pub eval_every: usize,
}

impl Default for DsgdConfig {
    fn default() -> Self {
        DsgdConfig {
            epochs: 50,
            // Column-batch update semantics (see update_block): batch-GD
            // scale steps.
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            seed: 42,
            eval_every: 1,
        }
    }
}

/// Per-worker view: row range plus the CSC of that row block.
struct RowBlock {
    start: usize,
    end: usize,
    cols: Csc,
}

/// A worker's updates to one column block (applied after the join).
struct ColumnDelta {
    /// Column block id.
    block: usize,
    /// New values for w in the block (block-local order).
    w: Vec<f32>,
    /// New values for v rows in the block.
    v: Vec<f32>,
    /// Sum of G_i over the worker's rows (for the shared w0 step).
    g_sum: f64,
    n_rows: usize,
}

/// Column-block boundaries: block b covers `[bounds[b], bounds[b+1])`.
fn column_bounds(d: usize, p: usize) -> Vec<usize> {
    let chunk = d.div_ceil(p);
    (0..=p).map(|b| (b * chunk).min(d)).collect()
}

/// Trains with synchronous block-cyclic DSGD, reporting each epoch to
/// `obs` (which may stop the run at an epoch boundary).
pub fn dsgd_train(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &DsgdConfig,
    obs: &mut dyn TrainObserver,
) -> TrainOutput {
    let p = cfg.workers.max(1).min(train.d().max(1));
    let n = train.n();
    let d = train.d();
    let k = fm.k;
    let mut rng = Pcg64::new(cfg.seed, 0xd5fd);
    let mut model = FmModel::init(d, k, fm.init_std, &mut rng);
    let mut probe = Probe::new(train, test, fm.lambda_w, fm.lambda_v, cfg.eval_every);

    // Row blocks + per-block column views (built once).
    let row_chunk = n.div_ceil(p);
    let blocks: Vec<RowBlock> = (0..p)
        .map(|b| {
            let start = (b * row_chunk).min(n);
            let end = ((b + 1) * row_chunk).min(n);
            RowBlock {
                start,
                end,
                cols: train.rows.slice_rows(start, end).to_csc(),
            }
        })
        .collect();
    let bounds = column_bounds(d, p);

    let mut sw = Stopwatch::start();
    let mut clock = 0f64;
    let mut stopped = probe.record(0, 0.0, &model, obs).is_stop();
    sw.lap();

    for epoch in 0..cfg.epochs {
        if stopped {
            break;
        }
        let eta = cfg.eta.at(epoch);
        for sub in 0..p {
            // --- Barrier: recompute G and A exactly (the bulk sync step).
            let (g_all, a_all) = compute_aux(&model, train, p);

            // --- Parallel block-diagonal updates.
            let deltas = std::thread::scope(|scope| {
                let model_ref = &model;
                let g_ref = &g_all;
                let a_ref = &a_all;
                let bounds_ref = &bounds;
                let handles: Vec<_> = blocks
                    .iter()
                    .enumerate()
                    .map(|(wid, rb)| {
                        let col_block = (wid + sub) % p;
                        scope.spawn(move || {
                            update_block(
                                model_ref, rb, g_ref, a_ref, bounds_ref, col_block, eta, fm, n, p,
                            )
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("dsgd worker panicked"))
                    .collect::<Vec<ColumnDelta>>()
            });

            // --- Apply deltas (disjoint column blocks; safe sequential write).
            let mut g_total = 0f64;
            let mut rows_total = 0usize;
            for delta in deltas {
                let (lo, hi) = (bounds[delta.block], bounds[delta.block + 1]);
                model.w[lo..hi].copy_from_slice(&delta.w);
                model.v[lo * k..hi * k].copy_from_slice(&delta.v);
                g_total += delta.g_sum;
                rows_total += delta.n_rows;
            }
            // Shared bias step with the merged multiplier mean (eq. 11).
            if rows_total > 0 {
                model.w0 -= eta * (g_total / rows_total as f64) as f32;
            }
        }
        clock += sw.lap();
        stopped = probe.record(epoch + 1, clock, &model, obs).is_stop();
        sw.lap();
    }

    TrainOutput {
        model,
        trace: probe.into_trace(),
        wall_secs: clock,
    }
}

/// Exact G (multipliers) and A (factor sums) for all rows, in parallel.
/// Each barrier builds the lane-blocked kernel view once (O(D K) copy)
/// and the workers score through per-thread scratch arenas — zero
/// per-example allocation.
fn compute_aux(model: &FmModel, ds: &Dataset, p: usize) -> (Vec<f32>, Vec<f32>) {
    let n = ds.n();
    let k = model.k;
    let chunk = n.div_ceil(p);
    let mut g = vec![0f32; n];
    let mut a = vec![0f32; n * k];
    let kern = FmKernel::from_model(model);
    std::thread::scope(|scope| {
        let kern_ref = &kern;
        let mut g_rest: &mut [f32] = &mut g;
        let mut a_rest: &mut [f32] = &mut a;
        for b in 0..p {
            let start = (b * chunk).min(n);
            let end = ((b + 1) * chunk).min(n);
            let take = end - start;
            let (g_blk, g_next) = g_rest.split_at_mut(take);
            let (a_blk, a_next) = a_rest.split_at_mut(take * k);
            g_rest = g_next;
            a_rest = a_next;
            scope.spawn(move || {
                let mut scratch = Scratch::for_k(k);
                for (r, i) in (start..end).enumerate() {
                    let (idx, val) = ds.rows.row(i);
                    let f = kern_ref.score_with_sums(
                        idx,
                        val,
                        &mut a_blk[r * k..(r + 1) * k],
                        &mut scratch,
                    );
                    g_blk[r] = loss::multiplier(f, ds.labels[i], ds.task);
                }
            });
        }
    });
    (g, a)
}

/// One worker's sub-epoch: updates of column block `col_block` against its
/// row block, with the (stale within the sub-epoch) G/A.
#[allow(clippy::too_many_arguments)]
fn update_block(
    model: &FmModel,
    rb: &RowBlock,
    g_all: &[f32],
    a_all: &[f32],
    bounds: &[usize],
    col_block: usize,
    eta: f32,
    fm: &FmHyper,
    n_total: usize,
    p_total: usize,
) -> ColumnDelta {
    let k = model.k;
    let (lo, hi) = (bounds[col_block], bounds[col_block + 1]);
    let mut w = model.w[lo..hi].to_vec();
    let mut v = model.v[lo * k..hi * k].to_vec();
    let mut g_sum = 0f64;

    // Column-batch semantics matching the NOMAD engine (see
    // `nomad::engine::Worker::update_visit`): with G frozen for the
    // sub-epoch, per-nonzero application of eqs. 12-13 compounds into an
    // unnormalized batch step; instead each sub-epoch applies the
    // 1/N-scaled local partial gradient with the L2 term split across the
    // P sub-epochs that touch a column per epoch.
    let inv_n = 1.0 / n_total.max(1) as f32;
    let reg_split = 1.0 / p_total.max(1) as f32;
    let mut gv = vec![0f32; k];
    for j in lo..hi {
        let (rows, xs) = rb.cols.col(j);
        let jl = j - lo;
        let mut gw = 0f32;
        gv.fill(0.0);
        let vj = &mut v[jl * k..(jl + 1) * k];
        for (r, x) in rows.iter().zip(xs) {
            let i = rb.start + *r as usize; // global row
            let g = g_all[i];
            let x = *x;
            gw += g * x; // eq. 7 partial sum
            let x2 = x * x;
            let a_i = &a_all[i * k..(i + 1) * k];
            for kk in 0..k {
                gv[kk] += g * (x * a_i[kk] - vj[kk] * x2); // eq. 8 partial sum
            }
        }
        w[jl] -= eta * (gw * inv_n + fm.lambda_w * reg_split * w[jl]);
        for kk in 0..k {
            vj[kk] -= eta * (gv[kk] * inv_n + fm.lambda_v * reg_split * vj[kk]);
        }
    }
    for i in rb.start..rb.end {
        g_sum += g_all[i] as f64;
    }
    ColumnDelta {
        block: col_block,
        w,
        v,
        g_sum,
        n_rows: rb.end - rb.start,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn column_bounds_tile_dimensions() {
        for (d, p) in [(10, 3), (8, 4), (7, 7), (5, 8), (1, 2)] {
            let b = column_bounds(d, p);
            assert_eq!(b.len(), p + 1);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), d);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn aux_matches_sequential() {
        let ds = synth::table2_dataset("housing", 1).unwrap();
        let mut rng = Pcg64::seeded(2);
        let m = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let (g, a) = compute_aux(&m, &ds, 3);
        let mut ak = vec![0f32; 4];
        let mut s2 = vec![0f32; 4];
        for i in 0..ds.n() {
            let (idx, val) = ds.rows.row(i);
            let f = m.score_with_sums(idx, val, &mut ak, &mut s2);
            assert!((g[i] - loss::multiplier(f, ds.labels[i], ds.task)).abs() < 1e-6);
            for kk in 0..4 {
                assert!((a[i * 4 + kk] - ak[kk]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dsgd_converges_on_housing() {
        let ds = synth::table2_dataset("housing", 2).unwrap();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = DsgdConfig {
            epochs: 20,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            ..Default::default()
        };
        let out = dsgd_train(&ds, None, &fm, &cfg, &mut ());
        let first = out.trace.first().unwrap().objective;
        let last = out.trace.last().unwrap().objective;
        assert!(last < 0.5 * first, "{first} -> {last}");
    }

    #[test]
    fn dsgd_matches_quality_of_sequential_sgd() {
        let ds = synth::table2_dataset("diabetes", 3).unwrap();
        let (train, test) = ds.split(0.8, 1);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = DsgdConfig {
            epochs: 30,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            ..Default::default()
        };
        let out = dsgd_train(&train, Some(&test), &fm, &cfg, &mut ());
        let acc = out.trace.last().unwrap().test.unwrap().accuracy;
        assert!(acc > 0.6, "accuracy {acc}");
    }

    #[test]
    fn single_worker_dsgd_reduces_objective() {
        let ds = synth::table2_dataset("housing", 4).unwrap();
        let fm = FmHyper::default();
        let cfg = DsgdConfig {
            epochs: 10,
            workers: 1,
            eta: LrSchedule::Constant(0.5),
            ..Default::default()
        };
        let out = dsgd_train(&ds, None, &fm, &cfg, &mut ());
        assert!(out.trace.last().unwrap().objective < 0.7 * out.trace[0].objective);
    }
}
