//! Bulk-synchronous full-gradient descent baseline.
//!
//! The strawman of paper §4.2: compute the synchronization terms G and A
//! exactly at every iteration with a barrier (an all-reduce over workers —
//! here the reduction is performed over per-worker partial gradients
//! computed on row blocks by scoped threads), then take one deterministic
//! gradient step (eqs. 6-8).
//!
//! The session-facing entry point is [`crate::train::BulkSyncTrainer`].

use crate::data::Dataset;
use crate::fm::{loss, FmHyper, FmModel};
use crate::kernel::{FmKernel, Scratch};
use crate::metrics::TrainOutput;
use crate::optim::LrSchedule;
use crate::train::{Probe, TrainObserver};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Bulk-synchronous GD configuration (replaces the old seven-positional
/// argument signature).
#[derive(Debug, Clone)]
pub struct BulkSyncConfig {
    /// Gradient iterations.
    pub iters: usize,
    /// Learning-rate schedule.
    pub eta: LrSchedule,
    /// Parallel reduce width.
    pub workers: usize,
    /// RNG seed (model init).
    pub seed: u64,
    /// Evaluate held-out metrics every this many iterations.
    pub eval_every: usize,
}

impl Default for BulkSyncConfig {
    fn default() -> Self {
        BulkSyncConfig {
            iters: 50,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            seed: 42,
            eval_every: 1,
        }
    }
}

/// Dense gradient buffers (the "reduce" payload).
#[derive(Debug, Clone)]
struct GradBuf {
    g0: f64,
    gw: Vec<f64>,
    gv: Vec<f64>,
    loss: f64,
}

impl GradBuf {
    fn zeros(d: usize, k: usize) -> Self {
        GradBuf {
            g0: 0.0,
            gw: vec![0.0; d],
            gv: vec![0.0; d * k],
            loss: 0.0,
        }
    }

    /// The all-reduce merge.
    fn merge(&mut self, other: &GradBuf) {
        self.g0 += other.g0;
        for (a, b) in self.gw.iter_mut().zip(&other.gw) {
            *a += b;
        }
        for (a, b) in self.gv.iter_mut().zip(&other.gv) {
            *a += b;
        }
        self.loss += other.loss;
    }
}

/// Accumulates the exact batch gradient of the rows in `[start, end)`,
/// scoring through the shared lane-blocked kernel view (per-worker
/// scratch; the only per-call allocations are this worker's own buffers).
fn partial_gradient(kern: &FmKernel, ds: &Dataset, start: usize, end: usize) -> GradBuf {
    let k = kern.k();
    let mut buf = GradBuf::zeros(kern.d(), k);
    let mut scratch = Scratch::for_k(k);
    let mut a = vec![0f32; k];
    for i in start..end {
        let (idx, val) = ds.rows.row(i);
        let f = kern.score_with_sums(idx, val, &mut a, &mut scratch);
        let g = loss::multiplier(f, ds.labels[i], ds.task) as f64;
        buf.loss += loss::loss(f, ds.labels[i], ds.task) as f64;
        buf.g0 += g;
        for (j, x) in idx.iter().zip(val) {
            let j = *j as usize;
            let x = *x as f64;
            buf.gw[j] += g * x;
            let x2 = x * x;
            let vj = kern.vrow(j);
            for kk in 0..k {
                let vjk = vj[kk] as f64;
                buf.gv[j * k + kk] += g * (x * a[kk] as f64 - vjk * x2);
            }
        }
    }
    buf
}

/// Deterministic full-batch gradient descent with a P-way parallel reduce,
/// reporting each iteration to `obs` (which may stop the run).
pub fn bulksync_train(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &BulkSyncConfig,
    obs: &mut dyn TrainObserver,
) -> TrainOutput {
    let workers = cfg.workers.max(1).min(train.n().max(1));
    let mut rng = Pcg64::new(cfg.seed, 0xb51c);
    let mut model = FmModel::init(train.d(), fm.k, fm.init_std, &mut rng);
    let mut probe = Probe::new(train, test, fm.lambda_w, fm.lambda_v, cfg.eval_every);

    let mut sw = Stopwatch::start();
    let mut clock = 0f64;
    let mut stopped = probe.record(0, 0.0, &model, obs).is_stop();
    sw.lap();

    let n = train.n();
    let chunk = n.div_ceil(workers);
    for t in 0..cfg.iters {
        if stopped {
            break;
        }
        // Map: per-worker partial gradients on disjoint row blocks, all
        // scoring through one shared kernel view of this iterate.
        let kern = FmKernel::from_model(&model);
        let total = std::thread::scope(|scope| {
            let kern_ref = &kern;
            let handles: Vec<_> = (0..workers)
                .map(|p| {
                    let start = p * chunk;
                    let end = ((p + 1) * chunk).min(n);
                    scope.spawn(move || partial_gradient(kern_ref, train, start, end))
                })
                .collect();
            // Reduce: merge in worker order (deterministic).
            let mut total = GradBuf::zeros(kern_ref.d(), kern_ref.k());
            for h in handles {
                total.merge(&h.join().expect("bulksync worker panicked"));
            }
            total
        });

        // Step (eqs. 6-8 with the mean gradient + L2 terms).
        let lr = cfg.eta.at(t);
        let inv_n = 1.0 / n as f64;
        model.w0 -= lr * (total.g0 * inv_n) as f32;
        for j in 0..model.d {
            let g = (total.gw[j] * inv_n) as f32 + fm.lambda_w * model.w[j];
            model.w[j] -= lr * g;
        }
        for p in 0..model.v.len() {
            let g = (total.gv[p] * inv_n) as f32 + fm.lambda_v * model.v[p];
            model.v[p] -= lr * g;
        }

        clock += sw.lap();
        stopped = probe.record(t + 1, clock, &model, obs).is_stop();
        sw.lap();
    }

    TrainOutput {
        model,
        trace: probe.into_trace(),
        wall_secs: clock,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn full_gradient_descends_monotonically() {
        let ds = synth::table2_dataset("housing", 1).unwrap();
        let fm = FmHyper {
            k: 4,
            lambda_w: 0.0,
            lambda_v: 0.0,
            ..Default::default()
        };
        let cfg = BulkSyncConfig {
            iters: 20,
            eta: LrSchedule::Constant(0.05),
            workers: 4,
            seed: 2,
            ..Default::default()
        };
        let out = bulksync_train(&ds, None, &fm, &cfg, &mut ());
        let objs: Vec<f64> = out.trace.iter().map(|p| p.objective).collect();
        for w in objs.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-9,
                "full GD with small eta must be monotone: {objs:?}"
            );
        }
        assert!(objs.last().unwrap() < &(0.8 * objs[0]));
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        let fm = FmHyper::default();
        let cfg = |workers| BulkSyncConfig {
            iters: 5,
            eta: LrSchedule::Constant(0.02),
            workers,
            seed: 7,
            ..Default::default()
        };
        let one = bulksync_train(&ds, None, &fm, &cfg(1), &mut ());
        let four = bulksync_train(&ds, None, &fm, &cfg(4), &mut ());
        // The reduce is order-deterministic but f64 summation differs by
        // block boundaries; results must agree to tight tolerance.
        for (a, b) in one.model.w.iter().zip(&four.model.w) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert!((one.trace.last().unwrap().objective - four.trace.last().unwrap().objective).abs() < 1e-6);
    }

    #[test]
    fn partial_gradients_tile_the_batch() {
        let ds = synth::table2_dataset("housing", 4).unwrap();
        let mut rng = Pcg64::seeded(1);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let kern = FmKernel::from_model(&model);
        let full = partial_gradient(&kern, &ds, 0, ds.n());
        let mut merged = GradBuf::zeros(model.d, model.k);
        let mid = ds.n() / 3;
        merged.merge(&partial_gradient(&kern, &ds, 0, mid));
        merged.merge(&partial_gradient(&kern, &ds, mid, ds.n()));
        assert!((full.g0 - merged.g0).abs() < 1e-9);
        for (a, b) in full.gw.iter().zip(&merged.gw) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
