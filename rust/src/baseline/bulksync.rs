//! Bulk-synchronous full-gradient descent baseline.
//!
//! The strawman of paper §4.2: compute the synchronization terms G and A
//! exactly at every iteration with a barrier (an all-reduce over workers —
//! here the reduction is performed over per-worker partial gradients
//! computed on row shards by scoped threads), then take one deterministic
//! gradient step (eqs. 6-8).
//!
//! Row shards come from [`crate::partition`]
//! ([`crate::partition::RowPartition`] planned and materialized through
//! the [`crate::data::DataSource`] seam — in-memory slices by default,
//! per-worker shard-cache files under `data_cache = <dir>`) — which also
//! fixes the old hand-rolled chunking's unclamped `start = p * chunk` (an
//! inverted range whenever `workers` did not divide `n`). The per-shard
//! gradient is computed column-major
//! through the lane-blocked [`visit::col_grad`] fold over the shard's
//! CSC: for a fixed column both orders add the same f64 terms in the same
//! (ascending-row) sequence, so [`partial_gradient`] is **bitwise
//! identical** to the row-major scalar reference it replaced — which
//! lives on as [`partial_gradient_rows`], the oracle
//! `rust/tests/partition_properties.rs` holds it to.
//!
//! The session-facing entry point is [`crate::train::BulkSyncTrainer`].

use crate::data::{Dataset, ShardSource};
use crate::fm::{loss, FmHyper, FmModel};
use crate::kernel::{visit, FmKernel, Scratch};
use crate::metrics::TrainOutput;
use crate::optim::LrSchedule;
use crate::partition::{build_shards_from_source, PartitionStats, RowStrategy, Shard};
use crate::train::{Probe, TrainObserver};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Bulk-synchronous GD configuration (replaces the old seven-positional
/// argument signature).
#[derive(Debug, Clone)]
pub struct BulkSyncConfig {
    /// Gradient iterations.
    pub iters: usize,
    /// Learning-rate schedule.
    pub eta: LrSchedule,
    /// Parallel reduce width.
    pub workers: usize,
    /// RNG seed (model init).
    pub seed: u64,
    /// Evaluate held-out metrics every this many iterations.
    pub eval_every: usize,
    /// Row-shard strategy (contiguous = legacy default).
    pub row_partition: RowStrategy,
    /// Where workers pull their row shards from (in-memory slices by
    /// default; a shard cache under `data_cache = <dir>`).
    pub source: ShardSource,
}

impl Default for BulkSyncConfig {
    fn default() -> Self {
        BulkSyncConfig {
            iters: 50,
            eta: LrSchedule::Constant(0.5),
            workers: 4,
            seed: 42,
            eval_every: 1,
            row_partition: RowStrategy::Contiguous,
            source: ShardSource::InMemory,
        }
    }
}

/// Dense gradient buffers (the "reduce" payload).
#[derive(Debug, Clone)]
pub struct GradBuf {
    /// Bias gradient partial sum.
    pub g0: f64,
    /// Linear-weight gradient partial sums (length D).
    pub gw: Vec<f64>,
    /// Factor gradient partial sums (length D*K, K-strided).
    pub gv: Vec<f64>,
    /// Summed (unnormalized) loss of the covered rows.
    pub loss: f64,
}

impl GradBuf {
    /// Zeroed buffers for a `d x k` model.
    pub fn zeros(d: usize, k: usize) -> Self {
        GradBuf {
            g0: 0.0,
            gw: vec![0.0; d],
            gv: vec![0.0; d * k],
            loss: 0.0,
        }
    }

    /// The all-reduce merge.
    pub fn merge(&mut self, other: &GradBuf) {
        self.g0 += other.g0;
        for (a, b) in self.gw.iter_mut().zip(&other.gw) {
            *a += b;
        }
        for (a, b) in self.gv.iter_mut().zip(&other.gv) {
            *a += b;
        }
        self.loss += other.loss;
    }
}

/// Accumulates the exact batch gradient of one row shard, column-major:
/// a single row sweep scores every local example through the shared
/// lane-blocked kernel (G, the `nloc x kp` factor-sum cache A, loss and
/// the bias partial sum), then the shard's CSC columns fold into the f64
/// eq. 7/8 partial sums via [`visit::col_grad`]. Bitwise identical to the
/// row-major [`partial_gradient_rows`] reference (see the module docs).
pub fn partial_gradient(kern: &FmKernel, shard: &Shard) -> GradBuf {
    partial_gradient_into(kern, shard, &mut Vec::new(), &mut Vec::new())
}

/// [`partial_gradient`] with caller-owned `g` / lane-blocked `aa` scratch
/// (grown on first use, reused across iterations — the training loop
/// keeps one pair per worker, so the O(nloc x kp) auxiliary buffers are
/// not re-allocated per iteration; what remains per call is the
/// `GradBuf` reduce payload plus small O(kp) kernel scratch). `aa`'s
/// padding lanes are zeroed on growth and never written afterwards,
/// preserving the kernel zero-padding invariant across reuse.
fn partial_gradient_into(
    kern: &FmKernel,
    shard: &Shard,
    g: &mut Vec<f32>,
    aa: &mut Vec<f32>,
) -> GradBuf {
    let k = kern.k();
    let kp = kern.padded();
    let d = kern.d();
    let mut buf = GradBuf::zeros(d, k);
    let mut scratch = Scratch::for_k(k);
    let nloc = shard.nloc();
    g.resize(nloc, 0.0);
    aa.resize(nloc * kp, 0.0);
    for r in 0..nloc {
        let (idx, val) = shard.rows.row(r);
        let f = kern.score_with_sums(idx, val, &mut aa[r * kp..r * kp + k], &mut scratch);
        let gi = loss::multiplier(f, shard.labels[r], shard.task);
        buf.loss += loss::loss(f, shard.labels[r], shard.task) as f64;
        buf.g0 += gi as f64;
        g[r] = gi;
    }
    let mut gv = vec![0f64; kp];
    for j in 0..d {
        let (rows, xs) = shard.cols.col(j);
        if rows.is_empty() {
            continue;
        }
        let gw = visit::col_grad(rows, xs, g, aa, kp, kern.vrows_padded(j, j + 1), &mut gv);
        buf.gw[j] += gw;
        for kk in 0..k {
            buf.gv[j * k + kk] += gv[kk];
        }
    }
    buf
}

/// The pre-refactor row-major scalar fold over global rows
/// `[start, end)`, kept as the oracle for [`partial_gradient`] (the
/// partition property suite asserts bitwise agreement) and as the
/// baseline side of any future bench pair.
pub fn partial_gradient_rows(kern: &FmKernel, ds: &Dataset, start: usize, end: usize) -> GradBuf {
    let k = kern.k();
    let mut buf = GradBuf::zeros(kern.d(), k);
    let mut scratch = Scratch::for_k(k);
    let mut a = vec![0f32; k];
    for i in start..end {
        let (idx, val) = ds.rows.row(i);
        let f = kern.score_with_sums(idx, val, &mut a, &mut scratch);
        let g = loss::multiplier(f, ds.labels[i], ds.task) as f64;
        buf.loss += loss::loss(f, ds.labels[i], ds.task) as f64;
        buf.g0 += g;
        for (j, x) in idx.iter().zip(val) {
            let j = *j as usize;
            let x = *x as f64;
            buf.gw[j] += g * x;
            let x2 = x * x;
            let vj = kern.vrow(j);
            for kk in 0..k {
                let vjk = vj[kk] as f64;
                buf.gv[j * k + kk] += g * (x * a[kk] as f64 - vjk * x2);
            }
        }
    }
    buf
}

/// Deterministic full-batch gradient descent with a P-way parallel reduce,
/// reporting each iteration to `obs` (which may stop the run).
pub fn bulksync_train(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &BulkSyncConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<TrainOutput> {
    Ok(bulksync_train_with_stats(train, test, fm, cfg, obs)?.0)
}

/// Like [`bulksync_train`], also returning the row-shard load summary.
pub fn bulksync_train_with_stats(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &BulkSyncConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<(TrainOutput, PartitionStats)> {
    let workers = cfg.workers.max(1).min(train.n().max(1));
    let mut rng = Pcg64::new(cfg.seed, 0xb51c);
    let model = FmModel::init(train.d(), fm.k, fm.init_std, &mut rng);
    let probe = Probe::new(train, test, fm.lambda_w, fm.lambda_v, cfg.eval_every);

    // Row shards, built once (CSR slice + CSC per worker), pulled through
    // the data seam (in-memory by default — bit-identical to the legacy
    // slice build; shard-cache files when configured).
    let resolved = cfg.source.resolve(train)?;
    let source = resolved.as_dyn();
    let row_plan = source.plan(cfg.row_partition, workers)?;
    let pstats = PartitionStats::from_plan(&row_plan, &train.rows);
    let shards = build_shards_from_source(source, &row_plan)?;
    let out = bulksync_core(&shards, train.n(), fm, cfg, model, probe, obs)?;
    Ok((out, pstats))
}

/// [`bulksync_train_with_stats`] off a [`DataSource`] — no caller-held
/// full matrix. As in the paper's distributed memory model, each
/// simulated worker holds its own row shard for the session (resident
/// across workers, never concatenated), and the convergence probe folds
/// over those resident shards. Model and trace are bitwise identical to
/// the in-memory run of the same config.
///
/// [`DataSource`]: crate::data::DataSource
pub fn bulksync_train_from_source(
    src: &dyn crate::data::DataSource,
    fm: &FmHyper,
    cfg: &BulkSyncConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<(TrainOutput, PartitionStats)> {
    let workers = cfg.workers.max(1).min(src.n().max(1));
    let mut rng = Pcg64::new(cfg.seed, 0xb51c);
    let model = FmModel::init(src.d(), fm.k, fm.init_std, &mut rng);
    let row_plan = src.plan(cfg.row_partition, workers)?;
    let shards = build_shards_from_source(src, &row_plan)?;
    let pstats =
        PartitionStats::from_shard_nnz(shards.iter().map(|s| s.rows.nnz()).collect());
    let probe = Probe::from_shards(&shards, src.n(), fm.lambda_w, fm.lambda_v, cfg.eval_every);
    let out = bulksync_core(&shards, src.n(), fm, cfg, model, probe, obs)?;
    Ok((out, pstats))
}

/// The shared map-reduce-step loop behind both entry points.
fn bulksync_core(
    shards: &[Shard],
    n: usize,
    fm: &FmHyper,
    cfg: &BulkSyncConfig,
    mut model: FmModel,
    mut probe: Probe<'_>,
    obs: &mut dyn TrainObserver,
) -> crate::Result<TrainOutput> {
    // Per-worker G / lane-blocked A scratch, grown on the first iteration
    // and reused for the rest of the run.
    let mut aux: Vec<(Vec<f32>, Vec<f32>)> =
        shards.iter().map(|_| (Vec::new(), Vec::new())).collect();

    let mut sw = Stopwatch::start();
    let mut clock = 0f64;
    let mut stopped = probe.try_record(0, 0.0, &model, obs)?.is_stop();
    sw.lap();
    for t in 0..cfg.iters {
        if stopped {
            break;
        }
        // Map: per-shard partial gradients, all scoring through one shared
        // kernel view of this iterate.
        let kern = FmKernel::from_model(&model);
        let total = std::thread::scope(|scope| {
            let kern_ref = &kern;
            let handles: Vec<_> = shards
                .iter()
                .zip(aux.iter_mut())
                .map(|(shard, (g, aa))| {
                    scope.spawn(move || partial_gradient_into(kern_ref, shard, g, aa))
                })
                .collect();
            // Reduce: merge in shard order (deterministic).
            let mut total = GradBuf::zeros(kern_ref.d(), kern_ref.k());
            for h in handles {
                total.merge(&h.join().expect("bulksync worker panicked"));
            }
            total
        });

        // Step (eqs. 6-8 with the mean gradient + L2 terms).
        let lr = cfg.eta.at(t);
        let inv_n = 1.0 / n as f64;
        model.w0 -= lr * (total.g0 * inv_n) as f32;
        for j in 0..model.d {
            let g = (total.gw[j] * inv_n) as f32 + fm.lambda_w * model.w[j];
            model.w[j] -= lr * g;
        }
        for p in 0..model.v.len() {
            let g = (total.gv[p] * inv_n) as f32 + fm.lambda_v * model.v[p];
            model.v[p] -= lr * g;
        }

        clock += sw.lap();
        stopped = probe.try_record(t + 1, clock, &model, obs)?.is_stop();
        sw.lap();
    }

    Ok(TrainOutput {
        model,
        trace: probe.into_trace(),
        wall_secs: clock,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::partition::{build_shards, RowPartition};

    #[test]
    fn full_gradient_descends_monotonically() {
        let ds = synth::table2_dataset("housing", 1).unwrap();
        let fm = FmHyper {
            k: 4,
            lambda_w: 0.0,
            lambda_v: 0.0,
            ..Default::default()
        };
        let cfg = BulkSyncConfig {
            iters: 20,
            eta: LrSchedule::Constant(0.05),
            workers: 4,
            seed: 2,
            ..Default::default()
        };
        let out = bulksync_train(&ds, None, &fm, &cfg, &mut ()).unwrap();
        let objs: Vec<f64> = out.trace.iter().map(|p| p.objective).collect();
        for w in objs.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-9,
                "full GD with small eta must be monotone: {objs:?}"
            );
        }
        assert!(objs.last().unwrap() < &(0.8 * objs[0]));
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        let fm = FmHyper::default();
        let cfg = |workers| BulkSyncConfig {
            iters: 5,
            eta: LrSchedule::Constant(0.02),
            workers,
            seed: 7,
            ..Default::default()
        };
        let one = bulksync_train(&ds, None, &fm, &cfg(1), &mut ()).unwrap();
        let four = bulksync_train(&ds, None, &fm, &cfg(4), &mut ()).unwrap();
        // The reduce is order-deterministic but f64 summation differs by
        // block boundaries; results must agree to tight tolerance.
        for (a, b) in one.model.w.iter().zip(&four.model.w) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        let (o1, o4) = (
            one.trace.last().unwrap().objective,
            four.trace.last().unwrap().objective,
        );
        assert!((o1 - o4).abs() < 1e-6);
    }

    #[test]
    fn partial_gradients_tile_the_batch() {
        let ds = synth::table2_dataset("housing", 4).unwrap();
        let mut rng = Pcg64::seeded(1);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let kern = FmKernel::from_model(&model);
        let whole = build_shards(&ds, &RowPartition::contiguous(ds.n(), 1));
        let full = partial_gradient(&kern, &whole[0]);
        let parts = build_shards(&ds, &RowPartition::contiguous(ds.n(), 3));
        let mut merged = GradBuf::zeros(model.d, model.k);
        for shard in &parts {
            merged.merge(&partial_gradient(&kern, shard));
        }
        assert!((full.g0 - merged.g0).abs() < 1e-9);
        for (a, b) in full.gw.iter().zip(&merged.gw) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn column_fold_matches_rowmajor_oracle_bitwise() {
        // The lane-blocked column-major shard gradient is bit-for-bit the
        // legacy row-major fold: same f64 terms, same order per column.
        let ds = synth::table2_dataset("housing", 9).unwrap();
        let mut rng = Pcg64::seeded(5);
        for k in [1usize, 4, 7, 9] {
            let model = FmModel::init(ds.d(), k, 0.1, &mut rng);
            let kern = FmKernel::from_model(&model);
            let shards = build_shards(&ds, &RowPartition::contiguous(ds.n(), 3));
            for shard in &shards {
                let col = partial_gradient(&kern, shard);
                let row = partial_gradient_rows(&kern, &ds, shard.start, shard.end);
                assert_eq!(col.g0.to_bits(), row.g0.to_bits(), "k={k}");
                assert_eq!(col.loss.to_bits(), row.loss.to_bits(), "k={k}");
                for (j, (a, b)) in col.gw.iter().zip(&row.gw).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "k={k} gw[{j}]");
                }
                for (q, (a, b)) in col.gv.iter().zip(&row.gv).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "k={k} gv[{q}]");
                }
            }
        }
    }

    #[test]
    fn row_blocks_clamped_when_workers_do_not_divide_n() {
        // Regression for the old unclamped `start = p * chunk`: at n = 5,
        // workers = 4 the legacy math produced an inverted 6..5 range.
        // The shared RowPartition clamps; training must tile all 5 rows
        // and still descend.
        let ds = synth::table2_dataset("housing", 11).unwrap();
        let five = ds.subset(&[0, 1, 2, 3, 4], "five");
        let fm = FmHyper {
            k: 2,
            ..Default::default()
        };
        let cfg = BulkSyncConfig {
            iters: 8,
            eta: LrSchedule::Constant(0.05),
            workers: 4,
            seed: 3,
            ..Default::default()
        };
        let (out, stats) = bulksync_train_with_stats(&five, None, &fm, &cfg, &mut ()).unwrap();
        assert_eq!(stats.shard_nnz.len(), 4);
        assert_eq!(stats.shard_nnz.iter().sum::<usize>(), five.nnz());
        assert_eq!(out.trace.len(), 9);
        let (first, last) = (out.trace[0].objective, out.trace.last().unwrap().objective);
        assert!(last.is_finite() && last < first, "{first} -> {last}");
    }
}
