//! Baseline trainers the paper compares against (or that bracket DS-FACTO's
//! behaviour):
//!
//! * [`libfm`] — single-machine stochastic SGD over all dimensions of each
//!   sampled example. This is what the paper's Figs. 4-5 plot as "libFM".
//! * [`dsgd`] — synchronous block-cyclic hybrid parallelism (DSGD-style):
//!   the bulk-synchronization counterpart that DS-FACTO's incremental
//!   synchronization replaces. A per-sub-epoch barrier, otherwise the same
//!   doubly-separable access pattern.
//! * [`bulksync`] — deterministic full-batch gradient descent with an
//!   all-reduce-style merge (the "Reduce step" strawman of §4.2).
//!
//! All three are normally driven through the uniform session API in
//! [`crate::train`] ([`crate::train::LibfmTrainer`] etc.); the free
//! functions here are the loops themselves.

pub mod bulksync;
pub mod dsgd;
pub mod libfm;

pub use bulksync::{
    bulksync_train, bulksync_train_from_source, bulksync_train_with_stats, BulkSyncConfig,
};
pub use dsgd::{dsgd_train, dsgd_train_from_source, dsgd_train_with_stats, DsgdConfig};
pub use libfm::{libfm_train, libfm_train_from_source, LibfmConfig};
