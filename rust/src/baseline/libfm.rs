//! libFM-style single-machine SGD baseline.
//!
//! "libFM is a stochastic method which samples the data points
//! stochastically; it however considers all dimensions of the data point
//! while making the parameter updates." (paper §5.1). One epoch = one
//! shuffled pass over the training examples, applying the full eq. 11-13
//! update at every example.
//!
//! The session-facing entry point is [`crate::train::LibfmTrainer`]; the
//! free function here is the loop itself, reporting through the
//! [`TrainObserver`] it is handed.

use crate::data::Dataset;
use crate::fm::{FmHyper, FmModel};
use crate::kernel::{FmKernel, Scratch};
use crate::metrics::TrainOutput;
use crate::optim::LrSchedule;
use crate::train::{Probe, TrainObserver};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Configuration for the libFM baseline.
#[derive(Debug, Clone)]
pub struct LibfmConfig {
    /// Epochs (outer iterations).
    pub epochs: usize,
    /// Learning-rate schedule.
    pub eta: LrSchedule,
    /// RNG seed (init + shuffling).
    pub seed: u64,
    /// Evaluate held-out metrics every this many epochs.
    pub eval_every: usize,
    /// Re-shuffle the visiting order each epoch.
    pub shuffle: bool,
}

impl Default for LibfmConfig {
    fn default() -> Self {
        LibfmConfig {
            epochs: 50,
            eta: LrSchedule::default(),
            seed: 42,
            eval_every: 1,
            shuffle: true,
        }
    }
}

/// Rows staged per gather in the batched epoch loop. A block of this many
/// examples' index/value slices (a few hundred KB at realistic densities)
/// is gathered into contiguous staging before updating, so the shuffled
/// permutation's random row reads happen once per block instead of once
/// per example and the update sweep streams cache-resident data.
const ROW_BLOCK: usize = 512;

/// Trains an FM with single-machine SGD; returns the model and trace.
/// Each recorded iteration is reported to `obs`, which may stop the run.
///
/// The per-example update runs through the fused lane-blocked kernel
/// ([`FmKernel::score_grad_step`]); the epoch iterates the shuffled
/// permutation in [`ROW_BLOCK`]-sized chunks, staging each chunk's rows
/// contiguously via [`Csr::gather_rows_into`](crate::data::Csr) (the
/// staging buffers are reused, so the steady state allocates nothing).
/// The visit order is exactly the shuffled permutation, unchanged from
/// the unbatched loop — results are bit-identical. The heap is otherwise
/// touched only by the per-epoch model write-back the observer sees.
pub fn libfm_train(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &LibfmConfig,
    obs: &mut dyn TrainObserver,
) -> TrainOutput {
    let mut rng = Pcg64::new(cfg.seed, 0x11bf);
    let mut model = FmModel::init(train.d(), fm.k, fm.init_std, &mut rng);
    let mut kern = FmKernel::from_model(&model);
    let mut scratch = Scratch::for_k(fm.k);
    let mut probe = Probe::new(train, test, fm.lambda_w, fm.lambda_v, cfg.eval_every);
    let mut order: Vec<usize> = (0..train.n()).collect();
    // Reusable row-block staging (see `ROW_BLOCK`); grown on the first
    // gather, allocation-free afterwards.
    let mut stage_ptr: Vec<usize> = Vec::new();
    let mut stage_idx: Vec<u32> = Vec::new();
    let mut stage_val: Vec<f32> = Vec::new();

    let mut sw = Stopwatch::start();
    let mut train_clock = 0f64;
    let mut stopped = probe.record(0, 0.0, &model, obs).is_stop();
    sw.lap(); // exclude the initial evaluation

    for epoch in 0..cfg.epochs {
        if stopped {
            break;
        }
        let eta = cfg.eta.at(epoch);
        if cfg.shuffle {
            rng.shuffle(&mut order);
        }
        if cfg.shuffle {
            for chunk in order.chunks(ROW_BLOCK) {
                train
                    .rows
                    .gather_rows_into(chunk, &mut stage_ptr, &mut stage_idx, &mut stage_val);
                for (b, &i) in chunk.iter().enumerate() {
                    let (a, e) = (stage_ptr[b], stage_ptr[b + 1]);
                    kern.score_grad_step(
                        &stage_idx[a..e],
                        &stage_val[a..e],
                        train.labels[i],
                        train.task,
                        eta,
                        fm.lambda_w,
                        fm.lambda_v,
                        &mut scratch,
                    );
                }
            }
        } else {
            // Identity order: the CSR rows are already contiguous, so
            // staging would be a pure copy with no locality gain.
            for &i in &order {
                let (idx, val) = train.rows.row(i);
                kern.score_grad_step(
                    idx,
                    val,
                    train.labels[i],
                    train.task,
                    eta,
                    fm.lambda_w,
                    fm.lambda_v,
                    &mut scratch,
                );
            }
        }
        train_clock += sw.lap();
        // The write-back (and the evaluation it feeds) stays off the
        // training clock.
        kern.write_model(&mut model);
        stopped = probe.record(epoch + 1, train_clock, &model, obs).is_stop();
        sw.lap(); // evaluation excluded from the training clock
    }

    TrainOutput {
        model,
        trace: probe.into_trace(),
        wall_secs: train_clock,
    }
}

/// [`libfm_train`] off a [`DataSource`], one shard resident at a time:
/// each epoch sweeps the shards of `part` in order and applies the
/// eq. 11-13 update to every local row, which visits the global rows in
/// exactly the identity order of the `shuffle = false` in-memory loop —
/// model and trace are bitwise identical to
/// `libfm_train(&src.materialize()?, None, ...)` with shuffling off.
/// (A streamed epoch cannot shuffle globally without materializing, which
/// is the point; per-epoch order randomization is future work.) The
/// per-iteration probe re-reads the shards through the same source, so
/// peak resident data stays one shard — two behind a prefetching source.
///
/// [`DataSource`]: crate::data::DataSource
pub fn libfm_train_from_source(
    src: &dyn crate::data::DataSource,
    part: &crate::partition::RowPartition,
    fm: &FmHyper,
    cfg: &LibfmConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<TrainOutput> {
    let mut rng = Pcg64::new(cfg.seed, 0x11bf);
    let mut model = FmModel::init(src.d(), fm.k, fm.init_std, &mut rng);
    let mut kern = FmKernel::from_model(&model);
    let mut scratch = Scratch::for_k(fm.k);
    let mut probe = Probe::streaming(src, part, fm.lambda_w, fm.lambda_v, cfg.eval_every);

    let mut sw = Stopwatch::start();
    let mut train_clock = 0f64;
    let mut stopped = probe.try_record(0, 0.0, &model, obs)?.is_stop();
    sw.lap(); // exclude the initial evaluation

    for epoch in 0..cfg.epochs {
        if stopped {
            break;
        }
        let eta = cfg.eta.at(epoch);
        for id in 0..part.n_shards() {
            let shard = src.shard(part, id)?;
            for r in 0..shard.nloc() {
                let (idx, val) = shard.rows.row(r);
                kern.score_grad_step(
                    idx,
                    val,
                    shard.labels[r],
                    shard.task,
                    eta,
                    fm.lambda_w,
                    fm.lambda_v,
                    &mut scratch,
                );
            }
        }
        train_clock += sw.lap();
        kern.write_model(&mut model);
        stopped = probe.try_record(epoch + 1, train_clock, &model, obs)?.is_stop();
        sw.lap(); // evaluation excluded from the training clock
    }

    Ok(TrainOutput {
        model,
        trace: probe.into_trace(),
        wall_secs: train_clock,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Task};
    use crate::metrics::evaluate;

    #[test]
    fn converges_on_housing_twin() {
        let ds = synth::table2_dataset("housing", 1).unwrap();
        let (train, test) = ds.split(0.8, 2);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = LibfmConfig {
            epochs: 15,
            eta: LrSchedule::Constant(0.02),
            ..Default::default()
        };
        let out = libfm_train(&train, Some(&test), &fm, &cfg, &mut ());
        let first = out.trace.first().unwrap().objective;
        let last = out.trace.last().unwrap().objective;
        assert!(last < 0.5 * first, "objective {first} -> {last}");
        // Test RMSE should beat predicting the mean.
        let rmse = out.trace.last().unwrap().test.unwrap().rmse;
        let mean = test.labels.iter().sum::<f32>() / test.n() as f32;
        let base = (test
            .labels
            .iter()
            .map(|y| ((y - mean) as f64).powi(2))
            .sum::<f64>()
            / test.n() as f64)
            .sqrt();
        assert!(rmse < base, "rmse {rmse} vs baseline {base}");
    }

    #[test]
    fn converges_on_diabetes_twin() {
        let ds = synth::table2_dataset("diabetes", 3).unwrap();
        let (train, test) = ds.split(0.8, 4);
        assert_eq!(train.task, Task::Classification);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = LibfmConfig {
            epochs: 25,
            eta: LrSchedule::Constant(0.05),
            ..Default::default()
        };
        let out = libfm_train(&train, Some(&test), &fm, &cfg, &mut ());
        let acc = evaluate(&out.model, &test).accuracy;
        // Planted-model accuracy is well above the majority class rate.
        let pos = test.labels.iter().filter(|&&y| y > 0.0).count() as f64 / test.n() as f64;
        let majority = pos.max(1.0 - pos);
        assert!(acc > majority.min(0.95) - 0.02, "acc {acc} vs majority {majority}");
        assert!(acc > 0.6, "acc {acc}");
    }

    #[test]
    fn trace_iterations_are_complete() {
        let ds = synth::table2_dataset("housing", 5).unwrap();
        let fm = FmHyper::default();
        let cfg = LibfmConfig {
            epochs: 3,
            ..Default::default()
        };
        let out = libfm_train(&ds, None, &fm, &cfg, &mut ());
        assert_eq!(out.trace.len(), 4); // 0 + 3 epochs
        assert!(out.trace.windows(2).all(|w| w[0].secs <= w[1].secs));
        assert!(out.trace.iter().all(|p| p.test.is_none()));
    }

    #[test]
    fn batched_epoch_matches_unbatched_reference_bitwise() {
        // n > 2 * ROW_BLOCK so the epoch spans several gathers plus a
        // ragged final chunk; the visit order (and therefore every
        // parameter bit) must match the plain per-row loop.
        let spec = synth::SynthSpec {
            n: 2 * super::ROW_BLOCK + 77,
            ..synth::SynthSpec::table2("housing").unwrap()
        };
        let ds = synth::generate(&spec, 9).dataset;
        let fm = FmHyper {
            k: 3,
            ..Default::default()
        };
        let cfg = LibfmConfig {
            epochs: 2,
            eta: LrSchedule::Constant(0.05),
            seed: 5,
            eval_every: usize::MAX,
            shuffle: true,
        };
        let out = libfm_train(&ds, None, &fm, &cfg, &mut ());

        // Unbatched reference over the identical RNG stream.
        let mut rng = Pcg64::new(cfg.seed, 0x11bf);
        let mut model = FmModel::init(ds.d(), fm.k, fm.init_std, &mut rng);
        let mut kern = FmKernel::from_model(&model);
        let mut scratch = Scratch::for_k(fm.k);
        let mut order: Vec<usize> = (0..ds.n()).collect();
        for epoch in 0..cfg.epochs {
            let eta = cfg.eta.at(epoch);
            rng.shuffle(&mut order);
            for &i in &order {
                let (idx, val) = ds.rows.row(i);
                kern.score_grad_step(
                    idx,
                    val,
                    ds.labels[i],
                    ds.task,
                    eta,
                    fm.lambda_w,
                    fm.lambda_v,
                    &mut scratch,
                );
            }
        }
        kern.write_model(&mut model);
        assert_eq!(out.model, model);
    }

    #[test]
    fn from_source_matches_in_order_in_memory_run_bitwise() {
        use crate::data::cache::{write_cache, ShardCacheSource};
        use crate::data::DataSource;
        use crate::partition::RowStrategy;
        let ds = synth::table2_dataset("housing", 8).unwrap();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = LibfmConfig {
            epochs: 3,
            eta: LrSchedule::Constant(0.05),
            seed: 5,
            eval_every: 1,
            shuffle: false,
        };
        let want = libfm_train(&ds, None, &fm, &cfg, &mut ());
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let dir = std::env::temp_dir()
                .join(format!("dsfacto_libfm_src_test_{}", strat.spec()));
            std::fs::remove_dir_all(&dir).ok();
            write_cache(&ds, strat, 3, &dir).unwrap();
            let src = ShardCacheSource::open(&dir).unwrap();
            let part = src.plan(strat, 3).unwrap();
            let got = libfm_train_from_source(&src, &part, &fm, &cfg, &mut ()).unwrap();
            assert_eq!(got.model, want.model, "{strat:?}");
            assert_eq!(got.trace.len(), want.trace.len(), "{strat:?}");
            for (a, b) in got.trace.iter().zip(&want.trace) {
                assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{strat:?}");
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{strat:?}");
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = synth::table2_dataset("housing", 6).unwrap();
        let fm = FmHyper::default();
        let cfg = LibfmConfig {
            epochs: 2,
            ..Default::default()
        };
        let a = libfm_train(&ds, None, &fm, &cfg, &mut ());
        let b = libfm_train(&ds, None, &fm, &cfg, &mut ());
        assert_eq!(a.model, b.model);
    }

    #[test]
    fn observer_stop_ends_training_early() {
        struct StopAt(usize);
        impl TrainObserver for StopAt {
            fn on_iter(
                &mut self,
                pt: &crate::metrics::TracePoint,
                _m: Option<&FmModel>,
            ) -> crate::train::ControlFlow {
                if pt.iter >= self.0 {
                    crate::train::ControlFlow::Stop
                } else {
                    crate::train::ControlFlow::Continue
                }
            }
        }
        let ds = synth::table2_dataset("housing", 7).unwrap();
        let fm = FmHyper::default();
        let cfg = LibfmConfig {
            epochs: 30,
            ..Default::default()
        };
        let out = libfm_train(&ds, None, &fm, &cfg, &mut StopAt(4));
        assert_eq!(out.trace.len(), 5); // iters 0..=4
    }
}
