//! A miniature property-testing harness (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! `forall` runs a property over generated cases; on failure it reports the
//! case index and the seed that reproduces it, so failures are replayable
//! with `PROP_SEED=<seed> cargo test <name>`.

use crate::util::rng::Pcg64;

/// Number of cases per property (overridable via `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Base seed (overridable via `PROP_SEED` for replay).
pub fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xd5fac70)
}

/// Runs `prop` on `cases` inputs drawn by `gen`. Panics with the seed on the
/// first failing case.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (replay with PROP_SEED={seed}): \
                 input = {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result`, so assertion context
/// can carry an error message.
pub fn forall_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (replay with PROP_SEED={seed}): {msg}\n\
                 input = {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "reverse twice is identity",
            32,
            |rng| {
                let n = rng.below_usize(20);
                (0..n).map(|_| rng.next_u32()).collect::<Vec<_>>()
            },
            |xs| {
                let mut r = xs.clone();
                r.reverse();
                r.reverse();
                r == *xs
            },
        );
    }

    #[test]
    #[should_panic(expected = "PROP_SEED")]
    fn failing_property_reports_seed() {
        forall("always fails", 4, |rng| rng.next_u32(), |_| false);
    }

    #[test]
    fn forall_res_reports_message() {
        let r = std::panic::catch_unwind(|| {
            forall_res(
                "msg prop",
                2,
                |rng| rng.below(10),
                |_| Err("custom context".to_string()),
            )
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("custom context"));
    }
}
