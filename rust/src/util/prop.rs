//! A miniature property-testing harness (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! `forall` runs a property over generated cases; on failure it reports the
//! case index and the seed that reproduces it, so failures are replayable
//! with `PROP_SEED=<seed> cargo test <name>`.
//!
//! The shared generators ([`sparse_row`], [`random_csr`]) keep the
//! kernel/data/codec property suites (`rust/tests/*_properties.rs`)
//! drawing from one distribution instead of re-rolling ad-hoc ones.

use crate::data::Csr;
use crate::util::rng::Pcg64;

/// Number of cases per property (overridable via `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Base seed (overridable via `PROP_SEED` for replay).
pub fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xd5fac70)
}

/// Runs `prop` on `cases` inputs drawn by `gen`. Panics with the seed on the
/// first failing case.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (replay with PROP_SEED={seed}): \
                 input = {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result`, so assertion context
/// can carry an error message.
pub fn forall_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (replay with PROP_SEED={seed}): {msg}\n\
                 input = {input:#?}"
            );
        }
    }
}

/// A sorted, duplicate-free sparse row over `d` columns with `nnz`
/// non-zeros (`nnz <= d`; `nnz == 0` yields the empty row), values drawn
/// standard normal. The canonical generator for per-example kernel
/// properties.
pub fn sparse_row(rng: &mut Pcg64, d: usize, nnz: usize) -> (Vec<u32>, Vec<f32>) {
    let mut idx: Vec<u32> = rng
        .sample_indices(d, nnz)
        .into_iter()
        .map(|c| c as u32)
        .collect();
    idx.sort_unstable();
    let val: Vec<f32> = idx.iter().map(|_| rng.normal32(0.0, 1.0)).collect();
    (idx, val)
}

/// Pads a `n x k` row-major matrix to `n x kp` stride (`kp >= k`),
/// zero-filling the trailing lanes — the lane-padding convention of
/// `kernel::FmKernel` and the column-visit kernels. Shared by the parity
/// suites and benches so every oracle pads one way.
pub fn pad_rows(src: &[f32], n: usize, k: usize, kp: usize) -> Vec<f32> {
    assert!(kp >= k, "padded stride {kp} < row width {k}");
    assert_eq!(src.len(), n * k, "source is not n x k");
    let mut out = vec![0f32; n * kp];
    for r in 0..n {
        out[r * kp..r * kp + k].copy_from_slice(&src[r * k..(r + 1) * k]);
    }
    out
}

/// Distance between two finite f32 values in units in the last place:
/// the number of representable floats strictly between them. Uses the
/// standard monotone mapping of the IEEE-754 bit patterns onto a signed
/// continuum, so the distance is well defined across zero (`-0.0` and
/// `+0.0` are 0 apart). Infinities and NaNs are only "close" to
/// themselves (`u32::MAX` otherwise).
pub fn ulp_distance(a: f32, b: f32) -> u32 {
    if a.is_nan() || b.is_nan() {
        return if a.to_bits() == b.to_bits() { 0 } else { u32::MAX };
    }
    fn monotone(x: f32) -> i64 {
        let bits = x.to_bits();
        if bits & 0x8000_0000 != 0 {
            -((bits & 0x7fff_ffff) as i64)
        } else {
            bits as i64
        }
    }
    (monotone(a) - monotone(b)).unsigned_abs().min(u32::MAX as u64) as u32
}

/// Asserts `got` is within `max_ulp` units in the last place of `want` —
/// the relaxed parity bound for SIMD kernels whose FMA contraction
/// genuinely reorders/merges roundings (everything non-FMA'd is held to
/// bitwise equality instead).
pub fn assert_ulp_close(got: f32, want: f32, max_ulp: u32, context: &str) {
    let d = ulp_distance(got, want);
    assert!(
        d <= max_ulp,
        "{context}: {got} ({:#010x}) is {d} ULPs from {want} ({:#010x}), bound {max_ulp}",
        got.to_bits(),
        want.to_bits()
    );
}

/// A random CSR of up to `max_rows x max_cols` built from random triplets
/// (duplicates summed by construction), for data-invariant properties.
pub fn random_csr(rng: &mut Pcg64, max_rows: usize, max_cols: usize) -> Csr {
    let n = 1 + rng.below_usize(max_rows);
    let d = 1 + rng.below_usize(max_cols);
    let nnz = rng.below_usize(n * d);
    let triplets: Vec<(usize, usize, f32)> = (0..nnz)
        .map(|_| {
            (
                rng.below_usize(n),
                rng.below_usize(d),
                rng.normal32(0.0, 1.0),
            )
        })
        .collect();
    Csr::from_triplets(n, d, &triplets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "reverse twice is identity",
            32,
            |rng| {
                let n = rng.below_usize(20);
                (0..n).map(|_| rng.next_u32()).collect::<Vec<_>>()
            },
            |xs| {
                let mut r = xs.clone();
                r.reverse();
                r.reverse();
                r == *xs
            },
        );
    }

    #[test]
    #[should_panic(expected = "PROP_SEED")]
    fn failing_property_reports_seed() {
        forall("always fails", 4, |rng| rng.next_u32(), |_| false);
    }

    #[test]
    fn forall_res_reports_message() {
        let r = std::panic::catch_unwind(|| {
            forall_res(
                "msg prop",
                2,
                |rng| rng.below(10),
                |_| Err("custom context".to_string()),
            )
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("custom context"));
    }

    #[test]
    fn sparse_row_is_sorted_and_distinct() {
        let mut rng = Pcg64::seeded(11);
        for _ in 0..50 {
            let d = 1 + rng.below_usize(30);
            let nnz = rng.below_usize(d + 1);
            let (idx, val) = sparse_row(&mut rng, d, nnz);
            assert_eq!(idx.len(), nnz);
            assert_eq!(val.len(), nnz);
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "{idx:?}");
            assert!(idx.iter().all(|&j| (j as usize) < d));
        }
    }

    #[test]
    fn ulp_distance_counts_representable_gaps() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert!(ulp_distance(f32::MIN_POSITIVE, -f32::MIN_POSITIVE) > 0);
        // Symmetric and monotone across zero.
        let a = -1e-38f32;
        let b = 1e-38f32;
        assert_eq!(ulp_distance(a, b), ulp_distance(b, a));
        assert_eq!(ulp_distance(f32::NAN, f32::NAN), 0);
        assert_eq!(ulp_distance(f32::NAN, 1.0), u32::MAX);
        assert_eq!(ulp_distance(f32::INFINITY, f32::INFINITY), 0);
    }

    #[test]
    fn assert_ulp_close_accepts_within_bound() {
        let next = f32::from_bits(2.5f32.to_bits() + 2);
        assert_ulp_close(next, 2.5, 2, "two ulps");
    }

    #[test]
    #[should_panic(expected = "ULPs")]
    fn assert_ulp_close_rejects_beyond_bound() {
        let far = f32::from_bits(2.5f32.to_bits() + 9);
        assert_ulp_close(far, 2.5, 4, "nine ulps");
    }

    #[test]
    fn random_csr_validates() {
        let mut rng = Pcg64::seeded(12);
        for _ in 0..30 {
            random_csr(&mut rng, 12, 12).validate().unwrap();
        }
    }
}
