//! A miniature property-testing harness (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! `forall` runs a property over generated cases; on failure it reports the
//! case index and the seed that reproduces it, so failures are replayable
//! with `PROP_SEED=<seed> cargo test <name>`.
//!
//! The shared generators ([`sparse_row`], [`random_csr`]) keep the
//! kernel/data/codec property suites (`rust/tests/*_properties.rs`)
//! drawing from one distribution instead of re-rolling ad-hoc ones.

use crate::data::Csr;
use crate::util::rng::Pcg64;

/// Number of cases per property (overridable via `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Base seed (overridable via `PROP_SEED` for replay).
pub fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xd5fac70)
}

/// Runs `prop` on `cases` inputs drawn by `gen`. Panics with the seed on the
/// first failing case.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (replay with PROP_SEED={seed}): \
                 input = {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result`, so assertion context
/// can carry an error message.
pub fn forall_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (replay with PROP_SEED={seed}): {msg}\n\
                 input = {input:#?}"
            );
        }
    }
}

/// A sorted, duplicate-free sparse row over `d` columns with `nnz`
/// non-zeros (`nnz <= d`; `nnz == 0` yields the empty row), values drawn
/// standard normal. The canonical generator for per-example kernel
/// properties.
pub fn sparse_row(rng: &mut Pcg64, d: usize, nnz: usize) -> (Vec<u32>, Vec<f32>) {
    let mut idx: Vec<u32> = rng
        .sample_indices(d, nnz)
        .into_iter()
        .map(|c| c as u32)
        .collect();
    idx.sort_unstable();
    let val: Vec<f32> = idx.iter().map(|_| rng.normal32(0.0, 1.0)).collect();
    (idx, val)
}

/// Pads a `n x k` row-major matrix to `n x kp` stride (`kp >= k`),
/// zero-filling the trailing lanes — the lane-padding convention of
/// `kernel::FmKernel` and the column-visit kernels. Shared by the parity
/// suites and benches so every oracle pads one way.
pub fn pad_rows(src: &[f32], n: usize, k: usize, kp: usize) -> Vec<f32> {
    assert!(kp >= k, "padded stride {kp} < row width {k}");
    assert_eq!(src.len(), n * k, "source is not n x k");
    let mut out = vec![0f32; n * kp];
    for r in 0..n {
        out[r * kp..r * kp + k].copy_from_slice(&src[r * k..(r + 1) * k]);
    }
    out
}

/// A random CSR of up to `max_rows x max_cols` built from random triplets
/// (duplicates summed by construction), for data-invariant properties.
pub fn random_csr(rng: &mut Pcg64, max_rows: usize, max_cols: usize) -> Csr {
    let n = 1 + rng.below_usize(max_rows);
    let d = 1 + rng.below_usize(max_cols);
    let nnz = rng.below_usize(n * d);
    let triplets: Vec<(usize, usize, f32)> = (0..nnz)
        .map(|_| {
            (
                rng.below_usize(n),
                rng.below_usize(d),
                rng.normal32(0.0, 1.0),
            )
        })
        .collect();
    Csr::from_triplets(n, d, &triplets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "reverse twice is identity",
            32,
            |rng| {
                let n = rng.below_usize(20);
                (0..n).map(|_| rng.next_u32()).collect::<Vec<_>>()
            },
            |xs| {
                let mut r = xs.clone();
                r.reverse();
                r.reverse();
                r == *xs
            },
        );
    }

    #[test]
    #[should_panic(expected = "PROP_SEED")]
    fn failing_property_reports_seed() {
        forall("always fails", 4, |rng| rng.next_u32(), |_| false);
    }

    #[test]
    fn forall_res_reports_message() {
        let r = std::panic::catch_unwind(|| {
            forall_res(
                "msg prop",
                2,
                |rng| rng.below(10),
                |_| Err("custom context".to_string()),
            )
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("custom context"));
    }

    #[test]
    fn sparse_row_is_sorted_and_distinct() {
        let mut rng = Pcg64::seeded(11);
        for _ in 0..50 {
            let d = 1 + rng.below_usize(30);
            let nnz = rng.below_usize(d + 1);
            let (idx, val) = sparse_row(&mut rng, d, nnz);
            assert_eq!(idx.len(), nnz);
            assert_eq!(val.len(), nnz);
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "{idx:?}");
            assert!(idx.iter().all(|&j| (j as usize) < d));
        }
    }

    #[test]
    fn random_csr_validates() {
        let mut rng = Pcg64::seeded(12);
        for _ in 0..30 {
            random_csr(&mut rng, 12, 12).validate().unwrap();
        }
    }
}
