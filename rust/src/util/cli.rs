//! A small GNU-style argument parser (`--key value`, `--key=value`,
//! `--flag`, positionals) — the offline crate set has no `clap`.
//!
//! Typed lookups parse on access and report friendly errors; unknown-flag
//! detection is the caller's choice via [`Args::finish`].

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
    consumed: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parses an iterator of raw arguments (without argv[0]).
    pub fn parse<I, S>(raw: I) -> Result<Self>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = raw.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends flag parsing.
                    args.positionals.extend(iter);
                    break;
                }
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let value = match inline {
                    Some(v) => Some(v),
                    // A following token that is not itself a flag is the value.
                    None => match iter.peek() {
                        Some(next) if !next.starts_with("--") => iter.next(),
                        _ => None,
                    },
                };
                args.flags
                    .entry(key)
                    .or_default()
                    .push(value.unwrap_or_default());
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// Parses the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional arguments (in order).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// True if `--key` was given (with or without a value).
    pub fn has(&mut self, key: &str) -> bool {
        let present = self.flags.contains_key(key);
        if present {
            self.consumed.insert(key.to_string());
        }
        present
    }

    /// Raw string value of `--key` (last occurrence wins).
    pub fn get(&mut self, key: &str) -> Option<String> {
        let v = self.flags.get(key).and_then(|vs| vs.last()).cloned();
        if v.is_some() {
            self.consumed.insert(key.to_string());
        }
        v.filter(|s| !s.is_empty())
    }

    /// Typed value of `--key`, or `default` when absent.
    pub fn get_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {raw:?}: {e}")),
        }
    }

    /// Typed value of a required `--key`.
    pub fn require<T: std::str::FromStr>(&mut self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(key)
            .with_context(|| format!("missing required --{key}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{key} {raw:?}: {e}"))
    }

    /// All values given for a repeatable `--key`.
    pub fn get_all(&mut self, key: &str) -> Vec<String> {
        if self.flags.contains_key(key) {
            self.consumed.insert(key.to_string());
        }
        self.flags.get(key).cloned().unwrap_or_default()
    }

    /// Comma-separated list value (`--workers 1,2,4`).
    pub fn get_list<T: std::str::FromStr>(&mut self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow::anyhow!("--{key} item {s:?}: {e}"))
                })
                .collect(),
        }
    }

    /// Errors on any flag that was never consumed — catches typos.
    pub fn finish(&self) -> Result<()> {
        let unknown: Vec<_> = self
            .flags
            .keys()
            .filter(|k| !self.consumed.contains(*k))
            .cloned()
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flag(s): {}", unknown.join(", "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().copied()).unwrap()
    }

    #[test]
    fn values_and_flags() {
        let mut a = parse(&["--eta", "0.1", "--quiet", "--k=8", "train"]);
        assert_eq!(a.get_or("eta", 0.0).unwrap(), 0.1);
        assert!(a.has("quiet"));
        assert_eq!(a.get_or("k", 0usize).unwrap(), 8);
        assert_eq!(a.positionals(), &["train".to_string()]);
    }

    #[test]
    fn last_occurrence_wins() {
        let mut a = parse(&["--p", "1", "--p", "2"]);
        assert_eq!(a.get_or("p", 0).unwrap(), 2);
        assert_eq!(a.get_all("p"), vec!["1", "2"]);
    }

    #[test]
    fn required_missing_errors() {
        let mut a = parse(&[]);
        assert!(a.require::<usize>("n").is_err());
    }

    #[test]
    fn bad_parse_reports_key() {
        let mut a = parse(&["--n", "abc"]);
        let err = a.require::<usize>("n").unwrap_err().to_string();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn lists() {
        let mut a = parse(&["--workers", "1,2,4,8"]);
        assert_eq!(a.get_list("workers", &[1]).unwrap(), vec![1, 2, 4, 8]);
        let mut b = parse(&[]);
        assert_eq!(b.get_list("workers", &[3]).unwrap(), vec![3]);
    }

    #[test]
    fn double_dash_stops_flags() {
        let mut a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.get_or("x", 0).unwrap(), 1);
        assert_eq!(a.positionals(), &["--not-a-flag".to_string()]);
    }

    #[test]
    fn finish_flags_unknown() {
        let mut a = parse(&["--known", "1", "--typo", "2"]);
        let _ = a.get_or("known", 0).unwrap();
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("typo"));
    }

    #[test]
    fn flag_followed_by_flag_has_no_value() {
        let mut a = parse(&["--quiet", "--eta", "0.5"]);
        assert!(a.has("quiet"));
        assert_eq!(a.get_or("eta", 0.0).unwrap(), 0.5);
    }
}
