//! PCG64 (DXSM) pseudo-random generator plus the sampling helpers the
//! trainers need (normals, permutations, Bernoulli draws).
//!
//! The offline crate set ships `rand_core` but not `rand`, so this is a
//! from-scratch implementation of the PCG-DXSM generator (O'Neill 2014;
//! the variant numpy's default generator uses). Deterministic across
//! platforms — every experiment in EXPERIMENTS.md records its seed.

/// A 128-bit-state PCG64-DXSM generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Creates a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.state = rng.state.wrapping_add(rng.inc).wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Creates a generator from a seed on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
    }

    /// Next uniform u64 (PCG-DXSM output permutation).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let state = self.state;
        self.step();
        let mut hi = (state >> 64) as u64;
        let lo = (state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire rejection (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        // Draw until u1 > 0 to avoid ln(0).
        let mut u1 = self.f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.f64();
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean / stddev, as f32.
    pub fn normal32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Samples `k` distinct indices from 0..n (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below_usize(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::seeded(3);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::seeded(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Pcg64::seeded(6);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(9, 0);
        let mut b = Pcg64::new(9, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
