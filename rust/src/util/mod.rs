//! Small self-contained substrates: RNG, CLI parsing, timing, CSV traces,
//! statistics helpers and a miniature property-testing harness.
//!
//! The offline crate universe for this build contains none of `rand`,
//! `clap`, `criterion` or `proptest`, so the pieces of each that DS-FACTO
//! needs are implemented here from scratch (and tested).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

/// Formats a byte count for logs (`1.5 GiB` style).
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Formats seconds for logs (`1m23.4s` style).
pub fn human_secs(s: f64) -> String {
    if s < 60.0 {
        format!("{s:.2}s")
    } else if s < 3600.0 {
        format!("{}m{:.1}s", (s / 60.0) as u64, s % 60.0)
    } else {
        format!("{}h{}m", (s / 3600.0) as u64, ((s % 3600.0) / 60.0) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(1.5), "1.50s");
        assert_eq!(human_secs(75.0), "1m15.0s");
        assert_eq!(human_secs(3700.0), "1h1m");
    }
}
