//! Wall-clock timing helpers used by the trainers and the bench harness.

use std::time::Instant;

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Starts a new stopwatch.
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds since start.
    pub fn nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }

    /// Resets the stopwatch and returns the elapsed seconds.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Times a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

/// CPU time consumed by the *calling thread*, in seconds.
///
/// Unlike wall-clock, this excludes blocking waits and preemption by other
/// threads — the right basis for per-worker busy time on machines with
/// fewer cores than workers (the Fig. 6 simulated-makespan substitution).
pub fn thread_cpu_secs() -> f64 {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: ts is a valid out-pointer; CLOCK_THREAD_CPUTIME_ID is
    // supported on all Linux targets this crate builds for.
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(3));
        let first = sw.lap();
        assert!(first >= 0.002);
        assert!(sw.secs() < first);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn thread_cpu_time_advances_with_work() {
        let t0 = thread_cpu_secs();
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let t1 = thread_cpu_secs();
        assert!(t1 > t0, "{t0} -> {t1}");
    }

    #[test]
    fn thread_cpu_time_ignores_sleep() {
        let t0 = thread_cpu_secs();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let t1 = thread_cpu_secs();
        assert!(t1 - t0 < 0.02, "sleep must not count as CPU time: {}", t1 - t0);
    }
}
