//! Statistics helpers shared by the metrics module and the bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Summary of repeated measurements (the bench harness's unit of report).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Summarizes a sample.
    pub fn of(xs: &[f64]) -> Summary {
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: if xs.is_empty() { 0.0 } else { min },
            p50: median(xs),
            p95: percentile(xs, 95.0),
            max: if xs.is_empty() { 0.0 } else { max },
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.6} std={:.6} min={:.6} p50={:.6} p95={:.6} max={:.6}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn empty_is_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
