//! CSV trace writer for convergence curves and bench series.
//!
//! Every experiment writes its (iter, time, objective, metric, ...) rows
//! through this so that Fig 4/5/6 series can be re-plotted from disk.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Creates the file (and parent dirs) and writes the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            cols: header.len(),
        })
    }

    /// Writes one row; panics in debug builds if the arity is wrong.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv arity mismatch");
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    /// Convenience: writes a row of display-able values.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    /// Flushes buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Parses a simple CSV file (no quoting) into header + rows.
pub fn read_csv<P: AsRef<Path>>(path: P) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .map(|h| h.split(',').map(str::to_string).collect())
        .unwrap_or_default();
    let rows = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.split(',').map(str::to_string).collect())
        .collect();
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("dsfacto_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["iter", "loss"]).unwrap();
            w.rowd(&[&0, &0.5]).unwrap();
            w.rowd(&[&1, &0.25]).unwrap();
            w.flush().unwrap();
        }
        let (hdr, rows) = read_csv(&path).unwrap();
        assert_eq!(hdr, vec!["iter", "loss"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["1", "0.25"]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
