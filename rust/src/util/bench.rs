//! Bench-harness support (the offline crate set has no criterion): timing
//! loops with warmup, ns/op reporting, table printing, and the
//! machine-readable [`BenchReport`] JSON emitter (`BENCH_*.json`) shared
//! by the `rust/benches/*` targets.
//!
//! Knobs (all env vars, so CI smoke runs stay short without code changes):
//! * `BENCH_SAMPLES` — samples per benchmark (benches read it themselves).
//! * `BENCH_MIN_MS` — per-sample calibration floor in milliseconds
//!   (default 10).
//! * `BENCH_JSON` — output path for the report (benches pick the default).

use crate::util::stats::Summary;
use crate::util::timer::Stopwatch;

/// Times `f` for `iters` iterations after `warmup` iterations; returns
/// per-iteration seconds samples.
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
    }
    samples
}

/// Per-sample calibration floor in seconds (`BENCH_MIN_MS`, default 10ms).
fn min_sample_secs() -> f64 {
    std::env::var("BENCH_MIN_MS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(|ms| (ms / 1e3).max(1e-5))
        .unwrap_or(0.01)
}

/// Runs a micro-benchmark: repeatedly calls `f` in batches sized so each
/// sample takes at least the calibration floor; returns the full ns/op
/// sample summary (and prints the usual table line).
pub fn bench_summary(name: &str, samples: usize, mut f: impl FnMut() -> u64) -> Summary {
    // Calibrate batch size.
    let floor = min_sample_secs();
    let mut batch = 1u64;
    loop {
        let sw = Stopwatch::start();
        let mut ops = 0u64;
        for _ in 0..batch {
            ops += f();
        }
        let secs = sw.secs();
        if secs >= floor || batch >= 1 << 24 {
            let _ = ops;
            break;
        }
        batch *= 4;
    }
    let mut per_op = Vec::with_capacity(samples);
    for _ in 0..samples {
        let sw = Stopwatch::start();
        let mut ops = 0u64;
        for _ in 0..batch {
            ops += f();
        }
        per_op.push(sw.secs() * 1e9 / ops.max(1) as f64);
    }
    let s = Summary::of(&per_op);
    println!(
        "{name:<44} {:>10.1} ns/op  (p50 {:>9.1}, p95 {:>9.1}, n={})",
        s.mean, s.p50, s.p95, s.n
    );
    s
}

/// One recorded micro-benchmark result (a row of a `BENCH_*.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    /// Benchmark name (stable across runs; the perf-trajectory key).
    pub name: String,
    /// Headline nanoseconds per operation (p50 across samples).
    pub ns_per_op: f64,
    /// Mean ns/op across samples.
    pub mean: f64,
    /// p95 ns/op across samples.
    pub p95: f64,
    /// Number of samples.
    pub samples: usize,
}

/// Collects benchmark entries and writes the machine-readable
/// `BENCH_*.json` report (schema documented in EXPERIMENTS.md §Perf) that
/// gives the perf trajectory comparable points across commits.
#[derive(Debug, Clone, Default)]
pub struct BenchReport {
    /// Report name (e.g. `hotpath_micro`).
    pub bench: String,
    /// Recorded entries, in run order.
    pub entries: Vec<BenchEntry>,
}

impl BenchReport {
    /// An empty report for the named bench target.
    pub fn new(bench: &str) -> Self {
        BenchReport {
            bench: bench.to_string(),
            entries: Vec::new(),
        }
    }

    /// Records a sampled summary under `name`.
    pub fn record(&mut self, name: &str, s: &Summary) {
        self.entries.push(BenchEntry {
            name: name.to_string(),
            ns_per_op: s.p50,
            mean: s.mean,
            p95: s.p95,
            samples: s.n,
        });
    }

    /// Records a single derived measurement (no sample distribution).
    pub fn record_value(&mut self, name: &str, ns_per_op: f64) {
        self.entries.push(BenchEntry {
            name: name.to_string(),
            ns_per_op,
            mean: ns_per_op,
            p95: ns_per_op,
            samples: 1,
        });
    }

    /// The recorded ns/op for `name`, if present.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| e.ns_per_op)
    }

    /// Serializes the report (hand-rolled: the offline crate set has no
    /// serde). Non-finite values are emitted as `null` to keep the
    /// document valid JSON.
    pub fn to_json(&self) -> String {
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:.4}")
            } else {
                "null".to_string()
            }
        }
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape_json(&self.bench)));
        out.push_str("  \"schema\": 1,\n");
        out.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"ns_per_op\": {}, \"mean\": {}, \"p95\": {}, \"samples\": {}}}{}\n",
                escape_json(&e.name),
                num(e.ns_per_op),
                num(e.mean),
                num(e.p95),
                e.samples,
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Writes the JSON report to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes).
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Prints a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Formats a ratio as a "who wins" string.
pub fn ratio_str(a: f64, b: f64) -> String {
    if a <= b {
        format!("{:.2}x faster", b / a.max(1e-12))
    } else {
        format!("{:.2}x slower", a / b.max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_iters_returns_samples() {
        let s = time_iters(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn ratio_formatting() {
        assert!(ratio_str(1.0, 2.0).contains("faster"));
        assert!(ratio_str(2.0, 1.0).contains("slower"));
    }

    #[test]
    fn report_collects_and_serializes() {
        let mut r = BenchReport::new("unit_test");
        r.record("op_a", &Summary::of(&[10.0, 12.0, 14.0]));
        r.record_value("derived", 7.5);
        assert_eq!(r.get("op_a"), Some(12.0));
        assert_eq!(r.get("derived"), Some(7.5));
        assert_eq!(r.get("missing"), None);
        let json = r.to_json();
        assert!(json.contains("\"bench\": \"unit_test\""));
        assert!(json.contains("\"name\": \"op_a\""));
        assert!(json.contains("\"ns_per_op\": 12.0000"));
        assert!(json.contains("\"samples\": 3"));
        // Braces/brackets balance (cheap well-formedness check).
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "{json}"
        );
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn json_escaping_handles_specials() {
        assert_eq!(escape_json("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape_json("line\nbreak"), "line\\nbreak");
        let mut r = BenchReport::new("x");
        r.record_value("nan_case", f64::NAN);
        assert!(r.to_json().contains("\"ns_per_op\": null"));
    }
}
