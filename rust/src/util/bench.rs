//! Bench-harness support (the offline crate set has no criterion): timing
//! loops with warmup, ns/op reporting, and table printing shared by the
//! `rust/benches/*` targets.

use crate::util::stats::Summary;
use crate::util::timer::Stopwatch;

/// Times `f` for `iters` iterations after `warmup` iterations; returns
/// per-iteration seconds samples.
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
    }
    samples
}

/// Runs a micro-benchmark: repeatedly calls `f` in batches sized so each
/// sample takes >= `min_batch_secs`, reporting ns/op.
pub fn bench_ns_per_op(name: &str, samples: usize, mut f: impl FnMut() -> u64) -> f64 {
    // Calibrate batch size.
    let mut batch = 1u64;
    loop {
        let sw = Stopwatch::start();
        let mut ops = 0u64;
        for _ in 0..batch {
            ops += f();
        }
        let secs = sw.secs();
        if secs >= 0.01 || batch >= 1 << 24 {
            let _ = ops;
            break;
        }
        batch *= 4;
    }
    let mut per_op = Vec::with_capacity(samples);
    for _ in 0..samples {
        let sw = Stopwatch::start();
        let mut ops = 0u64;
        for _ in 0..batch {
            ops += f();
        }
        per_op.push(sw.secs() * 1e9 / ops.max(1) as f64);
    }
    let s = Summary::of(&per_op);
    println!("{name:<44} {:>10.1} ns/op  (p50 {:>9.1}, p95 {:>9.1}, n={})", s.mean, s.p50, s.p95, s.n);
    s.p50
}

/// Prints a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Formats a ratio as a "who wins" string.
pub fn ratio_str(a: f64, b: f64) -> String {
    if a <= b {
        format!("{:.2}x faster", b / a.max(1e-12))
    } else {
        format!("{:.2}x slower", a / b.max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_iters_returns_samples() {
        let s = time_iters(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn ratio_formatting() {
        assert!(ratio_str(1.0, 2.0).contains("faster"));
        assert!(ratio_str(2.0, 1.0).contains("slower"));
    }
}
