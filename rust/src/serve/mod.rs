//! `dsfacto serve` — the zero-allocation batched scoring server.
//!
//! Training produces a checkpoint; this module is the request path that
//! serves it: a std-only TCP server speaking the length-prefixed frame
//! protocol of [`frames`] (magic `0xD5FE`, sharing the cluster codec's
//! wire helpers), scoring LIBSVM-shaped sparse rows through the same
//! fused kernels the trainers use. Three properties define it, each
//! pinned by `rust/tests/serve_e2e.rs`:
//!
//! * **Zero steady-state allocation** — per-connection grow-only arenas
//!   ([`frames::RowStaging`], [`model::ServeScratch`]) absorb decode and
//!   scoring; after the largest batch has been seen once, a request
//!   touches the heap zero times ([`server`] module docs).
//! * **Micro-batching with bitwise-stable scores** — pipelined requests
//!   gathered within `batch_window` (up to `max_batch`) score through
//!   one fused sweep; batched, unbatched, and `col_blocks > 1` block-wise
//!   serving all produce bitwise-identical scores, equal to
//!   [`Predictor::predict_batch`](crate::train::Predictor::predict_batch).
//! * **Hot reload without request disruption** — a watcher thread swaps
//!   re-fingerprinted checkpoints behind an `Arc`; the request path pays
//!   one atomic load per batch and never blocks on a swap
//!   ([`model`] module docs).
//!
//! Latency/throughput numbers (p50/p99 at 1/8/64 streams, batched vs
//! unbatched) land in `BENCH_serve.json` via
//! `cargo bench --bench serve_bench` (EXPERIMENTS.md §Serve).

pub mod client;
pub mod frames;
pub mod model;
pub mod server;

pub use client::ScoreClient;
pub use frames::{Frame, RowStaging, ServerStats};
pub use model::{ModelSlot, ServeScratch, ServedModel};
pub use server::{serve, ServeHandle, ServeOptions};
