//! A small blocking client for the serve wire — what `dsfacto score`,
//! the e2e suite and the latency bench speak.
//!
//! [`ScoreClient::score`] is the synchronous one-request path;
//! [`send_score_request`](ScoreClient::send_score_request) +
//! [`recv`](ScoreClient::recv) expose the pipelined path (fire several
//! requests back to back, then collect responses in order) that the
//! server's micro-batching rewards.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::frames::{self, Frame, ServerStats, MAX_FRAME};

/// One connection to a scoring server.
pub struct ScoreClient {
    stream: TcpStream,
    body: Vec<u8>,
    msg: Vec<u8>,
    next_id: u64,
}

impl ScoreClient {
    /// Connects (with Nagle off — the protocol is request/response).
    pub fn connect(addr: &str) -> Result<ScoreClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .context("set read timeout")?;
        Ok(ScoreClient {
            stream,
            body: Vec::new(),
            msg: Vec::new(),
            next_id: 0,
        })
    }

    /// Sends one score request without waiting for the response; returns
    /// the request id to match against [`recv`](ScoreClient::recv).
    pub fn send_score_request(&mut self, rows: &[(&[u32], &[f32])]) -> Result<u64> {
        self.next_id += 1;
        let req_id = self.next_id;
        frames::encode_score_request(req_id, rows, &mut self.body);
        self.send_body()?;
        Ok(req_id)
    }

    /// Reads the next frame off the connection.
    pub fn recv(&mut self) -> Result<Frame> {
        let mut len_buf = [0u8; 4];
        self.stream
            .read_exact(&mut len_buf)
            .context("read frame length")?;
        let len = u32::from_le_bytes(len_buf) as usize;
        ensure!(len <= MAX_FRAME, "oversized frame ({len} bytes)");
        self.body.resize(len, 0);
        self.stream
            .read_exact(&mut self.body)
            .context("read frame body")?;
        Frame::decode(&self.body)
    }

    /// Scores a batch of rows synchronously. An error frame from the
    /// server surfaces as `Err` carrying its message.
    pub fn score(&mut self, rows: &[(&[u32], &[f32])]) -> Result<Vec<f32>> {
        let sent = self.send_score_request(rows)?;
        match self.recv()? {
            Frame::ScoreResponse { req_id, scores } => {
                ensure!(req_id == sent, "response for {req_id}, expected {sent}");
                ensure!(
                    scores.len() == rows.len(),
                    "got {} scores for {} rows",
                    scores.len(),
                    rows.len()
                );
                Ok(scores)
            }
            Frame::Error { req_id, message } => {
                bail!("server rejected request {req_id}: {message}")
            }
            other => bail!("unexpected frame {other:?}"),
        }
    }

    /// Fetches the server's stats snapshot (flushes any batch the server
    /// is gathering on this connection first, by protocol).
    pub fn stats(&mut self) -> Result<ServerStats> {
        frames::encode_stats_request(&mut self.body);
        self.send_body()?;
        match self.recv()? {
            Frame::StatsResponse(s) => Ok(s),
            other => bail!("unexpected frame {other:?}"),
        }
    }

    fn send_body(&mut self) -> Result<()> {
        self.msg.clear();
        self.msg
            .extend_from_slice(&(self.body.len() as u32).to_le_bytes());
        self.msg.extend_from_slice(&self.body);
        self.stream.write_all(&self.msg).context("write frame")
    }
}
