//! The scoring-server wire protocol: length-prefixed little-endian frames
//! carrying sparse rows in, scores out.
//!
//! Every frame on the socket is `len u32 | magic u16 | kind u8 | body`,
//! with `len` covering everything after itself — the same outer framing
//! (and the same [`codec::wire`] helpers) as the cluster control plane,
//! under the serve plane's own magic [`MAGIC`]. Kinds:
//!
//! | kind | frame          | body                                                            |
//! |------|----------------|-----------------------------------------------------------------|
//! | 1    | ScoreRequest   | `req_id u64, n_rows u32`, then per row `nnz u32, idx u32 x nnz, val f32 x nnz` |
//! | 2    | ScoreResponse  | `req_id u64, n u32, score f32 x n`                              |
//! | 3    | Error          | `req_id u64, message (u32-prefixed UTF-8)`                      |
//! | 4    | StatsRequest   | empty                                                           |
//! | 5    | StatsResponse  | [`ServerStats`] fields in struct order, all u64 but `col_blocks` (u32) |
//!
//! Row indices must be strictly ascending and in `[0, d)` — exactly the
//! [`Csr`](crate::data::Csr) row invariant, so a request's rows decode
//! straight into CSR raw parts with no sort or dedup pass. A violating
//! row rejects the whole request with an [`Error`](Frame::Error) frame
//! (the connection survives); a frame that is not even well-formed at the
//! `len`/`magic`/`kind` layer kills the connection, since the stream can
//! no longer be trusted to be frame-aligned.
//!
//! Decoding a request appends into a caller-owned [`RowStaging`] arena —
//! grow-only, like the kernel's [`Scratch`](crate::kernel::Scratch) — so
//! the server's steady-state request path performs no allocation.

use anyhow::{bail, ensure, Result};

use crate::cluster::codec::wire::{put_f32, put_str, put_u16, put_u32, put_u64, put_u8, Reader};

/// Serve-plane frame magic (the cluster planes use `0xD5FA`/`0xD5FB`/
/// `0xD5FC`/`0xDB16`).
pub const MAGIC: u16 = 0xD5FE;

/// Hard cap on one frame body; larger length prefixes are treated as
/// stream corruption.
pub const MAX_FRAME: usize = 1 << 26;

/// Cap on rows per request — batching beyond this belongs to the client.
pub const MAX_REQUEST_ROWS: usize = 1 << 20;

pub(crate) const KIND_SCORE_REQUEST: u8 = 1;
pub(crate) const KIND_SCORE_RESPONSE: u8 = 2;
pub(crate) const KIND_ERROR: u8 = 3;
pub(crate) const KIND_STATS_REQUEST: u8 = 4;
pub(crate) const KIND_STATS_RESPONSE: u8 = 5;

/// Server-side counters and identity, as carried by a StatsResponse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStats {
    /// Model feature dimension D.
    pub d: u64,
    /// Model factor count K.
    pub k: u64,
    /// Hot-reload generation (1 = the initially loaded model).
    pub generation: u64,
    /// FNV-1a fingerprint of the served checkpoint bytes.
    pub fingerprint: u64,
    /// Column blocks the factor matrix is served in (1 = unblocked).
    pub col_blocks: u32,
    /// The answering connection's row-staging arena capacity (elements
    /// across its index/value/indptr buffers). Grow-only.
    pub staging_capacity: u64,
    /// The answering connection's scoring-scratch capacity in floats.
    /// Together with `staging_capacity` this is the zero-steady-state-
    /// allocation watermark the e2e suite asserts stops growing.
    pub scratch_capacity: u64,
    /// Score requests answered.
    pub requests: u64,
    /// Rows scored.
    pub rows: u64,
    /// Fused `score_rows` sweeps executed (`batches <= requests` — the
    /// gap is the micro-batching win).
    pub batches: u64,
}

/// A decoded serve-plane frame. Score requests are not decoded into this
/// enum on the server — they stream into [`RowStaging`] via
/// [`decode_score_request_into`] to keep the hot path allocation-free;
/// [`Frame::decode`] (used by the client and the tests) materializes them.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    ScoreRequest {
        req_id: u64,
        /// Row `i` is `(indices[indptr[i]..indptr[i+1]], values[..])`.
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    },
    ScoreResponse {
        req_id: u64,
        scores: Vec<f32>,
    },
    Error {
        req_id: u64,
        message: String,
    },
    StatsRequest,
    StatsResponse(ServerStats),
}

/// Grow-only staging arena for inbound rows: CSR raw parts plus the
/// originating request id and row span of every request currently staged
/// in the batch. `clear` keeps capacity, so a connection that has seen
/// its largest batch never allocates again.
#[derive(Debug)]
pub struct RowStaging {
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    /// `(req_id, first_row, n_rows)` per staged request, in arrival order.
    pub requests: Vec<(u64, usize, usize)>,
}

impl Default for RowStaging {
    fn default() -> Self {
        RowStaging::new()
    }
}

impl RowStaging {
    pub fn new() -> Self {
        RowStaging {
            // A CSR indptr always carries the leading 0.
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            requests: Vec::new(),
        }
    }

    /// Drops staged rows, keeping every buffer's capacity.
    pub fn clear(&mut self) {
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        self.requests.clear();
    }

    /// Total staged rows across all staged requests.
    pub fn n_rows(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Capacity watermark (index + value + indptr + request slots): the
    /// grow-only number the zero-allocation e2e assertions sample.
    pub fn capacity(&self) -> usize {
        self.indices.capacity()
            + self.values.capacity()
            + self.indptr.capacity()
            + self.requests.capacity()
    }
}

fn header(out: &mut Vec<u8>, kind: u8) {
    put_u16(out, MAGIC);
    put_u8(out, kind);
}

/// Encodes a score request for `rows` (parallel index/value slices per
/// row) into `out` (cleared first), body only — the caller writes the
/// u32 length prefix.
pub fn encode_score_request(req_id: u64, rows: &[(&[u32], &[f32])], out: &mut Vec<u8>) {
    out.clear();
    header(out, KIND_SCORE_REQUEST);
    put_u64(out, req_id);
    put_u32(out, rows.len() as u32);
    for (idx, val) in rows {
        debug_assert_eq!(idx.len(), val.len());
        put_u32(out, idx.len() as u32);
        for &j in *idx {
            put_u32(out, j);
        }
        for &x in *val {
            put_f32(out, x);
        }
    }
}

/// Encodes a score response (body only).
pub fn encode_score_response(req_id: u64, scores: &[f32], out: &mut Vec<u8>) {
    out.clear();
    header(out, KIND_SCORE_RESPONSE);
    put_u64(out, req_id);
    put_u32(out, scores.len() as u32);
    for &s in scores {
        put_f32(out, s);
    }
}

/// Encodes an error frame (body only).
pub fn encode_error(req_id: u64, message: &str, out: &mut Vec<u8>) {
    out.clear();
    header(out, KIND_ERROR);
    put_u64(out, req_id);
    put_str(out, message);
}

/// Encodes a stats request (body only).
pub fn encode_stats_request(out: &mut Vec<u8>) {
    out.clear();
    header(out, KIND_STATS_REQUEST);
}

/// Encodes a stats response (body only).
pub fn encode_stats_response(s: &ServerStats, out: &mut Vec<u8>) {
    out.clear();
    header(out, KIND_STATS_RESPONSE);
    put_u64(out, s.d);
    put_u64(out, s.k);
    put_u64(out, s.generation);
    put_u64(out, s.fingerprint);
    put_u32(out, s.col_blocks);
    put_u64(out, s.staging_capacity);
    put_u64(out, s.scratch_capacity);
    put_u64(out, s.requests);
    put_u64(out, s.rows);
    put_u64(out, s.batches);
}

/// Checks the `magic | kind` header and returns the kind. An unexpected
/// magic means the stream is not speaking this protocol — fatal.
pub(crate) fn frame_kind(body: &[u8]) -> Result<(u8, Reader<'_>)> {
    let mut r = Reader::new(body);
    let magic = r.u16()?;
    ensure!(magic == MAGIC, "not a serve frame (magic {magic:#06x})");
    let kind = r.u8()?;
    Ok((kind, r))
}

/// Appends one score request's rows into `staging`, validating each row
/// against the CSR invariant (strictly ascending indices, all `< d`,
/// index/value arity matched by construction of the wire format). On
/// error the staging arena is left exactly as it was — the already-staged
/// requests of the batch stay scorable — and the message names the
/// offending row. Returns the request id and its row count.
pub(crate) fn decode_score_request_into(
    mut r: Reader<'_>,
    d: usize,
    staging: &mut RowStaging,
) -> Result<(u64, usize)> {
    let req_id = r.u64()?;
    let n_rows = r.u32()? as usize;
    let (rows0, idx0, val0) = (staging.n_rows(), staging.indices.len(), staging.values.len());
    let unwind = |s: &mut RowStaging| {
        s.indptr.truncate(rows0 + 1);
        s.indices.truncate(idx0);
        s.values.truncate(val0);
    };
    if let Err(e) = stage_rows(&mut r, d, n_rows, staging) {
        unwind(staging);
        return Err(e);
    }
    staging.requests.push((req_id, rows0, n_rows));
    Ok((req_id, n_rows))
}

fn stage_rows(r: &mut Reader<'_>, d: usize, n_rows: usize, staging: &mut RowStaging) -> Result<()> {
    ensure!(
        n_rows <= MAX_REQUEST_ROWS,
        "request has {n_rows} rows (cap {MAX_REQUEST_ROWS})"
    );
    for row in 0..n_rows {
        let nnz = r.u32()? as usize;
        ensure!(nnz <= d, "row {row}: {nnz} non-zeros exceed d={d}");
        let start = staging.indices.len();
        for t in 0..nnz {
            let j = r.u32()?;
            ensure!(
                (j as usize) < d,
                "row {row}: feature index {j} out of range for d={d}"
            );
            ensure!(
                t == 0 || j > staging.indices[start + t - 1],
                "row {row}: column indices not strictly increasing"
            );
            staging.indices.push(j);
        }
        for _ in 0..nnz {
            staging.values.push(r.f32()?);
        }
        staging.indptr.push(staging.indices.len());
    }
    r.finish()
}

/// Fully decodes one frame body (client side and tests; the server's
/// request hot path uses [`decode_score_request_into`] instead).
impl Frame {
    pub fn decode(body: &[u8]) -> Result<Frame> {
        let (kind, mut r) = frame_kind(body)?;
        match kind {
            KIND_SCORE_REQUEST => {
                // Permissive width: a decoded request's own validation
                // against the model's d happens server-side.
                let mut staging = RowStaging::new();
                let (req_id, _) = decode_score_request_into(r, u32::MAX as usize, &mut staging)?;
                Ok(Frame::ScoreRequest {
                    req_id,
                    indptr: staging.indptr,
                    indices: staging.indices,
                    values: staging.values,
                })
            }
            KIND_SCORE_RESPONSE => {
                let req_id = r.u64()?;
                let n = r.u32()? as usize;
                ensure!(n <= MAX_REQUEST_ROWS, "response has {n} scores");
                let mut scores = Vec::with_capacity(n);
                for _ in 0..n {
                    scores.push(r.f32()?);
                }
                r.finish()?;
                Ok(Frame::ScoreResponse { req_id, scores })
            }
            KIND_ERROR => {
                let req_id = r.u64()?;
                let message = r.string(MAX_FRAME)?;
                r.finish()?;
                Ok(Frame::Error { req_id, message })
            }
            KIND_STATS_REQUEST => {
                r.finish()?;
                Ok(Frame::StatsRequest)
            }
            KIND_STATS_RESPONSE => {
                let s = ServerStats {
                    d: r.u64()?,
                    k: r.u64()?,
                    generation: r.u64()?,
                    fingerprint: r.u64()?,
                    col_blocks: r.u32()?,
                    staging_capacity: r.u64()?,
                    scratch_capacity: r.u64()?,
                    requests: r.u64()?,
                    rows: r.u64()?,
                    batches: r.u64()?,
                };
                r.finish()?;
                Ok(Frame::StatsResponse(s))
            }
            other => bail!("unknown serve frame kind {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_request_roundtrips_through_staging() {
        let rows: Vec<(&[u32], &[f32])> = vec![
            (&[0, 3, 7][..], &[1.0, -0.5, 2.0][..]),
            (&[][..], &[][..]),
            (&[2][..], &[4.5][..]),
        ];
        let mut body = Vec::new();
        encode_score_request(99, &rows, &mut body);
        let (kind, r) = frame_kind(&body).unwrap();
        assert_eq!(kind, KIND_SCORE_REQUEST);
        let mut staging = RowStaging::new();
        let (req_id, n) = decode_score_request_into(r, 8, &mut staging).unwrap();
        assert_eq!((req_id, n), (99, 3));
        assert_eq!(staging.indptr, vec![0, 3, 3, 4]);
        assert_eq!(staging.indices, vec![0, 3, 7, 2]);
        assert_eq!(staging.values, vec![1.0, -0.5, 2.0, 4.5]);
        assert_eq!(staging.requests, vec![(99, 0, 3)]);

        // A second staged request appends.
        let rows2: Vec<(&[u32], &[f32])> = vec![(&[1][..], &[9.0][..])];
        encode_score_request(100, &rows2, &mut body);
        let (_, r) = frame_kind(&body).unwrap();
        decode_score_request_into(r, 8, &mut staging).unwrap();
        assert_eq!(staging.n_rows(), 4);
        assert_eq!(staging.requests, vec![(99, 0, 3), (100, 3, 1)]);
    }

    #[test]
    fn invalid_rows_reject_without_disturbing_staged_batch() {
        let mut staging = RowStaging::new();
        let good: Vec<(&[u32], &[f32])> = vec![(&[0, 1][..], &[1.0, 2.0][..])];
        let mut body = Vec::new();
        encode_score_request(1, &good, &mut body);
        let (_, r) = frame_kind(&body).unwrap();
        decode_score_request_into(r, 4, &mut staging).unwrap();

        // Out-of-range index.
        let bad: Vec<(&[u32], &[f32])> = vec![(&[0, 9][..], &[1.0, 2.0][..])];
        encode_score_request(2, &bad, &mut body);
        let (_, r) = frame_kind(&body).unwrap();
        let err = decode_score_request_into(r, 4, &mut staging)
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");

        // Non-ascending indices.
        let bad: Vec<(&[u32], &[f32])> = vec![(&[2, 2][..], &[1.0, 2.0][..])];
        encode_score_request(3, &bad, &mut body);
        let (_, r) = frame_kind(&body).unwrap();
        let err = decode_score_request_into(r, 4, &mut staging)
            .unwrap_err()
            .to_string();
        assert!(err.contains("strictly increasing"), "{err}");

        // The staged batch is untouched.
        assert_eq!(staging.n_rows(), 1);
        assert_eq!(staging.indices, vec![0, 1]);
        assert_eq!(staging.requests, vec![(1, 0, 1)]);
    }

    #[test]
    fn response_error_and_stats_roundtrip() {
        let mut body = Vec::new();
        encode_score_response(7, &[0.5, -1.5], &mut body);
        assert_eq!(
            Frame::decode(&body).unwrap(),
            Frame::ScoreResponse {
                req_id: 7,
                scores: vec![0.5, -1.5]
            }
        );

        encode_error(8, "row 0: bad", &mut body);
        assert_eq!(
            Frame::decode(&body).unwrap(),
            Frame::Error {
                req_id: 8,
                message: "row 0: bad".into()
            }
        );

        encode_stats_request(&mut body);
        assert_eq!(Frame::decode(&body).unwrap(), Frame::StatsRequest);

        let s = ServerStats {
            d: 10,
            k: 4,
            generation: 2,
            fingerprint: 0xdead_beef,
            col_blocks: 3,
            staging_capacity: 123,
            scratch_capacity: 456,
            requests: 7,
            rows: 70,
            batches: 3,
        };
        encode_stats_response(&s, &mut body);
        assert_eq!(Frame::decode(&body).unwrap(), Frame::StatsResponse(s));
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        assert!(frame_kind(&[]).is_err());
        assert!(frame_kind(&0xBEEFu16.to_le_bytes()).is_err());
        let mut body = Vec::new();
        encode_stats_request(&mut body);
        body[2] = 42; // unknown kind
        assert!(Frame::decode(&body).is_err());
        encode_score_response(1, &[1.0], &mut body);
        body.push(0); // trailing byte
        assert!(Frame::decode(&body).is_err());
        encode_score_response(1, &[1.0], &mut body);
        body.truncate(body.len() - 2); // truncated scores
        assert!(Frame::decode(&body).is_err());
    }

    #[test]
    fn staging_clear_keeps_capacity() {
        let mut staging = RowStaging::new();
        let rows: Vec<(&[u32], &[f32])> = vec![(&[0, 1, 2][..], &[1.0, 2.0, 3.0][..])];
        let mut body = Vec::new();
        encode_score_request(1, &rows, &mut body);
        let (_, r) = frame_kind(&body).unwrap();
        decode_score_request_into(r, 4, &mut staging).unwrap();
        let cap = staging.capacity();
        assert!(cap > 0);
        staging.clear();
        assert_eq!(staging.n_rows(), 0);
        assert_eq!(staging.capacity(), cap, "clear must keep capacity");
    }
}
