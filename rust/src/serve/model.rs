//! Model management for the scoring server: the immutable served view of
//! a checkpoint, the generation-stamped swap slot connections read it
//! through, and the file watcher that hot-reloads new checkpoints.
//!
//! ## Swap contract
//!
//! A [`ServedModel`] is immutable once built; connections hold it behind
//! an `Arc` cached alongside the generation number they loaded it at. The
//! [`ModelSlot`] publishes the live generation in a single atomic — the
//! request path's *only* synchronization is one relaxed atomic load per
//! batch; the slot's mutex is touched exclusively when the generation
//! actually moved (a reload, i.e. almost never). In-flight batches keep
//! scoring the model they started with; the old `Arc` drops when its last
//! connection refreshes. No request is ever dropped or blocked by a swap.
//!
//! ## Watcher contract
//!
//! The watcher polls the checkpoint path's `(len, mtime)` every
//! `reload_poll_ms`. On a change it reads the file **once**, fingerprints
//! the bytes (FNV-1a, the shard cache's hash) and re-parses from that
//! same buffer — no second read, so there is no parse-after-check race
//! against a writer (and [`crate::fm::io::save`] renames complete files
//! into place anyway). A fingerprint equal to the served one is a no-op;
//! a parse failure keeps the current model and logs, so a bad push can
//! never take the server down.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use crate::data::cache::fnv1a;
use crate::fm::{io as fm_io, FmModel};
use crate::kernel::{BlockScratch, BlockedFm, FmKernel, Scratch};
use crate::partition::ColPartition;

/// One immutable, scoring-ready view of a checkpoint. `col_blocks = 1`
/// serves the fused [`FmKernel`] directly; `col_blocks > 1` serves the
/// [`ColPartition`]-sliced [`BlockedFm`] (bitwise-identical scores, see
/// its module docs).
pub struct ServedModel {
    pub d: usize,
    pub k: usize,
    /// Reload generation: 1 for the initially loaded checkpoint, +1 per
    /// successful hot swap.
    pub generation: u64,
    /// FNV-1a fingerprint of the checkpoint bytes this view was built
    /// from.
    pub fingerprint: u64,
    pub col_blocks: usize,
    scorer: Scorer,
}

enum Scorer {
    Fused(FmKernel),
    Blocked(BlockedFm),
}

impl ServedModel {
    /// Builds the served view of `m`. `col_blocks` is clamped to `[1, d]`.
    pub fn build(m: &FmModel, col_blocks: usize, generation: u64, fingerprint: u64) -> Self {
        let col_blocks = col_blocks.clamp(1, m.d.max(1));
        let scorer = if col_blocks == 1 {
            Scorer::Fused(FmKernel::from_model(m))
        } else {
            Scorer::Blocked(BlockedFm::from_model(
                m,
                ColPartition::with_n_blocks(m.d, col_blocks),
            ))
        };
        ServedModel {
            d: m.d,
            k: m.k,
            generation,
            fingerprint,
            col_blocks,
            scorer,
        }
    }

    /// Reads, fingerprints and builds a checkpoint file as generation
    /// `generation`.
    pub fn load(path: &Path, col_blocks: usize, generation: u64) -> Result<ServedModel> {
        let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        let m = fm_io::read_model(&bytes[..])
            .with_context(|| format!("parse model {}", path.display()))?;
        Ok(ServedModel::build(&m, col_blocks, generation, fnv1a(&bytes)))
    }

    /// Scores staged CSR rows into `out`. Allocation-free once `scratch`
    /// has grown to the largest batch. Scores are bitwise identical
    /// across `col_blocks` settings.
    pub fn score_rows(
        &self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut ServeScratch,
    ) {
        match &self.scorer {
            Scorer::Fused(k) => k.score_rows(indptr, indices, values, out, &mut scratch.fused),
            Scorer::Blocked(b) => {
                b.score_rows(indptr, indices, values, out, &mut scratch.blocked)
            }
        }
    }
}

/// Per-connection scoring scratch covering both scorer shapes, so a hot
/// swap that changes `k` (or a future per-generation `col_blocks`) reuses
/// the same arena. Grow-only, like its parts.
#[derive(Default)]
pub struct ServeScratch {
    fused: Scratch,
    blocked: BlockScratch,
}

impl ServeScratch {
    pub fn new() -> Self {
        ServeScratch::default()
    }

    /// Combined grow-only capacity watermark in floats.
    pub fn capacity(&self) -> usize {
        self.fused.capacity() + self.blocked.capacity()
    }
}

/// The swap slot: the one place a model generation is published.
pub struct ModelSlot {
    current: Mutex<Arc<ServedModel>>,
    generation: AtomicU64,
}

impl ModelSlot {
    pub fn new(m: ServedModel) -> Self {
        let generation = AtomicU64::new(m.generation);
        ModelSlot {
            current: Mutex::new(Arc::new(m)),
            generation,
        }
    }

    /// The live generation (one relaxed load; the request path's per-batch
    /// staleness check).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// A fresh handle to the live model (locks; used at connection setup
    /// and by the stats path).
    pub fn get(&self) -> Arc<ServedModel> {
        self.current.lock().unwrap().clone()
    }

    /// Publishes a new generation. The generation counter is bumped only
    /// after the model is visible behind the mutex, so a reader that
    /// observes the new generation always refreshes to the new model.
    pub fn install(&self, m: ServedModel) {
        let generation = m.generation;
        *self.current.lock().unwrap() = Arc::new(m);
        self.generation.store(generation, Ordering::Release);
    }

    /// Refreshes a connection's cached handle iff the slot moved past it.
    /// Steady state this is one atomic load and nothing else.
    pub fn refresh(&self, cached: &mut Arc<ServedModel>, cached_gen: &mut u64) {
        let live = self.generation();
        if live != *cached_gen {
            *cached = self.get();
            *cached_gen = cached.generation;
        }
    }
}

/// Spawns the checkpoint watcher thread. Returns its join handle; the
/// thread exits once `down` is set.
pub fn spawn_watcher(
    path: PathBuf,
    col_blocks: usize,
    poll: Duration,
    slot: Arc<ModelSlot>,
    down: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("serve-reload".into())
        .spawn(move || {
            let mut last_meta = file_meta(&path);
            while !down.load(Ordering::Relaxed) {
                std::thread::sleep(poll);
                let meta = file_meta(&path);
                if meta == last_meta || meta.is_none() {
                    // Unchanged — or gone (a swap-in-progress rename or a
                    // deleted checkpoint keeps the served model).
                    continue;
                }
                last_meta = meta;
                let current = slot.get();
                match ServedModel::load(&path, col_blocks, current.generation + 1) {
                    Ok(m) if m.fingerprint == current.fingerprint => {} // touch, not a new model
                    Ok(m) => {
                        eprintln!(
                            "dsfacto serve: reloaded {} (generation {}, fingerprint {:016x})",
                            path.display(),
                            m.generation,
                            m.fingerprint
                        );
                        slot.install(m);
                    }
                    Err(e) => {
                        eprintln!(
                            "dsfacto serve: keeping generation {} — reload of {} failed: {e:#}",
                            current.generation,
                            path.display()
                        );
                    }
                }
            }
        })
        .expect("spawn reload watcher")
}

fn file_meta(path: &Path) -> Option<(u64, SystemTime)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.len(), meta.modified().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn model(seed: u64) -> FmModel {
        let mut rng = Pcg64::seeded(seed);
        let mut m = FmModel::init(9, 3, 0.2, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.4);
        }
        m.w0 = -0.5;
        m
    }

    #[test]
    fn blocked_and_fused_served_scores_are_bitwise_equal() {
        let m = model(5);
        let rows: Vec<(Vec<u32>, Vec<f32>)> = vec![
            (vec![0, 4, 8], vec![1.0, -2.0, 0.5]),
            (vec![], vec![]),
            (vec![2, 3], vec![0.25, 4.0]),
        ];
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (idx, val) in &rows {
            indices.extend_from_slice(idx);
            values.extend_from_slice(val);
            indptr.push(indices.len());
        }
        let fused = ServedModel::build(&m, 1, 1, 7);
        let mut want = vec![0f32; rows.len()];
        fused.score_rows(&indptr, &indices, &values, &mut want, &mut ServeScratch::new());
        for blocks in [2usize, 3, 9, 50] {
            let served = ServedModel::build(&m, blocks, 1, 7);
            assert_eq!(served.col_blocks, blocks.min(9));
            let mut got = vec![0f32; rows.len()];
            served.score_rows(&indptr, &indices, &values, &mut got, &mut ServeScratch::new());
            assert_eq!(got, want, "blocks={blocks}");
        }
    }

    #[test]
    fn slot_swaps_without_disturbing_cached_handles() {
        let slot = ModelSlot::new(ServedModel::build(&model(1), 1, 1, 111));
        let mut cached = slot.get();
        let mut gen = cached.generation;
        assert_eq!(gen, 1);
        slot.refresh(&mut cached, &mut gen);
        assert_eq!(gen, 1, "no swap, no movement");

        slot.install(ServedModel::build(&model(2), 1, 2, 222));
        // The cached handle still scores generation 1 until refreshed.
        assert_eq!(cached.fingerprint, 111);
        slot.refresh(&mut cached, &mut gen);
        assert_eq!((gen, cached.fingerprint), (2, 222));
    }

    #[test]
    fn watcher_swaps_on_change_and_survives_corrupt_push() {
        let dir = std::env::temp_dir().join("dsfacto_serve_watcher_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("model.dsfm");
        fm_io::save(&model(1), &path).unwrap();
        let first = ServedModel::load(&path, 1, 1).unwrap();
        let fp1 = first.fingerprint;
        let slot = Arc::new(ModelSlot::new(first));
        let down = Arc::new(AtomicBool::new(false));
        let watcher = spawn_watcher(
            path.clone(),
            1,
            Duration::from_millis(10),
            Arc::clone(&slot),
            Arc::clone(&down),
        );

        let wait_for = |pred: &dyn Fn() -> bool, what: &str| {
            for _ in 0..500 {
                if pred() {
                    return;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            panic!("timed out waiting for {what}");
        };

        // A real new checkpoint swaps in as generation 2.
        fm_io::save(&model(2), &path).unwrap();
        wait_for(&|| slot.generation() == 2, "generation 2");
        assert_ne!(slot.get().fingerprint, fp1);
        let fp2 = slot.get().fingerprint;

        // A corrupt push is ignored: generation and fingerprint hold.
        std::fs::write(&path, b"NOPE not a model").unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(slot.generation(), 2);
        assert_eq!(slot.get().fingerprint, fp2);

        // And a subsequent good push still lands (generation 3).
        fm_io::save(&model(3), &path).unwrap();
        wait_for(&|| slot.generation() == 3, "generation 3");

        down.store(true, Ordering::SeqCst);
        watcher.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
