//! The scoring server: accept loop, per-connection micro-batching request
//! loop, and the [`ServeHandle`] that owns the whole thing.
//!
//! ## Hot-path shape
//!
//! Each connection gets one thread and one set of grow-only arenas
//! ([`RowStaging`] for inbound rows, [`ServeScratch`] for the kernel
//! accumulators, reused `Vec<u8>`s for frames). After warmup — once the
//! largest batch a connection will ever see has been staged once — a
//! request costs **zero heap allocation**: decode appends into staging,
//! scoring runs through the borrowed-scratch kernels, responses are
//! assembled into a reused write buffer. The e2e suite pins this by
//! sampling the arena watermarks over a steady load.
//!
//! ## Micro-batching
//!
//! A batch opens with the first request frame and keeps gathering while
//! (a) fewer than `max_batch` requests are staged and (b) the next frame
//! arrives within `batch_window`. Pipelined clients therefore amortize
//! one fused [`ServedModel::score_rows`] sweep (and one model-staleness
//! check, and one socket write) over many requests; a lone synchronous
//! client pays at most one `batch_window` of extra latency. Batched and
//! unbatched scores are bitwise identical — scoring is row-independent —
//! which the e2e suite also pins.
//!
//! The model handle is refreshed from the [`ModelSlot`] once per batch,
//! *before* any of the batch's rows are validated, so a request's width
//! check and its scoring always see the same model even across a hot
//! swap (see [`super::model`] for the swap contract).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::frames::{self, RowStaging, ServerStats, MAX_FRAME};
use super::model::{spawn_watcher, ModelSlot, ServeScratch, ServedModel};

/// Everything `dsfacto serve` needs to come up.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Listen address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Checkpoint to serve (and to watch for hot reloads).
    pub model_path: PathBuf,
    /// Column blocks to slice the factor matrix into (1 = the fused
    /// kernel; >1 = the block-wise sweep, bitwise-identical scores).
    pub col_blocks: usize,
    /// Most requests gathered into one scoring batch.
    pub max_batch: usize,
    /// How long a non-empty batch waits for the next pipelined request.
    pub batch_window: Duration,
    /// Checkpoint poll period for hot reload.
    pub reload_poll: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".into(),
            model_path: PathBuf::new(),
            col_blocks: 1,
            max_batch: 64,
            batch_window: Duration::from_micros(100),
            reload_poll: Duration::from_millis(200),
        }
    }
}

#[derive(Default)]
pub(crate) struct Counters {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
}

/// A running scoring server. Dropping it (or calling
/// [`shutdown`](ServeHandle::shutdown)) stops the acceptor, the reload
/// watcher and every connection thread.
pub struct ServeHandle {
    addr: SocketAddr,
    slot: Arc<ModelSlot>,
    counters: Arc<Counters>,
    down: Arc<AtomicBool>,
    threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ServeHandle {
    /// The bound listen address (resolves `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live model generation (1 = initial load).
    pub fn generation(&self) -> u64 {
        self.slot.generation()
    }

    /// Requests answered so far.
    pub fn requests(&self) -> u64 {
        self.counters.requests.load(Ordering::Relaxed)
    }

    /// Stops everything and joins the threads.
    pub fn shutdown(&self) {
        self.down.store(true, Ordering::SeqCst);
        // Join outside the lock: the acceptor pushes new connection
        // handles under it, so holding it across `join` would deadlock
        // against a connection accepted during shutdown.
        loop {
            let drained: Vec<_> = {
                let mut threads = self.threads.lock().unwrap();
                threads.drain(..).collect()
            };
            if drained.is_empty() {
                break;
            }
            for h in drained {
                let _ = h.join();
            }
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Loads the checkpoint, binds the listener and spawns the acceptor and
/// the reload watcher. Returns once the server is accepting.
pub fn serve(opts: &ServeOptions) -> Result<ServeHandle> {
    let initial = ServedModel::load(&opts.model_path, opts.col_blocks, 1)
        .with_context(|| format!("load model {}", opts.model_path.display()))?;
    eprintln!(
        "dsfacto serve: model d={} k={} col_blocks={} fingerprint={:016x}",
        initial.d, initial.k, initial.col_blocks, initial.fingerprint
    );
    let slot = Arc::new(ModelSlot::new(initial));
    let counters = Arc::new(Counters::default());
    let down = Arc::new(AtomicBool::new(false));
    let threads = Arc::new(Mutex::new(Vec::new()));

    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("bind {}", opts.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let watcher = spawn_watcher(
        opts.model_path.clone(),
        opts.col_blocks,
        opts.reload_poll,
        Arc::clone(&slot),
        Arc::clone(&down),
    );
    threads.lock().unwrap().push(watcher);

    let acceptor = {
        let slot = Arc::clone(&slot);
        let counters = Arc::clone(&counters);
        let down = Arc::clone(&down);
        let threads = Arc::clone(&threads);
        let conn_opts = ConnOptions {
            max_batch: opts.max_batch.max(1),
            batch_window: opts.batch_window,
        };
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || {
                let mut conn_id = 0u64;
                while !down.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            conn_id += 1;
                            stream.set_nodelay(true).ok();
                            let slot = Arc::clone(&slot);
                            let counters = Arc::clone(&counters);
                            let down = Arc::clone(&down);
                            let h = std::thread::Builder::new()
                                .name(format!("serve-conn-{conn_id}"))
                                .spawn(move || {
                                    connection_loop(stream, slot, counters, down, conn_opts)
                                })
                                .expect("spawn connection thread");
                            threads.lock().unwrap().push(h);
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn acceptor")?
    };
    threads.lock().unwrap().push(acceptor);

    Ok(ServeHandle {
        addr,
        slot,
        counters,
        down,
        threads,
    })
}

#[derive(Debug, Clone, Copy)]
struct ConnOptions {
    max_batch: usize,
    batch_window: Duration,
}

/// A reply owed for one inbound frame, in arrival order.
enum Pending {
    /// `(req_id, first_row, n_rows)` — scores come from the batch output.
    Scores(u64, usize, usize),
    /// A request rejected at validation; the connection survives.
    Error(u64, String),
    /// Stats snapshot taken at flush time.
    Stats,
}

/// Idle read timeout: bounds how long a blocked read can ignore `down`.
const IDLE_TICK: Duration = Duration::from_millis(50);

fn connection_loop(
    mut stream: TcpStream,
    slot: Arc<ModelSlot>,
    counters: Arc<Counters>,
    down: Arc<AtomicBool>,
    opts: ConnOptions,
) {
    if stream.set_read_timeout(Some(IDLE_TICK)).is_err() {
        return;
    }
    // Connection-lifetime state: every buffer below is grow-only, so the
    // steady state allocates nothing.
    let mut model = slot.get();
    let mut model_gen = model.generation;
    let mut staging = RowStaging::new();
    let mut scratch = ServeScratch::new();
    let mut scores: Vec<f32> = Vec::new();
    let mut frame: Vec<u8> = Vec::new();
    let mut body: Vec<u8> = Vec::new();
    let mut outbuf: Vec<u8> = Vec::new();
    let mut pending: Vec<Pending> = Vec::new();

    'conn: loop {
        // Wait for the frame that opens a batch.
        match read_frame(&mut stream, &mut frame, &down, None) {
            ReadOutcome::Frame => {}
            ReadOutcome::Idle => continue,
            ReadOutcome::Closed => break,
        }
        // One model handle per batch: validation and scoring agree on d
        // even across a hot swap; in-flight batches are never retargeted.
        slot.refresh(&mut model, &mut model_gen);
        staging.clear();
        pending.clear();

        // Gather: stage frames until the batch is full or the window
        // closes. The short read timeout makes the window precise.
        let mut gathering = true;
        while gathering {
            match handle_frame(&frame, &model, &mut staging, &mut pending) {
                FrameAction::Continue => {}
                FrameAction::Flush => break,
                FrameAction::Fatal => break 'conn,
            }
            if pending.len() >= opts.max_batch {
                break;
            }
            if stream.set_read_timeout(Some(opts.batch_window.max(Duration::from_micros(1)))).is_err() {
                break 'conn;
            }
            let deadline = Instant::now() + opts.batch_window;
            match read_frame(&mut stream, &mut frame, &down, Some(deadline)) {
                ReadOutcome::Frame => {}
                ReadOutcome::Idle => gathering = false,
                ReadOutcome::Closed => {
                    // Flush what we have, then close.
                    gathering = false;
                    down_after_flush(&mut stream);
                }
            }
        }
        if stream.set_read_timeout(Some(IDLE_TICK)).is_err() {
            break;
        }

        // Score the whole staged batch in one sweep.
        let n = staging.n_rows();
        if scores.len() < n {
            scores.resize(n, 0.0);
        }
        if n > 0 {
            model.score_rows(
                &staging.indptr,
                &staging.indices,
                &staging.values,
                &mut scores[..n],
                &mut scratch,
            );
            counters.batches.fetch_add(1, Ordering::Relaxed);
            counters.rows.fetch_add(n as u64, Ordering::Relaxed);
        }

        // Reply in arrival order, one buffered write for the whole batch.
        outbuf.clear();
        for p in &pending {
            match p {
                Pending::Scores(req_id, first, rows) => {
                    counters.requests.fetch_add(1, Ordering::Relaxed);
                    frames::encode_score_response(*req_id, &scores[*first..*first + *rows], &mut body);
                }
                Pending::Error(req_id, msg) => {
                    frames::encode_error(*req_id, msg, &mut body);
                }
                Pending::Stats => {
                    let stats = ServerStats {
                        d: model.d as u64,
                        k: model.k as u64,
                        generation: model.generation,
                        fingerprint: model.fingerprint,
                        col_blocks: model.col_blocks as u32,
                        staging_capacity: staging.capacity() as u64,
                        scratch_capacity: scratch.capacity() as u64,
                        requests: counters.requests.load(Ordering::Relaxed),
                        rows: counters.rows.load(Ordering::Relaxed),
                        batches: counters.batches.load(Ordering::Relaxed),
                    };
                    frames::encode_stats_response(&stats, &mut body);
                }
            }
            outbuf.extend_from_slice(&(body.len() as u32).to_le_bytes());
            outbuf.extend_from_slice(&body);
        }
        if !outbuf.is_empty() && stream.write_all(&outbuf).is_err() {
            break;
        }
    }
}

enum FrameAction {
    Continue,
    Flush,
    Fatal,
}

/// Classifies and stages one inbound frame. Request-level problems
/// (invalid rows) become [`Pending::Error`] replies; protocol-level
/// problems (wrong magic, unknown kind, garbled header) are fatal for the
/// connection, since the stream can no longer be trusted.
fn handle_frame(
    frame: &[u8],
    model: &ServedModel,
    staging: &mut RowStaging,
    pending: &mut Vec<Pending>,
) -> FrameAction {
    let (kind, reader) = match frames::frame_kind(frame) {
        Ok(k) => k,
        Err(_) => return FrameAction::Fatal,
    };
    match kind {
        frames::KIND_SCORE_REQUEST => {
            // Peek the request id off a reader clone so a row-validation
            // failure can still name the request in its error frame.
            let req_id = match reader.clone().u64() {
                Ok(id) => id,
                Err(_) => return FrameAction::Fatal,
            };
            match frames::decode_score_request_into(reader, model.d, staging) {
                Ok((id, n_rows)) => {
                    pending.push(Pending::Scores(id, staging.n_rows() - n_rows, n_rows));
                    FrameAction::Continue
                }
                Err(e) => {
                    pending.push(Pending::Error(req_id, format!("{e:#}")));
                    FrameAction::Continue
                }
            }
        }
        frames::KIND_STATS_REQUEST => {
            // Stats flush the batch: the snapshot must reflect every
            // request that arrived before it.
            pending.push(Pending::Stats);
            FrameAction::Flush
        }
        _ => FrameAction::Fatal,
    }
}

/// Marks the stream so the post-flush read discovers the close: shutting
/// down our read half makes the next `read` return `Ok(0)`.
fn down_after_flush(stream: &mut TcpStream) {
    stream.shutdown(std::net::Shutdown::Read).ok();
}

enum ReadOutcome {
    Frame,
    /// No frame *started* before the deadline (or, with no deadline, one
    /// idle tick elapsed) — distinguishable from `Closed` so the batcher
    /// can flush and keep the connection.
    Idle,
    Closed,
}

/// Reads one length-prefixed frame into `frame`. With a deadline, gives
/// up (`Idle`) only between frames — a frame whose first byte arrived is
/// always read to completion. Tolerates `WouldBlock`/`TimedOut` from the
/// socket's read timeout; polls `down` throughout.
fn read_frame(
    stream: &mut TcpStream,
    frame: &mut Vec<u8>,
    down: &AtomicBool,
    deadline: Option<Instant>,
) -> ReadOutcome {
    let mut len_buf = [0u8; 4];
    let mut off = 0usize;
    while off < 4 {
        if down.load(Ordering::Relaxed) {
            return ReadOutcome::Closed;
        }
        match stream.read(&mut len_buf[off..]) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => off += n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if off == 0 {
                    match deadline {
                        Some(d) if Instant::now() >= d => return ReadOutcome::Idle,
                        Some(_) => {}
                        None => return ReadOutcome::Idle,
                    }
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Closed,
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return ReadOutcome::Closed; // corrupt stream
    }
    frame.resize(len, 0);
    let mut read = 0usize;
    while read < len {
        if down.load(Ordering::Relaxed) {
            return ReadOutcome::Closed;
        }
        match stream.read(&mut frame[read..]) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => read += n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Closed,
        }
    }
    ReadOutcome::Frame
}
