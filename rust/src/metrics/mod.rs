//! Evaluation metrics (test RMSE / accuracy / logloss / AUC — the Fig. 5
//! quantities) and the convergence trace record shared by all trainers.

use crate::data::{Dataset, Task};
use crate::fm::{loss, FmModel};

/// One point of a convergence trace (a row of Fig 4/5's series).
#[derive(Debug, Clone, PartialEq)]
pub struct TracePoint {
    /// Outer iteration (epoch) index, 0 = before training.
    pub iter: usize,
    /// Wall-clock seconds since training started.
    pub secs: f64,
    /// Regularized training objective (paper eq. 5).
    pub objective: f64,
    /// Mean training data loss (no regularizer).
    pub train_loss: f64,
    /// Held-out metrics, when a test set was provided.
    pub test: Option<EvalMetrics>,
}

/// Held-out evaluation results.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalMetrics {
    /// Mean data loss on the eval set.
    pub loss: f64,
    /// RMSE (regression) — NaN for classification.
    pub rmse: f64,
    /// Accuracy in [0,1] (classification) — NaN for regression.
    pub accuracy: f64,
    /// ROC AUC (classification) — NaN for regression.
    pub auc: f64,
}

impl EvalMetrics {
    /// The paper's Fig. 5 headline metric for the task: RMSE or accuracy.
    pub fn headline(&self, task: Task) -> f64 {
        match task {
            Task::Regression => self.rmse,
            Task::Classification => self.accuracy,
        }
    }
}

/// Evaluates a model on a dataset by scoring every row (Rust scorer).
///
/// The `coordinator::Evaluator` offers the same computation through the
/// AOT XLA artifact; integration tests assert they agree.
pub fn evaluate(model: &FmModel, ds: &Dataset) -> EvalMetrics {
    let kern = crate::kernel::FmKernel::from_model(model);
    let mut scratch = crate::kernel::Scratch::for_k(model.k);
    let mut scores = vec![0f32; ds.n()];
    kern.score_batch(&ds.rows, &mut scores, &mut scratch);
    evaluate_scores(&scores, &ds.labels, ds.task)
}

/// Metrics from precomputed scores (used by the XLA evaluation path too).
pub fn evaluate_scores(scores: &[f32], labels: &[f32], task: Task) -> EvalMetrics {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len().max(1) as f64;
    let mean_loss = scores
        .iter()
        .zip(labels)
        .map(|(&f, &y)| loss::loss(f, y, task) as f64)
        .sum::<f64>()
        / n;
    match task {
        Task::Regression => {
            let mse = scores
                .iter()
                .zip(labels)
                .map(|(&f, &y)| ((f - y) as f64).powi(2))
                .sum::<f64>()
                / n;
            EvalMetrics {
                loss: mean_loss,
                rmse: mse.sqrt(),
                accuracy: f64::NAN,
                auc: f64::NAN,
            }
        }
        Task::Classification => {
            let correct = scores
                .iter()
                .zip(labels)
                .filter(|&(&f, &y)| (f >= 0.0) == (y > 0.0))
                .count();
            EvalMetrics {
                loss: mean_loss,
                rmse: f64::NAN,
                accuracy: correct as f64 / n,
                auc: roc_auc(scores, labels),
            }
        }
    }
}

/// ROC AUC via the rank-sum (Mann-Whitney) formulation, ties averaged.
pub fn roc_auc(scores: &[f32], labels: &[f32]) -> f64 {
    let n = scores.len();
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return f64::NAN;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // Average ranks over tied score groups.
    let mut rank = vec![0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // ranks are 1-based
        for p in i..=j {
            rank[order[p]] = avg;
        }
        i = j + 1;
    }
    let rank_sum_pos: f64 = labels
        .iter()
        .enumerate()
        .filter(|(_, &y)| y > 0.0)
        .map(|(i, _)| rank[i])
        .sum();
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

/// The result every trainer returns: final model + convergence trace.
///
/// Trace recording itself lives in the session layer
/// ([`crate::train::Probe`] computes the points; observers consume them).
#[derive(Debug, Clone)]
pub struct TrainOutput {
    pub model: FmModel,
    pub trace: Vec<TracePoint>,
    /// Total wall-clock training seconds (excludes evaluation time).
    pub wall_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Csr;

    fn ds(task: Task, labels: Vec<f32>) -> Dataset {
        let n = labels.len();
        let rows = Csr::from_triplets(
            n,
            2,
            &(0..n).map(|i| (i, 0, i as f32)).collect::<Vec<_>>(),
        );
        Dataset {
            name: "m".into(),
            task,
            rows,
            labels,
        }
    }

    #[test]
    fn regression_rmse() {
        let m = evaluate_scores(&[1.0, 2.0, 3.0], &[1.0, 2.0, 5.0], Task::Regression);
        assert!((m.rmse - (4.0f64 / 3.0).sqrt()).abs() < 1e-9);
        assert!(m.accuracy.is_nan());
    }

    #[test]
    fn classification_accuracy() {
        let m = evaluate_scores(&[0.5, -0.5, 0.5, -0.5], &[1.0, -1.0, -1.0, 1.0], Task::Classification);
        assert_eq!(m.accuracy, 0.5);
        assert!(m.rmse.is_nan());
    }

    #[test]
    fn auc_perfect_and_random() {
        let labels = [1.0f32, 1.0, -1.0, -1.0];
        assert_eq!(roc_auc(&[4.0, 3.0, 2.0, 1.0], &labels), 1.0);
        assert_eq!(roc_auc(&[1.0, 2.0, 3.0, 4.0], &labels), 0.0);
        // All-tied scores give AUC 0.5.
        assert_eq!(roc_auc(&[1.0, 1.0, 1.0, 1.0], &labels), 0.5);
    }

    #[test]
    fn auc_degenerate_is_nan() {
        assert!(roc_auc(&[1.0, 2.0], &[1.0, 1.0]).is_nan());
    }

    #[test]
    fn evaluate_uses_model_scores() {
        let data = ds(Task::Regression, vec![0.0, 1.0, 2.0]);
        let mut model = FmModel::zeros(2, 2);
        model.w[0] = 1.0; // f(x_i) = i
        let m = evaluate(&model, &data);
        assert!(m.rmse < 1e-6, "rmse {}", m.rmse);
    }

    #[test]
    fn headline_selects_by_task() {
        let m = EvalMetrics {
            loss: 0.0,
            rmse: 1.5,
            accuracy: 0.9,
            auc: 0.8,
        };
        assert_eq!(m.headline(Task::Regression), 1.5);
        assert_eq!(m.headline(Task::Classification), 0.9);
    }
}
