//! The canonical per-example FM SGD update (paper eqs. 11-13) and a
//! DiFacto-style AdaGrad state.
//!
//! This is the update rule every trainer in the repo shares: the libFM
//! baseline applies it over all dimensions of a sampled example; the NOMAD
//! engine applies the *same arithmetic* restricted to the dimension a token
//! carries, with cached auxiliary variables standing in for the fresh
//! synchronization terms.

use crate::data::Task;
use crate::fm::{loss, FmModel};

/// Applies eqs. 11-13 for one example over all its non-zeros; returns the
/// example's (pre-update) loss.
///
/// Buffers `a` and `s2` (length K each) are caller-provided scratch for
/// the factor sums so the loop stays allocation-free.
///
/// This is the *scalar reference* implementation of the update: trainers
/// run the fused lane-blocked
/// [`FmKernel::score_grad_step`](crate::kernel::FmKernel::score_grad_step)
/// instead, and the property suite in `rust/tests/kernel_properties.rs`
/// holds the two to parity.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn sgd_update_example(
    model: &mut FmModel,
    idx: &[u32],
    val: &[f32],
    y: f32,
    task: Task,
    eta: f32,
    lambda_w: f32,
    lambda_v: f32,
    a: &mut [f32],
    s2: &mut [f32],
) -> f32 {
    debug_assert_eq!(a.len(), model.k);
    let f = model.score_with_sums(idx, val, a, s2);
    let g = loss::multiplier(f, y, task);
    let l = loss::loss(f, y, task);

    // eq. 11 (stochastic form): w0 <- w0 - eta * G_i
    model.w0 -= eta * g;
    let k = model.k;
    for (j, x) in idx.iter().zip(val) {
        let j = *j as usize;
        let x = *x;
        // eq. 12: w_j <- w_j - eta (G_i x_ij + lambda_w w_j)
        let wj = &mut model.w[j];
        *wj -= eta * (g * x + lambda_w * *wj);
        // eq. 13: v_jk <- v_jk - eta (G_i (x_ij a_ik - v_jk x_ij^2) + lambda_v v_jk)
        let x2 = x * x;
        let vj = &mut model.v[j * k..(j + 1) * k];
        for kk in 0..k {
            let vjk = vj[kk];
            vj[kk] = vjk - eta * (g * (x * a[kk] - vjk * x2) + lambda_v * vjk);
        }
    }
    l
}

/// Per-coordinate AdaGrad accumulators (DiFacto-style adaptivity).
#[derive(Debug, Clone)]
pub struct AdaGradState {
    /// Accumulated squared gradients for w (length D).
    pub gw2: Vec<f32>,
    /// Accumulated squared gradients for V (length D*K).
    pub gv2: Vec<f32>,
    /// Accumulated squared gradient for w0.
    pub g02: f32,
    /// Numerical floor.
    pub eps: f32,
    /// Internal scratch for the squared factor sums (keeps
    /// [`update_example`](AdaGradState::update_example) allocation-free
    /// without widening its signature).
    s2: Vec<f32>,
}

impl AdaGradState {
    /// Fresh state for a d x k model.
    pub fn new(d: usize, k: usize) -> Self {
        AdaGradState {
            gw2: vec![0.0; d],
            gv2: vec![0.0; d * k],
            g02: 0.0,
            eps: 1e-8,
            s2: vec![0.0; k],
        }
    }

    /// AdaGrad variant of [`sgd_update_example`]; returns the example loss.
    #[allow(clippy::too_many_arguments)]
    pub fn update_example(
        &mut self,
        model: &mut FmModel,
        idx: &[u32],
        val: &[f32],
        y: f32,
        task: Task,
        eta: f32,
        lambda_w: f32,
        lambda_v: f32,
        a: &mut [f32],
    ) -> f32 {
        debug_assert_eq!(self.s2.len(), model.k);
        let f = model.score_with_sums(idx, val, a, &mut self.s2);
        let g = loss::multiplier(f, y, task);
        let l = loss::loss(f, y, task);

        self.g02 += g * g;
        model.w0 -= eta * g / (self.g02.sqrt() + self.eps);

        let k = model.k;
        for (j, x) in idx.iter().zip(val) {
            let j = *j as usize;
            let x = *x;
            let gw = g * x + lambda_w * model.w[j];
            self.gw2[j] += gw * gw;
            model.w[j] -= eta * gw / (self.gw2[j].sqrt() + self.eps);

            let x2 = x * x;
            for kk in 0..k {
                let p = j * k + kk;
                let vjk = model.v[p];
                let gv = g * (x * a[kk] - vjk * x2) + lambda_v * vjk;
                self.gv2[p] += gv * gv;
                model.v[p] -= eta * gv / (self.gv2[p].sqrt() + self.eps);
            }
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::prop::forall_res;
    use crate::util::rng::Pcg64;

    /// Finite-difference check of the *full-dimension* stochastic gradient
    /// implied by the update (eta -> 0 limit).
    #[test]
    fn update_direction_matches_finite_differences() {
        let mut rng = Pcg64::seeded(1);
        let d = 6;
        let k = 3;
        let mut m = FmModel::init(d, k, 0.2, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.3);
        }
        let idx = [0u32, 2, 5];
        let val = [0.7f32, -1.2, 0.4];
        let y = 1.0f32;
        let task = Task::Classification;

        // Analytic gradient from the update with eta=1, lambda=0:
        // delta = -(grad), so grad = old - new.
        let mut m2 = m.clone();
        let mut a = vec![0f32; k];
        let mut s2 = vec![0f32; k];
        sgd_update_example(&mut m2, &idx, &val, y, task, 1.0, 0.0, 0.0, &mut a, &mut s2);
        // NOTE: eq. 13 uses a_ik computed *before* the update, and w updates
        // before v — the per-coordinate updates are simultaneous in the
        // analytic gradient, matching this implementation.
        let eps = 1e-3f32;
        let loss_of = |m: &FmModel| loss::loss(m.score_sparse(&idx, &val), y, task);
        // check w gradient at j=2
        let j = 2usize;
        let mut mp = m.clone();
        mp.w[j] += eps;
        let mut mm = m.clone();
        mm.w[j] -= eps;
        let num = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
        let ana = m.w[j] - m2.w[j];
        assert!((num - ana).abs() < 5e-3, "w: {num} vs {ana}");
        // check v gradient at (j=5, k=1)
        let p = 5 * k + 1;
        let mut mp = m.clone();
        mp.v[p] += eps;
        let mut mm = m.clone();
        mm.v[p] -= eps;
        let num = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
        let ana = m.v[p] - m2.v[p];
        assert!((num - ana).abs() < 5e-3, "v: {num} vs {ana}");
        // w0
        let mut mp = m.clone();
        mp.w0 += eps;
        let mut mm = m.clone();
        mm.w0 -= eps;
        let num = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
        let ana = m.w0 - m2.w0;
        assert!((num - ana).abs() < 5e-3, "w0: {num} vs {ana}");
    }

    #[test]
    fn prop_small_step_decreases_example_loss() {
        forall_res(
            "one sgd step decreases the sampled example's loss",
            48,
            |rng| {
                let d = 2 + rng.below_usize(10);
                let k = 1 + rng.below_usize(6);
                let mut m = FmModel::init(d, k, 0.2, rng);
                for x in m.w.iter_mut() {
                    *x = rng.normal32(0.0, 0.3);
                }
                let nnz = 1 + rng.below_usize(d);
                let mut idx: Vec<u32> = rng
                    .sample_indices(d, nnz)
                    .into_iter()
                    .map(|c| c as u32)
                    .collect();
                idx.sort_unstable();
                let val: Vec<f32> = idx.iter().map(|_| rng.normal32(0.0, 1.0)).collect();
                let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
                (m, idx, val, y)
            },
            |(m, idx, val, y)| {
                let task = Task::Classification;
                let mut m2 = m.clone();
                let mut a = vec![0f32; m.k];
                let mut s2 = vec![0f32; m.k];
                let before = sgd_update_example(
                    &mut m2, idx, val, *y, task, 1e-3, 0.0, 0.0, &mut a, &mut s2,
                );
                let after = loss::loss(m2.score_sparse(idx, val), *y, task);
                // Small-eta descent on a smooth loss must not increase it
                // (allow fp slack for near-zero gradients).
                if after <= before + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("loss rose {before} -> {after}"))
                }
            },
        );
    }

    #[test]
    fn sgd_epochs_reduce_dataset_objective() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        let mut rng = Pcg64::seeded(4);
        let mut m = FmModel::init(ds.d(), 4, 0.01, &mut rng);
        let (lw, lv) = (1e-4, 1e-4);
        let before = m.objective(&ds, lw, lv);
        let mut a = vec![0f32; 4];
        let mut s2 = vec![0f32; 4];
        for _epoch in 0..5 {
            for i in 0..ds.n() {
                let (idx, val) = ds.rows.row(i);
                sgd_update_example(
                    &mut m, idx, val, ds.labels[i], ds.task, 0.01, lw, lv, &mut a, &mut s2,
                );
            }
        }
        let after = m.objective(&ds, lw, lv);
        assert!(
            after < 0.7 * before,
            "objective did not drop: {before} -> {after}"
        );
    }

    #[test]
    fn adagrad_also_converges() {
        let ds = synth::table2_dataset("housing", 5).unwrap();
        let mut rng = Pcg64::seeded(6);
        let mut m = FmModel::init(ds.d(), 4, 0.01, &mut rng);
        let mut st = AdaGradState::new(ds.d(), 4);
        let before = m.objective(&ds, 0.0, 0.0);
        let mut a = vec![0f32; 4];
        for _ in 0..5 {
            for i in 0..ds.n() {
                let (idx, val) = ds.rows.row(i);
                st.update_example(&mut m, idx, val, ds.labels[i], ds.task, 0.1, 0.0, 0.0, &mut a);
            }
        }
        let after = m.objective(&ds, 0.0, 0.0);
        assert!(after < 0.7 * before, "{before} -> {after}");
    }

    #[test]
    fn regularization_shrinks_parameters() {
        let mut rng = Pcg64::seeded(7);
        let mut m = FmModel::init(4, 2, 0.5, &mut rng);
        for x in m.w.iter_mut() {
            *x = 1.0;
        }
        let idx = [0u32, 1, 2, 3];
        let val = [0.0f32; 4]; // zero features: only the regularizer acts on w/V
        let mut a = vec![0f32; 2];
        let mut s2 = vec![0f32; 2];
        let w_norm0: f32 = m.w.iter().map(|x| x * x).sum();
        sgd_update_example(
            &mut m, &idx, &val, 0.0, Task::Regression, 0.1, 0.5, 0.5, &mut a, &mut s2,
        );
        let w_norm1: f32 = m.w.iter().map(|x| x * x).sum();
        assert!(w_norm1 < w_norm0);
    }
}
