//! Learning-rate schedules.

/// Learning-rate schedule: eta(t) for outer iteration t.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Constant eta.
    Constant(f32),
    /// eta0 / (1 + decay * t) — the classic Robbins-Monro style decay.
    InvDecay { eta0: f32, decay: f32 },
    /// eta0 * gamma^t — exponential decay.
    Exponential { eta0: f32, gamma: f32 },
}

impl LrSchedule {
    /// The rate at outer iteration `t` (0-based).
    pub fn at(&self, t: usize) -> f32 {
        match *self {
            LrSchedule::Constant(eta) => eta,
            LrSchedule::InvDecay { eta0, decay } => eta0 / (1.0 + decay * t as f32),
            LrSchedule::Exponential { eta0, gamma } => eta0 * gamma.powi(t as i32),
        }
    }

    /// Parses `"constant:0.05"`, `"inv:0.1,0.01"`, `"exp:0.1,0.99"`.
    pub fn parse(s: &str) -> anyhow::Result<LrSchedule> {
        let (kind, rest) = s.split_once(':').unwrap_or(("constant", s));
        let nums: Vec<f32> = rest
            .split(',')
            .map(|x| x.trim().parse::<f32>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad schedule {s:?}: {e}"))?;
        match (kind, nums.as_slice()) {
            ("constant", [eta]) => Ok(LrSchedule::Constant(*eta)),
            ("inv", [eta0, decay]) => Ok(LrSchedule::InvDecay {
                eta0: *eta0,
                decay: *decay,
            }),
            ("exp", [eta0, gamma]) => Ok(LrSchedule::Exponential {
                eta0: *eta0,
                gamma: *gamma,
            }),
            _ => anyhow::bail!("bad schedule spec {s:?}"),
        }
    }
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule::Constant(0.02)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1000), 0.1);
    }

    #[test]
    fn inv_decay_decreases() {
        let s = LrSchedule::InvDecay {
            eta0: 1.0,
            decay: 1.0,
        };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(1), 0.5);
        assert_eq!(s.at(3), 0.25);
    }

    #[test]
    fn exponential_decreases() {
        let s = LrSchedule::Exponential {
            eta0: 1.0,
            gamma: 0.5,
        };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(2), 0.25);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(LrSchedule::parse("constant:0.2").unwrap(), LrSchedule::Constant(0.2));
        assert_eq!(LrSchedule::parse("0.2").unwrap(), LrSchedule::Constant(0.2));
        assert_eq!(
            LrSchedule::parse("inv:0.1,0.5").unwrap(),
            LrSchedule::InvDecay {
                eta0: 0.1,
                decay: 0.5
            }
        );
        assert!(LrSchedule::parse("warmup:1").is_err());
        assert!(LrSchedule::parse("inv:0.1").is_err());
    }
}
