//! Optimizer substrates: learning-rate schedules, the canonical
//! per-example FM SGD update (paper eqs. 11-13), and a DiFacto-style
//! AdaGrad variant (frequency-adaptive regularization, the extension the
//! related-work section calls out).

pub mod schedule;
pub mod sgd;

pub use schedule::LrSchedule;
pub use sgd::{sgd_update_example, AdaGradState};
