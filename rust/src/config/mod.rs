//! Experiment configuration: dataset specs, trainer selection, and a
//! key=value config-file format with CLI overrides.
//!
//! Config files look like:
//!
//! ```text
//! # fig4 diabetes run
//! dataset   = diabetes
//! trainer   = nomad
//! workers   = 4
//! outer_iters = 60
//! eta       = inv:0.05,0.05
//! lambda_w  = 1e-4
//! lambda_v  = 1e-4
//! k         = 4
//! seed      = 42
//! # DS-FACTO engine knobs (ignored by the other trainers):
//! transport = simnet:50us,1e9,2
//! update_mode = mean
//! cols_per_token = 0
//! ```
//!
//! [`TrainerKind::build`] (defined in [`crate::train`]) turns a parsed
//! config into a ready `Box<dyn Trainer>`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::cluster::codec::WirePrecision;
use crate::data::Task;
use crate::fm::FmHyper;
use crate::nomad::{TransportKind, UpdateMode};
use crate::optim::LrSchedule;
use crate::partition::RowStrategy;

/// Which training engine to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainerKind {
    /// DS-FACTO: the paper's hybrid-parallel NOMAD engine.
    Nomad,
    /// libFM-style single-machine SGD (the paper's baseline).
    Libfm,
    /// Synchronous DSGD (block-cyclic with barriers).
    Dsgd,
    /// Bulk-synchronous full-gradient descent.
    BulkSync,
    /// Dense-minibatch SGD through the AOT XLA `step` artifact.
    XlaDense,
}

impl TrainerKind {
    /// Parses the config spelling.
    pub fn parse(s: &str) -> Result<TrainerKind> {
        Ok(match s {
            "nomad" | "dsfacto" | "ds-facto" => TrainerKind::Nomad,
            "libfm" | "sgd" => TrainerKind::Libfm,
            "dsgd" => TrainerKind::Dsgd,
            "bulksync" | "gd" => TrainerKind::BulkSync,
            "xla" | "xla-dense" => TrainerKind::XlaDense,
            other => bail!("unknown trainer {other:?}"),
        })
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            TrainerKind::Nomad => "nomad",
            TrainerKind::Libfm => "libfm",
            TrainerKind::Dsgd => "dsgd",
            TrainerKind::BulkSync => "bulksync",
            TrainerKind::XlaDense => "xla-dense",
        }
    }

    /// All kinds, in canonical order (test/bench sweeps).
    pub fn all() -> [TrainerKind; 5] {
        [
            TrainerKind::Nomad,
            TrainerKind::Libfm,
            TrainerKind::Dsgd,
            TrainerKind::BulkSync,
            TrainerKind::XlaDense,
        ]
    }
}

/// Where a dataset comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetSpec {
    /// A synthetic Table-2 twin by name (diabetes|housing|ijcnn1|realsim).
    Table2(String),
    /// A LIBSVM file on disk.
    File {
        path: String,
        task: Task,
        n_features: Option<usize>,
    },
    /// A binary shard-cache directory written by `dsfacto ingest`
    /// ([`crate::data::libsvm::stream_ingest`]); config spelling
    /// `cache:<dir>`. Shape, task and name come from the manifest.
    Cache {
        dir: String,
    },
}

impl DatasetSpec {
    /// Loads / generates the dataset. File datasets are named by the file
    /// *stem* (not the full path), so `runtime::artifact_name_for` — and
    /// anything else keyed on the dataset name — stays stable no matter
    /// which directory the file lives in. Cache datasets materialize from
    /// the shard files and carry the name recorded at ingest.
    pub fn load(&self, seed: u64) -> Result<crate::data::Dataset> {
        match self {
            DatasetSpec::Table2(name) => crate::data::synth::table2_dataset(name, seed),
            DatasetSpec::File {
                path,
                task,
                n_features,
            } => {
                let name = Path::new(path)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or(path.as_str());
                crate::data::libsvm::load(path, name, *task, *n_features)
            }
            DatasetSpec::Cache { dir } => {
                use crate::data::DataSource;
                crate::data::ShardCacheSource::open(dir)?.materialize()
            }
        }
    }

    /// The dataset's display name: the Table-2 name, or a file/cache
    /// dataset's path.
    pub fn name(&self) -> &str {
        match self {
            DatasetSpec::Table2(name) => name,
            DatasetSpec::File { path, .. } => path,
            DatasetSpec::Cache { dir } => dir,
        }
    }

    /// The config spelling (the `dataset =` value), so
    /// [`ExperimentConfig::dump`] round-trips every variant.
    pub fn spec(&self) -> String {
        match self {
            DatasetSpec::Table2(name) => name.clone(),
            DatasetSpec::File { path, .. } => path.clone(),
            DatasetSpec::Cache { dir } => format!("cache:{dir}"),
        }
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub dataset: DatasetSpec,
    pub trainer: TrainerKind,
    /// FM hyper-parameters (k, lambdas, init).
    pub fm: FmHyper,
    /// Learning-rate schedule.
    pub eta: LrSchedule,
    /// Outer iterations (epochs for the sequential baselines).
    pub outer_iters: usize,
    /// Worker count for the distributed engines.
    pub workers: usize,
    /// Train fraction of the split.
    pub train_frac: f64,
    /// RNG seed (data generation, init, sampling).
    pub seed: u64,
    /// Evaluate the test set every `eval_every` outer iterations.
    pub eval_every: usize,
    /// Optional CSV trace output path.
    pub trace_path: Option<String>,
    /// Artifact directory for the XLA evaluation / dense trainer.
    pub artifacts_dir: String,
    /// Use the XLA scorer for held-out evaluation when artifacts exist.
    pub xla_eval: bool,
    /// Token medium for the DS-FACTO engine (`local`, `tcp`,
    /// `simnet[:LAT,BW,WPM]`).
    pub transport: TransportKind,
    /// Update-visit semantics for the DS-FACTO engine (`mean`,
    /// `stochastic[:N]`).
    pub update_mode: UpdateMode,
    /// Columns per circulating token for the DS-FACTO engine (0 = auto).
    pub cols_per_token: usize,
    /// Row-shard strategy for the distributed trainers (nomad, dsgd,
    /// bulksync): `contiguous` (equal row counts; the default) or
    /// `balanced` (equal per-shard nnz on row-skewed data).
    pub row_partition: RowStrategy,
    /// Shard-cache directory for the distributed trainers: when set, each
    /// worker loads its row shard from the cache's per-shard files (the
    /// out-of-core path) instead of slicing the in-memory training set.
    /// The cache must have been ingested for exactly the training rows
    /// and the same `row_partition`/`workers` plan.
    pub data_cache: Option<String>,
    /// Multi-process cluster role for `dsfacto driver` / `dsfacto worker`
    /// (`driver:<addr>,p=<P>` or `worker:<addr>`); `None` runs in-process.
    pub cluster: Option<crate::cluster::runtime::ClusterSpec>,
    /// Shared secret for cluster frame authentication: when set, every
    /// control and ring frame carries an HMAC-SHA256 tag and unkeyed or
    /// wrong-keyed peers are dropped. The driver strips this key from the
    /// config it ships to workers — each process takes the secret from
    /// its own command line or config file, never from the wire.
    pub cluster_secret: Option<String>,
    /// Numeric format of the token payloads on the cluster ring (`f32`,
    /// the exact default, or `bf16`, which halves the factor bytes per
    /// hop). Every process of a cluster must agree: workers declare
    /// theirs at `Join` and the driver rejects a mismatch. Like
    /// `cluster_secret`, this key is stripped from the config the driver
    /// ships — each process takes it from its own command line or file.
    pub wire_precision: WirePrecision,
    /// `dsfacto serve` listen address.
    pub serve_addr: String,
    /// Checkpoint `dsfacto serve` loads and watches (`--model` CLI
    /// override; required to serve).
    pub serve_model: Option<String>,
    /// Most requests the serving batcher gathers into one scoring sweep.
    pub serve_max_batch: usize,
    /// Micro-batch gather window in microseconds (0 disables batching).
    pub serve_batch_window_us: u64,
    /// Column blocks the served factor matrix is sliced into (1 = the
    /// fused kernel; >1 = block-wise sweep, bitwise-identical scores).
    pub serve_col_blocks: usize,
    /// Checkpoint hot-reload poll period in milliseconds.
    pub serve_reload_poll_ms: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: DatasetSpec::Table2("diabetes".into()),
            trainer: TrainerKind::Nomad,
            fm: FmHyper::default(),
            eta: LrSchedule::default(),
            outer_iters: 50,
            workers: 4,
            train_frac: 0.8,
            seed: 42,
            eval_every: 1,
            trace_path: None,
            artifacts_dir: "artifacts".into(),
            xla_eval: false,
            transport: TransportKind::Local,
            update_mode: UpdateMode::MeanGradient,
            cols_per_token: 0,
            row_partition: RowStrategy::Contiguous,
            data_cache: None,
            cluster: None,
            cluster_secret: None,
            wire_precision: WirePrecision::F32,
            serve_addr: "127.0.0.1:7878".into(),
            serve_model: None,
            serve_max_batch: 64,
            serve_batch_window_us: 100,
            serve_col_blocks: 1,
            serve_reload_poll_ms: 200,
        }
    }
}

impl ExperimentConfig {
    /// Applies one `key = value` assignment.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "dataset" => {
                self.dataset = if let Some(dir) = value.strip_prefix("cache:") {
                    DatasetSpec::Cache {
                        dir: dir.to_string(),
                    }
                } else if value.contains('/') || value.ends_with(".svm") {
                    DatasetSpec::File {
                        path: value.to_string(),
                        task: Task::Classification,
                        n_features: None,
                    }
                } else {
                    DatasetSpec::Table2(value.to_string())
                }
            }
            "dataset_task" => {
                if let DatasetSpec::File { task, .. } = &mut self.dataset {
                    *task = Task::parse(value)?;
                } else {
                    bail!("dataset_task only applies to file datasets");
                }
            }
            "trainer" => self.trainer = TrainerKind::parse(value)?,
            "k" => self.fm.k = value.parse().context("k")?,
            "lambda_w" => self.fm.lambda_w = value.parse().context("lambda_w")?,
            "lambda_v" => self.fm.lambda_v = value.parse().context("lambda_v")?,
            "init_std" => self.fm.init_std = value.parse().context("init_std")?,
            "eta" => self.eta = LrSchedule::parse(value)?,
            "outer_iters" => self.outer_iters = value.parse().context("outer_iters")?,
            "workers" => self.workers = value.parse().context("workers")?,
            "train_frac" => self.train_frac = value.parse().context("train_frac")?,
            "seed" => self.seed = value.parse().context("seed")?,
            "eval_every" => self.eval_every = value.parse().context("eval_every")?,
            "trace" => self.trace_path = Some(value.to_string()),
            "artifacts" => self.artifacts_dir = value.to_string(),
            "xla_eval" => self.xla_eval = value.parse().context("xla_eval")?,
            "transport" => self.transport = TransportKind::parse(value)?,
            "update_mode" => self.update_mode = UpdateMode::parse(value)?,
            "cols_per_token" => {
                self.cols_per_token = value.parse().context("cols_per_token")?
            }
            "row_partition" => self.row_partition = RowStrategy::parse(value)?,
            "data_cache" => self.data_cache = Some(value.to_string()),
            "cluster" => {
                self.cluster = Some(crate::cluster::runtime::ClusterSpec::parse(value)?)
            }
            "cluster_secret" => {
                ensure!(!value.is_empty(), "cluster_secret must be non-empty");
                self.cluster_secret = Some(value.to_string());
            }
            "wire_precision" => self.wire_precision = WirePrecision::parse(value)?,
            "serve_addr" => self.serve_addr = value.to_string(),
            "serve_model" => self.serve_model = Some(value.to_string()),
            "serve_max_batch" => {
                self.serve_max_batch = value.parse().context("serve_max_batch")?;
                ensure!(self.serve_max_batch >= 1, "serve_max_batch must be >= 1");
            }
            "serve_batch_window_us" => {
                self.serve_batch_window_us = value.parse().context("serve_batch_window_us")?
            }
            "serve_col_blocks" => {
                self.serve_col_blocks = value.parse().context("serve_col_blocks")?;
                ensure!(self.serve_col_blocks >= 1, "serve_col_blocks must be >= 1");
            }
            "serve_reload_poll_ms" => {
                self.serve_reload_poll_ms = value.parse().context("serve_reload_poll_ms")?;
                ensure!(
                    self.serve_reload_poll_ms >= 1,
                    "serve_reload_poll_ms must be >= 1"
                );
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Parses a config file body.
    pub fn parse_str(text: &str) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            cfg.set(key.trim(), value.trim())
                .with_context(|| format!("line {}", lineno + 1))?;
        }
        Ok(cfg)
    }

    /// Loads a config file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read config {}", path.as_ref().display()))?;
        Self::parse_str(&text)
    }

    /// Key=value dump (round-trips through [`parse_str`](Self::parse_str)).
    pub fn dump(&self) -> String {
        let mut kv: BTreeMap<&str, String> = BTreeMap::new();
        kv.insert("dataset", self.dataset.spec());
        if let DatasetSpec::File { task, .. } = &self.dataset {
            kv.insert("dataset_task", task.name().to_string());
        }
        kv.insert("trainer", self.trainer.name().to_string());
        kv.insert("k", self.fm.k.to_string());
        kv.insert("lambda_w", self.fm.lambda_w.to_string());
        kv.insert("lambda_v", self.fm.lambda_v.to_string());
        kv.insert("init_std", self.fm.init_std.to_string());
        kv.insert(
            "eta",
            match self.eta {
                LrSchedule::Constant(e) => format!("constant:{e}"),
                LrSchedule::InvDecay { eta0, decay } => format!("inv:{eta0},{decay}"),
                LrSchedule::Exponential { eta0, gamma } => format!("exp:{eta0},{gamma}"),
            },
        );
        kv.insert("outer_iters", self.outer_iters.to_string());
        kv.insert("workers", self.workers.to_string());
        kv.insert("train_frac", self.train_frac.to_string());
        kv.insert("seed", self.seed.to_string());
        kv.insert("eval_every", self.eval_every.to_string());
        kv.insert("artifacts", self.artifacts_dir.clone());
        kv.insert("xla_eval", self.xla_eval.to_string());
        kv.insert("transport", self.transport.spec());
        kv.insert("update_mode", self.update_mode.spec());
        kv.insert("cols_per_token", self.cols_per_token.to_string());
        kv.insert("row_partition", self.row_partition.spec().to_string());
        if let Some(dir) = &self.data_cache {
            kv.insert("data_cache", dir.clone());
        }
        if let Some(cluster) = &self.cluster {
            kv.insert("cluster", cluster.spec());
        }
        if let Some(secret) = &self.cluster_secret {
            kv.insert("cluster_secret", secret.clone());
        }
        if self.wire_precision != WirePrecision::F32 {
            kv.insert("wire_precision", self.wire_precision.name().to_string());
        }
        let serve_defaults = ExperimentConfig::default();
        if self.serve_addr != serve_defaults.serve_addr {
            kv.insert("serve_addr", self.serve_addr.clone());
        }
        if let Some(model) = &self.serve_model {
            kv.insert("serve_model", model.clone());
        }
        if self.serve_max_batch != serve_defaults.serve_max_batch {
            kv.insert("serve_max_batch", self.serve_max_batch.to_string());
        }
        if self.serve_batch_window_us != serve_defaults.serve_batch_window_us {
            kv.insert(
                "serve_batch_window_us",
                self.serve_batch_window_us.to_string(),
            );
        }
        if self.serve_col_blocks != serve_defaults.serve_col_blocks {
            kv.insert("serve_col_blocks", self.serve_col_blocks.to_string());
        }
        if self.serve_reload_poll_ms != serve_defaults.serve_reload_poll_ms {
            kv.insert("serve_reload_poll_ms", self.serve_reload_poll_ms.to_string());
        }
        kv.into_iter()
            .map(|(k, v)| format!("{k} = {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_config() {
        let cfg = ExperimentConfig::parse_str(
            "dataset = housing\ntrainer = libfm\nk = 8\neta = inv:0.1,0.01\nworkers=16\n",
        )
        .unwrap();
        assert_eq!(cfg.dataset, DatasetSpec::Table2("housing".into()));
        assert_eq!(cfg.trainer, TrainerKind::Libfm);
        assert_eq!(cfg.fm.k, 8);
        assert_eq!(cfg.workers, 16);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = ExperimentConfig::parse_str("# hi\n\nseed = 7 # trailing\n").unwrap();
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn unknown_key_errors_with_line() {
        let err = ExperimentConfig::parse_str("nope = 3\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
    }

    #[test]
    fn file_dataset_detected() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("dataset", "data/real.svm").unwrap();
        match &cfg.dataset {
            DatasetSpec::File { path, .. } => assert_eq!(path, "data/real.svm"),
            other => panic!("{other:?}"),
        }
        cfg.set("dataset_task", "regression").unwrap();
    }

    #[test]
    fn file_dataset_name_uses_stem() {
        // The *loaded* dataset is named by the file stem so artifact lookup
        // is independent of the directory the file came from.
        let dir = std::env::temp_dir().join("dsfacto_cfg_stem_test");
        let path = dir.join("housing.svm");
        let ds = crate::data::synth::table2_dataset("housing", 17).unwrap();
        crate::data::libsvm::save(&ds, &path).unwrap();
        let spec = DatasetSpec::File {
            path: path.to_str().unwrap().to_string(),
            task: Task::Regression,
            n_features: None,
        };
        let loaded = spec.load(1).unwrap();
        assert_eq!(loaded.name, "housing");
        assert_eq!(crate::runtime::artifact_name_for(&loaded), "housing");
        // The config-facing name stays the path (dump round-trip).
        assert_eq!(spec.name(), path.to_str().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dump_roundtrips() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("trainer", "dsgd").unwrap();
        cfg.set("eta", "exp:0.2,0.95").unwrap();
        cfg.set("outer_iters", "33").unwrap();
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.trainer, TrainerKind::Dsgd);
        assert_eq!(back.eta, cfg.eta);
        assert_eq!(back.outer_iters, 33);
    }

    #[test]
    fn dump_roundtrips_engine_keys() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("transport", "simnet:50us,1e9,2").unwrap();
        cfg.set("update_mode", "stochastic:4").unwrap();
        cfg.set("cols_per_token", "40").unwrap();
        cfg.set("row_partition", "balanced").unwrap();
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.transport, cfg.transport);
        assert_eq!(back.update_mode, cfg.update_mode);
        assert_eq!(back.cols_per_token, 40);
        assert_eq!(back.row_partition, RowStrategy::NnzBalanced);
        assert!(ExperimentConfig::default().dump().contains("row_partition = contiguous"));
        assert!(ExperimentConfig::parse_str("row_partition = random\n").is_err());
        match back.transport {
            TransportKind::SimNet(m) => {
                assert_eq!(m.latency, std::time::Duration::from_micros(50));
                assert_eq!(m.bandwidth_bps, 1e9);
                assert_eq!(m.workers_per_machine, 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dump_roundtrips_file_dataset_task() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("dataset", "data/real.svm").unwrap();
        cfg.set("dataset_task", "regression").unwrap();
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.dataset, cfg.dataset);
    }

    #[test]
    fn dump_roundtrips_cache_dataset_and_data_cache_key() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("dataset", "cache:/tmp/crit/train").unwrap();
        cfg.set("data_cache", "/tmp/crit/train").unwrap();
        match &cfg.dataset {
            DatasetSpec::Cache { dir } => assert_eq!(dir, "/tmp/crit/train"),
            other => panic!("{other:?}"),
        }
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.data_cache.as_deref(), Some("/tmp/crit/train"));
        // Absent by default, and absent from the default dump.
        assert_eq!(ExperimentConfig::default().data_cache, None);
        assert!(!ExperimentConfig::default().dump().contains("data_cache"));
        // dataset_task applies to file datasets only; a cache carries its
        // task in the manifest.
        assert!(cfg.set("dataset_task", "regression").is_err());
    }

    #[test]
    fn dump_roundtrips_cluster_key() {
        use crate::cluster::runtime::ClusterSpec;
        let mut cfg = ExperimentConfig::default();
        cfg.set("cluster", "driver:127.0.0.1:4700,p=3").unwrap();
        assert_eq!(
            cfg.cluster,
            Some(ClusterSpec::Driver {
                addr: "127.0.0.1:4700".into(),
                p: 3
            })
        );
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.cluster, cfg.cluster);

        cfg.set("cluster", "worker:10.0.0.5:4700").unwrap();
        assert_eq!(
            cfg.cluster,
            Some(ClusterSpec::Worker {
                driver: "10.0.0.5:4700".into()
            })
        );
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.cluster, cfg.cluster);

        // Absent by default, and absent from the default dump.
        assert_eq!(ExperimentConfig::default().cluster, None);
        assert!(!ExperimentConfig::default().dump().contains("cluster"));
        // Malformed specs fail loudly.
        assert!(ExperimentConfig::parse_str("cluster = driver:\n").is_err());
        assert!(ExperimentConfig::parse_str("cluster = driver:x:1\n").is_err());
        assert!(ExperimentConfig::parse_str("cluster = peer:x:1\n").is_err());
    }

    #[test]
    fn dump_roundtrips_cluster_secret_key() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("cluster_secret", "hunter2 hunter2").unwrap();
        assert_eq!(cfg.cluster_secret.as_deref(), Some("hunter2 hunter2"));
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.cluster_secret, cfg.cluster_secret);
        // Absent by default, and absent from the default dump.
        assert_eq!(ExperimentConfig::default().cluster_secret, None);
        assert!(!ExperimentConfig::default().dump().contains("cluster_secret"));
        // An empty secret is a misconfiguration, not "no auth".
        assert!(ExperimentConfig::parse_str("cluster_secret =\n").is_err());
    }

    #[test]
    fn dump_roundtrips_wire_precision_key() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("wire_precision", "bf16").unwrap();
        assert_eq!(cfg.wire_precision, WirePrecision::Bf16);
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.wire_precision, WirePrecision::Bf16);
        // f32 is the default, and default-absent from the dump.
        assert_eq!(
            ExperimentConfig::default().wire_precision,
            WirePrecision::F32
        );
        assert!(!ExperimentConfig::default().dump().contains("wire_precision"));
        // Unknown precisions fail loudly.
        assert!(ExperimentConfig::parse_str("wire_precision = f16\n").is_err());
    }

    #[test]
    fn dump_roundtrips_serve_keys() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("serve_addr", "0.0.0.0:9090").unwrap();
        cfg.set("serve_model", "out/model.dsfm").unwrap();
        cfg.set("serve_max_batch", "128").unwrap();
        cfg.set("serve_batch_window_us", "250").unwrap();
        cfg.set("serve_col_blocks", "4").unwrap();
        cfg.set("serve_reload_poll_ms", "50").unwrap();
        let back = ExperimentConfig::parse_str(&cfg.dump()).unwrap();
        assert_eq!(back.serve_addr, "0.0.0.0:9090");
        assert_eq!(back.serve_model.as_deref(), Some("out/model.dsfm"));
        assert_eq!(back.serve_max_batch, 128);
        assert_eq!(back.serve_batch_window_us, 250);
        assert_eq!(back.serve_col_blocks, 4);
        assert_eq!(back.serve_reload_poll_ms, 50);
        // Defaults stay out of the dump.
        assert!(!ExperimentConfig::default().dump().contains("serve_"));
        // Degenerate values fail loudly.
        assert!(ExperimentConfig::parse_str("serve_max_batch = 0\n").is_err());
        assert!(ExperimentConfig::parse_str("serve_col_blocks = 0\n").is_err());
        assert!(ExperimentConfig::parse_str("serve_reload_poll_ms = 0\n").is_err());
    }

    #[test]
    fn cache_dataset_spec_loads_from_manifest() {
        let dir = std::env::temp_dir().join("dsfacto_cfg_cache_test");
        let ds = crate::data::synth::table2_dataset("housing", 23).unwrap();
        crate::data::cache::write_cache(&ds, RowStrategy::Contiguous, 2, &dir).unwrap();
        let spec = DatasetSpec::Cache {
            dir: dir.to_str().unwrap().to_string(),
        };
        let loaded = spec.load(1).unwrap();
        assert_eq!(loaded.name, "housing");
        assert_eq!(loaded.rows, ds.rows);
        assert_eq!(loaded.labels, ds.labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trainer_aliases() {
        assert_eq!(TrainerKind::parse("ds-facto").unwrap(), TrainerKind::Nomad);
        assert_eq!(TrainerKind::parse("gd").unwrap(), TrainerKind::BulkSync);
        assert!(TrainerKind::parse("adam").is_err());
        for kind in TrainerKind::all() {
            assert_eq!(TrainerKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn table2_spec_loads() {
        let spec = DatasetSpec::Table2("diabetes".into());
        let ds = spec.load(1).unwrap();
        assert_eq!(ds.n(), 513);
    }
}
