//! Composable [`TrainObserver`]s: trace capture, early stopping, periodic
//! model checkpointing and CSV streaming — the cross-cutting session logic
//! the trainer loops no longer carry.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::fm::FmModel;
use crate::metrics::{TracePoint, TrainOutput};
use crate::util::csv::CsvWriter;

use super::{ControlFlow, TrainObserver};

/// Fans one session out to several observers. Every observer sees every
/// point; the session stops as soon as *any* observer asks to.
#[derive(Default)]
pub struct Observers<'a> {
    list: Vec<&'a mut dyn TrainObserver>,
}

impl<'a> Observers<'a> {
    /// An empty composite (equivalent to the null observer).
    pub fn new() -> Self {
        Observers { list: Vec::new() }
    }

    /// Adds an observer to the fan-out.
    pub fn push(&mut self, obs: &'a mut dyn TrainObserver) {
        self.list.push(obs);
    }
}

impl TrainObserver for Observers<'_> {
    fn wants_model(&self, iter: usize) -> bool {
        self.list.iter().any(|o| o.wants_model(iter))
    }

    fn on_iter(&mut self, pt: &TracePoint, model: Option<&FmModel>) -> ControlFlow {
        let mut flow = ControlFlow::Continue;
        for o in self.list.iter_mut() {
            flow = flow.join(o.on_iter(pt, model));
        }
        flow
    }

    fn on_done(&mut self, out: &TrainOutput) {
        for o in self.list.iter_mut() {
            o.on_done(out);
        }
    }
}

/// Captures every [`TracePoint`] the session emits. Useful when driving a
/// trainer through the trait without keeping the whole [`TrainOutput`], and
/// in tests that assert on observer-visible state.
#[derive(Default)]
pub struct TraceRecorder {
    /// The points seen so far, in iteration order.
    pub trace: Vec<TracePoint>,
}

impl TrainObserver for TraceRecorder {
    fn on_iter(&mut self, pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        self.trace.push(pt.clone());
        ControlFlow::Continue
    }
}

/// Stops training when the objective has not improved by at least
/// `min_delta` for `patience` consecutive recorded points.
pub struct EarlyStop {
    patience: usize,
    min_delta: f64,
    best: f64,
    bad: usize,
    /// The iteration at which the stop was requested, once triggered.
    pub stopped_at: Option<usize>,
}

impl EarlyStop {
    /// `patience` = how many non-improving points to tolerate;
    /// `min_delta` = the smallest objective decrease that counts.
    pub fn new(patience: usize, min_delta: f64) -> Self {
        EarlyStop {
            patience: patience.max(1),
            min_delta,
            best: f64::INFINITY,
            bad: 0,
            stopped_at: None,
        }
    }
}

impl TrainObserver for EarlyStop {
    fn on_iter(&mut self, pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        if pt.objective + self.min_delta < self.best {
            self.best = pt.objective;
            self.bad = 0;
            return ControlFlow::Continue;
        }
        self.bad += 1;
        if self.bad >= self.patience {
            self.stopped_at = Some(pt.iter);
            return ControlFlow::Stop;
        }
        ControlFlow::Continue
    }
}

/// Saves the model every `every` iterations (`ckpt-00010.dsfm` style) and
/// once more as `final.dsfm` when the session completes.
pub struct Checkpointer {
    dir: PathBuf,
    every: usize,
    /// Checkpoints written so far (in order).
    pub saved: Vec<PathBuf>,
    /// The first I/O error hit, if any (checkpointing never aborts a run).
    pub error: Option<anyhow::Error>,
}

impl Checkpointer {
    /// Checkpoints into `dir` every `every` iterations.
    pub fn new<P: AsRef<Path>>(dir: P, every: usize) -> Self {
        Checkpointer {
            dir: dir.as_ref().to_path_buf(),
            every: every.max(1),
            saved: Vec::new(),
            error: None,
        }
    }

    fn save(&mut self, model: &FmModel, filename: String) {
        let path = self.dir.join(filename);
        match crate::fm::io::save(model, &path) {
            Ok(()) => self.saved.push(path),
            Err(e) => {
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
    }

    /// File name of rank `rank`'s block checkpoint for epoch `iter`.
    pub fn block_file_name(rank: usize, iter: u32) -> String {
        format!("blocks-r{rank:03}-e{iter:05}.dsfb")
    }

    /// Writes one rank's **block-granular** checkpoint: the post-flip
    /// tokens this rank carried across the tagged epoch boundary, exactly
    /// the state they must be re-dealt with on restart. The union of all
    /// P rank files at a tag is one complete token set (a rank may
    /// legitimately flip zero tokens at an epoch — the empty file still
    /// marks that rank's epoch as complete). The write is atomic
    /// (tmp-file + rename), so a crash mid-checkpoint can never leave a
    /// truncated file that [`Checkpointer::latest_block_epoch`] would
    /// count.
    ///
    /// Format: `DSFB | version u32 | rank u32 | iter u32 | count u32`,
    /// then `count` length-prefixed K-strided token frames
    /// ([`crate::cluster::codec::encode_token_padded`]).
    pub fn save_blocks(
        dir: &Path,
        rank: usize,
        iter: u32,
        tokens: &[crate::nomad::token::Token],
        k: usize,
    ) -> anyhow::Result<PathBuf> {
        use std::io::Write;
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(b"DSFB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(rank as u32).to_le_bytes());
        out.extend_from_slice(&iter.to_le_bytes());
        out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
        let mut frame = Vec::new();
        for tok in tokens {
            crate::cluster::codec::encode_token_padded(tok, k, &mut frame);
            out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
            out.extend_from_slice(&frame);
        }
        let path = dir.join(Self::block_file_name(rank, iter));
        let tmp = dir.join(format!(".{}.tmp", Self::block_file_name(rank, iter)));
        {
            let mut f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(&out)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing checkpoint {path:?}"))?;
        Ok(path)
    }

    /// Reads one rank file back: `(rank, iter, tokens)`, tokens in the
    /// engine's lane-padded in-memory layout.
    pub fn load_blocks(path: &Path) -> anyhow::Result<(u32, u32, Vec<crate::nomad::token::Token>)> {
        let buf = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            buf.len() >= 20 && &buf[..4] == b"DSFB",
            "not a block checkpoint: {path:?}"
        );
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        anyhow::ensure!(version == 1, "unsupported block checkpoint version {version}");
        let rank = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        let iter = u32::from_le_bytes(buf[12..16].try_into().unwrap());
        let count = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
        anyhow::ensure!(count <= 1 << 24, "implausible token count {count}");
        let mut tokens = Vec::with_capacity(count);
        let mut pos = 20usize;
        for _ in 0..count {
            anyhow::ensure!(pos + 4 <= buf.len(), "truncated block checkpoint {path:?}");
            let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
            pos += 4;
            anyhow::ensure!(pos + len <= buf.len(), "truncated block checkpoint {path:?}");
            tokens.push(crate::cluster::codec::decode_token_padded(&buf[pos..pos + len])?);
            pos += len;
        }
        anyhow::ensure!(pos == buf.len(), "trailing bytes in block checkpoint {path:?}");
        Ok((rank, iter, tokens))
    }

    /// Parses `blocks-rRRR-eEEEEE.dsfb` into `(rank, epoch)`.
    fn parse_block_name(name: &str) -> Option<(usize, u32)> {
        let rest = name.strip_prefix("blocks-r")?.strip_suffix(".dsfb")?;
        let (rank, epoch) = rest.split_once("-e")?;
        Some((rank.parse().ok()?, epoch.parse().ok()?))
    }

    /// The newest epoch tag for which **all** `p` rank files exist in
    /// `dir` — the restart point checkpoint-recovery agrees on. `None` if
    /// the directory is missing or no epoch is complete (a crash can
    /// leave a partial set of rank files at the newest tag; those are
    /// skipped, not an error).
    pub fn latest_block_epoch(dir: &Path, p: usize) -> anyhow::Result<Option<u32>> {
        use std::collections::HashMap;
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return Ok(None),
        };
        let mut per_epoch: HashMap<u32, usize> = HashMap::new();
        for entry in entries {
            let Ok(entry) = entry else { continue };
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some((rank, epoch)) = Self::parse_block_name(name) else { continue };
            if rank < p {
                *per_epoch.entry(epoch).or_insert(0) += 1;
            }
        }
        Ok(per_epoch
            .into_iter()
            .filter(|&(_, have)| have == p)
            .map(|(epoch, _)| epoch)
            .max())
    }

    /// Checkpoint GC: removes block files of epochs superseded by the
    /// newest `keep` *complete* epochs (all `p` rank files present), so a
    /// long run holds a bounded number of checkpoint files instead of one
    /// set per epoch. Epochs at or above the cutoff — including
    /// incomplete ones still being written — are never touched, and
    /// removals are best-effort (a sibling worker process GC-ing the same
    /// directory concurrently must not fail the caller). Returns the
    /// number of files removed.
    pub fn prune_block_epochs(dir: &Path, p: usize, keep: usize) -> anyhow::Result<usize> {
        use std::collections::HashMap;
        anyhow::ensure!(keep >= 1, "prune_block_epochs must keep at least one epoch");
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return Ok(0),
        };
        let mut files: Vec<(u32, PathBuf)> = Vec::new();
        let mut per_epoch: HashMap<u32, usize> = HashMap::new();
        for entry in entries {
            let Ok(entry) = entry else { continue };
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some((rank, epoch)) = Self::parse_block_name(name) else { continue };
            if rank < p {
                *per_epoch.entry(epoch).or_insert(0) += 1;
            }
            files.push((epoch, entry.path()));
        }
        let mut complete: Vec<u32> = per_epoch
            .into_iter()
            .filter(|&(_, have)| have == p)
            .map(|(epoch, _)| epoch)
            .collect();
        complete.sort_unstable();
        if complete.len() <= keep {
            return Ok(0);
        }
        let cutoff = complete[complete.len() - keep];
        let mut removed = 0usize;
        for (epoch, path) in files {
            if epoch < cutoff && std::fs::remove_file(&path).is_ok() {
                removed += 1;
            }
        }
        Ok(removed)
    }
}

impl TrainObserver for Checkpointer {
    fn wants_model(&self, iter: usize) -> bool {
        iter > 0 && iter % self.every == 0
    }

    fn on_iter(&mut self, pt: &TracePoint, model: Option<&FmModel>) -> ControlFlow {
        if pt.iter > 0 && pt.iter % self.every == 0 {
            if let Some(m) = model {
                self.save(m, format!("ckpt-{:05}.dsfm", pt.iter));
            }
        }
        ControlFlow::Continue
    }

    fn on_done(&mut self, out: &TrainOutput) {
        self.save(&out.model, "final.dsfm".to_string());
    }
}

/// The CSV column set every trace series uses (the Fig 4/5 format).
pub const TRACE_COLUMNS: [&str; 6] =
    ["iter", "secs", "objective", "train_loss", "test_loss", "test_metric"];

/// Formats one trace point as a [`TRACE_COLUMNS`] row.
pub fn trace_row(pt: &TracePoint) -> Vec<String> {
    let (tl, tm) = match &pt.test {
        Some(m) => (
            format!("{}", m.loss),
            format!("{}", if m.rmse.is_nan() { m.accuracy } else { m.rmse }),
        ),
        None => (String::new(), String::new()),
    };
    vec![
        pt.iter.to_string(),
        format!("{:.6}", pt.secs),
        format!("{}", pt.objective),
        format!("{}", pt.train_loss),
        tl,
        tm,
    ]
}

/// Streams the convergence trace to a CSV file as training runs, one row
/// per recorded point, flushed eagerly so partial runs leave usable series.
pub struct CsvStreamer {
    writer: CsvWriter,
    path: PathBuf,
    error: Option<anyhow::Error>,
}

impl CsvStreamer {
    /// Creates the file (and parent dirs) and writes the header.
    pub fn create<P: AsRef<Path>>(path: P) -> crate::Result<Self> {
        Ok(CsvStreamer {
            writer: CsvWriter::create(path.as_ref(), &TRACE_COLUMNS)?,
            path: path.as_ref().to_path_buf(),
            error: None,
        })
    }

    /// Surfaces the first write error, if any, after the session.
    pub fn finish(self) -> crate::Result<()> {
        match self.error {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Removes the partially written file — for the session-failed path,
    /// where a half-streamed trace would otherwise be left looking like a
    /// finished series. Removal failure is ignored (the file may never
    /// have made it to disk).
    pub fn abort(self) {
        let CsvStreamer { writer, path, .. } = self;
        drop(writer);
        let _ = std::fs::remove_file(&path);
    }
}

impl TrainObserver for CsvStreamer {
    fn on_iter(&mut self, pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        let write = self
            .writer
            .row(&trace_row(pt))
            .and_then(|()| self.writer.flush());
        if let Err(e) = write {
            if self.error.is_none() {
                self.error = Some(e);
            }
        }
        ControlFlow::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalMetrics;

    fn pt(iter: usize, objective: f64) -> TracePoint {
        TracePoint {
            iter,
            secs: iter as f64,
            objective,
            train_loss: objective,
            test: None,
        }
    }

    fn model() -> FmModel {
        FmModel::zeros(3, 2)
    }

    #[test]
    fn early_stop_triggers_after_patience() {
        let mut es = EarlyStop::new(2, 1e-9);
        assert_eq!(es.on_iter(&pt(0, 1.0), None), ControlFlow::Continue);
        assert_eq!(es.on_iter(&pt(1, 0.5), None), ControlFlow::Continue); // improves
        assert_eq!(es.on_iter(&pt(2, 0.5), None), ControlFlow::Continue); // bad 1
        assert_eq!(es.on_iter(&pt(3, 0.51), None), ControlFlow::Stop); // bad 2
        assert_eq!(es.stopped_at, Some(3));
    }

    #[test]
    fn early_stop_resets_on_improvement() {
        let mut es = EarlyStop::new(2, 1e-9);
        es.on_iter(&pt(0, 1.0), None);
        es.on_iter(&pt(1, 1.0), None); // bad 1
        es.on_iter(&pt(2, 0.5), None); // improvement resets
        assert_eq!(es.on_iter(&pt(3, 0.5), None), ControlFlow::Continue); // bad 1 again
        assert!(es.stopped_at.is_none());
    }

    #[test]
    fn checkpointer_saves_on_cadence_and_done() {
        let dir = std::env::temp_dir().join("dsfacto_ckpt_obs_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut ck = Checkpointer::new(&dir, 2);
        let m = model();
        assert!(!ck.wants_model(1));
        assert!(ck.wants_model(2));
        for i in 0..=4 {
            ck.on_iter(&pt(i, 1.0), Some(&m));
        }
        ck.on_done(&TrainOutput {
            model: m.clone(),
            trace: vec![],
            wall_secs: 0.0,
        });
        assert!(ck.error.is_none(), "{:?}", ck.error);
        assert_eq!(ck.saved.len(), 3); // iters 2, 4 + final
        assert!(ck.saved[0].ends_with("ckpt-00002.dsfm"));
        assert!(ck.saved[2].ends_with("final.dsfm"));
        let back = crate::fm::io::load(&ck.saved[2]).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn block_checkpoints_round_trip_and_scan() {
        use crate::nomad::token::{Phase, Token, BIAS};
        let dir = std::env::temp_dir().join("dsfacto_block_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        let k = 3usize;
        let kp = crate::kernel::padded_k(k);
        let mk = |j: u32, iter: u32, ncols: usize| {
            let mut v = vec![0f32; ncols * kp];
            for bi in 0..ncols {
                for kk in 0..k {
                    v[bi * kp + kk] = (j as usize * 100 + bi * 10 + kk) as f32 * 0.25;
                }
            }
            Token {
                j,
                iter,
                phase: Phase::Update,
                visits: 0,
                w: (0..ncols).map(|i| i as f32 - 0.5).collect(),
                v: v.into_boxed_slice(),
            }
        };
        let bias = Token {
            j: BIAS,
            iter: 4,
            phase: Phase::Update,
            visits: 0,
            w: Box::from([0.125f32]),
            v: Box::from([]),
        };
        // Epoch 4 complete across both ranks (rank 1 holds zero tokens:
        // still a valid, countable file); epoch 6 missing rank 1.
        let r0 = vec![mk(0, 4, 2), mk(1, 4, 2), bias.clone()];
        let p0 = Checkpointer::save_blocks(&dir, 0, 4, &r0, k).unwrap();
        Checkpointer::save_blocks(&dir, 1, 4, &[], k).unwrap();
        Checkpointer::save_blocks(&dir, 0, 6, &[mk(0, 6, 2)], k).unwrap();

        let (rank, iter, back) = Checkpointer::load_blocks(&p0).unwrap();
        assert_eq!((rank, iter), (0, 4));
        assert_eq!(back, r0, "padded payloads must survive the round trip");

        assert_eq!(Checkpointer::latest_block_epoch(&dir, 2).unwrap(), Some(4));
        // A lone rank can restart from its own newest complete tag too.
        assert_eq!(Checkpointer::latest_block_epoch(&dir, 1).unwrap(), Some(6));
        let missing = dir.join("no_such_subdir");
        assert_eq!(Checkpointer::latest_block_epoch(&missing, 2).unwrap(), None);

        // ---- GC. Only one complete epoch (4) at p=2: nothing prunable,
        // and the incomplete epoch-6 file must survive untouched.
        assert_eq!(Checkpointer::prune_block_epochs(&dir, 2, 2).unwrap(), 0);
        assert_eq!(Checkpointer::latest_block_epoch(&dir, 2).unwrap(), Some(4));
        // Complete epochs 2 and 8 as well; keep=2 then drops every file
        // below the second-newest complete epoch (4): both epoch-2 files.
        Checkpointer::save_blocks(&dir, 0, 2, &[], k).unwrap();
        Checkpointer::save_blocks(&dir, 1, 2, &[], k).unwrap();
        Checkpointer::save_blocks(&dir, 0, 8, &[], k).unwrap();
        Checkpointer::save_blocks(&dir, 1, 8, &[], k).unwrap();
        assert_eq!(Checkpointer::prune_block_epochs(&dir, 2, 2).unwrap(), 2);
        assert_eq!(Checkpointer::latest_block_epoch(&dir, 2).unwrap(), Some(8));
        // Epoch 4 (the keep-floor) and the incomplete epoch 6 both remain;
        // the pruned epoch 2 is gone. Restart data stays loadable.
        assert!(dir.join(Checkpointer::block_file_name(0, 4)).exists());
        assert!(dir.join(Checkpointer::block_file_name(0, 6)).exists());
        assert!(!dir.join(Checkpointer::block_file_name(0, 2)).exists());
        assert!(!dir.join(Checkpointer::block_file_name(1, 2)).exists());
        let (_, _, back) = Checkpointer::load_blocks(&p0).unwrap();
        assert_eq!(back, r0, "GC must not disturb kept epochs");
        // Idempotent: a second sweep finds nothing below the cutoff.
        assert_eq!(Checkpointer::prune_block_epochs(&dir, 2, 2).unwrap(), 0);
        // Keeping fewer than one epoch is a caller bug, not a silent wipe.
        assert!(Checkpointer::prune_block_epochs(&dir, 2, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn observers_fan_out_and_stop_wins() {
        let mut rec = TraceRecorder::default();
        let mut es = EarlyStop::new(1, 1e-9);
        let mut obs = Observers::new();
        obs.push(&mut rec);
        obs.push(&mut es);
        assert_eq!(obs.on_iter(&pt(0, 1.0), None), ControlFlow::Continue);
        // No improvement: EarlyStop(patience=1) stops; recorder still sees it.
        assert_eq!(obs.on_iter(&pt(1, 1.0), None), ControlFlow::Stop);
        drop(obs);
        assert_eq!(rec.trace.len(), 2);
        assert_eq!(es.stopped_at, Some(1));
    }

    #[test]
    fn csv_streamer_writes_trace_rows() {
        let dir = std::env::temp_dir().join("dsfacto_csv_obs_test");
        let path = dir.join("trace.csv");
        let mut csv = CsvStreamer::create(&path).unwrap();
        let mut with_test = pt(0, 2.0);
        with_test.test = Some(EvalMetrics {
            loss: 0.5,
            rmse: 1.5,
            accuracy: f64::NAN,
            auc: f64::NAN,
        });
        csv.on_iter(&with_test, None);
        csv.on_iter(&pt(1, 1.0), None);
        csv.finish().unwrap();
        let (hdr, rows) = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(hdr, TRACE_COLUMNS.to_vec());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][0], "0");
        assert_eq!(rows[0][5], "1.5"); // rmse is the headline column
        assert_eq!(rows[1][4], ""); // no test metrics on row 1
        std::fs::remove_dir_all(&dir).ok();
    }
}
