//! Composable [`TrainObserver`]s: trace capture, early stopping, periodic
//! model checkpointing and CSV streaming — the cross-cutting session logic
//! the trainer loops no longer carry.

use std::path::{Path, PathBuf};

use crate::fm::FmModel;
use crate::metrics::{TracePoint, TrainOutput};
use crate::util::csv::CsvWriter;

use super::{ControlFlow, TrainObserver};

/// Fans one session out to several observers. Every observer sees every
/// point; the session stops as soon as *any* observer asks to.
#[derive(Default)]
pub struct Observers<'a> {
    list: Vec<&'a mut dyn TrainObserver>,
}

impl<'a> Observers<'a> {
    /// An empty composite (equivalent to the null observer).
    pub fn new() -> Self {
        Observers { list: Vec::new() }
    }

    /// Adds an observer to the fan-out.
    pub fn push(&mut self, obs: &'a mut dyn TrainObserver) {
        self.list.push(obs);
    }
}

impl TrainObserver for Observers<'_> {
    fn wants_model(&self, iter: usize) -> bool {
        self.list.iter().any(|o| o.wants_model(iter))
    }

    fn on_iter(&mut self, pt: &TracePoint, model: Option<&FmModel>) -> ControlFlow {
        let mut flow = ControlFlow::Continue;
        for o in self.list.iter_mut() {
            flow = flow.join(o.on_iter(pt, model));
        }
        flow
    }

    fn on_done(&mut self, out: &TrainOutput) {
        for o in self.list.iter_mut() {
            o.on_done(out);
        }
    }
}

/// Captures every [`TracePoint`] the session emits. Useful when driving a
/// trainer through the trait without keeping the whole [`TrainOutput`], and
/// in tests that assert on observer-visible state.
#[derive(Default)]
pub struct TraceRecorder {
    /// The points seen so far, in iteration order.
    pub trace: Vec<TracePoint>,
}

impl TrainObserver for TraceRecorder {
    fn on_iter(&mut self, pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        self.trace.push(pt.clone());
        ControlFlow::Continue
    }
}

/// Stops training when the objective has not improved by at least
/// `min_delta` for `patience` consecutive recorded points.
pub struct EarlyStop {
    patience: usize,
    min_delta: f64,
    best: f64,
    bad: usize,
    /// The iteration at which the stop was requested, once triggered.
    pub stopped_at: Option<usize>,
}

impl EarlyStop {
    /// `patience` = how many non-improving points to tolerate;
    /// `min_delta` = the smallest objective decrease that counts.
    pub fn new(patience: usize, min_delta: f64) -> Self {
        EarlyStop {
            patience: patience.max(1),
            min_delta,
            best: f64::INFINITY,
            bad: 0,
            stopped_at: None,
        }
    }
}

impl TrainObserver for EarlyStop {
    fn on_iter(&mut self, pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        if pt.objective + self.min_delta < self.best {
            self.best = pt.objective;
            self.bad = 0;
            return ControlFlow::Continue;
        }
        self.bad += 1;
        if self.bad >= self.patience {
            self.stopped_at = Some(pt.iter);
            return ControlFlow::Stop;
        }
        ControlFlow::Continue
    }
}

/// Saves the model every `every` iterations (`ckpt-00010.dsfm` style) and
/// once more as `final.dsfm` when the session completes.
pub struct Checkpointer {
    dir: PathBuf,
    every: usize,
    /// Checkpoints written so far (in order).
    pub saved: Vec<PathBuf>,
    /// The first I/O error hit, if any (checkpointing never aborts a run).
    pub error: Option<anyhow::Error>,
}

impl Checkpointer {
    /// Checkpoints into `dir` every `every` iterations.
    pub fn new<P: AsRef<Path>>(dir: P, every: usize) -> Self {
        Checkpointer {
            dir: dir.as_ref().to_path_buf(),
            every: every.max(1),
            saved: Vec::new(),
            error: None,
        }
    }

    fn save(&mut self, model: &FmModel, filename: String) {
        let path = self.dir.join(filename);
        match crate::fm::io::save(model, &path) {
            Ok(()) => self.saved.push(path),
            Err(e) => {
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
    }
}

impl TrainObserver for Checkpointer {
    fn wants_model(&self, iter: usize) -> bool {
        iter > 0 && iter % self.every == 0
    }

    fn on_iter(&mut self, pt: &TracePoint, model: Option<&FmModel>) -> ControlFlow {
        if pt.iter > 0 && pt.iter % self.every == 0 {
            if let Some(m) = model {
                self.save(m, format!("ckpt-{:05}.dsfm", pt.iter));
            }
        }
        ControlFlow::Continue
    }

    fn on_done(&mut self, out: &TrainOutput) {
        self.save(&out.model, "final.dsfm".to_string());
    }
}

/// The CSV column set every trace series uses (the Fig 4/5 format).
pub const TRACE_COLUMNS: [&str; 6] =
    ["iter", "secs", "objective", "train_loss", "test_loss", "test_metric"];

/// Formats one trace point as a [`TRACE_COLUMNS`] row.
pub fn trace_row(pt: &TracePoint) -> Vec<String> {
    let (tl, tm) = match &pt.test {
        Some(m) => (
            format!("{}", m.loss),
            format!("{}", if m.rmse.is_nan() { m.accuracy } else { m.rmse }),
        ),
        None => (String::new(), String::new()),
    };
    vec![
        pt.iter.to_string(),
        format!("{:.6}", pt.secs),
        format!("{}", pt.objective),
        format!("{}", pt.train_loss),
        tl,
        tm,
    ]
}

/// Streams the convergence trace to a CSV file as training runs, one row
/// per recorded point, flushed eagerly so partial runs leave usable series.
pub struct CsvStreamer {
    writer: CsvWriter,
    error: Option<anyhow::Error>,
}

impl CsvStreamer {
    /// Creates the file (and parent dirs) and writes the header.
    pub fn create<P: AsRef<Path>>(path: P) -> crate::Result<Self> {
        Ok(CsvStreamer {
            writer: CsvWriter::create(path, &TRACE_COLUMNS)?,
            error: None,
        })
    }

    /// Surfaces the first write error, if any, after the session.
    pub fn finish(self) -> crate::Result<()> {
        match self.error {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl TrainObserver for CsvStreamer {
    fn on_iter(&mut self, pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        let write = self
            .writer
            .row(&trace_row(pt))
            .and_then(|()| self.writer.flush());
        if let Err(e) = write {
            if self.error.is_none() {
                self.error = Some(e);
            }
        }
        ControlFlow::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalMetrics;

    fn pt(iter: usize, objective: f64) -> TracePoint {
        TracePoint {
            iter,
            secs: iter as f64,
            objective,
            train_loss: objective,
            test: None,
        }
    }

    fn model() -> FmModel {
        FmModel::zeros(3, 2)
    }

    #[test]
    fn early_stop_triggers_after_patience() {
        let mut es = EarlyStop::new(2, 1e-9);
        assert_eq!(es.on_iter(&pt(0, 1.0), None), ControlFlow::Continue);
        assert_eq!(es.on_iter(&pt(1, 0.5), None), ControlFlow::Continue); // improves
        assert_eq!(es.on_iter(&pt(2, 0.5), None), ControlFlow::Continue); // bad 1
        assert_eq!(es.on_iter(&pt(3, 0.51), None), ControlFlow::Stop); // bad 2
        assert_eq!(es.stopped_at, Some(3));
    }

    #[test]
    fn early_stop_resets_on_improvement() {
        let mut es = EarlyStop::new(2, 1e-9);
        es.on_iter(&pt(0, 1.0), None);
        es.on_iter(&pt(1, 1.0), None); // bad 1
        es.on_iter(&pt(2, 0.5), None); // improvement resets
        assert_eq!(es.on_iter(&pt(3, 0.5), None), ControlFlow::Continue); // bad 1 again
        assert!(es.stopped_at.is_none());
    }

    #[test]
    fn checkpointer_saves_on_cadence_and_done() {
        let dir = std::env::temp_dir().join("dsfacto_ckpt_obs_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut ck = Checkpointer::new(&dir, 2);
        let m = model();
        assert!(!ck.wants_model(1));
        assert!(ck.wants_model(2));
        for i in 0..=4 {
            ck.on_iter(&pt(i, 1.0), Some(&m));
        }
        ck.on_done(&TrainOutput {
            model: m.clone(),
            trace: vec![],
            wall_secs: 0.0,
        });
        assert!(ck.error.is_none(), "{:?}", ck.error);
        assert_eq!(ck.saved.len(), 3); // iters 2, 4 + final
        assert!(ck.saved[0].ends_with("ckpt-00002.dsfm"));
        assert!(ck.saved[2].ends_with("final.dsfm"));
        let back = crate::fm::io::load(&ck.saved[2]).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn observers_fan_out_and_stop_wins() {
        let mut rec = TraceRecorder::default();
        let mut es = EarlyStop::new(1, 1e-9);
        let mut obs = Observers::new();
        obs.push(&mut rec);
        obs.push(&mut es);
        assert_eq!(obs.on_iter(&pt(0, 1.0), None), ControlFlow::Continue);
        // No improvement: EarlyStop(patience=1) stops; recorder still sees it.
        assert_eq!(obs.on_iter(&pt(1, 1.0), None), ControlFlow::Stop);
        drop(obs);
        assert_eq!(rec.trace.len(), 2);
        assert_eq!(es.stopped_at, Some(1));
    }

    #[test]
    fn csv_streamer_writes_trace_rows() {
        let dir = std::env::temp_dir().join("dsfacto_csv_obs_test");
        let path = dir.join("trace.csv");
        let mut csv = CsvStreamer::create(&path).unwrap();
        let mut with_test = pt(0, 2.0);
        with_test.test = Some(EvalMetrics {
            loss: 0.5,
            rmse: 1.5,
            accuracy: f64::NAN,
            auc: f64::NAN,
        });
        csv.on_iter(&with_test, None);
        csv.on_iter(&pt(1, 1.0), None);
        csv.finish().unwrap();
        let (hdr, rows) = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(hdr, TRACE_COLUMNS.to_vec());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][0], "0");
        assert_eq!(rows[0][5], "1.5"); // rmse is the headline column
        assert_eq!(rows[1][4], ""); // no test metrics on row 1
        std::fs::remove_dir_all(&dir).ok();
    }
}
