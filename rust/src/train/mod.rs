//! Unified training and serving API: the [`Trainer`] trait every engine
//! implements, the [`TrainObserver`] callback interface that training
//! sessions report through, and the [`Predictor`] trait both scoring
//! backends (native Rust and the AOT XLA artifact) expose.
//!
//! Before this module existed, the crate shipped five trainers with five
//! incompatible signatures, and every caller (CLI, coordinator, examples,
//! benches) re-plumbed dispatch by hand. Now:
//!
//! * **Dispatch** goes through [`TrainerKind::build`], which turns an
//!   [`ExperimentConfig`] into a `Box<dyn Trainer>`. Adding a new model
//!   variant (e.g. a rank-aware or binarized FM) means implementing one
//!   trait, not touching six call sites.
//! * **Cross-cutting training concerns** — trace capture, eval cadence,
//!   early stopping, periodic checkpoints, CSV streaming — live in
//!   composable [`TrainObserver`]s (see [`observers`]), not inside the
//!   trainer loops.
//! * **Serving** goes through [`Predictor`] (see [`predict`]), so the
//!   request path has one interface regardless of backend.
//!
//! # The observer contract
//!
//! Implementations of [`Trainer::fit`] must:
//!
//! 1. Call [`TrainObserver::on_iter`] exactly once per recorded
//!    [`TracePoint`], **in iteration order**, starting with the pre-training
//!    point at `iter == 0` and including a point for every completed outer
//!    iteration. The `TracePoint` carries held-out metrics only on the
//!    trainer's eval cadence (`eval_every`).
//! 2. Pass `Some(model)` whenever a model snapshot is cheaply available.
//!    Trainers for which snapshots are expensive (the distributed NOMAD
//!    engine must materialize its eventually-consistent mirror) may consult
//!    [`TrainObserver::wants_model`] first and pass `None` when no observer
//!    asked for the iteration; `model` is guaranteed to be `Some` whenever
//!    `wants_model(iter)` returned `true`.
//! 3. Honor [`ControlFlow::Stop`] by ending training *promptly*: the
//!    sequential trainers record no further points after a Stop; the
//!    asynchronous NOMAD engine stops within a bounded number of outer
//!    iterations (its in-flight pipeline depth, at most three) while
//!    preserving exact token finalization. The drain-window iterations it
//!    completes are still recorded — and still delivered through
//!    `on_iter` (return values ignored once stopping) — so an observer's
//!    view always equals the returned trace.
//! 4. Call [`TrainObserver::on_done`] once with the final [`TrainOutput`]
//!    before returning.
//!
//! Observers must tolerate `fit` being invoked multiple times on the same
//! trainer only if they are freshly constructed per run; the built-in
//! observers are single-run objects.
//!
//! ```no_run
//! use dsfacto::prelude::*;
//! use dsfacto::train::observers::{EarlyStop, Observers, TraceRecorder};
//!
//! let cfg = ExperimentConfig::default(); // diabetes twin, DS-FACTO engine
//! let ds = cfg.dataset.load(cfg.seed).unwrap();
//! let (train, test) = ds.split(0.8, 7);
//!
//! let trainer = cfg.trainer.build(&cfg);
//! let mut rec = TraceRecorder::default();
//! let mut stop = EarlyStop::new(5, 1e-6);
//! let mut obs = Observers::new();
//! obs.push(&mut rec);
//! obs.push(&mut stop);
//! let out = trainer.fit(&train, Some(&test), &mut obs).unwrap();
//! println!("{}: final objective {}", trainer.name(),
//!          out.trace.last().unwrap().objective);
//! ```

pub mod observers;
pub mod predict;
pub mod trainers;

pub use observers::{Checkpointer, CsvStreamer, EarlyStop, Observers, TraceRecorder};
pub use predict::{Predictor, XlaPredictor};
pub use trainers::{
    BulkSyncTrainer, DsgdTrainer, LibfmTrainer, NomadTrainer, XlaDenseTrainer,
};

use crate::config::{ExperimentConfig, TrainerKind};
use crate::data::Dataset;
use crate::fm::FmModel;
use crate::metrics::{evaluate, TracePoint, TrainOutput};
use crate::nomad::EngineStats;
use crate::partition::PartitionStats;

/// What an observer tells the training session to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ControlFlow {
    /// Keep training.
    #[default]
    Continue,
    /// End training promptly (see the module docs for trainer-specific
    /// latitude) and return the model as of the last completed iteration.
    Stop,
}

impl ControlFlow {
    /// True for [`ControlFlow::Stop`].
    #[inline]
    pub fn is_stop(self) -> bool {
        self == ControlFlow::Stop
    }

    /// Combines two decisions: `Stop` wins.
    #[inline]
    pub fn join(self, other: ControlFlow) -> ControlFlow {
        if self.is_stop() || other.is_stop() {
            ControlFlow::Stop
        } else {
            ControlFlow::Continue
        }
    }
}

/// Callback interface every training session reports through.
///
/// See the module docs for the full contract between trainers and
/// observers. The unit type `()` implements this as the null observer, so
/// `&mut ()` is the idiomatic "just train" argument to [`Trainer::fit`].
pub trait TrainObserver {
    /// Return true when [`on_iter`](Self::on_iter) needs the model for
    /// `iter`. Trainers with expensive snapshots only materialize one when
    /// some observer asks.
    fn wants_model(&self, _iter: usize) -> bool {
        false
    }

    /// Called once per recorded trace point, in iteration order. `model`
    /// follows the snapshot rules in the module docs.
    fn on_iter(&mut self, pt: &TracePoint, model: Option<&FmModel>) -> ControlFlow;

    /// Called once with the final output before `fit` returns.
    fn on_done(&mut self, _out: &TrainOutput) {}
}

/// The null observer: observes nothing, never stops training.
impl TrainObserver for () {
    fn on_iter(&mut self, _pt: &TracePoint, _model: Option<&FmModel>) -> ControlFlow {
        ControlFlow::Continue
    }
}

/// A training engine behind the uniform session API.
///
/// Build one from an [`ExperimentConfig`] via [`TrainerKind::build`], or
/// construct the concrete trainers in [`trainers`] directly when you need
/// engine-specific knobs.
pub trait Trainer {
    /// Canonical trainer name (matches [`TrainerKind::name`]).
    fn name(&self) -> &'static str;

    /// Runs one training session and returns the trained model, the
    /// convergence trace and the wall-clock training time.
    fn fit(
        &self,
        train: &Dataset,
        test: Option<&Dataset>,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput>;

    /// Trains straight from a [`DataSource`] — no held-out set, no
    /// caller-held full matrix. The default materializes the source and
    /// delegates to [`fit`](Self::fit) (correct for the single-machine
    /// trainers); the shard-native trainers override it with
    /// bounded-memory loops whose traces are bitwise identical to the
    /// in-memory run of the same config.
    ///
    /// [`DataSource`]: crate::data::DataSource
    fn fit_source(
        &self,
        src: &dyn crate::data::DataSource,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let ds = src.materialize()?;
        self.fit(&ds, None, observer)
    }

    /// Engine counters from the most recent [`fit`](Self::fit), when the
    /// engine collects them (the DS-FACTO engine does; the sequential
    /// baselines return `None`).
    fn stats(&self) -> Option<EngineStats> {
        None
    }

    /// Row-shard load summary (per-shard nnz + imbalance ratio) of the
    /// most recent [`fit`](Self::fit), for the trainers that shard rows
    /// (nomad, dsgd, bulksync); `None` for the single-machine trainers.
    fn partition_stats(&self) -> Option<PartitionStats> {
        None
    }
}

impl TrainerKind {
    /// Builds the trainer this kind names, configured from `cfg`.
    ///
    /// This is the only dispatch point in the crate: the coordinator, the
    /// CLI, the examples and the benches all obtain trainers here.
    pub fn build(self, cfg: &ExperimentConfig) -> Box<dyn Trainer> {
        // The data seam for the distributed trainers: a configured
        // `data_cache` routes worker shard loads through the binary shard
        // cache (opened lazily at fit time, where errors can surface);
        // otherwise workers slice the in-memory training set as always.
        let shard_source = match &cfg.data_cache {
            Some(dir) => crate::data::ShardSource::Cache(dir.clone()),
            None => crate::data::ShardSource::InMemory,
        };
        match self {
            TrainerKind::Nomad => Box::new(NomadTrainer::new(
                cfg.fm,
                crate::nomad::NomadConfig {
                    workers: cfg.workers,
                    outer_iters: cfg.outer_iters,
                    eta: cfg.eta,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every,
                    transport: cfg.transport,
                    update_mode: cfg.update_mode,
                    cols_per_token: cfg.cols_per_token,
                    row_partition: cfg.row_partition,
                    source: shard_source,
                },
            )),
            TrainerKind::Libfm => Box::new(LibfmTrainer::new(
                cfg.fm,
                crate::baseline::LibfmConfig {
                    epochs: cfg.outer_iters,
                    eta: cfg.eta,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every,
                    // Cache-fed runs stream shards in ingested row order;
                    // the in-memory run of the same config visits rows in
                    // the same order so the two traces stay bitwise
                    // comparable (the parity suite pins this).
                    shuffle: cfg.data_cache.is_none()
                        && !matches!(cfg.dataset, crate::config::DatasetSpec::Cache { .. }),
                },
            )),
            TrainerKind::Dsgd => Box::new(DsgdTrainer::new(
                cfg.fm,
                crate::baseline::DsgdConfig {
                    epochs: cfg.outer_iters,
                    eta: cfg.eta,
                    workers: cfg.workers,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every,
                    row_partition: cfg.row_partition,
                    source: shard_source,
                },
            )),
            TrainerKind::BulkSync => Box::new(BulkSyncTrainer::new(
                cfg.fm,
                crate::baseline::BulkSyncConfig {
                    iters: cfg.outer_iters,
                    eta: cfg.eta,
                    workers: cfg.workers,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every,
                    row_partition: cfg.row_partition,
                    source: shard_source,
                },
            )),
            TrainerKind::XlaDense => Box::new(XlaDenseTrainer::new(
                cfg.fm,
                trainers::XlaDenseConfig {
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    epochs: cfg.outer_iters,
                    eta: cfg.eta,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every,
                },
            )),
        }
    }
}

/// Computes one convergence-trace point: the regularized training objective
/// (paper eq. 5), the mean training loss, and — when `test` is given —
/// held-out metrics. Cadence gating is the caller's job: pass
/// `test.filter(|_| iter % eval_every == 0)`. Scoring runs through the
/// fused lane-blocked kernel (one layout conversion, amortized over the
/// dataset sweep).
pub fn trace_point(
    train: &Dataset,
    test: Option<&Dataset>,
    lambda_w: f32,
    lambda_v: f32,
    iter: usize,
    secs: f64,
    model: &FmModel,
) -> TracePoint {
    let kern = crate::kernel::FmKernel::from_model(model);
    let mut scratch = crate::kernel::Scratch::for_k(model.k);
    let data_loss = kern.data_loss(train, &mut scratch);
    let rw: f64 = model.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let rv: f64 = model.v.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let objective = data_loss + 0.5 * lambda_w as f64 * rw + 0.5 * lambda_v as f64 * rv;
    TracePoint {
        iter,
        secs,
        objective,
        train_loss: data_loss,
        test: test.map(|ts| evaluate(model, ts)),
    }
}

/// Streaming counterpart of [`trace_point`]'s objective for callers that
/// never hold the full matrix: one `f64` fold over the shards of `src`
/// visited in partition order, peak memory one shard. The cluster driver
/// uses this to report the pre-training (iter 0) objective straight off the
/// shard cache.
///
/// Returns `(objective, train_loss)`. For a contiguous partition the shard
/// sweep visits rows in exactly the global order [`FmKernel::data_loss`]
/// uses, so the fold is bitwise-identical to the in-memory path — the same
/// accumulator, the same addition order.
///
/// [`FmKernel::data_loss`]: crate::kernel::FmKernel::data_loss
pub fn streaming_objective(
    src: &dyn crate::data::DataSource,
    part: &crate::partition::RowPartition,
    model: &FmModel,
    lambda_w: f32,
    lambda_v: f32,
) -> crate::Result<(f64, f64)> {
    let kern = crate::kernel::FmKernel::from_model(model);
    let mut scratch = crate::kernel::Scratch::for_k(model.k);
    let mut total = 0f64;
    for id in 0..part.n_shards() {
        let shard = src.shard(part, id)?;
        for r in 0..shard.nloc() {
            let (idx, val) = shard.rows.row(r);
            let f = kern.score(idx, val, &mut scratch);
            total += crate::fm::loss::loss(f, shard.labels[r], shard.task) as f64;
        }
    }
    let train_loss = total / src.n().max(1) as f64;
    let rw: f64 = model.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let rv: f64 = model.v.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let objective = train_loss + 0.5 * lambda_w as f64 * rw + 0.5 * lambda_v as f64 * rv;
    Ok((objective, train_loss))
}

/// [`trace_point`] off a [`DataSource`]: the same objective / train-loss
/// fold as [`streaming_objective`] packaged as a [`TracePoint`] (no
/// held-out metrics — a streaming run has no test split; evaluate with
/// [`streaming_eval`] instead). Bitwise identical to
/// `trace_point(train, None, ...)` on the materialized dataset for any
/// partition whose shards cover rows in global order — which both
/// `contiguous` and `balanced` plans do.
///
/// [`DataSource`]: crate::data::DataSource
pub fn streaming_trace_point(
    src: &dyn crate::data::DataSource,
    part: &crate::partition::RowPartition,
    model: &FmModel,
    lambda_w: f32,
    lambda_v: f32,
    iter: usize,
    secs: f64,
) -> crate::Result<TracePoint> {
    let (objective, train_loss) = streaming_objective(src, part, model, lambda_w, lambda_v)?;
    Ok(TracePoint {
        iter,
        secs,
        objective,
        train_loss,
        test: None,
    })
}

/// [`evaluate`] off a [`DataSource`], shard by shard: scores land in a
/// global buffer at `shard.start + r`, so the score vector — and every
/// derived metric — is bitwise identical to
/// [`evaluate`]`(model, &src.materialize()?)` while peak resident data
/// stays one shard (plus the `n`-length score/label buffers).
///
/// [`DataSource`]: crate::data::DataSource
pub fn streaming_eval(
    src: &dyn crate::data::DataSource,
    part: &crate::partition::RowPartition,
    model: &FmModel,
) -> crate::Result<crate::metrics::EvalMetrics> {
    let kern = crate::kernel::FmKernel::from_model(model);
    let mut scratch = crate::kernel::Scratch::for_k(model.k);
    let mut scores = vec![0f32; src.n()];
    let mut labels = vec![0f32; src.n()];
    for id in 0..part.n_shards() {
        let shard = src.shard(part, id)?;
        for r in 0..shard.nloc() {
            let (idx, val) = shard.rows.row(r);
            scores[shard.start + r] = kern.score(idx, val, &mut scratch);
            labels[shard.start + r] = shard.labels[r];
        }
    }
    Ok(crate::metrics::evaluate_scores(&scores, &labels, src.task()))
}

/// The [`streaming_objective`] fold over shards that are already
/// resident (the DSGD / bulk-sync epoch loops hold every worker's shard
/// for the whole session) — same accumulator, same order, no re-read.
fn shards_trace_point(
    shards: &[crate::partition::Shard],
    n: usize,
    model: &FmModel,
    lambda_w: f32,
    lambda_v: f32,
    iter: usize,
    secs: f64,
) -> TracePoint {
    let kern = crate::kernel::FmKernel::from_model(model);
    let mut scratch = crate::kernel::Scratch::for_k(model.k);
    let mut total = 0f64;
    for shard in shards {
        for r in 0..shard.nloc() {
            let (idx, val) = shard.rows.row(r);
            let f = kern.score(idx, val, &mut scratch);
            total += crate::fm::loss::loss(f, shard.labels[r], shard.task) as f64;
        }
    }
    let train_loss = total / n.max(1) as f64;
    let rw: f64 = model.w.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let rv: f64 = model.v.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let objective = train_loss + 0.5 * lambda_w as f64 * rw + 0.5 * lambda_v as f64 * rv;
    TracePoint {
        iter,
        secs,
        objective,
        train_loss,
        test: None,
    }
}

/// Shared per-session recording helper used by the trainer loops: computes
/// each [`TracePoint`] (objective, train loss, cadenced test metrics),
/// accumulates the trace for [`TrainOutput`], and dispatches every point to
/// the session's observer. Trainer loops reduce to
/// `if probe.record(iter, clock, &model, obs).is_stop() { break }`.
pub struct Probe<'a> {
    data: ProbeData<'a>,
    lambda_w: f32,
    lambda_v: f32,
    eval_every: usize,
    trace: Vec<TracePoint>,
}

/// Where a [`Probe`] computes its objective from. The three variants are
/// bitwise interchangeable: all fold the same per-row losses in global
/// row order with the same `f64` accumulator.
enum ProbeData<'a> {
    /// The classic path: full training set (+ optional held-out set).
    Memory {
        train: &'a Dataset,
        test: Option<&'a Dataset>,
    },
    /// One shard resident at a time, read back through the seam each
    /// record (the streaming libFM loop, which holds no shards between
    /// epochs).
    Stream {
        src: &'a dyn crate::data::DataSource,
        part: &'a crate::partition::RowPartition,
    },
    /// Already-resident shards (DSGD / bulk-sync keep every worker's
    /// shard live for the session) — no re-read per record.
    Shards {
        shards: &'a [crate::partition::Shard],
        n: usize,
    },
}

impl<'a> Probe<'a> {
    /// New in-memory probe; `eval_every` controls how often test metrics
    /// are run.
    pub fn new(
        train: &'a Dataset,
        test: Option<&'a Dataset>,
        lambda_w: f32,
        lambda_v: f32,
        eval_every: usize,
    ) -> Self {
        Probe {
            data: ProbeData::Memory { train, test },
            lambda_w,
            lambda_v,
            eval_every: eval_every.max(1),
            trace: Vec::new(),
        }
    }

    /// A probe that computes each point shard-by-shard off a
    /// [`DataSource`] (no held-out metrics; record through
    /// [`try_record`](Self::try_record), since shard loads can fail).
    ///
    /// [`DataSource`]: crate::data::DataSource
    pub fn streaming(
        src: &'a dyn crate::data::DataSource,
        part: &'a crate::partition::RowPartition,
        lambda_w: f32,
        lambda_v: f32,
        eval_every: usize,
    ) -> Self {
        Probe {
            data: ProbeData::Stream { src, part },
            lambda_w,
            lambda_v,
            eval_every: eval_every.max(1),
            trace: Vec::new(),
        }
    }

    /// A probe over already-materialized shards covering `n` rows in
    /// partition order (no held-out metrics).
    pub fn from_shards(
        shards: &'a [crate::partition::Shard],
        n: usize,
        lambda_w: f32,
        lambda_v: f32,
        eval_every: usize,
    ) -> Self {
        Probe {
            data: ProbeData::Shards { shards, n },
            lambda_w,
            lambda_v,
            eval_every: eval_every.max(1),
            trace: Vec::new(),
        }
    }

    /// Records a point at outer iteration `iter` with training clock `secs`
    /// and reports it to `obs`. Returns the observer's decision.
    /// Infallible convenience for the in-memory variant — panics if a
    /// streaming probe's shard load fails (those callers use
    /// [`try_record`](Self::try_record)).
    pub fn record(
        &mut self,
        iter: usize,
        secs: f64,
        model: &FmModel,
        obs: &mut dyn TrainObserver,
    ) -> ControlFlow {
        self.try_record(iter, secs, model, obs)
            .expect("in-memory probe cannot fail")
    }

    /// [`record`](Self::record) with shard-load errors surfaced instead
    /// of panicking — the form the streaming trainer loops use.
    pub fn try_record(
        &mut self,
        iter: usize,
        secs: f64,
        model: &FmModel,
        obs: &mut dyn TrainObserver,
    ) -> crate::Result<ControlFlow> {
        let pt = match &self.data {
            ProbeData::Memory { train, test } => {
                let test = test.filter(|_| iter % self.eval_every == 0);
                trace_point(train, test, self.lambda_w, self.lambda_v, iter, secs, model)
            }
            ProbeData::Stream { src, part } => streaming_trace_point(
                *src,
                part,
                model,
                self.lambda_w,
                self.lambda_v,
                iter,
                secs,
            )?,
            ProbeData::Shards { shards, n } => {
                shards_trace_point(shards, *n, model, self.lambda_w, self.lambda_v, iter, secs)
            }
        };
        let flow = obs.on_iter(&pt, Some(model));
        self.trace.push(pt);
        Ok(flow)
    }

    /// Consumes the probe, yielding the accumulated trace.
    pub fn into_trace(self) -> Vec<TracePoint> {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Pcg64;

    #[test]
    fn control_flow_join_prefers_stop() {
        use ControlFlow::*;
        assert_eq!(Continue.join(Continue), Continue);
        assert_eq!(Continue.join(Stop), Stop);
        assert_eq!(Stop.join(Continue), Stop);
        assert!(Stop.is_stop());
        assert!(!Continue.is_stop());
    }

    #[test]
    fn trace_point_matches_objective() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        let mut rng = Pcg64::seeded(4);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let pt = trace_point(&ds, None, 1e-2, 1e-3, 5, 1.25, &model);
        assert_eq!(pt.iter, 5);
        assert!((pt.objective - model.objective(&ds, 1e-2, 1e-3)).abs() < 1e-9);
        assert!(pt.test.is_none());
    }

    #[test]
    fn probe_gates_eval_cadence() {
        let ds = synth::table2_dataset("housing", 5).unwrap();
        let (train, test) = ds.split(0.8, 6);
        let mut rng = Pcg64::seeded(7);
        let model = FmModel::init(train.d(), 4, 0.1, &mut rng);
        let mut probe = Probe::new(&train, Some(&test), 0.0, 0.0, 2);
        for i in 0..5 {
            assert_eq!(probe.record(i, i as f64, &model, &mut ()), ControlFlow::Continue);
        }
        let trace = probe.into_trace();
        assert_eq!(trace.len(), 5);
        for pt in &trace {
            assert_eq!(pt.test.is_some(), pt.iter % 2 == 0, "iter {}", pt.iter);
        }
    }

    #[test]
    fn streaming_objective_is_bitwise_trace_point() {
        use crate::data::{cache::ShardCacheSource, DataSource};
        use crate::partition::RowStrategy;
        let ds = synth::table2_dataset("housing", 11).unwrap();
        let mut rng = Pcg64::seeded(13);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let dir =
                std::env::temp_dir().join(format!("dsfacto_stream_obj_test_{}", strat.spec()));
            std::fs::remove_dir_all(&dir).ok();
            crate::data::cache::write_cache(&ds, strat, 3, &dir).unwrap();
            let src = ShardCacheSource::open(&dir).unwrap();
            let part = src.plan(strat, 3).unwrap();
            let (obj, loss) = streaming_objective(&src, &part, &model, 1e-2, 1e-3).unwrap();
            let pt = trace_point(&ds, None, 1e-2, 1e-3, 0, 0.0, &model);
            assert_eq!(obj.to_bits(), pt.objective.to_bits(), "{strat:?}");
            assert_eq!(loss.to_bits(), pt.train_loss.to_bits(), "{strat:?}");
            // The TracePoint wrapper and the streaming probe agree too.
            let spt = streaming_trace_point(&src, &part, &model, 1e-2, 1e-3, 0, 0.0).unwrap();
            assert_eq!(spt.objective.to_bits(), pt.objective.to_bits(), "{strat:?}");
            let mut probe = Probe::streaming(&src, &part, 1e-2, 1e-3, 1);
            probe.try_record(0, 0.0, &model, &mut ()).unwrap();
            assert_eq!(
                probe.into_trace()[0].objective.to_bits(),
                pt.objective.to_bits(),
                "{strat:?}"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn streaming_eval_is_bitwise_evaluate() {
        use crate::data::{cache::ShardCacheSource, DataSource};
        use crate::partition::RowStrategy;
        let ds = synth::table2_dataset("housing", 19).unwrap();
        let mut rng = Pcg64::seeded(23);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let want = evaluate(&model, &ds);
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let dir =
                std::env::temp_dir().join(format!("dsfacto_stream_eval_test_{}", strat.spec()));
            std::fs::remove_dir_all(&dir).ok();
            crate::data::cache::write_cache(&ds, strat, 3, &dir).unwrap();
            let src = ShardCacheSource::open(&dir).unwrap();
            let part = src.plan(strat, 3).unwrap();
            let got = streaming_eval(&src, &part, &model).unwrap();
            assert_eq!(got.loss.to_bits(), want.loss.to_bits(), "{strat:?}");
            assert_eq!(got.rmse.to_bits(), want.rmse.to_bits(), "{strat:?}");
            assert_eq!(got.accuracy.to_bits(), want.accuracy.to_bits(), "{strat:?}");
            assert_eq!(got.auc.to_bits(), want.auc.to_bits(), "{strat:?}");
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn build_names_match_kinds() {
        let cfg = ExperimentConfig::default();
        for kind in TrainerKind::all() {
            assert_eq!(kind.build(&cfg).name(), kind.name());
        }
    }
}
