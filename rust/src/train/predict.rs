//! The serving-side [`Predictor`] trait: one scoring interface over both
//! backends — the native Rust scorer ([`FmModel`]) and the AOT XLA `score`
//! artifact ([`XlaPredictor`]). Integration tests assert the two agree on
//! the Table-2 datasets.

use anyhow::ensure;

use crate::data::{Csr, Dataset};
use crate::fm::FmModel;
use crate::kernel::{FmKernel, Scratch};
use crate::runtime::{artifact_name_for, FmExecutable, Runtime};

/// Scores examples; the request-path abstraction.
pub trait Predictor {
    /// Backend name (for logs).
    fn name(&self) -> &'static str;

    /// Scores one sparse example.
    fn predict_one(&self, idx: &[u32], val: &[f32]) -> crate::Result<f32>;

    /// Scores every row of a sparse block into `out`
    /// (`out.len() == rows.n_rows()`).
    fn predict_batch(&self, rows: &Csr, out: &mut [f32]) -> crate::Result<()>;

    /// [`predict_batch`](Predictor::predict_batch) borrowing the caller's
    /// scratch arena, so a request loop that keeps one `Scratch` per
    /// connection allocates nothing per batch. The default ignores the
    /// arena and falls back to `predict_batch` (which may allocate);
    /// zero-alloc backends override it. Scores are identical to
    /// `predict_batch` either way.
    fn score_batch(&self, rows: &Csr, out: &mut [f32], scratch: &mut Scratch) -> crate::Result<()> {
        let _ = scratch;
        self.predict_batch(rows, out)
    }

    /// Convenience: scores a whole dataset.
    fn predict_dataset(&self, ds: &Dataset) -> crate::Result<Vec<f32>> {
        let mut out = vec![0f32; ds.n()];
        self.predict_batch(&ds.rows, &mut out)?;
        Ok(out)
    }
}

/// The native scorer: paper eq. 4's O(K nnz) rewrite. Single examples go
/// through the scalar `score_sparse`; batches build the fused lane-blocked
/// [`crate::kernel::FmKernel`] view once and amortize it over the block.
impl Predictor for FmModel {
    fn name(&self) -> &'static str {
        "native"
    }

    fn predict_one(&self, idx: &[u32], val: &[f32]) -> crate::Result<f32> {
        ensure!(idx.len() == val.len(), "index/value length mismatch");
        ensure!(
            idx.iter().all(|&j| (j as usize) < self.d),
            "feature index out of range for d={}",
            self.d
        );
        Ok(self.score_sparse(idx, val))
    }

    fn predict_batch(&self, rows: &Csr, out: &mut [f32]) -> crate::Result<()> {
        ensure!(
            out.len() == rows.n_rows(),
            "output buffer {} != rows {}",
            out.len(),
            rows.n_rows()
        );
        ensure!(
            rows.n_cols() <= self.d,
            "block width {} exceeds model d={}",
            rows.n_cols(),
            self.d
        );
        let kern = FmKernel::from_model(self);
        let mut scratch = Scratch::for_k(self.k);
        kern.score_batch(rows, out, &mut scratch);
        Ok(())
    }

    fn score_batch(&self, rows: &Csr, out: &mut [f32], scratch: &mut Scratch) -> crate::Result<()> {
        ensure!(
            out.len() == rows.n_rows(),
            "output buffer {} != rows {}",
            out.len(),
            rows.n_rows()
        );
        ensure!(
            rows.n_cols() <= self.d,
            "block width {} exceeds model d={}",
            rows.n_cols(),
            self.d
        );
        // Still builds the kernel view per call (the `FmKernel` impl below
        // skips even that); only the accumulators are borrowed.
        FmKernel::from_model(self).score_batch(rows, out, scratch);
        Ok(())
    }
}

/// The fused lane-blocked kernel served directly: the scoring server holds
/// a long-lived `FmKernel` per model generation and drives batches through
/// the borrowed-scratch path, so steady-state requests allocate nothing.
impl Predictor for FmKernel {
    fn name(&self) -> &'static str {
        "kernel"
    }

    fn predict_one(&self, idx: &[u32], val: &[f32]) -> crate::Result<f32> {
        ensure!(idx.len() == val.len(), "index/value length mismatch");
        ensure!(
            idx.iter().all(|&j| (j as usize) < self.d()),
            "feature index out of range for d={}",
            self.d()
        );
        Ok(self.score(idx, val, &mut Scratch::for_k(self.k())))
    }

    fn predict_batch(&self, rows: &Csr, out: &mut [f32]) -> crate::Result<()> {
        Predictor::score_batch(self, rows, out, &mut Scratch::for_k(self.k()))
    }

    fn score_batch(&self, rows: &Csr, out: &mut [f32], scratch: &mut Scratch) -> crate::Result<()> {
        ensure!(
            out.len() == rows.n_rows(),
            "output buffer {} != rows {}",
            out.len(),
            rows.n_rows()
        );
        ensure!(
            rows.n_cols() <= self.d(),
            "block width {} exceeds model d={}",
            rows.n_cols(),
            self.d()
        );
        FmKernel::score_batch(self, rows, out, scratch);
        Ok(())
    }
}

/// The XLA-artifact scorer: densifies rows into the artifact's fixed
/// (B, D) batch shape and executes the AOT-compiled `score` entry point
/// (the Pallas-kernel request path).
pub struct XlaPredictor {
    exec: FmExecutable,
    model: FmModel,
}

impl XlaPredictor {
    /// Wraps a loaded `score` executable around a model; the shapes must
    /// match the artifact's specialization.
    pub fn new(exec: FmExecutable, model: FmModel) -> crate::Result<Self> {
        ensure!(exec.spec.entry == "score", "not a score artifact");
        ensure!(
            exec.spec.d == model.d && exec.spec.k == model.k,
            "artifact (d={}, k={}) != model (d={}, k={})",
            exec.spec.d,
            exec.spec.k,
            model.d,
            model.k
        );
        Ok(XlaPredictor { exec, model })
    }

    /// Loads the score artifact matching the dataset's shape and binds it
    /// to `model`.
    pub fn for_dataset(artifacts_dir: &str, ds: &Dataset, model: FmModel) -> crate::Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        let exec = rt.load(&artifact_name_for(ds), "score")?;
        ensure!(
            exec.spec.d == ds.d(),
            "artifact d={} != dataset d={}",
            exec.spec.d,
            ds.d()
        );
        Self::new(exec, model)
    }

    /// The model this predictor serves.
    pub fn model(&self) -> &FmModel {
        &self.model
    }

    fn densify_rows(&self, rows: &Csr, start: usize, xbuf: &mut [f32]) -> usize {
        // The shared batch-densify path (also behind Dataset::densify_batch).
        rows.densify_rows(start, self.exec.batch(), self.exec.spec.d, xbuf)
    }
}

impl Predictor for XlaPredictor {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn predict_one(&self, idx: &[u32], val: &[f32]) -> crate::Result<f32> {
        let (b, d) = (self.exec.batch(), self.exec.spec.d);
        ensure!(idx.len() == val.len(), "index/value length mismatch");
        ensure!(
            idx.iter().all(|&j| (j as usize) < d),
            "feature index out of range for d={d}"
        );
        let mut xbuf = vec![0f32; b * d];
        for (j, v) in idx.iter().zip(val) {
            xbuf[*j as usize] = *v;
        }
        Ok(self.exec.score_batch(&self.model, &xbuf)?[0])
    }

    fn predict_batch(&self, rows: &Csr, out: &mut [f32]) -> crate::Result<()> {
        let (b, d) = (self.exec.batch(), self.exec.spec.d);
        ensure!(
            out.len() == rows.n_rows(),
            "output buffer {} != rows {}",
            out.len(),
            rows.n_rows()
        );
        ensure!(
            rows.n_cols() <= d,
            "block width {} exceeds artifact d={d}",
            rows.n_cols()
        );
        let mut xbuf = vec![0f32; b * d];
        let mut start = 0;
        while start < rows.n_rows() {
            let real = self.densify_rows(rows, start, &mut xbuf);
            let scores = self.exec.score_batch(&self.model, &xbuf)?;
            out[start..start + real].copy_from_slice(&scores[..real]);
            start += real;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Pcg64;

    // XLA-backed predictor tests live in rust/tests/runtime_integration.rs
    // (they need built artifacts); the native path is covered here.

    #[test]
    fn native_predictor_matches_scorer() {
        let ds = synth::table2_dataset("housing", 9).unwrap();
        let mut rng = Pcg64::seeded(10);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let p: &dyn Predictor = &model;
        let scores = p.predict_dataset(&ds).unwrap();
        assert_eq!(scores.len(), ds.n());
        for i in (0..ds.n()).step_by(37) {
            let (idx, val) = ds.rows.row(i);
            // The batch path runs the fused lane-blocked kernel; it must
            // agree with the scalar scorer to float accumulation noise.
            let want = model.score_sparse(idx, val);
            assert!(
                (scores[i] - want).abs() < 1e-5 * (1.0 + want.abs()),
                "row {i}: batch {} vs scalar {want}",
                scores[i]
            );
            assert_eq!(p.predict_one(idx, val).unwrap(), want);
        }
    }

    #[test]
    fn borrowed_scratch_batches_are_bitwise_equal() {
        let ds = synth::table2_dataset("housing", 9).unwrap();
        let mut rng = Pcg64::seeded(11);
        let model = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        let mut want = vec![0f32; ds.n()];
        model.predict_batch(&ds.rows, &mut want).unwrap();

        let mut scratch = Scratch::new();
        let mut got = vec![0f32; ds.n()];
        Predictor::score_batch(&model, &ds.rows, &mut got, &mut scratch).unwrap();
        assert_eq!(got, want, "FmModel::score_batch");

        let kern = FmKernel::from_model(&model);
        got.fill(0.0);
        Predictor::score_batch(&kern, &ds.rows, &mut got, &mut scratch).unwrap();
        assert_eq!(got, want, "FmKernel::score_batch");
        got.fill(0.0);
        kern.predict_batch(&ds.rows, &mut got).unwrap();
        assert_eq!(got, want, "FmKernel::predict_batch");
        let (idx, val) = ds.rows.row(3);
        assert_eq!(Predictor::predict_one(&kern, idx, val).unwrap(), want[3]);
        assert!(Predictor::predict_one(&kern, &[1_000_000], &[1.0]).is_err());
    }

    #[test]
    fn native_predictor_validates_shapes() {
        let model = FmModel::zeros(4, 2);
        assert!(model.predict_one(&[5], &[1.0]).is_err()); // index out of range
        assert!(model.predict_one(&[0, 1], &[1.0]).is_err()); // arity mismatch
        let rows = Csr::from_triplets(2, 3, &[(0, 0, 1.0), (1, 2, 2.0)]);
        let mut out = vec![0f32; 1];
        assert!(model.predict_batch(&rows, &mut out).is_err()); // wrong buffer
        let mut out = vec![0f32; 2];
        model.predict_batch(&rows, &mut out).unwrap(); // width 3 <= d 4
    }
}
