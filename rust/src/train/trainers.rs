//! The five [`Trainer`] implementations: DS-FACTO (NOMAD), the libFM /
//! DSGD / bulk-synchronous baselines, and the dense-minibatch trainer that
//! runs the update inside the AOT XLA `step` artifact.
//!
//! Each trainer owns a proper config struct; [`TrainerKind::build`]
//! (`crate::config::TrainerKind`) constructs them from an
//! [`ExperimentConfig`](crate::config::ExperimentConfig).

use std::cell::RefCell;

use crate::baseline::{
    bulksync_train_from_source, bulksync_train_with_stats, dsgd_train_from_source,
    dsgd_train_with_stats, libfm_train, libfm_train_from_source, BulkSyncConfig, DsgdConfig,
    LibfmConfig,
};
use crate::data::Dataset;
use crate::fm::{FmHyper, FmModel};
use crate::metrics::TrainOutput;
use crate::nomad::{self, EngineStats, NomadConfig};
use crate::optim::LrSchedule;
use crate::partition::PartitionStats;
use crate::runtime::{artifact_name_for, Runtime};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::{Probe, TrainObserver, Trainer};

/// DS-FACTO: the paper's hybrid-parallel NOMAD engine behind the session
/// API. Keeps the engine counters of the most recent run for
/// [`Trainer::stats`].
pub struct NomadTrainer {
    fm: FmHyper,
    cfg: NomadConfig,
    stats: RefCell<Option<EngineStats>>,
}

impl NomadTrainer {
    /// A trainer for the given hyper-parameters and engine config.
    pub fn new(fm: FmHyper, cfg: NomadConfig) -> Self {
        NomadTrainer {
            fm,
            cfg,
            stats: RefCell::new(None),
        }
    }
}

impl Trainer for NomadTrainer {
    fn name(&self) -> &'static str {
        "nomad"
    }

    fn fit(
        &self,
        train: &Dataset,
        test: Option<&Dataset>,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let (out, stats) = nomad::train_with_observer(train, test, &self.fm, &self.cfg, observer)?;
        *self.stats.borrow_mut() = Some(stats);
        observer.on_done(&out);
        Ok(out)
    }

    fn fit_source(
        &self,
        src: &dyn crate::data::DataSource,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let (out, stats) = nomad::train_from_source(src, &self.fm, &self.cfg, observer)?;
        *self.stats.borrow_mut() = Some(stats);
        observer.on_done(&out);
        Ok(out)
    }

    fn stats(&self) -> Option<EngineStats> {
        self.stats.borrow().clone()
    }

    fn partition_stats(&self) -> Option<PartitionStats> {
        self.stats.borrow().as_ref().map(|s| s.partition.clone())
    }
}

/// libFM-style single-machine SGD behind the session API.
pub struct LibfmTrainer {
    fm: FmHyper,
    cfg: LibfmConfig,
}

impl LibfmTrainer {
    /// A trainer for the given hyper-parameters and baseline config.
    pub fn new(fm: FmHyper, cfg: LibfmConfig) -> Self {
        LibfmTrainer { fm, cfg }
    }
}

impl Trainer for LibfmTrainer {
    fn name(&self) -> &'static str {
        "libfm"
    }

    fn fit(
        &self,
        train: &Dataset,
        test: Option<&Dataset>,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let out = libfm_train(train, test, &self.fm, &self.cfg, observer);
        observer.on_done(&out);
        Ok(out)
    }

    fn fit_source(
        &self,
        src: &dyn crate::data::DataSource,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        // A shard-backed source (its files fix the sweep order) streams
        // epoch by epoch; an unsharded source falls back to materializing.
        match src.native_plan() {
            Some(part) => {
                let out = libfm_train_from_source(src, &part, &self.fm, &self.cfg, observer)?;
                observer.on_done(&out);
                Ok(out)
            }
            None => {
                let ds = src.materialize()?;
                self.fit(&ds, None, observer)
            }
        }
    }
}

/// Synchronous block-cyclic DSGD behind the session API. Keeps the
/// row-shard load summary of the most recent run for
/// [`Trainer::partition_stats`].
pub struct DsgdTrainer {
    fm: FmHyper,
    cfg: DsgdConfig,
    partition: RefCell<Option<PartitionStats>>,
}

impl DsgdTrainer {
    /// A trainer for the given hyper-parameters and baseline config.
    pub fn new(fm: FmHyper, cfg: DsgdConfig) -> Self {
        DsgdTrainer {
            fm,
            cfg,
            partition: RefCell::new(None),
        }
    }
}

impl Trainer for DsgdTrainer {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn fit(
        &self,
        train: &Dataset,
        test: Option<&Dataset>,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let (out, pstats) = dsgd_train_with_stats(train, test, &self.fm, &self.cfg, observer)?;
        *self.partition.borrow_mut() = Some(pstats);
        observer.on_done(&out);
        Ok(out)
    }

    fn fit_source(
        &self,
        src: &dyn crate::data::DataSource,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let (out, pstats) = dsgd_train_from_source(src, &self.fm, &self.cfg, observer)?;
        *self.partition.borrow_mut() = Some(pstats);
        observer.on_done(&out);
        Ok(out)
    }

    fn partition_stats(&self) -> Option<PartitionStats> {
        self.partition.borrow().clone()
    }
}

/// Bulk-synchronous full-gradient descent behind the session API. Keeps
/// the row-shard load summary of the most recent run for
/// [`Trainer::partition_stats`].
pub struct BulkSyncTrainer {
    fm: FmHyper,
    cfg: BulkSyncConfig,
    partition: RefCell<Option<PartitionStats>>,
}

impl BulkSyncTrainer {
    /// A trainer for the given hyper-parameters and baseline config.
    pub fn new(fm: FmHyper, cfg: BulkSyncConfig) -> Self {
        BulkSyncTrainer {
            fm,
            cfg,
            partition: RefCell::new(None),
        }
    }
}

impl Trainer for BulkSyncTrainer {
    fn name(&self) -> &'static str {
        "bulksync"
    }

    fn fit(
        &self,
        train: &Dataset,
        test: Option<&Dataset>,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let (out, pstats) =
            bulksync_train_with_stats(train, test, &self.fm, &self.cfg, observer)?;
        *self.partition.borrow_mut() = Some(pstats);
        observer.on_done(&out);
        Ok(out)
    }

    fn fit_source(
        &self,
        src: &dyn crate::data::DataSource,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let (out, pstats) = bulksync_train_from_source(src, &self.fm, &self.cfg, observer)?;
        *self.partition.borrow_mut() = Some(pstats);
        observer.on_done(&out);
        Ok(out)
    }

    fn partition_stats(&self) -> Option<PartitionStats> {
        self.partition.borrow().clone()
    }
}

/// Configuration of the dense-minibatch XLA trainer.
#[derive(Debug, Clone)]
pub struct XlaDenseConfig {
    /// Directory holding the AOT artifacts (`manifest.txt`).
    pub artifacts_dir: String,
    /// Epochs (outer iterations).
    pub epochs: usize,
    /// Learning-rate schedule.
    pub eta: LrSchedule,
    /// RNG seed (model init).
    pub seed: u64,
    /// Evaluate held-out metrics every this many epochs.
    pub eval_every: usize,
}

impl Default for XlaDenseConfig {
    fn default() -> Self {
        XlaDenseConfig {
            artifacts_dir: "artifacts".into(),
            epochs: 50,
            eta: LrSchedule::default(),
            seed: 42,
            eval_every: 1,
        }
    }
}

/// Dense-minibatch SGD through the AOT `step` artifact: the trainer variant
/// that runs the paper's update entirely inside XLA (demonstrates the
/// L3->L2->L1 training path).
pub struct XlaDenseTrainer {
    fm: FmHyper,
    cfg: XlaDenseConfig,
}

impl XlaDenseTrainer {
    /// A trainer for the given hyper-parameters and artifact config.
    pub fn new(fm: FmHyper, cfg: XlaDenseConfig) -> Self {
        XlaDenseTrainer { fm, cfg }
    }
}

impl Trainer for XlaDenseTrainer {
    fn name(&self) -> &'static str {
        "xla-dense"
    }

    fn fit(
        &self,
        train: &Dataset,
        test: Option<&Dataset>,
        observer: &mut dyn TrainObserver,
    ) -> crate::Result<TrainOutput> {
        let fm = &self.fm;
        let cfg = &self.cfg;
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        let name = artifact_name_for(train);
        let step = rt.load(&name, "step")?;
        anyhow::ensure!(step.spec.d == train.d(), "artifact/dataset shape mismatch");
        let (b, k) = (step.spec.b, step.spec.k);
        anyhow::ensure!(
            k == fm.k,
            "artifact k={k} != config k={} (dense XLA trainer is shape-specialized)",
            fm.k
        );

        let mut rng = Pcg64::new(cfg.seed, 0x71a);
        let mut model = FmModel::init(train.d(), k, fm.init_std, &mut rng);
        let mut probe = Probe::new(train, test, fm.lambda_w, fm.lambda_v, cfg.eval_every);

        let mut xbuf = vec![0f32; b * train.d()];
        let mut ybuf = vec![0f32; b];
        let mut sw = Stopwatch::start();
        let mut clock = 0f64;
        let mut stopped = probe.record(0, 0.0, &model, observer).is_stop();
        sw.lap();

        let n_batches = train.n().div_ceil(b);
        for epoch in 0..cfg.epochs {
            if stopped {
                break;
            }
            let eta = cfg.eta.at(epoch);
            for bi in 0..n_batches {
                let start = bi * b;
                let real = train.densify_batch(start, b, &mut xbuf);
                train.labels_batch(start, b, &mut ybuf);
                // Padding rows have x=0, y=0: their squared-loss gradient
                // contribution is w0-only; rescale eta by real/b to keep the
                // batch-mean semantics approximately right on the tail batch.
                let eff_eta = eta * (real as f32 / b as f32);
                step.step_batch(&mut model, &xbuf, &ybuf, eff_eta, fm.lambda_w, fm.lambda_v)?;
            }
            clock += sw.lap();
            stopped = probe.record(epoch + 1, clock, &model, observer).is_stop();
            sw.lap();
        }

        let out = TrainOutput {
            model,
            trace: probe.into_trace(),
            wall_secs: clock,
        };
        observer.on_done(&out);
        Ok(out)
    }
}
