//! Binary model (de)serialization.
//!
//! Format (little-endian): magic `DSFM`, version u32, d u64, k u64, w0 f32,
//! then `w` (d f32s) and `V` (d*k f32s). Self-describing enough for the CLI
//! `inspect` subcommand and stable across runs.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::FmModel;

const MAGIC: &[u8; 4] = b"DSFM";
const VERSION: u32 = 1;

/// Serializes a model to a writer.
pub fn write_model<W: Write>(m: &FmModel, mut out: W) -> Result<()> {
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&(m.d as u64).to_le_bytes())?;
    out.write_all(&(m.k as u64).to_le_bytes())?;
    out.write_all(&m.w0.to_le_bytes())?;
    for &x in &m.w {
        out.write_all(&x.to_le_bytes())?;
    }
    for &x in &m.v {
        out.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Deserializes a model from a reader.
pub fn read_model<R: Read>(mut inp: R) -> Result<FmModel> {
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC {
        bail!("not a DSFM model file (bad magic {magic:?})");
    }
    let version = read_u32(&mut inp)?;
    if version != VERSION {
        bail!("unsupported model version {version}");
    }
    let d = read_u64(&mut inp)? as usize;
    let k = read_u64(&mut inp)? as usize;
    // Guard absurd sizes before allocating.
    if d.checked_mul(k.max(1)).map_or(true, |p| p > 1 << 34) {
        bail!("model dimensions too large: d={d} k={k}");
    }
    let w0 = read_f32(&mut inp)?;
    let mut w = vec![0f32; d];
    read_f32s(&mut inp, &mut w)?;
    let mut v = vec![0f32; d * k];
    read_f32s(&mut inp, &mut v)?;
    Ok(FmModel { d, k, w0, w, v })
}

/// Saves a model to a file (creating parent dirs).
pub fn save<P: AsRef<Path>>(m: &FmModel, path: P) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = std::fs::File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    write_model(m, std::io::BufWriter::new(file))
}

/// Loads a model from a file.
pub fn load<P: AsRef<Path>>(path: P) -> Result<FmModel> {
    let file = std::fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_model(std::io::BufReader::new(file))
}

fn read_u32<R: Read>(inp: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(inp: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32<R: Read>(inp: &mut R) -> Result<f32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn read_f32s<R: Read>(inp: &mut R, out: &mut [f32]) -> Result<()> {
    // Bulk read: reinterpret the output as bytes once, then fix endianness.
    let mut bytes = vec![0u8; out.len() * 4];
    inp.read_exact(&mut bytes)?;
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn model() -> FmModel {
        let mut rng = Pcg64::seeded(1);
        let mut m = FmModel::init(7, 3, 0.1, &mut rng);
        m.w0 = 1.5;
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 1.0);
        }
        m
    }

    #[test]
    fn roundtrip_in_memory() {
        let m = model();
        let mut buf = Vec::new();
        write_model(&m, &mut buf).unwrap();
        let back = read_model(&buf[..]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn roundtrip_on_disk() {
        let m = model();
        let path = std::env::temp_dir().join("dsfacto_io_test.dsfm");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let err = read_model(&b"NOPE...."[..]).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn rejects_truncated() {
        let m = model();
        let mut buf = Vec::new();
        write_model(&m, &mut buf).unwrap();
        buf.truncate(buf.len() - 5);
        assert!(read_model(&buf[..]).is_err());
    }

    #[test]
    fn rejects_future_version() {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&99u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 32]);
        let err = read_model(&buf[..]).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }
}
