//! Binary model (de)serialization.
//!
//! Format (little-endian): magic `DSFM`, version u32, d u64, k u64, w0 f32,
//! then `w` (d f32s) and `V` (d*k f32s). Self-describing enough for the CLI
//! `inspect` subcommand and stable across runs.
//!
//! Loading is strict, mirroring the shard-cache reader in
//! [`crate::data::cache`]: wrong magic, unsupported version, absurd
//! dimensions, truncation inside any section and trailing bytes after the
//! last factor are all hard errors with a section-naming context. A model
//! file either round-trips exactly or is rejected — never silently
//! zero-filled or partially read. [`save`] writes through a temp file and
//! renames it into place, so a concurrent reader (the serving reload
//! watcher) can never observe a half-written checkpoint.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::FmModel;

const MAGIC: &[u8; 4] = b"DSFM";
const VERSION: u32 = 1;

/// Serializes a model to a writer.
pub fn write_model<W: Write>(m: &FmModel, mut out: W) -> Result<()> {
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&(m.d as u64).to_le_bytes())?;
    out.write_all(&(m.k as u64).to_le_bytes())?;
    out.write_all(&m.w0.to_le_bytes())?;
    for &x in &m.w {
        out.write_all(&x.to_le_bytes())?;
    }
    for &x in &m.v {
        out.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Deserializes a model from a reader. Strict: the reader must hold
/// exactly one well-formed model and nothing after it.
pub fn read_model<R: Read>(mut inp: R) -> Result<FmModel> {
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC {
        bail!("not a DSFM model file (bad magic {magic:?})");
    }
    let version = read_u32(&mut inp).context("read version")?;
    if version != VERSION {
        bail!("unsupported model version {version}");
    }
    let d = read_u64(&mut inp).context("read d")? as usize;
    let k = read_u64(&mut inp).context("read k")? as usize;
    // Guard absurd sizes before allocating.
    if d.checked_mul(k.max(1)).map_or(true, |p| p > 1 << 34) {
        bail!("model dimensions too large: d={d} k={k}");
    }
    let w0 = read_f32(&mut inp).context("read w0")?;
    let mut w = vec![0f32; d];
    read_f32s(&mut inp, &mut w).context("model file truncated in w")?;
    let mut v = vec![0f32; d * k];
    read_f32s(&mut inp, &mut v).context("model file truncated in V")?;
    ensure_eof(&mut inp)?;
    Ok(FmModel { d, k, w0, w, v })
}

/// Saves a model to a file (creating parent dirs). The bytes land in a
/// sibling temp file first and are renamed into place, so readers racing
/// the save — notably `dsfacto serve`'s hot-reload watcher — see either
/// the old complete model or the new complete model, never a partial one.
pub fn save<P: AsRef<Path>>(m: &FmModel, path: P) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut tmp_name = path.file_name().unwrap_or_default().to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let file =
        std::fs::File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?;
    let mut out = std::io::BufWriter::new(file);
    write_model(m, &mut out)?;
    out.flush().context("flush model file")?;
    drop(out);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} into place", tmp.display()))
}

/// Loads a model from a file.
pub fn load<P: AsRef<Path>>(path: P) -> Result<FmModel> {
    let file = std::fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_model(std::io::BufReader::new(file))
}

/// Rejects any bytes after the last factor (a truncated-then-appended or
/// concatenated file is corrupt, not "close enough").
fn ensure_eof<R: Read>(inp: &mut R) -> Result<()> {
    let mut probe = [0u8; 1];
    loop {
        match inp.read(&mut probe) {
            Ok(0) => return Ok(()),
            Ok(_) => bail!("model file has trailing bytes after the factor matrix"),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("probe for trailing bytes"),
        }
    }
}

fn read_u32<R: Read>(inp: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(inp: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32<R: Read>(inp: &mut R) -> Result<f32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn read_f32s<R: Read>(inp: &mut R, out: &mut [f32]) -> Result<()> {
    // Bulk read: reinterpret the output as bytes once, then fix endianness.
    let mut bytes = vec![0u8; out.len() * 4];
    inp.read_exact(&mut bytes)?;
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn model() -> FmModel {
        let mut rng = Pcg64::seeded(1);
        let mut m = FmModel::init(7, 3, 0.1, &mut rng);
        m.w0 = 1.5;
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 1.0);
        }
        m
    }

    #[test]
    fn roundtrip_in_memory() {
        let m = model();
        let mut buf = Vec::new();
        write_model(&m, &mut buf).unwrap();
        let back = read_model(&buf[..]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn roundtrip_on_disk() {
        let m = model();
        let path = std::env::temp_dir().join("dsfacto_io_test.dsfm");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_leaves_no_temp_file_behind() {
        let dir = std::env::temp_dir().join("dsfacto_io_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("model.dsfm");
        save(&model(), &path).unwrap();
        let names: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names, vec!["model.dsfm".to_string()], "{names:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let err = read_model(&b"NOPE...."[..]).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn rejects_truncation_at_every_section() {
        let m = model();
        let mut buf = Vec::new();
        write_model(&m, &mut buf).unwrap();
        // Header, inside w (after w0 at 4+4+8+8+4 = 28 bytes), inside V.
        for cut in [2, 10, 20, 28 + 3, 28 + 4 * m.d - 1, buf.len() - 5] {
            let err = format!("{:#}", read_model(&buf[..cut]).unwrap_err());
            assert!(
                err.contains("read") || err.contains("truncated"),
                "cut={cut}: {err}"
            );
        }
    }

    #[test]
    fn rejects_trailing_bytes() {
        let m = model();
        let mut buf = Vec::new();
        write_model(&m, &mut buf).unwrap();
        buf.push(0);
        let err = read_model(&buf[..]).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        // A whole second model appended is equally corrupt.
        let mut twice = Vec::new();
        write_model(&m, &mut twice).unwrap();
        write_model(&m, &mut twice).unwrap();
        assert!(read_model(&twice[..]).is_err());
    }

    #[test]
    fn rejects_absurd_dimensions() {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&u64::MAX.to_le_bytes()); // d
        buf.extend_from_slice(&u64::MAX.to_le_bytes()); // k
        let err = read_model(&buf[..]).unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn rejects_future_version() {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&99u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 32]);
        let err = read_model(&buf[..]).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }
}
