//! Losses and their multipliers G_i = dl/df (paper eq. 9).
//!
//! Mirrors `python/compile/kernels/ref.py` exactly — the Rust trainers and
//! the AOT artifacts must agree on these formulas (tested both here and in
//! the integration suite against artifact outputs).

use crate::data::Task;

/// Per-example loss l(f, y).
#[inline]
pub fn loss(f: f32, y: f32, task: Task) -> f32 {
    match task {
        Task::Regression => 0.5 * (f - y) * (f - y),
        Task::Classification => {
            // log(1 + exp(-y f)), stable for large |f|.
            let m = -y * f;
            if m > 30.0 {
                m
            } else {
                m.exp().ln_1p()
            }
        }
    }
}

/// The multiplier G_i = dl/df (paper eq. 9).
#[inline]
pub fn multiplier(f: f32, y: f32, task: Task) -> f32 {
    match task {
        Task::Regression => f - y,
        Task::Classification => {
            let z = y * f;
            // -y / (1 + exp(y f)), stable on both tails.
            if z > 30.0 {
                0.0
            } else if z < -30.0 {
                -y
            } else {
                -y / (1.0 + z.exp())
            }
        }
    }
}

/// Hard prediction from a score.
#[inline]
pub fn predict(f: f32, task: Task) -> f32 {
    match task {
        Task::Regression => f,
        Task::Classification => {
            if f >= 0.0 {
                1.0
            } else {
                -1.0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squared_loss_and_grad() {
        assert_eq!(loss(3.0, 1.0, Task::Regression), 2.0);
        assert_eq!(multiplier(3.0, 1.0, Task::Regression), 2.0);
        assert_eq!(multiplier(1.0, 3.0, Task::Regression), -2.0);
    }

    #[test]
    fn logistic_loss_known_values() {
        // l(0, y) = ln 2 for either label.
        assert!((loss(0.0, 1.0, Task::Classification) - 2f32.ln()).abs() < 1e-6);
        assert!((loss(0.0, -1.0, Task::Classification) - 2f32.ln()).abs() < 1e-6);
        // G(0, 1) = -1/2.
        assert!((multiplier(0.0, 1.0, Task::Classification) + 0.5).abs() < 1e-6);
    }

    #[test]
    fn logistic_stable_at_extremes() {
        for &(f, y) in &[(1e5f32, -1.0f32), (-1e5, 1.0), (1e5, 1.0), (-1e5, -1.0)] {
            assert!(loss(f, y, Task::Classification).is_finite());
            assert!(multiplier(f, y, Task::Classification).is_finite());
        }
        // Confident-correct gradient goes to 0; confident-wrong to -y.
        assert_eq!(multiplier(100.0, 1.0, Task::Classification), 0.0);
        assert!((multiplier(-100.0, 1.0, Task::Classification) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn multiplier_is_loss_derivative() {
        // Finite-difference check over a grid.
        let eps = 1e-3f32;
        for task in [Task::Regression, Task::Classification] {
            for f in [-2.0f32, -0.5, 0.0, 0.7, 2.5] {
                for y in [-1.0f32, 1.0] {
                    let num = (loss(f + eps, y, task) - loss(f - eps, y, task)) / (2.0 * eps);
                    let ana = multiplier(f, y, task);
                    assert!(
                        (num - ana).abs() < 5e-3,
                        "task={task:?} f={f} y={y}: {num} vs {ana}"
                    );
                }
            }
        }
    }

    #[test]
    fn predictions() {
        assert_eq!(predict(0.3, Task::Regression), 0.3);
        assert_eq!(predict(0.3, Task::Classification), 1.0);
        assert_eq!(predict(-0.3, Task::Classification), -1.0);
    }
}
