//! Factorization Machine model: parameters, scoring (paper eqs. 2-4),
//! losses/multipliers (eq. 9), gradients (eqs. 6-8) and (de)serialization.
//!
//! Everything here is the *single-node* model math. The distributed
//! coordination that is the paper's contribution lives in [`crate::nomad`];
//! the AOT-compiled dense-batch versions of these same equations live in
//! `python/compile/` and are executed through [`crate::runtime`].

pub mod io;
pub mod loss;


use crate::util::rng::Pcg64;

/// Hyper-parameters of an FM model (paper Table 1).
#[derive(Debug, Clone, Copy)]
pub struct FmHyper {
    /// Number of latent factors K.
    pub k: usize,
    /// L2 penalty on the linear weights (lambda_w).
    pub lambda_w: f32,
    /// L2 penalty on the factors (lambda_v).
    pub lambda_v: f32,
    /// Std-dev of the factor initialization (paper: N(0, 0.01)).
    pub init_std: f32,
}

impl Default for FmHyper {
    fn default() -> Self {
        FmHyper {
            k: 4,
            lambda_w: 1e-4,
            lambda_v: 1e-4,
            init_std: 0.01,
        }
    }
}

/// FM parameters: `w0`, `w in R^D`, `V in R^{D x K}` (row-major, K
/// contiguous per feature — the token layout the NOMAD engine circulates).
#[derive(Debug, Clone, PartialEq)]
pub struct FmModel {
    pub d: usize,
    pub k: usize,
    pub w0: f32,
    pub w: Vec<f32>,
    pub v: Vec<f32>,
}

impl FmModel {
    /// All-zero model.
    pub fn zeros(d: usize, k: usize) -> Self {
        FmModel {
            d,
            k,
            w0: 0.0,
            w: vec![0.0; d],
            v: vec![0.0; d * k],
        }
    }

    /// Paper initialization: `w = 0`, `V ~ N(0, init_std)` (Algorithm 1 l.4).
    pub fn init(d: usize, k: usize, init_std: f32, rng: &mut Pcg64) -> Self {
        let mut m = FmModel::zeros(d, k);
        for x in m.v.iter_mut() {
            *x = rng.normal32(0.0, init_std);
        }
        m
    }

    /// The factor row `v_j` (length K).
    #[inline]
    pub fn vrow(&self, j: usize) -> &[f32] {
        &self.v[j * self.k..(j + 1) * self.k]
    }

    /// Mutable factor row `v_j`.
    #[inline]
    pub fn vrow_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.v[j * self.k..(j + 1) * self.k]
    }

    /// Computes the factor sums `a_k = sum_j v_jk x_j` (paper eq. 10) into
    /// `a` (length K) and returns `sum_k v_jk^2 x_j^2` accumulated in `s2`.
    #[inline]
    pub fn factor_sums(&self, idx: &[u32], val: &[f32], a: &mut [f32], s2: &mut [f32]) {
        debug_assert_eq!(a.len(), self.k);
        debug_assert_eq!(s2.len(), self.k);
        a.fill(0.0);
        s2.fill(0.0);
        for (j, x) in idx.iter().zip(val) {
            let vj = self.vrow(*j as usize);
            let x = *x;
            for k in 0..self.k {
                let vx = vj[k] * x;
                a[k] += vx;
                s2[k] += vx * vx;
            }
        }
    }

    /// FM score of a sparse example via the O(K nnz) rewrite (eq. 4).
    pub fn score_sparse(&self, idx: &[u32], val: &[f32]) -> f32 {
        let mut linear = self.w0;
        for (j, x) in idx.iter().zip(val) {
            linear += self.w[*j as usize] * x;
        }
        let mut pair = 0f32;
        // Stack buffers for the common small-K case; heap for large K.
        if self.k <= 32 {
            let mut a = [0f32; 32];
            let mut s2 = [0f32; 32];
            self.factor_sums(idx, val, &mut a[..self.k], &mut s2[..self.k]);
            for k in 0..self.k {
                pair += a[k] * a[k] - s2[k];
            }
        } else {
            let mut a = vec![0f32; self.k];
            let mut s2 = vec![0f32; self.k];
            self.factor_sums(idx, val, &mut a, &mut s2);
            for k in 0..self.k {
                pair += a[k] * a[k] - s2[k];
            }
        }
        linear + 0.5 * pair
    }

    /// Score plus the factor sums `a` (callers that need eq. 10's cache).
    ///
    /// Both `a` and `s2` (length K each) are caller-provided scratch so
    /// the hot loop stays allocation-free; hot paths should prefer the
    /// fused [`crate::kernel::FmKernel::score_with_sums`], which also
    /// single-passes the non-zeros.
    pub fn score_with_sums(&self, idx: &[u32], val: &[f32], a: &mut [f32], s2: &mut [f32]) -> f32 {
        self.factor_sums(idx, val, a, s2);
        let mut linear = self.w0;
        for (j, x) in idx.iter().zip(val) {
            linear += self.w[*j as usize] * x;
        }
        let mut pair = 0f32;
        for k in 0..self.k {
            pair += a[k] * a[k] - s2[k];
        }
        linear + 0.5 * pair
    }

    /// Naive O(K nnz^2) score via eq. 2 — test oracle for the rewrite.
    pub fn score_naive(&self, idx: &[u32], val: &[f32]) -> f32 {
        let mut f = self.w0;
        for (j, x) in idx.iter().zip(val) {
            f += self.w[*j as usize] * x;
        }
        for (p, (j, xj)) in idx.iter().zip(val).enumerate() {
            for (jp, xjp) in idx.iter().zip(val).skip(p + 1) {
                let (vj, vjp) = (self.vrow(*j as usize), self.vrow(*jp as usize));
                let dot: f32 = vj.iter().zip(vjp).map(|(a, b)| a * b).sum();
                f += dot * xj * xjp;
            }
        }
        f
    }

    /// The regularized objective (paper eq. 5) over a dataset, computed
    /// through the fused lane-blocked kernel (one layout conversion per
    /// call, amortized over the whole dataset).
    pub fn objective(&self, ds: &crate::data::Dataset, lambda_w: f32, lambda_v: f32) -> f64 {
        let kern = crate::kernel::FmKernel::from_model(self);
        let mut scratch = crate::kernel::Scratch::for_k(self.k);
        kern.objective(ds, lambda_w, lambda_v, &mut scratch)
    }

    /// Total parameter count (for logs).
    pub fn n_params(&self) -> usize {
        1 + self.d + self.d * self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::prop::forall_res;

    fn random_model(d: usize, k: usize, seed: u64) -> FmModel {
        let mut rng = Pcg64::seeded(seed);
        let mut m = FmModel::init(d, k, 0.3, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.5);
        }
        m.w0 = 0.7;
        m
    }

    #[test]
    fn zeros_scores_zero() {
        let m = FmModel::zeros(5, 3);
        assert_eq!(m.score_sparse(&[0, 4], &[1.0, 2.0]), 0.0);
        assert_eq!(m.n_params(), 1 + 5 + 15);
    }

    #[test]
    fn rewrite_matches_naive() {
        // Paper eq. 3: O(K nnz) rewrite == O(K nnz^2) double sum.
        let m = random_model(10, 4, 1);
        let idx = [0u32, 3, 7, 9];
        let val = [0.5f32, -1.0, 2.0, 0.25];
        let fast = m.score_sparse(&idx, &val);
        let naive = m.score_naive(&idx, &val);
        assert!((fast - naive).abs() < 1e-4, "{fast} vs {naive}");
    }

    #[test]
    fn prop_rewrite_matches_naive() {
        forall_res(
            "eq3 rewrite equals naive pairwise sum",
            64,
            |rng| {
                let d = 2 + rng.below_usize(20);
                let k = 1 + rng.below_usize(8);
                let m = random_model(d, k, rng.next_u64());
                let nnz = 1 + rng.below_usize(d);
                let cols = rng.sample_indices(d, nnz);
                let mut idx: Vec<u32> = cols.iter().map(|&c| c as u32).collect();
                idx.sort_unstable();
                let val: Vec<f32> = idx.iter().map(|_| rng.normal32(0.0, 1.0)).collect();
                (m, idx, val)
            },
            |(m, idx, val)| {
                let fast = m.score_sparse(idx, val);
                let naive = m.score_naive(idx, val);
                let tol = 1e-3 * (1.0 + naive.abs());
                if (fast - naive).abs() < tol {
                    Ok(())
                } else {
                    Err(format!("fast {fast} != naive {naive}"))
                }
            },
        );
    }

    #[test]
    fn score_with_sums_returns_eq10() {
        let m = random_model(6, 3, 2);
        let idx = [1u32, 4];
        let val = [2.0f32, -0.5];
        let mut a = vec![0f32; 3];
        let mut s2 = vec![0f32; 3];
        let f = m.score_with_sums(&idx, &val, &mut a, &mut s2);
        assert!((f - m.score_sparse(&idx, &val)).abs() < 1e-6);
        for k in 0..3 {
            let want = m.vrow(1)[k] * 2.0 + m.vrow(4)[k] * -0.5;
            assert!((a[k] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn large_k_heap_path() {
        let m = random_model(8, 40, 3);
        let idx = [0u32, 2, 5];
        let val = [1.0f32, 1.0, 1.0];
        let fast = m.score_sparse(&idx, &val);
        let naive = m.score_naive(&idx, &val);
        assert!((fast - naive).abs() < 2e-3 * (1.0 + naive.abs()));
    }

    #[test]
    fn objective_includes_regularizer() {
        let ds = synth::table2_dataset("housing", 11).unwrap();
        let m = random_model(ds.d(), 4, 4);
        let o0 = m.objective(&ds, 0.0, 0.0);
        let o1 = m.objective(&ds, 1.0, 1.0);
        let rw: f64 = m.w.iter().map(|&x| (x as f64).powi(2)).sum();
        let rv: f64 = m.v.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((o1 - o0 - 0.5 * (rw + rv)).abs() < 1e-6);
    }

    #[test]
    fn init_matches_paper_scheme() {
        let mut rng = Pcg64::seeded(5);
        let m = FmModel::init(100, 8, 0.01, &mut rng);
        assert!(m.w.iter().all(|&x| x == 0.0), "w starts at zero");
        assert_eq!(m.w0, 0.0);
        let std: f32 = (m.v.iter().map(|&x| x * x).sum::<f32>() / m.v.len() as f32).sqrt();
        assert!((std - 0.01).abs() < 0.002, "factor std {std}");
    }
}
