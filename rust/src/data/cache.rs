//! The versioned binary shard cache: the on-disk format behind
//! [`ShardCacheSource`].
//!
//! A cache is a directory written by
//! [`crate::data::libsvm::stream_ingest`] (or [`write_cache`] from an
//! in-memory dataset):
//!
//! ```text
//! <dir>/manifest.dsfc     header + dataset shape + row partition + per-shard records
//! <dir>/shard_00000.dsfs  shard 0: labels + local CSR segments
//! <dir>/shard_00001.dsfs  ...one file per shard of the cached RowPartition
//! ```
//!
//! All integers and floats are **little-endian**; floats are stored as
//! their IEEE-754 bit patterns, so a cache round-trip is bit-exact.
//! Layouts (EXPERIMENTS.md §Data documents the same tables):
//!
//! * **Manifest**: magic `"DSFC"`, version `u32`, `n`/`d`/`nnz` as `u64`,
//!   task `u8` (0 = regression, 1 = classification), row strategy `u8`
//!   (0 = contiguous, 1 = balanced), shard count `p` as `u64`, dataset
//!   name (`u32` length + UTF-8 bytes), then `p` shard records of
//!   `start`/`end`/`nnz`/`file hash` (each `u64`), and a trailing `u64`
//!   FNV-1a hash over every preceding manifest byte. Truncation, trailing
//!   bytes, bit flips and version skew are all rejected at
//!   [`ShardCacheSource::open`].
//! * **Shard file**: magic `"DSFS"`, version `u32`, `id`/`start`/`end`/
//!   `d`/`nnz` as `u64`, task `u8`, then the segments: labels
//!   (`nloc x f32`), local `indptr` (`(nloc+1) x u64`, `indptr[0] = 0`),
//!   column indices (`nnz x u32`), values (`nnz x f32`). The manifest
//!   records each file's FNV-1a hash, so shard corruption is caught at
//!   load time even when the header still parses.
//!
//! The reader holds at most **one shard file** in memory at a time
//! (`materialize` streams shard by shard); nothing in this module ever
//! allocates the full-dataset CSR while serving shards — that is the
//! out-of-core contract the `DataSource` seam exists for.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Context, Result};

use crate::partition::{RowPartition, RowStrategy, Shard};

use super::source::DataSource;
use super::{Csr, Dataset, Task};

/// On-disk format version (bump on any layout change).
pub const CACHE_VERSION: u32 = 1;
/// Manifest file name inside a cache directory.
pub const MANIFEST_FILE: &str = "manifest.dsfc";

const MANIFEST_MAGIC: [u8; 4] = *b"DSFC";
const SHARD_MAGIC: [u8; 4] = *b"DSFS";

/// Shard `id`'s file name inside a cache directory.
pub fn shard_file_name(id: usize) -> String {
    format!("shard_{id:05}.dsfs")
}

/// FNV-1a 64-bit hash (the cache's corruption check; no crates, `std`
/// only, deterministic across platforms).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn task_byte(task: Task) -> u8 {
    match task {
        Task::Regression => 0,
        Task::Classification => 1,
    }
}

fn task_from_byte(b: u8) -> Result<Task> {
    match b {
        0 => Ok(Task::Regression),
        1 => Ok(Task::Classification),
        other => bail!("unknown task byte {other}"),
    }
}

fn strategy_byte(s: RowStrategy) -> u8 {
    match s {
        RowStrategy::Contiguous => 0,
        RowStrategy::NnzBalanced => 1,
    }
}

fn strategy_from_byte(b: u8) -> Result<RowStrategy> {
    match b {
        0 => Ok(RowStrategy::Contiguous),
        1 => Ok(RowStrategy::NnzBalanced),
        other => bail!("unknown row-strategy byte {other}"),
    }
}

// ---------------------------------------------------------------------
// Little-endian slice reader (exact-length, no std::io churn).

struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated: need {} bytes at offset {}, file has {}",
            n,
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize64(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("64-bit count overflows usize")
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes after the last segment",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    push_u32(out, v.to_bits());
}

// ---------------------------------------------------------------------
// Writer.

/// One shard's data, ready to serialize (borrowed from the ingester's
/// per-shard assembly buffers or from an in-memory dataset slice).
pub struct ShardPayload<'a> {
    /// Shard id (position in the partition).
    pub id: usize,
    /// Global row range `[start, end)`.
    pub start: usize,
    /// Exclusive end of the global row range.
    pub end: usize,
    /// Total feature count D.
    pub d: usize,
    /// Task (copied into every shard header for self-description).
    pub task: Task,
    /// Labels, length `end - start`.
    pub labels: &'a [f32],
    /// Local CSR row pointers, length `end - start + 1`, `indptr[0] = 0`.
    pub indptr: &'a [usize],
    /// Column indices, length `indptr[last]`.
    pub indices: &'a [u32],
    /// Values, same length as `indices`.
    pub values: &'a [f32],
}

impl ShardPayload<'_> {
    /// Serialized size in bytes (header + segments).
    pub fn byte_len(&self) -> usize {
        4 + 4 + 5 * 8 + 1 + 4 * self.labels.len() + 8 * self.indptr.len() + 8 * self.indices.len()
    }
}

/// Serializes one shard's file bytes (header + segments) without touching
/// disk — the shared assembly behind [`write_shard`] and the row-content
/// fingerprint check ([`ShardCacheSource::verify_content`]), which hashes
/// exactly these bytes against the manifest's recorded shard hash.
pub fn shard_bytes(payload: &ShardPayload<'_>) -> Result<Vec<u8>> {
    let nloc = payload.end - payload.start;
    ensure!(payload.labels.len() == nloc, "shard labels length mismatch");
    ensure!(payload.indptr.len() == nloc + 1, "shard indptr length mismatch");
    ensure!(
        payload.indices.len() == payload.values.len()
            && payload.indices.len() == *payload.indptr.last().unwrap_or(&0),
        "shard indices/values/indptr mismatch"
    );
    let mut out = Vec::with_capacity(payload.byte_len());
    out.extend_from_slice(&SHARD_MAGIC);
    push_u32(&mut out, CACHE_VERSION);
    push_u64(&mut out, payload.id as u64);
    push_u64(&mut out, payload.start as u64);
    push_u64(&mut out, payload.end as u64);
    push_u64(&mut out, payload.d as u64);
    push_u64(&mut out, payload.indices.len() as u64);
    out.push(task_byte(payload.task));
    for &y in payload.labels {
        push_f32(&mut out, y);
    }
    for &p in payload.indptr {
        push_u64(&mut out, p as u64);
    }
    for &j in payload.indices {
        push_u32(&mut out, j);
    }
    for &x in payload.values {
        push_f32(&mut out, x);
    }
    Ok(out)
}

/// Writes one shard file; returns the file's FNV-1a hash (recorded in the
/// manifest). The file bytes are assembled in one shard-sized buffer —
/// the only allocation is proportional to this shard, never the dataset.
pub fn write_shard(dir: &Path, payload: &ShardPayload<'_>) -> Result<u64> {
    let out = shard_bytes(payload)?;
    let hash = fnv1a(&out);
    let path = dir.join(shard_file_name(payload.id));
    std::fs::write(&path, &out).with_context(|| format!("write {}", path.display()))?;
    Ok(hash)
}

/// Per-shard manifest record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardRecord {
    /// Global row range start.
    pub start: usize,
    /// Global row range end (exclusive).
    pub end: usize,
    /// Stored non-zeros in the shard.
    pub nnz: usize,
    /// FNV-1a hash of the shard file's bytes.
    pub hash: u64,
}

/// The decoded manifest: dataset shape + the row partition the shards
/// were cut on.
#[derive(Debug, Clone)]
pub struct CacheManifest {
    /// Number of examples.
    pub n: usize,
    /// Number of features.
    pub d: usize,
    /// Total stored non-zeros.
    pub nnz: usize,
    /// Prediction task.
    pub task: Task,
    /// Dataset name (traces, artifact lookup).
    pub name: String,
    /// The partition the shard files were cut on.
    pub partition: RowPartition,
    /// Per-shard records, in shard order.
    pub shards: Vec<ShardRecord>,
}

/// Writes the manifest for a fully written cache. Call this **last**: a
/// directory without a (valid) manifest is not a cache, so an interrupted
/// ingest can never be opened as one.
pub fn write_manifest(
    dir: &Path,
    name: &str,
    d: usize,
    task: Task,
    partition: &RowPartition,
    shards: &[ShardRecord],
) -> Result<()> {
    ensure!(
        shards.len() == partition.n_shards(),
        "manifest has {} shard records for {} shards",
        shards.len(),
        partition.n_shards()
    );
    let nnz: usize = shards.iter().map(|s| s.nnz).sum();
    let mut out = Vec::new();
    out.extend_from_slice(&MANIFEST_MAGIC);
    push_u32(&mut out, CACHE_VERSION);
    push_u64(&mut out, partition.n_rows() as u64);
    push_u64(&mut out, d as u64);
    push_u64(&mut out, nnz as u64);
    out.push(task_byte(task));
    out.push(strategy_byte(partition.strategy()));
    push_u64(&mut out, partition.n_shards() as u64);
    push_u32(&mut out, name.len() as u32);
    out.extend_from_slice(name.as_bytes());
    for rec in shards {
        push_u64(&mut out, rec.start as u64);
        push_u64(&mut out, rec.end as u64);
        push_u64(&mut out, rec.nnz as u64);
        push_u64(&mut out, rec.hash);
    }
    let footer = fnv1a(&out);
    push_u64(&mut out, footer);
    let path = dir.join(MANIFEST_FILE);
    std::fs::write(&path, &out).with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Writes a complete cache from an in-memory dataset (tests, and the
/// `dsfacto ingest` path for data already loaded). The streaming ingester
/// ([`crate::data::libsvm::stream_ingest`]) produces byte-identical
/// caches without ever holding the full CSR; this helper is the
/// small-data convenience over the same [`write_shard`]/
/// [`write_manifest`] primitives.
pub fn write_cache(ds: &Dataset, strategy: RowStrategy, shards: usize, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    // Remove any stale manifest first so a half-rewritten directory can
    // never be opened against old shard files.
    let _ = std::fs::remove_file(dir.join(MANIFEST_FILE));
    let part = RowPartition::new(strategy, &ds.rows, shards);
    let mut records = Vec::with_capacity(part.n_shards());
    for (id, &(start, end)) in part.bounds().iter().enumerate() {
        let local = ds.rows.slice_rows(start, end);
        let (indptr, indices, values) = local.raw_parts();
        let payload = ShardPayload {
            id,
            start,
            end,
            d: ds.d(),
            task: ds.task,
            labels: &ds.labels[start..end],
            indptr,
            indices,
            values,
        };
        let hash = write_shard(dir, &payload)?;
        records.push(ShardRecord {
            start,
            end,
            nnz: indices.len(),
            hash,
        });
    }
    write_manifest(dir, &ds.name, ds.d(), ds.task, &part, &records)
}

// ---------------------------------------------------------------------
// Reader.

/// One shard's decoded segments (no CSC yet).
struct RawShard {
    start: usize,
    end: usize,
    labels: Vec<f32>,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

/// A [`DataSource`] over a shard-cache directory. Opening reads and
/// verifies the manifest only; each [`DataSource::shard`] call reads
/// exactly one shard file (hash-checked against the manifest), so peak
/// resident data per worker is one shard — never the full CSR.
#[derive(Debug)]
pub struct ShardCacheSource {
    dir: PathBuf,
    manifest: CacheManifest,
    /// Largest single shard-file read so far, in bytes (the bounded-memory
    /// instrumentation the ingest tests assert on).
    peak_load_bytes: AtomicU64,
}

impl ShardCacheSource {
    /// Opens a cache directory, reading and verifying the manifest
    /// (magic, version, footer hash, partition invariants).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<ShardCacheSource> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join(MANIFEST_FILE);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("open shard cache manifest {}", path.display()))?;
        let manifest = decode_manifest(&bytes)
            .with_context(|| format!("decode shard cache manifest {}", path.display()))?;
        Ok(ShardCacheSource {
            dir,
            manifest,
            peak_load_bytes: AtomicU64::new(0),
        })
    }

    /// The decoded manifest.
    pub fn manifest(&self) -> &CacheManifest {
        &self.manifest
    }

    /// Largest single shard file read through this source so far, in
    /// bytes (0 until the first load). Because shards are read one file
    /// at a time, this is also the peak resident *cache* memory of any
    /// shard load.
    pub fn peak_load_bytes(&self) -> u64 {
        self.peak_load_bytes.load(Ordering::Relaxed)
    }

    /// The serialized size of the largest shard, from the manifest alone
    /// (what a worker will transiently hold; compare against the full
    /// CSR's footprint for the out-of-core win).
    pub fn max_shard_file_bytes(&self) -> usize {
        self.manifest
            .shards
            .iter()
            .map(|r| shard_file_len(r.end - r.start, r.nnz))
            .max()
            .unwrap_or(0)
    }

    /// Errors unless `ds`'s row **content** matches the cached shards —
    /// not just its shape. Re-serializes the first and last shards of the
    /// cached partition from `ds` and compares their FNV-1a fingerprints
    /// against the manifest's recorded shard hashes. The hash covers
    /// labels, row pointers, column indices and values byte-for-byte, so
    /// a same-shape dataset with permuted or edited rows is rejected
    /// (the case the shape-only `ensure_matches` check cannot see).
    /// Cost: two shard serializations, no file I/O, peak memory one
    /// shard's bytes.
    pub fn verify_content(&self, ds: &Dataset) -> Result<()> {
        ensure!(
            self.manifest.n == ds.n() && self.manifest.d == ds.d(),
            "content check on a shape-mismatched dataset (cache n={} d={}, dataset n={} d={})",
            self.manifest.n,
            self.manifest.d,
            ds.n(),
            ds.d()
        );
        let p = self.manifest.shards.len();
        if p == 0 {
            return Ok(());
        }
        let mut ids = vec![0];
        if p > 1 {
            ids.push(p - 1);
        }
        for id in ids {
            let rec = &self.manifest.shards[id];
            let local = ds.rows.slice_rows(rec.start, rec.end);
            let (indptr, indices, values) = local.raw_parts();
            let payload = ShardPayload {
                id,
                start: rec.start,
                end: rec.end,
                d: ds.d(),
                task: ds.task,
                labels: &ds.labels[rec.start..rec.end],
                indptr,
                indices,
                values,
            };
            let got = fnv1a(&shard_bytes(&payload)?);
            ensure!(
                got == rec.hash,
                "shard {id} content fingerprint mismatch: the cache at {} was ingested \
                 from different rows than this training set (same shape, different \
                 content — e.g. permuted or edited rows); re-ingest the exact \
                 pre-split training file",
                self.dir.display()
            );
        }
        Ok(())
    }

    fn load_shard_raw(&self, id: usize) -> Result<RawShard> {
        let rec = self
            .manifest
            .shards
            .get(id)
            .with_context(|| format!("shard {id} out of range ({} shards)", self.manifest.shards.len()))?;
        let path = self.dir.join(shard_file_name(id));
        let bytes =
            std::fs::read(&path).with_context(|| format!("read shard file {}", path.display()))?;
        self.peak_load_bytes
            .fetch_max(bytes.len() as u64, Ordering::Relaxed);
        decode_shard(&bytes, id, rec, self.manifest.d, self.manifest.task)
            .with_context(|| format!("decode shard file {}", path.display()))
    }
}

/// Exact byte length of a shard file with `nloc` rows and `nnz` stored
/// entries.
fn shard_file_len(nloc: usize, nnz: usize) -> usize {
    4 + 4 + 5 * 8 + 1 + 4 * nloc + 8 * (nloc + 1) + 8 * nnz
}

fn decode_manifest(bytes: &[u8]) -> Result<CacheManifest> {
    ensure!(bytes.len() >= 8 + 8, "manifest shorter than its footer");
    let (body, footer) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(footer.try_into().unwrap());
    ensure!(
        fnv1a(body) == want,
        "manifest hash mismatch (corrupt or torn write)"
    );
    let mut rd = Rd::new(body);
    let magic = rd.take(4)?;
    ensure!(magic == &MANIFEST_MAGIC[..], "bad manifest magic {magic:02x?}");
    let version = rd.u32()?;
    ensure!(
        version == CACHE_VERSION,
        "cache version {version}, this build reads version {CACHE_VERSION} — re-ingest"
    );
    let n = rd.usize64()?;
    let d = rd.usize64()?;
    let nnz = rd.usize64()?;
    let task = task_from_byte(rd.u8()?)?;
    let strategy = strategy_from_byte(rd.u8()?)?;
    let p = rd.usize64()?;
    let name_len = rd.u32()? as usize;
    let name = std::str::from_utf8(rd.take(name_len)?)
        .context("dataset name is not UTF-8")?
        .to_string();
    // Bound the record count by the bytes actually present before
    // allocating: FNV is not cryptographic, so a crafted count with a
    // recomputed footer must still fail as an *error*, not an
    // allocation abort.
    ensure!(
        p <= rd.remaining() / 32,
        "manifest claims {p} shards but only {} bytes of records follow",
        rd.remaining()
    );
    let mut bounds = Vec::with_capacity(p);
    let mut shards = Vec::with_capacity(p);
    for _ in 0..p {
        let start = rd.usize64()?;
        let end = rd.usize64()?;
        let snnz = rd.usize64()?;
        let hash = rd.u64()?;
        bounds.push((start, end));
        shards.push(ShardRecord {
            start,
            end,
            nnz: snnz,
            hash,
        });
    }
    rd.done()?;
    let partition = RowPartition::from_bounds(strategy, n, bounds)?;
    let total: usize = shards.iter().map(|s| s.nnz).sum();
    ensure!(
        total == nnz,
        "manifest nnz {nnz} != sum of shard nnz {total}"
    );
    Ok(CacheManifest {
        n,
        d,
        nnz,
        task,
        name,
        partition,
        shards,
    })
}

fn decode_shard(bytes: &[u8], id: usize, rec: &ShardRecord, d: usize, task: Task) -> Result<RawShard> {
    let nloc = rec.end - rec.start;
    ensure!(
        bytes.len() == shard_file_len(nloc, rec.nnz),
        "shard file is {} bytes, manifest implies {}",
        bytes.len(),
        shard_file_len(nloc, rec.nnz)
    );
    ensure!(
        fnv1a(bytes) == rec.hash,
        "shard file hash mismatch (corrupt or stale shard)"
    );
    let mut rd = Rd::new(bytes);
    let magic = rd.take(4)?;
    ensure!(magic == &SHARD_MAGIC[..], "bad shard magic {magic:02x?}");
    let version = rd.u32()?;
    ensure!(
        version == CACHE_VERSION,
        "shard version {version}, this build reads version {CACHE_VERSION}"
    );
    let hdr_id = rd.usize64()?;
    let start = rd.usize64()?;
    let end = rd.usize64()?;
    let hdr_d = rd.usize64()?;
    let nnz = rd.usize64()?;
    let hdr_task = task_from_byte(rd.u8()?)?;
    ensure!(hdr_id == id, "shard header id {hdr_id}, expected {id}");
    ensure!(
        (start, end) == (rec.start, rec.end),
        "shard header range {start}..{end}, manifest says {}..{}",
        rec.start,
        rec.end
    );
    ensure!(hdr_d == d, "shard header d {hdr_d}, manifest says {d}");
    ensure!(nnz == rec.nnz, "shard header nnz {nnz}, manifest says {}", rec.nnz);
    ensure!(hdr_task == task, "shard header task differs from manifest");
    // Bulk segment decode: the exact-length check above already bounds
    // every segment (so `nloc`/`nnz`-sized reserves are backed by real
    // file bytes), and chunked conversion avoids a bounds check + error
    // path per element on the per-worker load hot path.
    let mut labels = Vec::with_capacity(nloc);
    for ch in rd.take(4 * nloc)?.chunks_exact(4) {
        labels.push(f32::from_le_bytes(ch.try_into().unwrap()));
    }
    let mut indptr = Vec::with_capacity(nloc + 1);
    for ch in rd.take(8 * (nloc + 1))?.chunks_exact(8) {
        let q = u64::from_le_bytes(ch.try_into().unwrap());
        indptr.push(usize::try_from(q).context("indptr entry overflows usize")?);
    }
    let mut indices = Vec::with_capacity(nnz);
    for ch in rd.take(4 * nnz)?.chunks_exact(4) {
        indices.push(u32::from_le_bytes(ch.try_into().unwrap()));
    }
    let mut values = Vec::with_capacity(nnz);
    for ch in rd.take(4 * nnz)?.chunks_exact(4) {
        values.push(f32::from_le_bytes(ch.try_into().unwrap()));
    }
    rd.done()?;
    Ok(RawShard {
        start,
        end,
        labels,
        indptr,
        indices,
        values,
    })
}

impl DataSource for ShardCacheSource {
    fn name(&self) -> &str {
        &self.manifest.name
    }

    fn n(&self) -> usize {
        self.manifest.n
    }

    fn d(&self) -> usize {
        self.manifest.d
    }

    fn nnz(&self) -> usize {
        self.manifest.nnz
    }

    fn task(&self) -> Task {
        self.manifest.task
    }

    fn plan(&self, strategy: RowStrategy, p: usize) -> Result<RowPartition> {
        ensure!(
            strategy == self.manifest.partition.strategy()
                && p == self.manifest.partition.n_shards(),
            "shard cache at {} was ingested as row_partition = {} with {} shards; \
             this run asked for {} with {p} — re-ingest with the matching plan",
            self.dir.display(),
            self.manifest.partition.strategy().spec(),
            self.manifest.partition.n_shards(),
            strategy.spec()
        );
        Ok(self.manifest.partition.clone())
    }

    fn shard(&self, part: &RowPartition, id: usize) -> Result<Shard> {
        ensure!(
            *part == self.manifest.partition,
            "requested partition differs from the cached one (plan through this source)"
        );
        let raw = self.load_shard_raw(id)?;
        let nloc = raw.end - raw.start;
        let rows = Csr::try_new(nloc, self.manifest.d, raw.indptr, raw.indices, raw.values)?;
        let cols = rows.to_csc();
        Ok(Shard {
            id,
            start: raw.start,
            end: raw.end,
            rows,
            cols,
            labels: raw.labels,
            task: self.manifest.task,
        })
    }

    fn materialize(&self) -> Result<Dataset> {
        // Deliberately no manifest-sized pre-allocation: n/nnz come from
        // the (forgeable-footer) manifest, and reserving from them before
        // any shard file has corroborated the sizes would turn a crafted
        // manifest into an allocation abort instead of a load error. The
        // vectors grow amortized as verified shard bytes arrive.
        let mut labels = Vec::new();
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for id in 0..self.manifest.shards.len() {
            let raw = self.load_shard_raw(id)?;
            ensure!(
                raw.start == labels.len(),
                "shard {id} starts at {} after {} concatenated rows",
                raw.start,
                labels.len()
            );
            let base = values.len();
            // Local indptr is 0-based; re-base onto the concatenation.
            indptr.extend(raw.indptr[1..].iter().map(|&q| base + q));
            indices.extend_from_slice(&raw.indices);
            values.extend_from_slice(&raw.values);
            labels.extend_from_slice(&raw.labels);
        }
        let rows = Csr::try_new(self.manifest.n, self.manifest.d, indptr, indices, values)?;
        let ds = Dataset {
            name: self.manifest.name.clone(),
            task: self.manifest.task,
            rows,
            labels,
        };
        ds.validate()?;
        Ok(ds)
    }

    fn shard_nnz_hint(&self, part: &RowPartition) -> Option<Vec<usize>> {
        // The manifest records every shard's nnz at ingest time; answer
        // only for the partition the cache was actually cut on.
        (*part == self.manifest.partition)
            .then(|| self.manifest.shards.iter().map(|r| r.nnz).collect())
    }

    fn native_plan(&self) -> Option<RowPartition> {
        Some(self.manifest.partition.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::InMemorySource;
    use crate::data::synth;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dsfacto_cache_unit_{tag}"))
    }

    #[test]
    fn cache_roundtrips_dataset_and_shards_bitwise() {
        let ds = synth::table2_dataset("housing", 11).unwrap();
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let dir = tmp(&format!("rt_{}", strat.spec()));
            write_cache(&ds, strat, 3, &dir).unwrap();
            let src = ShardCacheSource::open(&dir).unwrap();
            assert_eq!(src.n(), ds.n());
            assert_eq!(src.d(), ds.d());
            assert_eq!(src.nnz(), ds.nnz());
            assert_eq!(src.task(), ds.task);
            assert_eq!(src.name(), ds.name);
            let part = src.plan(strat, 3).unwrap();
            let mem = InMemorySource::new(&ds);
            assert_eq!(part, mem.plan(strat, 3).unwrap());
            for id in 0..3 {
                let got = src.shard(&part, id).unwrap();
                let want = mem.shard(&part, id).unwrap();
                assert_eq!(got.rows, want.rows, "{strat:?} shard {id}");
                assert_eq!(got.cols, want.cols);
                assert_eq!(got.labels, want.labels);
                assert_eq!((got.start, got.end, got.task), (want.start, want.end, want.task));
            }
            let back = src.materialize().unwrap();
            assert_eq!(back.rows, ds.rows);
            assert_eq!(back.labels, ds.labels);
            assert_eq!(back.name, ds.name);
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn plan_mismatch_is_rejected() {
        let ds = synth::table2_dataset("housing", 12).unwrap();
        let dir = tmp("plan");
        write_cache(&ds, RowStrategy::Contiguous, 4, &dir).unwrap();
        let src = ShardCacheSource::open(&dir).unwrap();
        assert!(src.plan(RowStrategy::Contiguous, 4).is_ok());
        assert!(src.plan(RowStrategy::Contiguous, 3).is_err());
        assert!(src.plan(RowStrategy::NnzBalanced, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dataset_caches_cleanly() {
        let ds = Dataset {
            name: "empty".into(),
            task: Task::Regression,
            rows: Csr::empty(0, 0),
            labels: Vec::new(),
        };
        let dir = tmp("empty");
        write_cache(&ds, RowStrategy::Contiguous, 3, &dir).unwrap();
        let src = ShardCacheSource::open(&dir).unwrap();
        assert_eq!(src.n(), 0);
        assert_eq!(src.nnz(), 0);
        let back = src.materialize().unwrap();
        assert_eq!(back.n(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn content_fingerprint_rejects_permuted_rows() {
        let ds = synth::table2_dataset("housing", 21).unwrap();
        let dir = tmp("fp");
        write_cache(&ds, RowStrategy::Contiguous, 3, &dir).unwrap();
        let src = ShardCacheSource::open(&dir).unwrap();
        assert!(src.verify_content(&ds).is_ok());
        // Same shape (n, d, nnz, task all unchanged), different content:
        // swap the first two rows. The shape-only check cannot see this;
        // the fingerprint must.
        let mut order: Vec<usize> = (0..ds.n()).collect();
        order.swap(0, 1);
        let permuted = ds.subset(&order, "housing");
        assert_eq!((permuted.n(), permuted.d(), permuted.nnz()), (ds.n(), ds.d(), ds.nnz()));
        let err = src.verify_content(&permuted).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
        // End to end through the resolve seam distributed trainers use.
        let seam = crate::data::ShardSource::Cache(dir.to_string_lossy().into_owned());
        assert!(seam.resolve(&ds).is_ok());
        assert!(seam.resolve(&permuted).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peak_load_tracks_largest_shard_file() {
        let ds = synth::table2_dataset("housing", 13).unwrap();
        let dir = tmp("peak");
        write_cache(&ds, RowStrategy::Contiguous, 4, &dir).unwrap();
        let src = ShardCacheSource::open(&dir).unwrap();
        assert_eq!(src.peak_load_bytes(), 0);
        let part = src.plan(RowStrategy::Contiguous, 4).unwrap();
        for id in 0..4 {
            src.shard(&part, id).unwrap();
        }
        assert_eq!(src.peak_load_bytes() as usize, src.max_shard_file_bytes());
        std::fs::remove_dir_all(&dir).ok();
    }
}
