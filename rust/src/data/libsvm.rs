//! LIBSVM / SVMlight format reader, writer, and the streaming out-of-core
//! ingester.
//!
//! Format: one example per line, `label idx:val idx:val ...` with 1-based
//! (conventional) or 0-based indices — auto-detected. Lines starting with
//! `#` and blank lines are skipped. [`parse`]/[`load`] materialize a full
//! in-memory [`Dataset`]; [`stream_ingest`] converts the same format into
//! a binary shard cache ([`crate::data::cache`]) in one bounded-memory
//! pass, so criteo-scale files never need to fit in RAM. Both paths share
//! one line parser ([`parse_example`] internally) and one
//! index-convention resolver, so a streamed cache is **bit-identical** to
//! the parsed dataset.
//!
//! ## The bounded-memory ingest contract
//!
//! `stream_ingest` holds, at any instant, at most:
//!
//! 1. one parse chunk (`IngestOptions::chunk_rows` rows of labels +
//!    indices + values) while scanning the text,
//! 2. the cumulative row-nnz prefix (`8 * (n + 1)` bytes — the only O(n)
//!    structure, and the input to the shared nnz-balanced planner), and
//! 3. one shard's assembly buffers (+ its serialized image) while writing
//!    shard files — i.e. `max_shard`, the paper's per-worker budget.
//!
//! It never builds the full CSR. The text is read **once**; parsed rows
//! go to a temporary binary row log (deleted on success) that is then
//! split into shard files along the plan computed from the nnz prefix.
//! [`IngestReport`] carries the instrumentation (`chunks_flushed`,
//! `peak_chunk_bytes`, `peak_shard_bytes`, `peak_resident_bytes`) the
//! out-of-core tests and the `hotpath_micro` bench assert on.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::partition::{RowPartition, RowStrategy};

use super::cache::{self, ShardPayload, ShardRecord};
use super::{Csr, Dataset, Task};

/// Parses one LIBSVM line into `idx`/`val` (cleared first). Returns
/// `Ok(None)` for blank and comment lines, else the label. Indices are
/// the raw file indices, strictly increasing within the row — the caller
/// applies the 1-based shift once the whole input's index range is known.
/// This is the one line parser behind both [`parse`] and
/// [`stream_ingest`], so the two paths accept and reject identical
/// inputs and produce identical f32 bits.
fn parse_example(
    line: &str,
    lineno: usize,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) -> Result<Option<f32>> {
    idx.clear();
    val.clear();
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let label_tok = parts.next().unwrap();
    let label: f32 = label_tok
        .parse()
        .with_context(|| format!("line {}: bad label {label_tok:?}", lineno + 1))?;
    let mut last_idx: i64 = -1;
    for tok in parts {
        if tok.starts_with('#') {
            break; // trailing comment
        }
        let (i_str, v_str) = tok
            .split_once(':')
            .with_context(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
        let index: i64 = i_str
            .parse()
            .with_context(|| format!("line {}: bad index {i_str:?}", lineno + 1))?;
        let value: f32 = v_str
            .parse()
            .with_context(|| format!("line {}: bad value {v_str:?}", lineno + 1))?;
        if index < 0 {
            bail!("line {}: negative feature index {index}", lineno + 1);
        }
        if index > u32::MAX as i64 {
            // The CSR stores u32 columns; silently wrapping would alias a
            // low feature while max_idx (i64) blows up the inferred d.
            bail!("line {}: feature index {index} exceeds u32::MAX", lineno + 1);
        }
        if index <= last_idx {
            bail!("line {}: indices not strictly increasing", lineno + 1);
        }
        last_idx = index;
        idx.push(index as u32);
        val.push(value);
    }
    Ok(Some(label))
}

/// Resolves the 1-based-vs-0-based convention and the dimensionality from
/// the global raw-index range (shared by [`parse`] and [`stream_ingest`]
/// so both decide identically). Returns `(one_based, d)`.
fn resolve_dims(min_idx: i64, max_idx: i64, n_features: Option<usize>) -> Result<(bool, usize)> {
    // 1-based (LIBSVM convention) vs 0-based: if no zero index ever
    // appears, assume 1-based and shift down.
    let one_based = min_idx >= 1 && max_idx >= 1;
    let max_idx = if one_based { max_idx - 1 } else { max_idx };
    let inferred_d = (max_idx + 1).max(0) as usize;
    let d = match n_features {
        Some(d) => {
            if d < inferred_d {
                bail!("n_features {d} < max feature index {inferred_d}");
            }
            d
        }
        None => inferred_d,
    };
    Ok((one_based, d))
}

/// Parses LIBSVM text into a [`Dataset`].
///
/// `n_features`: pass `Some(d)` to force the dimensionality (needed when a
/// test split does not exercise the trailing features); `None` infers it.
pub fn parse(text: &str, name: &str, task: Task, n_features: Option<usize>) -> Result<Dataset> {
    let mut labels = Vec::new();
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut max_idx: i64 = -1;
    let mut min_idx: i64 = i64::MAX;
    let mut row_idx: Vec<u32> = Vec::new();
    let mut row_val: Vec<f32> = Vec::new();

    for (lineno, line) in text.lines().enumerate() {
        let Some(label) = parse_example(line, lineno, &mut row_idx, &mut row_val)? else {
            continue;
        };
        labels.push(label);
        for &j in row_idx.iter() {
            max_idx = max_idx.max(j as i64);
            min_idx = min_idx.min(j as i64);
        }
        indices.extend_from_slice(&row_idx);
        values.extend_from_slice(&row_val);
        indptr.push(indices.len());
    }

    let (one_based, d) = resolve_dims(min_idx, max_idx, n_features)?;
    if one_based {
        for i in indices.iter_mut() {
            *i -= 1;
        }
    }

    let n = labels.len();
    let ds = Dataset {
        name: name.to_string(),
        task,
        rows: Csr::new(n, d, indptr, indices, values),
        labels,
    };
    ds.validate()?;
    Ok(ds)
}

/// Loads a LIBSVM file from disk (fully in memory; for data that does not
/// fit, see [`stream_ingest`]).
pub fn load<P: AsRef<Path>>(
    path: P,
    name: &str,
    task: Task,
    n_features: Option<usize>,
) -> Result<Dataset> {
    let file = std::fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut text = String::new();
    BufReader::new(file).read_to_string(&mut text)?;
    parse(&text, name, task, n_features)
}

/// Writes a dataset in LIBSVM format (1-based indices).
pub fn save<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.n() {
        write!(out, "{}", ds.labels[i])?;
        let (idx, val) = ds.rows.row(i);
        for (j, v) in idx.iter().zip(val) {
            write!(out, " {}:{}", j + 1, v)?;
        }
        writeln!(out)?;
    }
    out.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Streaming ingest.

/// Options for [`stream_ingest`].
#[derive(Debug, Clone)]
pub struct IngestOptions {
    /// Prediction task (classification labels are validated to +/-1
    /// during the parse pass, like [`Dataset::validate`]).
    pub task: Task,
    /// Forced dimensionality (`None` infers it from the max index).
    pub n_features: Option<usize>,
    /// Row-shard strategy baked into the cache (contiguous or
    /// nnz-balanced; bit-identical to the in-memory planners).
    pub strategy: RowStrategy,
    /// Shard count P (= worker count the cache will serve).
    pub shards: usize,
    /// Rows parsed per in-memory chunk before flushing to the row log
    /// (the pass-A memory bound).
    pub chunk_rows: usize,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions {
            task: Task::Classification,
            n_features: None,
            strategy: RowStrategy::Contiguous,
            shards: 4,
            chunk_rows: 4096,
        }
    }
}

/// What one [`stream_ingest`] run did: the resolved dataset shape plus
/// the bounded-memory instrumentation (peaks of every transient buffer
/// the ingester holds), so tests and benches can assert the full CSR was
/// never resident.
#[derive(Debug, Clone, Default)]
pub struct IngestReport {
    /// Examples ingested.
    pub n: usize,
    /// Resolved dimensionality.
    pub d: usize,
    /// Total stored non-zeros.
    pub nnz: usize,
    /// Whether the file used 1-based indices.
    pub one_based: bool,
    /// Parse chunks flushed to the row log (>= 2 proves streaming).
    pub chunks_flushed: usize,
    /// Largest chunk, in rows.
    pub peak_chunk_rows: usize,
    /// Largest chunk, in buffer bytes (labels + indptr + indices + values).
    pub peak_chunk_bytes: usize,
    /// Largest shard assembled while writing the cache, in serialized
    /// bytes (header + labels + CSR segments) — the `max_shard` budget.
    pub peak_shard_bytes: usize,
    /// Bytes of the cumulative row-nnz prefix (`8 * (n + 1)`), the one
    /// O(n) structure.
    pub prefix_bytes: usize,
    /// Peak transient memory bound: `prefix + max(chunk, 2 * shard)` —
    /// pass A holds one chunk; pass B holds one shard's assembly buffers
    /// *plus* its serialized image (~`peak_shard_bytes` each) while
    /// [`crate::data::cache::write_shard`] runs; never both passes at
    /// once.
    pub peak_resident_bytes: usize,
}

/// Streams a LIBSVM file into a binary shard cache at `cache_dir` (see
/// the module docs for the memory contract and
/// [`crate::data::ShardCacheSource`] for the reader). The cache is
/// bit-identical to `parse` + [`crate::data::cache::write_cache`] on the
/// same file.
pub fn stream_ingest<P: AsRef<Path>, Q: AsRef<Path>>(
    path: P,
    name: &str,
    opts: &IngestOptions,
    cache_dir: Q,
) -> Result<IngestReport> {
    let file = File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    stream_ingest_from(BufReader::new(file), name, opts, cache_dir)
}

/// [`stream_ingest`] over any buffered reader (tests feed strings through
/// `std::io::Cursor`).
pub fn stream_ingest_from<R: BufRead, Q: AsRef<Path>>(
    mut reader: R,
    name: &str,
    opts: &IngestOptions,
    cache_dir: Q,
) -> Result<IngestReport> {
    let dir = cache_dir.as_ref();
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    // A stale manifest must never pair with fresh shard files: remove it
    // first, write the new one last.
    let _ = std::fs::remove_file(dir.join(cache::MANIFEST_FILE));
    let rowlog_path = dir.join("rowlog.tmp");
    // The row log can be as large as the dataset; make sure it goes away
    // on *every* exit path, including parse errors deep into pass A.
    struct RemoveOnDrop(std::path::PathBuf);
    impl Drop for RemoveOnDrop {
        fn drop(&mut self) {
            std::fs::remove_file(&self.0).ok();
        }
    }
    let _rowlog_guard = RemoveOnDrop(rowlog_path.clone());

    let mut report = IngestReport::default();
    let chunk_rows = opts.chunk_rows.max(1);
    // prefix[i] = nnz of rows 0..i — the input to the shared balanced
    // planner and the shard-size oracle for pass B.
    let mut prefix: Vec<usize> = vec![0];
    let mut min_idx: i64 = i64::MAX;
    let mut max_idx: i64 = -1;

    // ---- Pass A: chunked line parse -> row log + nnz prefix scan.
    {
        let mut log = BufWriter::new(
            File::create(&rowlog_path)
                .with_context(|| format!("create row log {}", rowlog_path.display()))?,
        );
        let mut chunk_labels: Vec<f32> = Vec::new();
        let mut chunk_ptr: Vec<usize> = vec![0];
        let mut chunk_idx: Vec<u32> = Vec::new();
        let mut chunk_val: Vec<f32> = Vec::new();
        let mut row_idx: Vec<u32> = Vec::new();
        let mut row_val: Vec<f32> = Vec::new();
        let mut line = String::new();
        let mut lineno = 0usize;
        loop {
            line.clear();
            let eof = reader
                .read_line(&mut line)
                .with_context(|| format!("read line {}", lineno + 1))?
                == 0;
            if !eof {
                if let Some(label) = parse_example(&line, lineno, &mut row_idx, &mut row_val)? {
                    if opts.task == Task::Classification && label != 1.0 && label != -1.0 {
                        bail!(
                            "line {}: classification label is {label}, want +/-1",
                            lineno + 1
                        );
                    }
                    for &j in row_idx.iter() {
                        max_idx = max_idx.max(j as i64);
                        min_idx = min_idx.min(j as i64);
                    }
                    chunk_labels.push(label);
                    chunk_idx.extend_from_slice(&row_idx);
                    chunk_val.extend_from_slice(&row_val);
                    chunk_ptr.push(chunk_idx.len());
                    let total = *prefix.last().unwrap();
                    prefix.push(total + row_idx.len());
                }
                lineno += 1;
            }
            let full = chunk_labels.len() >= chunk_rows;
            if full || (eof && !chunk_labels.is_empty()) {
                flush_chunk(&mut log, &chunk_labels, &chunk_ptr, &chunk_idx, &chunk_val)?;
                report.chunks_flushed += 1;
                report.peak_chunk_rows = report.peak_chunk_rows.max(chunk_labels.len());
                let bytes =
                    4 * chunk_labels.len() + 8 * chunk_ptr.len() + 8 * chunk_idx.len();
                report.peak_chunk_bytes = report.peak_chunk_bytes.max(bytes);
                chunk_labels.clear();
                chunk_idx.clear();
                chunk_val.clear();
                chunk_ptr.clear();
                chunk_ptr.push(0);
            }
            if eof {
                break;
            }
        }
        log.flush().context("flush row log")?;
    }

    let n = prefix.len() - 1;
    let nnz = prefix[n];
    let (one_based, d) = resolve_dims(min_idx, max_idx, opts.n_features)?;
    report.n = n;
    report.d = d;
    report.nnz = nnz;
    report.one_based = one_based;
    report.prefix_bytes = 8 * prefix.len();

    // ---- Plan: the same boundary math as the in-memory planners, off
    // the prefix instead of a CSR.
    let p = opts.shards.max(1);
    let part = match opts.strategy {
        RowStrategy::Contiguous => RowPartition::contiguous(n, p),
        RowStrategy::NnzBalanced => RowPartition::nnz_balanced_from_prefix(&prefix, p),
    };

    // ---- Pass B: split the row log into shard files, one shard resident
    // at a time (shards are contiguous ordered row ranges, so this is a
    // single sequential read of the log).
    let shift: u32 = if one_based { 1 } else { 0 };
    let mut records = Vec::with_capacity(p);
    {
        let mut log = BufReader::new(
            File::open(&rowlog_path)
                .with_context(|| format!("reopen row log {}", rowlog_path.display()))?,
        );
        let mut rowbuf: Vec<u8> = Vec::new();
        for (id, &(start, end)) in part.bounds().iter().enumerate() {
            let nloc = end - start;
            let snnz = prefix[end] - prefix[start];
            let mut labels: Vec<f32> = Vec::with_capacity(nloc);
            let mut indptr: Vec<usize> = Vec::with_capacity(nloc + 1);
            indptr.push(0);
            let mut indices: Vec<u32> = Vec::with_capacity(snnz);
            let mut values: Vec<f32> = Vec::with_capacity(snnz);
            for _ in 0..nloc {
                read_log_row(&mut log, &mut rowbuf, &mut labels, &mut indices, &mut values)?;
                indptr.push(indices.len());
            }
            ensure!(
                indices.len() == snnz,
                "row log shard {id} carries {} nnz, prefix says {snnz}",
                indices.len()
            );
            for j in indices.iter_mut() {
                *j -= shift;
                ensure!((*j as usize) < d, "feature index {j} >= d {d} in shard {id}");
            }
            let payload = ShardPayload {
                id,
                start,
                end,
                d,
                task: opts.task,
                labels: &labels,
                indptr: &indptr,
                indices: &indices,
                values: &values,
            };
            report.peak_shard_bytes = report.peak_shard_bytes.max(payload.byte_len());
            let hash = cache::write_shard(dir, &payload)?;
            records.push(ShardRecord {
                start,
                end,
                nnz: snnz,
                hash,
            });
        }
    }
    cache::write_manifest(dir, name, d, opts.task, &part, &records)?;

    // Pass B transiently holds a shard twice: assembly buffers + the
    // serialized image write_shard builds (each ~peak_shard_bytes).
    report.peak_resident_bytes =
        report.prefix_bytes + report.peak_chunk_bytes.max(2 * report.peak_shard_bytes);
    Ok(report)
}

/// Appends one chunk's rows to the row log. Record format (LE): label
/// `f32`, nnz `u32`, then the row's raw indices (`u32` each) followed by
/// its values (`f32` bits each).
fn flush_chunk(
    log: &mut impl Write,
    labels: &[f32],
    ptr: &[usize],
    idx: &[u32],
    val: &[f32],
) -> Result<()> {
    for (r, &label) in labels.iter().enumerate() {
        let (a, b) = (ptr[r], ptr[r + 1]);
        log.write_all(&label.to_bits().to_le_bytes())?;
        log.write_all(&((b - a) as u32).to_le_bytes())?;
        for &j in &idx[a..b] {
            log.write_all(&j.to_le_bytes())?;
        }
        for &x in &val[a..b] {
            log.write_all(&x.to_bits().to_le_bytes())?;
        }
    }
    Ok(())
}

/// Reads one row-log record, appending to the shard assembly buffers.
fn read_log_row(
    log: &mut impl Read,
    rowbuf: &mut Vec<u8>,
    labels: &mut Vec<f32>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) -> Result<()> {
    let mut hdr = [0u8; 8];
    log.read_exact(&mut hdr).context("row log truncated")?;
    labels.push(f32::from_le_bytes(hdr[0..4].try_into().unwrap()));
    let nnz = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    rowbuf.resize(8 * nnz, 0);
    log.read_exact(rowbuf).context("row log truncated")?;
    let (ib, vb) = rowbuf.split_at(4 * nnz);
    for ch in ib.chunks_exact(4) {
        indices.push(u32::from_le_bytes(ch.try_into().unwrap()));
    }
    for ch in vb.chunks_exact(4) {
        values.push(f32::from_le_bytes(ch.try_into().unwrap()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cache::ShardCacheSource;
    use crate::data::source::DataSource;

    #[test]
    fn parses_one_based() {
        let ds = parse("1 1:0.5 3:2\n-1 2:1\n", "t", Task::Classification, None).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.rows.row(0), (&[0u32, 2][..], &[0.5f32, 2.0][..]));
        assert_eq!(ds.labels, vec![1.0, -1.0]);
    }

    #[test]
    fn parses_zero_based() {
        let ds = parse("2.5 0:1 2:3\n", "t", Task::Regression, None).unwrap();
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.rows.row(0), (&[0u32, 2][..], &[1.0f32, 3.0][..]));
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse("# header\n\n1 1:1\n", "t", Task::Classification, None).unwrap();
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn forced_dimensionality() {
        let ds = parse("1 1:1\n", "t", Task::Classification, Some(10)).unwrap();
        assert_eq!(ds.d(), 10);
        assert!(parse("1 5:1\n", "t", Task::Classification, Some(2)).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("abc 1:1\n", "t", Task::Regression, None).is_err());
        assert!(parse("1 x:1\n", "t", Task::Regression, None).is_err());
        assert!(parse("1 2:1 1:1\n", "t", Task::Regression, None).is_err()); // unsorted
        assert!(parse("1 1:y\n", "t", Task::Regression, None).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = parse("1 1:0.5 3:2\n-1 2:1.25\n1 1:4\n", "t", Task::Classification, None).unwrap();
        let dir = std::env::temp_dir().join("dsfacto_libsvm_test");
        let path = dir.join("x.svm");
        save(&ds, &path).unwrap();
        let back = load(&path, "t", Task::Classification, Some(ds.d())).unwrap();
        assert_eq!(back.rows, ds.rows);
        assert_eq!(back.labels, ds.labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_input_is_empty_dataset() {
        let ds = parse("", "t", Task::Regression, None).unwrap();
        assert_eq!(ds.n(), 0);
        assert_eq!(ds.d(), 0);
    }

    #[test]
    fn stream_ingest_matches_parse_on_text() {
        let text = "# twin\n1 1:0.5 3:2\n-1 2:1.25\n\n1 1:4 2:-0.5 3:0.125\n-1 3:9\n";
        let parsed = parse(text, "mini", Task::Classification, None).unwrap();
        let dir = std::env::temp_dir().join("dsfacto_libsvm_stream_unit");
        std::fs::remove_dir_all(&dir).ok();
        let opts = IngestOptions {
            shards: 2,
            chunk_rows: 1, // force a flush per row
            ..Default::default()
        };
        let report =
            stream_ingest_from(std::io::Cursor::new(text), "mini", &opts, &dir).unwrap();
        assert_eq!((report.n, report.d, report.nnz), (4, 3, 7));
        assert!(report.one_based);
        assert_eq!(report.chunks_flushed, 4);
        assert_eq!(report.peak_chunk_rows, 1);
        let src = ShardCacheSource::open(&dir).unwrap();
        let back = src.materialize().unwrap();
        assert_eq!(back.rows, parsed.rows);
        assert_eq!(back.labels, parsed.labels);
        assert_eq!(back.task, parsed.task);
        assert!(!dir.join("rowlog.tmp").exists(), "row log must be cleaned up");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_ingest_rejects_what_parse_rejects() {
        let dir = std::env::temp_dir().join("dsfacto_libsvm_stream_rej");
        std::fs::remove_dir_all(&dir).ok();
        let opts = IngestOptions::default();
        for bad in [
            "abc 1:1\n",
            "1 x:1\n",
            "1 2:1 1:1\n",
            "1 1:y\n",
            "1 4294967297:1\n", // u32 overflow would alias feature 1
        ] {
            assert!(parse(bad, "t", Task::Classification, None).is_err(), "{bad:?}");
            assert!(
                stream_ingest_from(std::io::Cursor::new(bad), "t", &opts, &dir).is_err(),
                "{bad:?}"
            );
            // The row log is cleaned up on the error path too.
            assert!(!dir.join("rowlog.tmp").exists(), "{bad:?}");
        }
        // Classification labels validated during the streaming pass too.
        assert!(
            stream_ingest_from(std::io::Cursor::new("0.5 1:1\n"), "t", &opts, &dir).is_err()
        );
        // Forced dimensionality below the max index.
        let opts_forced = IngestOptions {
            n_features: Some(2),
            ..Default::default()
        };
        assert!(
            stream_ingest_from(std::io::Cursor::new("1 5:1\n"), "t", &opts_forced, &dir)
                .is_err()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_ingest_empty_input() {
        let dir = std::env::temp_dir().join("dsfacto_libsvm_stream_empty");
        std::fs::remove_dir_all(&dir).ok();
        let report = stream_ingest_from(
            std::io::Cursor::new(""),
            "empty",
            &IngestOptions::default(),
            &dir,
        )
        .unwrap();
        assert_eq!((report.n, report.d, report.nnz), (0, 0, 0));
        assert_eq!(report.chunks_flushed, 0);
        let src = ShardCacheSource::open(&dir).unwrap();
        assert_eq!(src.n(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
