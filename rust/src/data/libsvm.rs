//! LIBSVM / SVMlight format reader and writer.
//!
//! Format: one example per line, `label idx:val idx:val ...` with 1-based
//! (conventional) or 0-based indices — auto-detected. Lines starting with
//! `#` and blank lines are skipped. This is the loader that accepts the
//! paper's real datasets (diabetes, housing, ijcnn1, realsim) when the user
//! has the files; the synthetic twins are used otherwise.

use std::io::{BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Csr, Dataset, Task};

/// Parses LIBSVM text into a [`Dataset`].
///
/// `n_features`: pass `Some(d)` to force the dimensionality (needed when a
/// test split does not exercise the trailing features); `None` infers it.
pub fn parse(text: &str, name: &str, task: Task, n_features: Option<usize>) -> Result<Dataset> {
    let mut labels = Vec::new();
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut max_idx: i64 = -1;
    let mut min_idx: i64 = i64::MAX;

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().unwrap();
        let label: f32 = label_tok
            .parse()
            .with_context(|| format!("line {}: bad label {label_tok:?}", lineno + 1))?;
        labels.push(label);

        let mut last_idx: i64 = -1;
        for tok in parts {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (i_str, v_str) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
            let idx: i64 = i_str
                .parse()
                .with_context(|| format!("line {}: bad index {i_str:?}", lineno + 1))?;
            let val: f32 = v_str
                .parse()
                .with_context(|| format!("line {}: bad value {v_str:?}", lineno + 1))?;
            if idx < 0 {
                bail!("line {}: negative feature index {idx}", lineno + 1);
            }
            if idx <= last_idx {
                bail!("line {}: indices not strictly increasing", lineno + 1);
            }
            last_idx = idx;
            max_idx = max_idx.max(idx);
            min_idx = min_idx.min(idx);
            indices.push(idx as u32);
            values.push(val);
        }
        indptr.push(indices.len());
    }

    // 1-based (LIBSVM convention) vs 0-based: if no zero index ever appears,
    // assume 1-based and shift down.
    let one_based = min_idx >= 1 && max_idx >= 1;
    if one_based {
        for i in indices.iter_mut() {
            *i -= 1;
        }
        max_idx -= 1;
    }
    let inferred_d = (max_idx + 1).max(0) as usize;
    let d = match n_features {
        Some(d) => {
            if d < inferred_d {
                bail!("n_features {d} < max feature index {inferred_d}");
            }
            d
        }
        None => inferred_d,
    };

    let n = labels.len();
    let ds = Dataset {
        name: name.to_string(),
        task,
        rows: Csr::new(n, d, indptr, indices, values),
        labels,
    };
    ds.validate()?;
    Ok(ds)
}

/// Loads a LIBSVM file from disk.
pub fn load<P: AsRef<Path>>(
    path: P,
    name: &str,
    task: Task,
    n_features: Option<usize>,
) -> Result<Dataset> {
    let file = std::fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut text = String::new();
    BufReader::new(file).read_to_string(&mut text)?;
    parse(&text, name, task, n_features)
}

use std::io::Read;

/// Writes a dataset in LIBSVM format (1-based indices).
pub fn save<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.n() {
        write!(out, "{}", ds.labels[i])?;
        let (idx, val) = ds.rows.row(i);
        for (j, v) in idx.iter().zip(val) {
            write!(out, " {}:{}", j + 1, v)?;
        }
        writeln!(out)?;
    }
    out.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_one_based() {
        let ds = parse("1 1:0.5 3:2\n-1 2:1\n", "t", Task::Classification, None).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.rows.row(0), (&[0u32, 2][..], &[0.5f32, 2.0][..]));
        assert_eq!(ds.labels, vec![1.0, -1.0]);
    }

    #[test]
    fn parses_zero_based() {
        let ds = parse("2.5 0:1 2:3\n", "t", Task::Regression, None).unwrap();
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.rows.row(0), (&[0u32, 2][..], &[1.0f32, 3.0][..]));
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse("# header\n\n1 1:1\n", "t", Task::Classification, None).unwrap();
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn forced_dimensionality() {
        let ds = parse("1 1:1\n", "t", Task::Classification, Some(10)).unwrap();
        assert_eq!(ds.d(), 10);
        assert!(parse("1 5:1\n", "t", Task::Classification, Some(2)).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("abc 1:1\n", "t", Task::Regression, None).is_err());
        assert!(parse("1 x:1\n", "t", Task::Regression, None).is_err());
        assert!(parse("1 2:1 1:1\n", "t", Task::Regression, None).is_err()); // unsorted
        assert!(parse("1 1:y\n", "t", Task::Regression, None).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = parse("1 1:0.5 3:2\n-1 2:1.25\n1 1:4\n", "t", Task::Classification, None).unwrap();
        let dir = std::env::temp_dir().join("dsfacto_libsvm_test");
        let path = dir.join("x.svm");
        save(&ds, &path).unwrap();
        let back = load(&path, "t", Task::Classification, Some(ds.d())).unwrap();
        assert_eq!(back.rows, ds.rows);
        assert_eq!(back.labels, ds.labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_input_is_empty_dataset() {
        let ds = parse("", "t", Task::Regression, None).unwrap();
        assert_eq!(ds.n(), 0);
        assert_eq!(ds.d(), 0);
    }
}
