//! Synthetic dataset twins for the paper's Table 2 (DESIGN.md §2).
//!
//! Each generator plants a ground-truth FM model `(w0*, w*, V*)` and draws
//! labels from its scores, so that (a) the optimizer has a real low-rank
//! pairwise signal to recover — the regime FMs are designed for — and
//! (b) tests can compare the learned objective against the planted model's.
//!
//! | twin     | N      | D      | K  | task           | features            |
//! |----------|--------|--------|----|----------------|---------------------|
//! | diabetes | 513    | 8      | 4  | classification | dense, standardized |
//! | housing  | 303    | 13     | 4  | regression     | dense, standardized |
//! | ijcnn1   | 49,990 | 22     | 4  | classification | dense, bounded      |
//! | realsim  | 50,616 | 20,958 | 16 | classification | sparse ~0.25%, tf-idf-like |

use anyhow::{bail, Result};

use super::{Csr, Dataset, Task};
use crate::fm::FmModel;
use crate::util::rng::Pcg64;

/// Generation spec for a planted-FM dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: String,
    pub task: Task,
    pub n: usize,
    pub d: usize,
    /// Rank of the planted factor matrix (paper's per-dataset K).
    pub k: usize,
    /// Expected fraction of non-zero features per example (1.0 = dense).
    pub density: f64,
    /// Std-dev of the planted pairwise factors (controls interaction
    /// strength relative to the linear term).
    pub factor_scale: f32,
    /// Observation noise: std-dev for regression, logit temperature for
    /// classification.
    pub noise: f32,
    /// Zipf-like skew of feature popularity for sparse data (0 = uniform).
    pub skew: f64,
}

impl SynthSpec {
    /// The Table 2 preset for one of the paper's datasets.
    pub fn table2(name: &str) -> Result<SynthSpec> {
        let spec = match name {
            "diabetes" => SynthSpec {
                name: "diabetes".into(),
                task: Task::Classification,
                n: 513,
                d: 8,
                k: 4,
                density: 1.0,
                factor_scale: 0.35,
                noise: 0.6,
                skew: 0.0,
            },
            "housing" => SynthSpec {
                name: "housing".into(),
                task: Task::Regression,
                n: 303,
                d: 13,
                k: 4,
                density: 1.0,
                factor_scale: 0.3,
                noise: 0.25,
                skew: 0.0,
            },
            "ijcnn1" => SynthSpec {
                name: "ijcnn1".into(),
                task: Task::Classification,
                n: 49_990,
                d: 22,
                k: 4,
                density: 1.0,
                factor_scale: 0.3,
                noise: 0.5,
                skew: 0.0,
            },
            "realsim" => SynthSpec {
                name: "realsim".into(),
                task: Task::Classification,
                n: 50_616,
                d: 20_958,
                // real-sim is text; the paper trains it with K=16.
                k: 16,
                // ~52 nnz/row, matching real-sim's ~0.25% density.
                density: 52.0 / 20_958.0,
                factor_scale: 0.15,
                noise: 0.4,
                skew: 1.1,
            },
            other => bail!("unknown Table-2 dataset {other:?} (want diabetes|housing|ijcnn1|realsim)"),
        };
        Ok(spec)
    }

    /// All four Table 2 names.
    pub fn table2_names() -> [&'static str; 4] {
        ["diabetes", "housing", "ijcnn1", "realsim"]
    }
}

/// Output of a generation run: dataset plus the planted model.
#[derive(Debug, Clone)]
pub struct SynthOutput {
    pub dataset: Dataset,
    /// The ground-truth model that produced the labels.
    pub planted: FmModel,
}

/// Generates a planted-FM dataset from a spec.
pub fn generate(spec: &SynthSpec, seed: u64) -> SynthOutput {
    let mut rng = Pcg64::new(seed, 0x7ab1e2);
    let planted = plant_model(spec, &mut rng);

    let dense = spec.density >= 0.999;
    let mut triplets: Vec<(usize, usize, f32)> = Vec::new();
    let popularity = if dense {
        Vec::new()
    } else {
        feature_popularity(spec.d, spec.skew, &mut rng)
    };

    let expected_nnz = (spec.density * spec.d as f64).max(1.0);
    for i in 0..spec.n {
        if dense {
            for j in 0..spec.d {
                triplets.push((i, j, rng.normal32(0.0, 1.0)));
            }
        } else {
            // Poisson-ish row length around the expected nnz, >= 1.
            let len = sample_row_len(expected_nnz, &mut rng).min(spec.d);
            let mut cols = std::collections::HashSet::with_capacity(len);
            while cols.len() < len {
                cols.insert(sample_feature(&popularity, &mut rng));
            }
            for j in cols {
                // tf-idf-like positive magnitudes.
                let v = (0.1 + rng.f32()).min(1.0);
                triplets.push((i, j, v));
            }
        }
    }
    let rows = Csr::from_triplets(spec.n, spec.d, &triplets);

    // Labels from the planted model's scores. Raw FM scores have a scale
    // that grows with D and the factor magnitudes; standardizing them keeps
    // every twin well-conditioned at paper-ballpark learning rates (the
    // real datasets are feature-scaled the same way in LIBSVM pipelines).
    let mut scores = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let (idx, val) = rows.row(i);
        scores.push(planted.score_sparse(idx, val));
    }
    let mean = scores.iter().sum::<f32>() / spec.n.max(1) as f32;
    let var = scores.iter().map(|f| (f - mean) * (f - mean)).sum::<f32>() / spec.n.max(1) as f32;
    let inv_std = 1.0 / var.sqrt().max(1e-6);

    let mut labels = Vec::with_capacity(spec.n);
    for &f in &scores {
        let z = (f - mean) * inv_std;
        let y = match spec.task {
            Task::Regression => z + rng.normal32(0.0, spec.noise),
            Task::Classification => {
                // y = +1 with probability sigmoid(z / noise): noise = logit
                // temperature, higher => harder problem.
                let p = 1.0 / (1.0 + (-z / spec.noise.max(1e-6)).exp());
                if rng.chance(p as f64) {
                    1.0
                } else {
                    -1.0
                }
            }
        };
        labels.push(y);
    }

    let dataset = Dataset {
        name: spec.name.clone(),
        task: spec.task,
        rows,
        labels,
    };
    debug_assert!(dataset.validate().is_ok());
    SynthOutput { dataset, planted }
}

/// Convenience: the Table 2 twin by name.
pub fn table2_dataset(name: &str, seed: u64) -> Result<Dataset> {
    Ok(generate(&SynthSpec::table2(name)?, seed).dataset)
}

fn plant_model(spec: &SynthSpec, rng: &mut Pcg64) -> FmModel {
    let mut m = FmModel::zeros(spec.d, spec.k);
    m.w0 = rng.normal32(0.0, 0.1);
    for j in 0..spec.d {
        m.w[j] = rng.normal32(0.0, 0.5);
    }
    for x in m.v.iter_mut() {
        *x = rng.normal32(0.0, spec.factor_scale);
    }
    m
}

/// Unnormalized Zipf-like popularity weights with cumulative sums for
/// inverse-CDF sampling.
fn feature_popularity(d: usize, skew: f64, rng: &mut Pcg64) -> Vec<f64> {
    let mut cum = Vec::with_capacity(d);
    let mut acc = 0f64;
    // Random permutation of ranks so popular features are spread over ids
    // (keeps column partitions balanced in expectation, like hashed vocab).
    let perm = rng.permutation(d);
    let mut weight = vec![0f64; d];
    for (rank, &j) in perm.iter().enumerate() {
        weight[j] = 1.0 / ((rank + 1) as f64).powf(skew);
    }
    for j in 0..d {
        acc += weight[j];
        cum.push(acc);
    }
    cum
}

fn sample_feature(cum: &[f64], rng: &mut Pcg64) -> usize {
    let total = *cum.last().unwrap();
    let u = rng.f64() * total;
    match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
        Ok(i) => i,
        Err(i) => i.min(cum.len() - 1),
    }
}

fn sample_row_len(expected: f64, rng: &mut Pcg64) -> usize {
    // Geometric-ish jitter around the mean, clamped to >= 1.
    let jitter = 0.5 + rng.f64();
    ((expected * jitter).round() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shapes_match_paper() {
        for (name, n, d, k) in [
            ("diabetes", 513, 8, 4),
            ("housing", 303, 13, 4),
            ("ijcnn1", 49_990, 22, 4),
            ("realsim", 50_616, 20_958, 16),
        ] {
            let spec = SynthSpec::table2(name).unwrap();
            assert_eq!((spec.n, spec.d, spec.k), (n, d, k), "{name}");
        }
        assert!(SynthSpec::table2("criteo").is_err());
    }

    #[test]
    fn dense_twin_is_dense_and_valid() {
        let out = generate(&SynthSpec::table2("diabetes").unwrap(), 1);
        let ds = &out.dataset;
        ds.validate().unwrap();
        assert_eq!(ds.n(), 513);
        assert_eq!(ds.d(), 8);
        assert!((ds.density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn classification_labels_are_pm1_and_mixed() {
        let ds = table2_dataset("diabetes", 2).unwrap();
        let pos = ds.labels.iter().filter(|&&y| y == 1.0).count();
        let neg = ds.labels.iter().filter(|&&y| y == -1.0).count();
        assert_eq!(pos + neg, ds.n());
        assert!(pos > ds.n() / 10 && neg > ds.n() / 10, "pos={pos} neg={neg}");
    }

    #[test]
    fn regression_labels_have_signal() {
        let out = generate(&SynthSpec::table2("housing").unwrap(), 3);
        let ds = &out.dataset;
        // Label variance should comfortably exceed the noise variance alone.
        let mean = ds.labels.iter().sum::<f32>() / ds.n() as f32;
        let var = ds.labels.iter().map(|y| (y - mean) * (y - mean)).sum::<f32>() / ds.n() as f32;
        assert!(var > 0.25 * 0.25, "var={var}");
    }

    #[test]
    fn sparse_twin_density_close_to_target() {
        let spec = SynthSpec {
            n: 2000,
            ..SynthSpec::table2("realsim").unwrap()
        };
        let out = generate(&spec, 4);
        let ds = &out.dataset;
        ds.validate().unwrap();
        let got = ds.density();
        let want = spec.density;
        assert!(
            got > 0.4 * want && got < 2.5 * want,
            "density {got} vs target {want}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = table2_dataset("housing", 9).unwrap();
        let b = table2_dataset("housing", 9).unwrap();
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.labels, b.labels);
        let c = table2_dataset("housing", 10).unwrap();
        assert_ne!(a.labels, c.labels);
    }

    #[test]
    fn planted_model_scores_correlate_with_labels() {
        let out = generate(&SynthSpec::table2("housing").unwrap(), 5);
        let (ds, m) = (&out.dataset, &out.planted);
        // Pearson correlation between planted score and label must be high.
        let mut fs = Vec::with_capacity(ds.n());
        for i in 0..ds.n() {
            let (idx, val) = ds.rows.row(i);
            fs.push(m.score_sparse(idx, val) as f64);
        }
        let ys: Vec<f64> = ds.labels.iter().map(|&y| y as f64).collect();
        let corr = correlation(&fs, &ys);
        assert!(corr > 0.8, "corr={corr}");
    }

    fn correlation(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
        let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
        let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
        cov / (va.sqrt() * vb.sqrt())
    }
}
