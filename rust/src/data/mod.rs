//! Data substrates: sparse matrices, LIBSVM parsing (in-memory and
//! streaming out-of-core ingest), the binary shard cache, the
//! [`DataSource`] seam distributed trainers pull shards through,
//! synthetic Table-2 dataset twins, splitting and feature scaling.
//!
//! The container has no network access, so the paper's UCI/LIBSVM datasets
//! (diabetes, housing, ijcnn1, realsim) are reproduced as *synthetic twins*
//! with identical (N, D, K), task type and sparsity, drawn from a planted
//! ground-truth FM model ([`synth`]). The [`libsvm`] parser loads the real
//! files unchanged if the user supplies them (DESIGN.md §2); for data that
//! does not fit RAM, [`libsvm::stream_ingest`] converts the same files
//! into a per-worker shard cache ([`cache`]) in one bounded-memory pass,
//! and [`ShardCacheSource`] serves worker shards from it file by file.

// Hot-path-adjacent module (every trainer's bytes flow through here):
// lint-clean regardless of the workflow-level gate (CI's hotpath-lint
// clippy job covers the whole library).
#![deny(clippy::all)]

pub mod cache;
pub mod libsvm;
pub mod prefetch;
pub mod source;
pub mod sparse;
pub mod synth;

pub use cache::ShardCacheSource;
pub use prefetch::PrefetchSource;
pub use source::{DataSource, InMemorySource, ResolvedSource, ShardSource};
pub use sparse::{Csc, Csr};

use crate::util::rng::Pcg64;

/// Prediction task, which selects the loss (paper eq. 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Squared loss; labels are reals.
    Regression,
    /// Logistic loss; labels are +/-1.
    Classification,
}

impl Task {
    /// Parses `"regression"` / `"classification"` (manifest + config format).
    pub fn parse(s: &str) -> anyhow::Result<Task> {
        match s {
            "regression" => Ok(Task::Regression),
            "classification" => Ok(Task::Classification),
            other => anyhow::bail!("unknown task {other:?}"),
        }
    }

    /// The manifest/config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Task::Regression => "regression",
            Task::Classification => "classification",
        }
    }
}

/// A labeled sparse dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Human-readable name (used in traces and artifact lookup).
    pub name: String,
    /// Task type.
    pub task: Task,
    /// Row-major sparse examples, `n x d`.
    pub rows: Csr,
    /// Labels, length `n`.
    pub labels: Vec<f32>,
}

impl Dataset {
    /// Number of examples.
    pub fn n(&self) -> usize {
        self.rows.n_rows()
    }

    /// Number of features.
    pub fn d(&self) -> usize {
        self.rows.n_cols()
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.rows.nnz()
    }

    /// Density of the feature matrix in [0, 1].
    pub fn density(&self) -> f64 {
        if self.n() == 0 || self.d() == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.n() as f64 * self.d() as f64)
        }
    }

    /// Deterministic train/test split by shuffled row assignment.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut rng = Pcg64::seeded(seed);
        let perm = rng.permutation(self.n());
        let n_train = ((self.n() as f64) * train_frac).round() as usize;
        let (tr_idx, te_idx) = perm.split_at(n_train.min(self.n()));
        (self.subset(tr_idx, "train"), self.subset(te_idx, "test"))
    }

    /// A new dataset containing the given rows (in the given order).
    pub fn subset(&self, idx: &[usize], suffix: &str) -> Dataset {
        let rows = self.rows.select_rows(idx);
        let labels = idx.iter().map(|&i| self.labels[i]).collect();
        Dataset {
            name: format!("{}-{suffix}", self.name),
            task: self.task,
            rows,
            labels,
        }
    }

    /// Max-abs scales every column **computed on this dataset**: column
    /// `j` is multiplied by `1 / max_i |x_ij|` (columns with no stored
    /// entries are left untouched, scale 1), and the per-column scale
    /// vector is returned so the same transform can be applied to a
    /// held-out set via [`Dataset::apply_scale`]. This is deliberately
    /// *not* zero-mean/unit-variance standardization: centering would
    /// densify sparse columns, so — as LIBSVM-style pipelines do — we only
    /// scale, which keeps every stored zero a zero and preserves the
    /// sparsity pattern exactly. Post-scale invariant: every stored value
    /// satisfies `|v| <= 1`.
    pub fn scale_columns(&mut self) -> Vec<f32> {
        let d = self.d();
        let mut max_abs = vec![0f32; d];
        for i in 0..self.n() {
            let (idx, val) = self.rows.row(i);
            for (j, v) in idx.iter().zip(val) {
                let a = v.abs();
                if a > max_abs[*j as usize] {
                    max_abs[*j as usize] = a;
                }
            }
        }
        let scale: Vec<f32> = max_abs
            .iter()
            .map(|&m| if m > 0.0 { 1.0 / m } else { 1.0 })
            .collect();
        self.rows.scale_columns(&scale);
        scale
    }

    /// Applies a previously computed per-column scale.
    pub fn apply_scale(&mut self, scale: &[f32]) {
        self.rows.scale_columns(scale);
    }

    /// Densifies rows `start..start+b` into a row-major `b x d` buffer,
    /// zero-padding past the end (the runtime's fixed-batch artifacts).
    /// Returns the number of real (non-padding) rows. Delegates to the
    /// shared [`Csr::densify_rows`] batch path.
    pub fn densify_batch(&self, start: usize, b: usize, out: &mut [f32]) -> usize {
        self.rows.densify_rows(start, b, self.d(), out)
    }

    /// Labels for the batch starting at `start`, zero-padded to length `b`.
    pub fn labels_batch(&self, start: usize, b: usize, out: &mut [f32]) -> usize {
        assert_eq!(out.len(), b);
        out.fill(0.0);
        let real = b.min(self.n().saturating_sub(start));
        out[..real].copy_from_slice(&self.labels[start..start + real]);
        real
    }

    /// Basic sanity checks (used by loaders and tests).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.labels.len() == self.n(),
            "label count {} != rows {}",
            self.labels.len(),
            self.n()
        );
        self.rows.validate()?;
        if self.task == Task::Classification {
            for (i, &y) in self.labels.iter().enumerate() {
                anyhow::ensure!(
                    y == 1.0 || y == -1.0,
                    "classification label at {i} is {y}, want +/-1"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        // 4 x 3: rows [1 0 2], [0 3 0], [4 5 6], [0 0 0]
        let rows = Csr::from_triplets(
            4,
            3,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 1, 5.0), (2, 2, 6.0)],
        );
        Dataset {
            name: "tiny".into(),
            task: Task::Regression,
            rows,
            labels: vec![1.0, 2.0, 3.0, 4.0],
        }
    }

    #[test]
    fn shape_accessors() {
        let ds = tiny();
        assert_eq!(ds.n(), 4);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.nnz(), 6);
        assert!((ds.density() - 0.5).abs() < 1e-12);
        ds.validate().unwrap();
    }

    #[test]
    fn split_partitions_rows() {
        let ds = tiny();
        let (tr, te) = ds.split(0.5, 1);
        assert_eq!(tr.n() + te.n(), ds.n());
        assert_eq!(tr.n(), 2);
        tr.validate().unwrap();
        te.validate().unwrap();
    }

    #[test]
    fn densify_pads_with_zeros() {
        let ds = tiny();
        let mut buf = vec![f32::NAN; 2 * 3];
        let real = ds.densify_batch(3, 2, &mut buf);
        assert_eq!(real, 1);
        assert_eq!(buf, vec![0.0; 6]); // row 3 is all zeros, row 4 is padding
        let real = ds.densify_batch(0, 2, &mut buf);
        assert_eq!(real, 2);
        assert_eq!(buf, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn labels_batch_pads() {
        let ds = tiny();
        let mut y = vec![9.0; 3];
        let real = ds.labels_batch(2, 3, &mut y);
        assert_eq!(real, 2);
        assert_eq!(y, vec![3.0, 4.0, 0.0]);
    }

    #[test]
    fn scale_columns_bounds_values() {
        let mut ds = tiny();
        let scale = ds.scale_columns();
        assert_eq!(scale.len(), 3);
        for i in 0..ds.n() {
            let (_, vals) = ds.rows.row(i);
            for v in vals {
                assert!(v.abs() <= 1.0 + 1e-6);
            }
        }
    }

    #[test]
    fn classification_labels_validated() {
        let mut ds = tiny();
        ds.task = Task::Classification;
        assert!(ds.validate().is_err());
        ds.labels = vec![1.0, -1.0, 1.0, -1.0];
        ds.validate().unwrap();
    }

    #[test]
    fn task_parse_roundtrip() {
        assert_eq!(Task::parse("regression").unwrap(), Task::Regression);
        assert_eq!(Task::parse(Task::Classification.name()).unwrap(), Task::Classification);
        assert!(Task::parse("ranking").is_err());
    }
}
