//! The data-access seam every distributed trainer pulls its shards
//! through.
//!
//! DS-FACTO's premise is that neither the data nor the model fits one
//! machine, so a worker must only ever hold **its own row shard** — peak
//! data memory per worker is `max_shard`, not `n x d`. [`DataSource`] is
//! that boundary: it answers the whole-dataset questions partition
//! planning needs (`n`, `d`, `nnz`, `task`), plans a [`RowPartition`],
//! and materializes individual [`Shard`]s on demand. Two implementations
//! exist:
//!
//! * [`InMemorySource`] — wraps a borrowed [`Dataset`] and reproduces the
//!   legacy `slice_rows + to_csc` shard build **bit for bit** (this is
//!   what every trainer uses by default, so existing runs are unchanged).
//! * [`crate::data::cache::ShardCacheSource`] — reads per-worker shard
//!   files from a versioned binary cache written by
//!   [`crate::data::libsvm::stream_ingest`], so no step of shard
//!   construction ever materializes the full CSR.
//!
//! Trainer configs carry a [`ShardSource`] (default: in-memory), resolved
//! against the training set at `fit` time; the `data_cache = <dir>`
//! config key routes all three distributed trainers through the cache.

use std::fmt::Debug;
use std::sync::Arc;

use anyhow::Result;

use crate::partition::{RowPartition, RowStrategy, Shard};

use super::{Dataset, Task};

/// A provider of dataset shape, partition plans, and materialized row
/// shards. The contract every implementation must honor:
///
/// * `plan(strategy, p)` returns a partition of exactly `n()` rows into
///   `p` shards, computed by (or bit-identical to) the shared
///   [`RowPartition`] planners — sources backed by a fixed on-disk layout
///   return an error for plans they cannot serve rather than
///   approximating.
/// * `shard(part, id)` materializes shard `id` exactly as
///   [`InMemorySource`] would from the equivalent in-memory dataset:
///   identical local CSR, CSC, labels and task, so training results are
///   independent of which source fed the workers.
/// * `materialize()` reconstructs the full [`Dataset`] (the single-machine
///   trainers, the train/test split and the convergence probe still need
///   whole-dataset access).
pub trait DataSource: Send + Sync + Debug {
    /// Human-readable dataset name (traces, artifact lookup).
    fn name(&self) -> &str;

    /// Number of examples.
    fn n(&self) -> usize;

    /// Number of features.
    fn d(&self) -> usize;

    /// Total stored non-zeros.
    fn nnz(&self) -> usize;

    /// Prediction task (selects the loss).
    fn task(&self) -> Task;

    /// Plans a row partition of the source's `n()` rows into `p` shards.
    fn plan(&self, strategy: RowStrategy, p: usize) -> Result<RowPartition>;

    /// Materializes one shard of `part`.
    fn shard(&self, part: &RowPartition, id: usize) -> Result<Shard>;

    /// Materializes the whole dataset.
    fn materialize(&self) -> Result<Dataset>;

    /// Per-shard stored-nnz counts for `part`, when the source can
    /// answer without loading any shard bytes (the cache reads them off
    /// its manifest; the in-memory view counts from the CSR). `None`
    /// means the caller must load shards to find out.
    fn shard_nnz_hint(&self, part: &RowPartition) -> Option<Vec<usize>> {
        let _ = part;
        None
    }

    /// The partition this source natively serves — a shard cache's
    /// ingested plan. `None` for sources that can cut any plan.
    fn native_plan(&self) -> Option<RowPartition> {
        None
    }
}

/// The in-memory source: a view over a borrowed [`Dataset`]. Its
/// [`DataSource::shard`] is byte-for-byte the shard build the trainers
/// ran before the seam existed (`slice_rows`, `to_csc`, label copy).
#[derive(Debug, Clone, Copy)]
pub struct InMemorySource<'a> {
    ds: &'a Dataset,
}

impl<'a> InMemorySource<'a> {
    /// A source over `ds`.
    pub fn new(ds: &'a Dataset) -> Self {
        InMemorySource { ds }
    }
}

impl DataSource for InMemorySource<'_> {
    fn name(&self) -> &str {
        &self.ds.name
    }

    fn n(&self) -> usize {
        self.ds.n()
    }

    fn d(&self) -> usize {
        self.ds.d()
    }

    fn nnz(&self) -> usize {
        self.ds.nnz()
    }

    fn task(&self) -> Task {
        self.ds.task
    }

    fn plan(&self, strategy: RowStrategy, p: usize) -> Result<RowPartition> {
        Ok(RowPartition::new(strategy, &self.ds.rows, p))
    }

    fn shard(&self, part: &RowPartition, id: usize) -> Result<Shard> {
        anyhow::ensure!(
            part.n_rows() == self.ds.n(),
            "partition covers {} rows, dataset has {}",
            part.n_rows(),
            self.ds.n()
        );
        let (start, end) = part.range(id);
        let rows = self.ds.rows.slice_rows(start, end);
        let cols = rows.to_csc();
        Ok(Shard {
            id,
            start,
            end,
            rows,
            cols,
            labels: self.ds.labels[start..end].to_vec(),
            task: self.ds.task,
        })
    }

    fn materialize(&self) -> Result<Dataset> {
        Ok(self.ds.clone())
    }

    fn shard_nnz_hint(&self, part: &RowPartition) -> Option<Vec<usize>> {
        (part.n_rows() == self.ds.n()).then(|| part.shard_nnz(&self.ds.rows))
    }
}

/// Errors unless `src`'s **shape** — `(n, d, nnz, task)` — matches `ds`.
/// Shard sources replace the *slicing* of the training set, not the
/// training set itself, so a mismatch means workers would train on
/// different rows than the probe evaluates. This is a shape check only —
/// a same-shape dataset with permuted or edited rows passes. The cache
/// resolve path closes that hole with a row-content fingerprint
/// ([`crate::data::cache::ShardCacheSource::verify_content`]): it
/// re-serializes the first and last shards from `ds` and compares their
/// FNV-1a hashes against the manifest's recorded shard hashes. The
/// supported flow remains ingesting the exact pre-split training file and
/// training with `train_frac = 1` (run_experiment keeps row order there).
pub fn ensure_matches(src: &dyn DataSource, ds: &Dataset) -> Result<()> {
    anyhow::ensure!(
        src.n() == ds.n()
            && src.d() == ds.d()
            && src.nnz() == ds.nnz()
            && src.task() == ds.task,
        "shard source {:?} (n={} d={} nnz={} task={}) does not describe the training set \
         (n={} d={} nnz={} task={}); a cache must cover exactly the training rows \
         (ingest the pre-split training file, or train with train_frac = 1)",
        src.name(),
        src.n(),
        src.d(),
        src.nnz(),
        src.task().name(),
        ds.n(),
        ds.d(),
        ds.nnz(),
        ds.task.name()
    );
    Ok(())
}

/// Where a distributed trainer's workers pull their row shards from.
/// Carried by `NomadConfig` / `DsgdConfig` / `BulkSyncConfig` and
/// resolved against the training set at `fit` time.
#[derive(Debug, Clone, Default)]
pub enum ShardSource {
    /// Slice the in-memory training `Dataset` (the legacy path, bit for
    /// bit; the default).
    #[default]
    InMemory,
    /// Load each worker's shard from a binary shard-cache directory
    /// written by [`crate::data::libsvm::stream_ingest`] (the
    /// `data_cache = <dir>` config key).
    Cache(String),
    /// A caller-provided source (embedding, tests).
    Custom(Arc<dyn DataSource>),
}

impl ShardSource {
    /// Resolves against the in-memory training set, validating that the
    /// source's shape matches it. Only `train` is borrowed by the result
    /// (the cache and custom variants resolve to owned/shared sources),
    /// so a temporary `ShardSource` works fine here.
    pub fn resolve<'a>(&self, train: &'a Dataset) -> Result<ResolvedSource<'a>> {
        match self {
            ShardSource::InMemory => Ok(ResolvedSource::Borrowed(InMemorySource::new(train))),
            ShardSource::Cache(dir) => {
                let src = super::cache::ShardCacheSource::open(dir)?;
                ensure_matches(&src, train)?;
                src.verify_content(train)?;
                // Worker shard loads through the cache get the
                // double-buffered prefetch decorator: sequential sweeps
                // overlap the next shard's read with compute, and the
                // parallel shard-build pool degrades to sync loads.
                Ok(ResolvedSource::Shared(Arc::new(
                    super::prefetch::PrefetchSource::new(Arc::new(src)),
                )))
            }
            ShardSource::Custom(src) => {
                ensure_matches(src.as_ref(), train)?;
                Ok(ResolvedSource::Shared(src.clone()))
            }
        }
    }
}

/// A [`ShardSource`] resolved for one training session (borrowed
/// in-memory view, freshly opened cache, or shared custom source).
#[derive(Debug)]
pub enum ResolvedSource<'a> {
    /// The in-memory view over the training set.
    Borrowed(InMemorySource<'a>),
    /// An owned source (a cache opened for this session).
    Owned(Box<dyn DataSource>),
    /// A shared caller-provided source.
    Shared(Arc<dyn DataSource>),
}

impl ResolvedSource<'_> {
    /// The seam as a trait object.
    pub fn as_dyn(&self) -> &dyn DataSource {
        match self {
            ResolvedSource::Borrowed(s) => s,
            ResolvedSource::Owned(s) => s.as_ref(),
            ResolvedSource::Shared(s) => s.as_ref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::partition::build_shards;

    #[test]
    fn in_memory_source_reports_dataset_shape() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        let src = InMemorySource::new(&ds);
        assert_eq!(src.n(), ds.n());
        assert_eq!(src.d(), ds.d());
        assert_eq!(src.nnz(), ds.nnz());
        assert_eq!(src.task(), ds.task);
        assert_eq!(src.name(), ds.name);
        let back = src.materialize().unwrap();
        assert_eq!(back.rows, ds.rows);
        assert_eq!(back.labels, ds.labels);
    }

    #[test]
    fn in_memory_shards_match_build_shards_bitwise() {
        let ds = synth::table2_dataset("housing", 5).unwrap();
        let src = InMemorySource::new(&ds);
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let part = src.plan(strat, 4).unwrap();
            assert_eq!(part, RowPartition::new(strat, &ds.rows, 4));
            let legacy = build_shards(&ds, &part);
            for (id, want) in legacy.iter().enumerate() {
                let got = src.shard(&part, id).unwrap();
                assert_eq!(got.rows, want.rows, "{strat:?} shard {id}");
                assert_eq!(got.cols, want.cols, "{strat:?} shard {id}");
                assert_eq!((got.start, got.end), (want.start, want.end));
                assert_eq!(got.task, want.task);
                let a: Vec<u32> = got.labels.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = want.labels.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "{strat:?} shard {id} labels");
            }
        }
    }

    #[test]
    fn ensure_matches_rejects_mismatched_shapes() {
        let ds = synth::table2_dataset("housing", 7).unwrap();
        let sub = ds.subset(&(0..ds.n() - 1).collect::<Vec<_>>(), "sub");
        let src = InMemorySource::new(&ds);
        assert!(ensure_matches(&src, &ds).is_ok());
        let err = ensure_matches(&src, &sub).unwrap_err();
        assert!(format!("{err:#}").contains("does not describe"), "{err:#}");
    }

    #[test]
    fn default_shard_source_resolves_to_in_memory() {
        let ds = synth::table2_dataset("housing", 9).unwrap();
        let resolved = ShardSource::default().resolve(&ds).unwrap();
        assert_eq!(resolved.as_dyn().n(), ds.n());
    }
}
