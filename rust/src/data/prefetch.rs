//! Double-buffered shard prefetch: a [`DataSource`] decorator that
//! overlaps the *next* shard's disk read + decode with the consumer's
//! work on the current one.
//!
//! The streaming sweeps (the coordinator's cache-fed trace/eval, libFM's
//! shard-epoch loop, `streaming_objective`) visit shards in partition
//! order, one at a time. Without prefetch every shard boundary stalls on
//! a synchronous read + hash check + CSC build; with it, delivering shard
//! `i` immediately launches shard `i + 1` on a plain worker thread, so a
//! sequential consumer alternates between *one shard in use* and *one in
//! flight* — never more. That is the whole buffer: depth one, plain
//! `std::thread` + `mpsc` channel, no extra dependencies.
//!
//! The decorator never changes *what* is delivered, only *when* the read
//! happens: every shard comes from the inner source verbatim, so the
//! bitwise parity guarantees of the shard cache pass through unchanged.
//! Out-of-order requests (e.g. from the parallel pool in
//! [`build_shards_from_source`]) are safe — a buffered shard that does
//! not match the request is discarded and the request served with a
//! synchronous load.
//!
//! # Residency accounting
//!
//! The inner cache's `peak_load_bytes` keeps its meaning (largest single
//! shard-file read). On top of that the decorator meters *deliveries*:
//! at the moment shard `i` is handed out, shard `i - 1` — delivered one
//! call earlier — is presumed still live at the consumer, so the meter
//! briefly holds both before retiring the older one. For a sequential
//! consumer the resulting `peak_resident_shards` is exactly the
//! double-buffer contract: 2 after the second delivery (1 after a
//! single delivery). Shard bytes
//! are counted as the shard's CSR footprint (`8·(nloc+1) + 8·nnz +
//! 4·nloc` for indptr + indices/values + labels; the derived CSC is the
//! same order and not double-counted), mirroring the full-CSR accounting
//! the bounded-memory tests compare against. Under a *parallel* consumer
//! the meter is an approximation (deliveries retire in request order,
//! not true drop order) — the pool by design holds every worker's shard
//! at once anyway, so the sequential sweeps are where the number is
//! load-bearing.
//!
//! [`build_shards_from_source`]: crate::partition::build_shards_from_source

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::cluster::retry::{Attempt, RetryPolicy, SystemClock};
use crate::partition::{RowPartition, RowStrategy, Shard};

use super::source::DataSource;
use super::{Dataset, Task};

/// A shard already launched on the prefetch thread.
#[derive(Debug)]
struct Pending {
    /// Shard id the thread is loading.
    id: usize,
    /// Partition the load was planned against (a mismatched request
    /// discards the buffer instead of delivering foreign rows).
    part: RowPartition,
    /// Receives the load result exactly once.
    rx: mpsc::Receiver<Result<Shard>>,
}

/// Meters + the single-slot prefetch buffer, all under one mutex.
#[derive(Debug, Default)]
struct State {
    pending: Option<Pending>,
    resident_bytes: usize,
    resident_shards: usize,
    delivered_bytes: usize,
    delivered_shards: usize,
    peak_bytes: usize,
    peak_shards: usize,
    hits: u64,
    misses: u64,
}

/// The shard's in-memory CSR footprint, in the same accounting the
/// bounded-memory tests use for the full matrix: `8·(n+1)` indptr +
/// `(4+4)·nnz` indices/values + `4·n` labels.
fn shard_mem_bytes(sh: &Shard) -> usize {
    8 * (sh.nloc() + 1) + 8 * sh.rows.nnz() + 4 * sh.nloc()
}

/// The died-prefetch-thread degradation path: reload synchronously under
/// the cluster's shared [`RetryPolicy`] with a small budget, so the one
/// anomalous way to reach this code (a prefetch thread killed mid-read)
/// is not compounded by failing the sweep on a transient I/O error.
fn sync_reload(inner: &dyn DataSource, part: &RowPartition, id: usize) -> Result<Shard> {
    let policy = RetryPolicy::new(
        Duration::from_millis(10),
        Duration::from_millis(50),
        Duration::from_millis(200),
    );
    policy.run(&mut SystemClock, |_| {
        inner.shard(part, id).map_err(Attempt::Retry)
    })
}

/// Double-buffering [`DataSource`] decorator: one shard in use, one in
/// flight. See the module docs for the contract.
#[derive(Debug)]
pub struct PrefetchSource {
    inner: Arc<dyn DataSource>,
    state: Mutex<State>,
}

impl PrefetchSource {
    /// Wraps `inner`. The decorator is inert until the first
    /// [`DataSource::shard`] call; it holds no threads while idle.
    pub fn new(inner: Arc<dyn DataSource>) -> PrefetchSource {
        PrefetchSource {
            inner,
            state: Mutex::new(State::default()),
        }
    }

    fn state(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().expect("prefetch state poisoned")
    }

    /// High-water mark of delivered shard bytes (CSR footprint); ≤ 2
    /// shards' worth for a sequential consumer.
    pub fn peak_resident_bytes(&self) -> usize {
        self.state().peak_bytes
    }

    /// High-water mark of concurrently live deliveries; 2 for any
    /// sequential sweep with at least two deliveries (1 after a single
    /// delivery).
    pub fn peak_resident_shards(&self) -> usize {
        self.state().peak_shards
    }

    /// Requests served from the in-flight buffer.
    pub fn prefetch_hits(&self) -> u64 {
        self.state().hits
    }

    /// Requests served by a synchronous load (first shard of a sweep,
    /// out-of-order access, or a died prefetch thread).
    pub fn prefetch_misses(&self) -> u64 {
        self.state().misses
    }
}

impl DataSource for PrefetchSource {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn d(&self) -> usize {
        self.inner.d()
    }

    fn nnz(&self) -> usize {
        self.inner.nnz()
    }

    fn task(&self) -> Task {
        self.inner.task()
    }

    fn plan(&self, strategy: RowStrategy, p: usize) -> Result<RowPartition> {
        self.inner.plan(strategy, p)
    }

    fn shard(&self, part: &RowPartition, id: usize) -> Result<Shard> {
        // Claim the in-flight shard (if any) under the lock, then do all
        // loading outside it so parallel consumers are not serialized.
        let pending = self.state().pending.take();
        let (shard, was_hit) = match pending {
            Some(pf) if pf.id == id && pf.part == *part => match pf.rx.recv() {
                Ok(Ok(sh)) => (sh, true),
                Ok(Err(e)) => {
                    self.state().hits += 1;
                    return Err(e);
                }
                // The prefetch thread died without sending; reload
                // synchronously (with retry) rather than surfacing a
                // channel error.
                Err(_) => (sync_reload(&*self.inner, part, id)?, false),
            },
            // Nothing buffered, or the buffer is for a different shard /
            // partition: discard it and load synchronously.
            _ => (self.inner.shard(part, id)?, false),
        };
        let sz = shard_mem_bytes(&shard);
        let mut st = self.state();
        if was_hit {
            st.hits += 1;
        } else {
            st.misses += 1;
        }
        // Add the new delivery before retiring the previous one: the
        // consumer is presumed to still hold shard `id - 1` at this
        // moment, and that overlap *is* the double-buffer peak.
        st.resident_bytes += sz;
        st.resident_shards += 1;
        st.peak_bytes = st.peak_bytes.max(st.resident_bytes);
        st.peak_shards = st.peak_shards.max(st.resident_shards);
        st.resident_bytes -= st.delivered_bytes;
        st.resident_shards -= st.delivered_shards;
        st.delivered_bytes = sz;
        st.delivered_shards = 1;
        // Launch the next shard in partition order. Slot already taken
        // (a parallel consumer got here first) or spawn failure both
        // degrade to synchronous loads — never an error.
        if st.pending.is_none() {
            if let Some(next) = id.checked_add(1).filter(|&nx| nx < part.n_shards()) {
                let (tx, rx) = mpsc::channel();
                let inner = Arc::clone(&self.inner);
                let p2 = part.clone();
                let spawned = std::thread::Builder::new()
                    .name("shard-prefetch".into())
                    .spawn(move || {
                        let _ = tx.send(inner.shard(&p2, next));
                    })
                    .is_ok();
                if spawned {
                    st.pending = Some(Pending {
                        id: next,
                        part: part.clone(),
                        rx,
                    });
                }
            }
        }
        drop(st);
        Ok(shard)
    }

    fn materialize(&self) -> Result<Dataset> {
        self.inner.materialize()
    }

    fn shard_nnz_hint(&self, part: &RowPartition) -> Option<Vec<usize>> {
        self.inner.shard_nnz_hint(part)
    }

    fn native_plan(&self) -> Option<RowPartition> {
        self.inner.native_plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cache::{write_cache, ShardCacheSource};
    use crate::data::synth;
    use crate::partition::RowStrategy;

    fn cache_source(tag: &str, shards: usize) -> (Dataset, Arc<ShardCacheSource>, RowPartition) {
        let ds = synth::table2_dataset("housing", 21).unwrap();
        let dir = std::env::temp_dir().join(format!("dsfacto_prefetch_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        write_cache(&ds, RowStrategy::Contiguous, shards, &dir).unwrap();
        let src = Arc::new(ShardCacheSource::open(&dir).unwrap());
        let part = src.plan(RowStrategy::Contiguous, shards).unwrap();
        (ds, src, part)
    }

    #[test]
    fn sequential_sweep_is_bitwise_and_double_buffered() {
        let (_ds, cache, part) = cache_source("seq", 4);
        let pf = PrefetchSource::new(cache.clone() as Arc<dyn DataSource>);
        for _epoch in 0..2 {
            for id in 0..part.n_shards() {
                let got = pf.shard(&part, id).unwrap();
                let want = cache.shard(&part, id).unwrap();
                assert_eq!(got.rows, want.rows, "shard {id}: CSR");
                assert_eq!(got.cols, want.cols, "shard {id}: CSC");
                assert_eq!((got.start, got.end), (want.start, want.end));
                let a: Vec<u32> = got.labels.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = want.labels.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "shard {id}: labels");
            }
        }
        // One miss per epoch (nothing in flight at the sweep start),
        // hits for every later shard.
        assert_eq!(pf.prefetch_misses(), 2);
        assert_eq!(pf.prefetch_hits(), 6);
        // The double-buffer contract: never more than 2 deliveries live.
        assert_eq!(pf.peak_resident_shards(), 2);
        assert!(pf.peak_resident_bytes() > 0);
        let full = 8 * (pf.n() + 1) + 8 * pf.nnz() + 4 * pf.n();
        assert!(
            pf.peak_resident_bytes() < full,
            "peak {} not below full CSR {full}",
            pf.peak_resident_bytes()
        );
    }

    #[test]
    fn out_of_order_requests_fall_back_to_sync_loads() {
        let (_ds, cache, part) = cache_source("ooo", 4);
        let pf = PrefetchSource::new(cache.clone() as Arc<dyn DataSource>);
        for &id in &[2usize, 0, 1, 3] {
            let got = pf.shard(&part, id).unwrap();
            let want = cache.shard(&part, id).unwrap();
            // Degraded (sync-load) deliveries must still be *byte*
            // identical to the direct cache read — CSR, CSC and labels.
            assert_eq!(got.rows, want.rows, "shard {id}: CSR");
            assert_eq!(got.cols, want.cols, "shard {id}: CSC");
            assert_eq!((got.start, got.end), (want.start, want.end));
            let a: Vec<u32> = got.labels.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = want.labels.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "shard {id}: labels");
        }
        // 2 (cold) and 0 (buffer holds 3) and 3 (buffer holds 2) miss;
        // 1 hits the buffer spawned after delivering 0.
        assert_eq!(pf.prefetch_misses(), 3);
        assert_eq!(pf.prefetch_hits(), 1);
    }

    #[test]
    fn single_shard_plan_peaks_at_one() {
        let (_ds, cache, part) = cache_source("one", 1);
        let pf = PrefetchSource::new(cache as Arc<dyn DataSource>);
        pf.shard(&part, 0).unwrap();
        assert_eq!(pf.peak_resident_shards(), 1);
        // No shard 1 to prefetch: a second delivery is another miss.
        pf.shard(&part, 0).unwrap();
        assert_eq!(pf.prefetch_hits(), 0);
        assert_eq!(pf.prefetch_misses(), 2);
        assert_eq!(pf.peak_resident_shards(), 2);
    }

    #[test]
    fn delegation_preserves_shape_and_plans() {
        let (ds, cache, part) = cache_source("shape", 3);
        let pf = PrefetchSource::new(cache as Arc<dyn DataSource>);
        assert_eq!(pf.n(), ds.n());
        assert_eq!(pf.d(), ds.d());
        assert_eq!(pf.nnz(), ds.nnz());
        assert_eq!(pf.task(), ds.task);
        assert_eq!(pf.name(), "housing");
        assert_eq!(pf.native_plan().as_ref(), Some(&part));
        assert_eq!(
            pf.shard_nnz_hint(&part).map(|v| v.iter().sum::<usize>()),
            Some(ds.nnz())
        );
        let back = pf.materialize().unwrap();
        assert_eq!(back.rows, ds.rows);
    }
}
