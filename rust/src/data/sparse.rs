//! Compressed sparse row / column matrices.
//!
//! `Csr` stores the examples (row = example); `Csc` is the column view each
//! NOMAD worker builds over its local row block so that "apply token j to
//! my examples" is a contiguous scan (the doubly-separable access pattern of
//! paper Figs. 1-2).

use anyhow::{ensure, Result};

/// Compressed sparse row matrix (f32 values, u32 column indices).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    n_rows: usize,
    n_cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl Csr {
    /// Builds from raw CSR arrays.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        let m = Csr {
            n_rows,
            n_cols,
            indptr,
            indices,
            values,
        };
        debug_assert!(m.validate().is_ok(), "invalid CSR");
        m
    }

    /// Builds from raw CSR arrays, validating instead of asserting — the
    /// constructor for untrusted bytes (the shard-cache reader), where a
    /// malformed matrix must surface as an error, not a debug panic.
    pub fn try_new(
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self> {
        let m = Csr {
            n_rows,
            n_cols,
            indptr,
            indices,
            values,
        };
        m.validate()?;
        Ok(m)
    }

    /// Builds from (row, col, value) triplets (any order; duplicates summed).
    pub fn from_triplets(n_rows: usize, n_cols: usize, triplets: &[(usize, usize, f32)]) -> Self {
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n_rows];
        for &(r, c, v) in triplets {
            assert!(r < n_rows && c < n_cols, "triplet out of bounds");
            per_row[r].push((c as u32, v));
        }
        let mut indptr = Vec::with_capacity(n_rows + 1);
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        indptr.push(0);
        for row in &mut per_row {
            row.sort_by_key(|&(c, _)| c);
            let mut last: Option<u32> = None;
            for &(c, v) in row.iter() {
                if last == Some(c) {
                    *values.last_mut().unwrap() += v;
                } else {
                    indices.push(c);
                    values.push(v);
                    last = Some(c);
                }
            }
            indptr.push(indices.len());
        }
        Csr::new(n_rows, n_cols, indptr, indices, values)
    }

    /// An empty matrix.
    pub fn empty(n_rows: usize, n_cols: usize) -> Self {
        Csr::new(n_rows, n_cols, vec![0; n_rows + 1], Vec::new(), Vec::new())
    }

    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Number of columns.
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// The (indices, values) pair of one row.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Non-zero count of one row.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Dot product of row `i` with a dense vector.
    pub fn row_dot(&self, i: usize, dense: &[f32]) -> f32 {
        let (idx, val) = self.row(i);
        let mut acc = 0f32;
        for (j, v) in idx.iter().zip(val) {
            acc += v * dense[*j as usize];
        }
        acc
    }

    /// Selects rows by index (with repetition allowed), preserving order.
    pub fn select_rows(&self, idx: &[usize]) -> Csr {
        let nnz: usize = idx.iter().map(|&i| self.row_nnz(i)).sum();
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        self.gather_rows_into(idx, &mut indptr, &mut indices, &mut values);
        Csr::new(idx.len(), self.n_cols, indptr, indices, values)
    }

    /// Gathers the given rows' CSR arrays into caller-owned staging
    /// buffers (cleared first; capacity is reused across calls, so a loop
    /// that gathers fixed-size row blocks allocates only on its first
    /// iteration). Row `b` of the gather is
    /// `indices[indptr[b]..indptr[b+1]]` / `values[..]`. This is the
    /// allocation-free core of [`Csr::select_rows`] and the staging path
    /// the libFM epoch loop uses to densify each shuffled row block into
    /// contiguous memory before updating (ROADMAP perf trajectory).
    pub fn gather_rows_into(
        &self,
        idx: &[usize],
        indptr: &mut Vec<usize>,
        indices: &mut Vec<u32>,
        values: &mut Vec<f32>,
    ) {
        indptr.clear();
        indices.clear();
        values.clear();
        indptr.reserve(idx.len() + 1);
        indptr.push(0);
        for &i in idx {
            let (ci, cv) = self.row(i);
            indices.extend_from_slice(ci);
            values.extend_from_slice(cv);
            indptr.push(indices.len());
        }
    }

    /// A contiguous row-range slice.
    pub fn slice_rows(&self, start: usize, end: usize) -> Csr {
        assert!(start <= end && end <= self.n_rows);
        let a = self.indptr[start];
        let b = self.indptr[end];
        let indptr = self.indptr[start..=end].iter().map(|&p| p - a).collect();
        Csr::new(
            end - start,
            self.n_cols,
            indptr,
            self.indices[a..b].to_vec(),
            self.values[a..b].to_vec(),
        )
    }

    /// Multiplies each column by `scale[j]` in place.
    pub fn scale_columns(&mut self, scale: &[f32]) {
        assert_eq!(scale.len(), self.n_cols);
        for (j, v) in self.indices.iter().zip(self.values.iter_mut()) {
            *v *= scale[*j as usize];
        }
    }

    /// Transposes into a CSC view (column -> (row, value) lists).
    pub fn to_csc(&self) -> Csc {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &j in &self.indices {
            counts[j as usize + 1] += 1;
        }
        for j in 0..self.n_cols {
            counts[j + 1] += counts[j];
        }
        let colptr = counts.clone();
        let mut cursor = counts;
        let mut rows = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        for i in 0..self.n_rows {
            let (idx, val) = self.row(i);
            for (j, v) in idx.iter().zip(val) {
                let p = cursor[*j as usize];
                rows[p] = i as u32;
                values[p] = *v;
                cursor[*j as usize] += 1;
            }
        }
        Csc {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            colptr,
            rows,
            values,
        }
    }

    /// Densifies rows `start..start+b` into a row-major `b x width`
    /// buffer (`width >= n_cols`), zero-filling both the tail past the
    /// last row and the columns past `n_cols`; returns the number of real
    /// (non-padding) rows. This is the one batch-densify path shared by
    /// `Dataset::densify_batch` and the XLA predictor's fixed-shape
    /// batches.
    pub fn densify_rows(&self, start: usize, b: usize, width: usize, out: &mut [f32]) -> usize {
        assert!(
            width >= self.n_cols,
            "densify width {width} < n_cols {}",
            self.n_cols
        );
        assert_eq!(out.len(), b * width, "densify buffer size");
        out.fill(0.0);
        let real = b.min(self.n_rows.saturating_sub(start));
        for r in 0..real {
            let (idx, val) = self.row(start + r);
            let row = &mut out[r * width..(r + 1) * width];
            for (j, v) in idx.iter().zip(val) {
                row[*j as usize] = *v;
            }
        }
        real
    }

    /// The raw CSR arrays `(indptr, indices, values)` — the serialization
    /// view the shard-cache writer streams to disk.
    pub fn raw_parts(&self) -> (&[usize], &[u32], &[f32]) {
        (&self.indptr, &self.indices, &self.values)
    }

    /// Dense row-major copy (tests / tiny data only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n_rows * self.n_cols];
        for i in 0..self.n_rows {
            let (idx, val) = self.row(i);
            for (j, v) in idx.iter().zip(val) {
                out[i * self.n_cols + *j as usize] = *v;
            }
        }
        out
    }

    /// Structural invariants.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.indptr.len() == self.n_rows + 1,
            "indptr len {} != n_rows+1 {}",
            self.indptr.len(),
            self.n_rows + 1
        );
        ensure!(self.indptr[0] == 0, "indptr[0] != 0");
        ensure!(
            *self.indptr.last().unwrap() == self.values.len(),
            "indptr end {} != nnz {}",
            self.indptr.last().unwrap(),
            self.values.len()
        );
        ensure!(
            self.indices.len() == self.values.len(),
            "indices/values length mismatch"
        );
        for w in self.indptr.windows(2) {
            ensure!(w[0] <= w[1], "indptr not monotone");
        }
        for i in 0..self.n_rows {
            let (idx, _) = self.row(i);
            for w in idx.windows(2) {
                ensure!(w[0] < w[1], "row {i}: column indices not strictly increasing");
            }
            if let Some(&last) = idx.last() {
                ensure!((last as usize) < self.n_cols, "row {i}: column out of range");
            }
        }
        Ok(())
    }
}

/// Compressed sparse column matrix: the per-worker column view.
#[derive(Debug, Clone, PartialEq)]
pub struct Csc {
    n_rows: usize,
    n_cols: usize,
    colptr: Vec<usize>,
    rows: Vec<u32>,
    values: Vec<f32>,
}

impl Csc {
    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Number of columns.
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// The (row indices, values) of one column.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.colptr[j], self.colptr[j + 1]);
        (&self.rows[a..b], &self.values[a..b])
    }

    /// Non-zero count of one column.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.colptr[j + 1] - self.colptr[j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_res;
    use crate::util::rng::Pcg64;

    fn example() -> Csr {
        Csr::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (0, 3, 4.0), (1, 0, 1.0), (2, 1, 5.0), (2, 2, 6.0)],
        )
    }

    #[test]
    fn triplets_build_sorted_rows() {
        let m = Csr::from_triplets(2, 3, &[(0, 2, 3.0), (0, 0, 1.0), (1, 1, 2.0)]);
        assert_eq!(m.row(0), (&[0u32, 2][..], &[1.0f32, 3.0][..]));
        assert_eq!(m.row(1), (&[1u32][..], &[2.0f32][..]));
        m.validate().unwrap();
    }

    #[test]
    fn duplicate_triplets_sum() {
        let m = Csr::from_triplets(1, 2, &[(0, 1, 2.0), (0, 1, 3.0)]);
        assert_eq!(m.row(0), (&[1u32][..], &[5.0f32][..]));
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn row_dot_matches_dense() {
        let m = example();
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.row_dot(0, &v), 2.0 * 2.0 + 4.0 * 4.0);
        assert_eq!(m.row_dot(1, &v), 1.0);
    }

    #[test]
    fn slice_and_select() {
        let m = example();
        let s = m.slice_rows(1, 3);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.row(0), m.row(1));
        assert_eq!(s.row(1), m.row(2));
        let sel = m.select_rows(&[2, 0]);
        assert_eq!(sel.row(0), m.row(2));
        assert_eq!(sel.row(1), m.row(0));
    }

    #[test]
    fn gather_rows_matches_select_and_reuses_buffers() {
        let m = example();
        let mut indptr = Vec::new();
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for pick in [vec![2usize, 0], vec![1], vec![0, 1, 2, 1]] {
            m.gather_rows_into(&pick, &mut indptr, &mut indices, &mut values);
            let sel = m.select_rows(&pick);
            assert_eq!(indptr.len(), pick.len() + 1);
            for (b, &i) in pick.iter().enumerate() {
                let (a, e) = (indptr[b], indptr[b + 1]);
                assert_eq!((&indices[a..e], &values[a..e]), m.row(i), "row {i}");
                assert_eq!((&indices[a..e], &values[a..e]), sel.row(b));
            }
        }
        // Buffers were cleared between gathers: last pick has 4 rows.
        assert_eq!(indptr.len(), 5);
    }

    #[test]
    fn csc_transpose_roundtrip() {
        let m = example();
        let t = m.to_csc();
        assert_eq!(t.nnz(), m.nnz());
        // Column 1 holds rows 0 and 2.
        assert_eq!(t.col(1), (&[0u32, 2][..], &[2.0f32, 5.0][..]));
        assert_eq!(t.col(0), (&[1u32][..], &[1.0f32][..]));
        assert_eq!(t.col_nnz(3), 1);
    }

    #[test]
    fn dense_copy() {
        let m = example();
        let d = m.to_dense();
        assert_eq!(d[0 * 4 + 1], 2.0);
        assert_eq!(d[2 * 4 + 2], 6.0);
        assert_eq!(d[1 * 4 + 3], 0.0);
    }

    #[test]
    fn validate_rejects_bad_indptr() {
        let m = Csr {
            n_rows: 2,
            n_cols: 2,
            indptr: vec![0, 2, 1],
            indices: vec![0, 1],
            values: vec![1.0, 1.0],
        };
        assert!(m.validate().is_err());
    }

    fn random_csr(rng: &mut Pcg64) -> Csr {
        let n = 1 + rng.below_usize(12);
        let d = 1 + rng.below_usize(12);
        let nnz = rng.below_usize(n * d);
        let triplets: Vec<(usize, usize, f32)> = (0..nnz)
            .map(|_| {
                (
                    rng.below_usize(n),
                    rng.below_usize(d),
                    rng.normal32(0.0, 1.0),
                )
            })
            .collect();
        Csr::from_triplets(n, d, &triplets)
    }

    #[test]
    fn prop_transpose_preserves_entries() {
        forall_res(
            "csc transpose preserves all entries",
            48,
            random_csr,
            |m| {
                let t = m.to_csc();
                if t.nnz() != m.nnz() {
                    return Err(format!("nnz {} != {}", t.nnz(), m.nnz()));
                }
                // Every (i, j, v) in CSR appears in CSC column j.
                for i in 0..m.n_rows() {
                    let (idx, val) = m.row(i);
                    for (j, v) in idx.iter().zip(val) {
                        let (rows, vals) = t.col(*j as usize);
                        let pos = rows.iter().position(|&r| r as usize == i);
                        match pos {
                            Some(p) if vals[p] == *v => {}
                            _ => return Err(format!("entry ({i},{j}) lost")),
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_slice_rows_consistent() {
        forall_res(
            "slice_rows equals select_rows on ranges",
            32,
            |rng| {
                let m = random_csr(rng);
                let a = rng.below_usize(m.n_rows() + 1);
                let b = a + rng.below_usize(m.n_rows() - a + 1);
                (m, a, b)
            },
            |(m, a, b)| {
                let s1 = m.slice_rows(*a, *b);
                let idx: Vec<usize> = (*a..*b).collect();
                let s2 = m.select_rows(&idx);
                if s1 == s2 {
                    Ok(())
                } else {
                    Err("slice != select".to_string())
                }
            },
        );
    }
}
