//! # DS-FACTO — Doubly Separable Factorization Machines
//!
//! A production-oriented reproduction of *"DS-FACTO: Doubly Separable
//! Factorization Machines"* (Raman & Vishwanathan, 2020): a hybrid-parallel,
//! fully decentralized, asynchronous stochastic optimizer for factorization
//! machines that partitions **both** the data (row blocks per worker) and the
//! model (parameter columns circulating as tokens through worker queues,
//! NOMAD-style) with no parameter server.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: the NOMAD-style token
//!   engine ([`nomad`]), single-machine and synchronous baselines
//!   ([`baseline`]), data substrates ([`data`]), metrics, config, CLI.
//! * **Layer 2/1 (build time, `python/compile/`)** — the FM compute graphs
//!   (JAX) built on Pallas kernels, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] module loads and executes through the PJRT CPU client
//!   (`xla` crate). Python never runs on the training/serving path.
//!
//! ## Quick start
//!
//! ```no_run
//! // A synthetic twin of the paper's `diabetes` dataset (Table 2).
//! let ds = dsfacto::data::synth::table2_dataset("diabetes", 42).unwrap();
//! let (train, test) = ds.split(0.8, 7);
//! let cfg = dsfacto::nomad::NomadConfig {
//!     workers: 4,
//!     outer_iters: 50,
//!     ..Default::default()
//! };
//! let fm = dsfacto::fm::FmHyper { k: 4, ..Default::default() };
//! let out = dsfacto::nomad::train(&train, Some(&test), &fm, &cfg).unwrap();
//! println!("final objective {}", out.trace.last().unwrap().objective);
//! ```

pub mod baseline;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fm;
pub mod metrics;
pub mod nomad;
pub mod optim;
pub mod runtime;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::config::{DatasetSpec, ExperimentConfig, TrainerKind};
    pub use crate::data::{Dataset, Task};
    pub use crate::fm::{FmHyper, FmModel};
    pub use crate::metrics::{EvalMetrics, TracePoint};
    pub use crate::nomad::{train as nomad_train, NomadConfig};
    pub use crate::util::rng::Pcg64;
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
