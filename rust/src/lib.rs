//! # DS-FACTO — Doubly Separable Factorization Machines
//!
//! A production-oriented reproduction of *"DS-FACTO: Doubly Separable
//! Factorization Machines"* (Raman & Vishwanathan, 2020): a hybrid-parallel,
//! fully decentralized, asynchronous stochastic optimizer for factorization
//! machines that partitions **both** the data (row blocks per worker) and the
//! model (parameter columns circulating as tokens through worker queues,
//! NOMAD-style) with no parameter server.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: the NOMAD-style token
//!   engine ([`nomad`]), single-machine and synchronous baselines
//!   ([`baseline`]), the doubly-separable partition plans all distributed
//!   trainers shard through ([`partition`]), the uniform trainer/predictor
//!   session API ([`train`]), data substrates ([`data`]) including the
//!   out-of-core layer (streaming LIBSVM ingest into a binary shard
//!   cache, served to workers through the [`data::DataSource`] seam),
//!   metrics, config, CLI.
//! * **Hot path ([`kernel`])** — the fused lane-blocked (AoSoA, 8-wide
//!   f32) per-example FM kernels all trainers and the serving path run
//!   on: one-pass scoring, a fused score+gradient+update step, and batch
//!   scoring, driven through a per-thread [`kernel::Scratch`] arena so
//!   the steady state performs zero heap allocation (EXPERIMENTS.md
//!   §Perf documents the layout and the `BENCH_hotpath.json` trajectory).
//! * **Layer 2/1 (build time, `python/compile/`)** — the FM compute graphs
//!   (JAX) built on Pallas kernels, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] module loads and executes through the PJRT CPU client
//!   (`xla` crate). Python never runs on the training/serving path.
//!
//! ## Quick start
//!
//! Every engine — DS-FACTO and all the paper's baselines — sits behind one
//! [`train::Trainer`] trait; [`config::TrainerKind::build`] turns a config
//! into a ready trainer, and composable [`train::TrainObserver`]s handle
//! trace capture, early stopping and checkpointing:
//!
//! ```no_run
//! use dsfacto::prelude::*;
//!
//! // A synthetic twin of the paper's `diabetes` dataset (Table 2).
//! let cfg = ExperimentConfig {
//!     dataset: DatasetSpec::Table2("diabetes".into()),
//!     trainer: TrainerKind::Nomad, // or Libfm | Dsgd | BulkSync | XlaDense
//!     outer_iters: 50,
//!     workers: 4,
//!     ..Default::default()
//! };
//! let ds = cfg.dataset.load(cfg.seed).unwrap();
//! let (train, test) = ds.split(cfg.train_frac, 7);
//!
//! let trainer = cfg.trainer.build(&cfg);
//! let mut stop = dsfacto::train::EarlyStop::new(5, 1e-6);
//! let out = trainer.fit(&train, Some(&test), &mut stop).unwrap();
//! println!("{}: final objective {}", trainer.name(),
//!          out.trace.last().unwrap().objective);
//!
//! // Serving: the same interface regardless of backend.
//! let scores = Predictor::predict_dataset(&out.model, &test).unwrap();
//! assert_eq!(scores.len(), test.n());
//! ```
//!
//! Or run a whole experiment (split, trainer, trace CSV, dual-backend
//! evaluation) in one call with [`coordinator::run_experiment`].

pub mod baseline;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fm;
pub mod kernel;
pub mod metrics;
pub mod nomad;
pub mod optim;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::config::{DatasetSpec, ExperimentConfig, TrainerKind};
    pub use crate::data::{Dataset, Task};
    pub use crate::fm::{FmHyper, FmModel};
    pub use crate::kernel::{FmKernel, Scratch};
    pub use crate::metrics::{EvalMetrics, TracePoint, TrainOutput};
    pub use crate::nomad::NomadConfig;
    pub use crate::train::{
        ControlFlow, Observers, Predictor, TraceRecorder, TrainObserver, Trainer,
    };
    pub use crate::util::rng::Pcg64;
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
