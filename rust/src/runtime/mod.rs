//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place Python's output touches the request path — as
//! pre-compiled XLA executables. Interchange is HLO *text* (see aot.py /
//! DESIGN.md §3 for why serialized protos are rejected by xla_extension
//! 0.5.1).
//!
//! Entry points per dataset shape (from `artifacts/manifest.txt`):
//! * `score` — `(w0, w[D], V[D,K], X[B,D]) -> (f[B],)`
//! * `grad`  — `(w0, w, V, X, y[B]) -> (g0, gw[D], gV[D,K], loss)`
//! * `step`  — `(w0, w, V, X, y, eta, lw, lv) -> (w0', w', V', loss)`

pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use std::path::Path;

use anyhow::{Context, Result};

use crate::data::{Dataset, Task};
use crate::fm::FmModel;

/// A compiled FM entry point bound to a fixed (B, D, K) shape.
pub struct FmExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Manifest row this executable was compiled from.
    pub spec: ArtifactEntry,
}

/// The PJRT client plus the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
}

impl Runtime {
    /// Creates a CPU PJRT client and reads the manifest in `dir`.
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest })
    }

    /// True when the artifact directory has a manifest (used by callers
    /// that fall back to the pure-Rust scorer).
    pub fn available<P: AsRef<Path>>(dir: P) -> bool {
        dir.as_ref().join("manifest.txt").exists()
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Loads + compiles one entry point.
    pub fn load(&self, name: &str, entry: &str) -> Result<FmExecutable> {
        let spec = self
            .manifest
            .find(name, entry)
            .with_context(|| format!("artifact {name}/{entry} not in manifest"))?
            .clone();
        let path = self.manifest.dir().join(&spec.filename);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}/{entry}"))?;
        Ok(FmExecutable { exe, spec })
    }
}

fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

fn lit_vec(xs: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(xs).reshape(dims)?)
}

impl FmExecutable {
    /// Batch size the artifact is specialized for.
    pub fn batch(&self) -> usize {
        self.spec.b
    }

    /// Scores one dense batch `x` (row-major `B x D`). Returns `f[B]`.
    pub fn score_batch(&self, model: &FmModel, x: &[f32]) -> Result<Vec<f32>> {
        let (b, d, k) = (self.spec.b, self.spec.d, self.spec.k);
        anyhow::ensure!(self.spec.entry == "score", "not a score artifact");
        anyhow::ensure!(model.d == d && model.k == k, "model/artifact shape mismatch");
        anyhow::ensure!(x.len() == b * d, "batch buffer size");
        let inputs = [
            lit_scalar(model.w0),
            lit_vec(&model.w, &[d as i64])?,
            lit_vec(&model.v, &[d as i64, k as i64])?,
            lit_vec(x, &[b as i64, d as i64])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let f = result.to_tuple1()?;
        Ok(f.to_vec::<f32>()?)
    }

    /// Full-batch gradient: returns `(g0, gw, gV, mean_loss)`.
    pub fn grad_batch(
        &self,
        model: &FmModel,
        x: &[f32],
        y: &[f32],
    ) -> Result<(f32, Vec<f32>, Vec<f32>, f32)> {
        let (b, d, k) = (self.spec.b, self.spec.d, self.spec.k);
        anyhow::ensure!(self.spec.entry == "grad", "not a grad artifact");
        anyhow::ensure!(x.len() == b * d && y.len() == b, "batch buffer size");
        let inputs = [
            lit_scalar(model.w0),
            lit_vec(&model.w, &[d as i64])?,
            lit_vec(&model.v, &[d as i64, k as i64])?,
            lit_vec(x, &[b as i64, d as i64])?,
            lit_vec(y, &[b as i64])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let (g0, gw, gv, loss) = result.to_tuple4()?;
        Ok((
            g0.get_first_element::<f32>()?,
            gw.to_vec::<f32>()?,
            gv.to_vec::<f32>()?,
            loss.get_first_element::<f32>()?,
        ))
    }

    /// One dense-minibatch SGD step; updates `model` in place, returns the
    /// pre-step batch loss.
    #[allow(clippy::too_many_arguments)]
    pub fn step_batch(
        &self,
        model: &mut FmModel,
        x: &[f32],
        y: &[f32],
        eta: f32,
        lambda_w: f32,
        lambda_v: f32,
    ) -> Result<f32> {
        let (b, d, k) = (self.spec.b, self.spec.d, self.spec.k);
        anyhow::ensure!(self.spec.entry == "step", "not a step artifact");
        anyhow::ensure!(x.len() == b * d && y.len() == b, "batch buffer size");
        let inputs = [
            lit_scalar(model.w0),
            lit_vec(&model.w, &[d as i64])?,
            lit_vec(&model.v, &[d as i64, k as i64])?,
            lit_vec(x, &[b as i64, d as i64])?,
            lit_vec(y, &[b as i64])?,
            lit_scalar(eta),
            lit_scalar(lambda_w),
            lit_scalar(lambda_v),
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let (w0n, wn, vn, loss) = result.to_tuple4()?;
        model.w0 = w0n.get_first_element::<f32>()?;
        model.w = wn.to_vec::<f32>()?;
        model.v = vn.to_vec::<f32>()?;
        Ok(loss.get_first_element::<f32>()?)
    }

    /// Scores an entire dataset through fixed-size padded batches.
    /// Returns one score per example (padding rows dropped).
    pub fn score_dataset(&self, model: &FmModel, ds: &Dataset) -> Result<Vec<f32>> {
        let (b, d) = (self.spec.b, self.spec.d);
        anyhow::ensure!(ds.d() == d, "dataset d {} != artifact d {d}", ds.d());
        let mut xbuf = vec![0f32; b * d];
        let mut out = Vec::with_capacity(ds.n());
        let mut start = 0;
        while start < ds.n() {
            let real = ds.densify_batch(start, b, &mut xbuf);
            let scores = self.score_batch(model, &xbuf)?;
            out.extend_from_slice(&scores[..real]);
            start += b;
        }
        Ok(out)
    }
}

/// Maps a Table-2 dataset name + task to its manifest artifact name.
pub fn artifact_name_for(ds: &Dataset) -> String {
    // Synthetic twins and real files use the dataset name directly when it
    // matches a manifest row; the `tiny_*` artifacts serve tests.
    match ds.task {
        Task::Regression => ds.name.split('-').next().unwrap_or("tiny_reg").to_string(),
        Task::Classification => ds.name.split('-').next().unwrap_or("tiny_clf").to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Compile-light tests only; executing artifacts requires `make
    // artifacts` and is covered by rust/tests/runtime_integration.rs.

    #[test]
    fn availability_check() {
        assert!(!Runtime::available("/nonexistent/dir"));
    }

    #[test]
    fn artifact_name_strips_split_suffix() {
        let ds = crate::data::synth::table2_dataset("housing", 1).unwrap();
        let (train, test) = ds.split(0.8, 2);
        assert_eq!(artifact_name_for(&train), "housing");
        assert_eq!(artifact_name_for(&test), "housing");
    }
}
