//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. One line per artifact:
//!
//! ```text
//! # name entry task B D K filename
//! diabetes score classification 256 8 4 diabetes_score.hlo.txt
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::Task;

/// One manifest row.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    /// `score` | `grad` | `step` | `score_aux`.
    pub entry: String,
    pub task: Task,
    /// Fixed batch size the artifact is specialized for.
    pub b: usize,
    pub d: usize,
    pub k: usize,
    pub filename: String,
}

/// Parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Reads `<dir>/manifest.txt`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        let entries = Self::parse(&text)?;
        Ok(Manifest { dir, entries })
    }

    /// Parses manifest text.
    pub fn parse(text: &str) -> Result<Vec<ArtifactEntry>> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 7 {
                bail!("manifest line {}: want 7 fields, got {}", lineno + 1, parts.len());
            }
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                entry: parts[1].to_string(),
                task: Task::parse(parts[2])
                    .with_context(|| format!("manifest line {}", lineno + 1))?,
                b: parts[3].parse().context("B")?,
                d: parts[4].parse().context("D")?,
                k: parts[5].parse().context("K")?,
                filename: parts[6].to_string(),
            });
        }
        Ok(entries)
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// All rows.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Finds a row by dataset name and entry point.
    pub fn find(&self, name: &str, entry: &str) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.entry == entry)
    }

    /// All dataset names present.
    pub fn names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.entries.iter().map(|e| e.name.as_str()).collect();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# name entry task B D K filename
tiny_reg score regression 8 16 4 tiny_reg_score.hlo.txt
tiny_reg grad regression 8 16 4 tiny_reg_grad.hlo.txt
diabetes score classification 256 8 4 diabetes_score.hlo.txt
";

    #[test]
    fn parses_rows() {
        let rows = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].name, "tiny_reg");
        assert_eq!(rows[0].task, Task::Regression);
        assert_eq!(rows[2].b, 256);
        assert_eq!(rows[2].d, 8);
    }

    #[test]
    fn find_by_name_and_entry() {
        let m = Manifest {
            dir: PathBuf::from("/tmp"),
            entries: Manifest::parse(SAMPLE).unwrap(),
        };
        assert!(m.find("tiny_reg", "grad").is_some());
        assert!(m.find("tiny_reg", "step").is_none());
        assert!(m.find("nope", "score").is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("a b c\n").is_err());
        assert!(Manifest::parse("a score bad-task 1 2 3 f.txt\n").is_err());
        assert!(Manifest::parse("a score regression x 2 3 f.txt\n").is_err());
    }

    #[test]
    fn load_errors_on_missing_dir() {
        assert!(Manifest::load("/definitely/not/here").is_err());
    }
}
