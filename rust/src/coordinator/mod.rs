//! Experiment driver: config -> data -> trainer -> trace/eval/persist.
//!
//! This is the layer the CLI, the examples and the benches call. Since the
//! [`crate::train`] redesign it is a thin shell: it builds the trainer via
//! [`TrainerKind::build`], wires up the session observers (CSV streaming
//! when a trace path is configured), and runs the held-out evaluation on
//! both scoring backends. It owns no trainer-specific dispatch.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{DatasetSpec, ExperimentConfig};
use crate::data::{DataSource, Dataset, PrefetchSource, ShardCacheSource, Task};
use crate::fm::FmModel;
use crate::metrics::{evaluate_scores, EvalMetrics, TrainOutput};
use crate::nomad::EngineStats;
use crate::runtime::{artifact_name_for, FmExecutable, Runtime};
use crate::train::observers::{trace_row, CsvStreamer, Observers, TRACE_COLUMNS};
use crate::util::csv::CsvWriter;

/// Everything a finished run reports.
pub struct RunSummary {
    pub output: TrainOutput,
    /// Engine counters (DS-FACTO runs only).
    pub stats: Option<EngineStats>,
    /// Training-set rows.
    pub train_n: usize,
    /// Training-set feature dimension.
    pub train_d: usize,
    pub task: Task,
    /// The held-out set. `None` for streaming (`cache:` + `train_frac = 1`)
    /// runs, which never materialize a dataset; `final_eval` then covers
    /// the cached training rows instead.
    pub test: Option<Dataset>,
    /// Final metrics via the Rust scorer: held-out when `test` is present,
    /// over the training shards otherwise.
    pub final_eval: EvalMetrics,
    /// Final held-out metrics via the XLA artifact (when available): the
    /// request-path number. Tests assert it agrees with `final_eval`.
    pub final_eval_xla: Option<EvalMetrics>,
    /// Shard-residency meters of a streaming run (`None` for in-memory
    /// runs): how many shards/bytes the coordinator's prefetching source
    /// ever held at once, and how often the prefetch buffer hit.
    pub residency: Option<ResidencyReport>,
}

/// Peak shard residency + prefetch-buffer meters, read off the
/// [`PrefetchSource`] that fed a streaming run. The bounded-memory
/// contract (EXPERIMENTS.md §Data) is `peak_resident_shards <= 2`:
/// one shard in use, at most one in flight.
#[derive(Debug, Clone, Copy)]
pub struct ResidencyReport {
    pub peak_resident_shards: usize,
    pub peak_resident_bytes: usize,
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
}

/// Runs one experiment end to end.
///
/// `train_frac >= 1` keeps the whole dataset as the training set **in its
/// original row order** (empty test set) instead of taking a shuffled
/// full-size split. This is what a shard cache requires: cached shard
/// files were cut on the ingested row order, so a permuted training set
/// would silently train on different shards than the probe evaluates —
/// the pre-split + `train_frac = 1` flow keeps both views identical.
///
/// With a `cache:` dataset and `train_frac = 1` the run is **streaming**:
/// the trainer, the per-iteration trace and the final metrics all pull
/// shard by shard through a double-buffered [`PrefetchSource`] and the
/// full matrix is never materialized ([`RunSummary::residency`] reports
/// the measured peak). Trace and metrics are bitwise identical to the
/// in-memory run of the same config. A `cache:` dataset with any other
/// `train_frac` is rejected outright (same contract as the cluster
/// driver): caches are pre-split at ingest.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunSummary> {
    if let DatasetSpec::Cache { dir } = &cfg.dataset {
        if cfg.train_frac >= 1.0 {
            return run_streaming(cfg, dir);
        }
        // Never fall back to materializing + re-splitting a cache: the
        // shard files were cut on the ingested row order, so a shuffled
        // split would train on different rows than the shards the probe
        // and any cluster run of the same cache see. Same contract (and
        // wording) as the cluster driver's rejection.
        anyhow::bail!(
            "cache datasets require train_frac = 1 (pre-split at ingest): got train_frac = {}",
            cfg.train_frac
        );
    }
    let ds = cfg.dataset.load(cfg.seed).context("load dataset")?;
    let (train, test) = if cfg.train_frac >= 1.0 {
        let test = ds.subset(&[], "test");
        (ds, test)
    } else {
        ds.split(cfg.train_frac, cfg.seed.wrapping_add(1))
    };
    run_on(cfg, train, test)
}

/// Runs one experiment on a pre-split dataset pair. All trainers dispatch
/// through [`crate::config::TrainerKind::build`].
pub fn run_on(cfg: &ExperimentConfig, train: Dataset, test: Dataset) -> Result<RunSummary> {
    let trainer = cfg.trainer.build(cfg);

    let mut csv = match &cfg.trace_path {
        Some(path) => Some(CsvStreamer::create(path)?),
        None => None,
    };
    let fit_result = {
        let mut obs = Observers::new();
        if let Some(c) = csv.as_mut() {
            obs.push(c);
        }
        trainer.fit(&train, Some(&test), &mut obs)
    };
    let output = match fit_result {
        Ok(out) => out,
        // Don't leave a half-written trace CSV behind a failed run.
        Err(e) => {
            if let Some(c) = csv {
                c.abort();
            }
            return Err(e);
        }
    };
    if let Some(c) = csv {
        c.finish().context("stream trace CSV")?;
    }
    let stats = trainer.stats();

    // Held-out evaluation, Rust path + (optionally) the XLA request path.
    let final_eval = crate::metrics::evaluate(&output.model, &test);
    let final_eval_xla = if cfg.xla_eval && Runtime::available(&cfg.artifacts_dir) {
        xla_eval_if_artifact(&cfg.artifacts_dir, &test, &output.model)?
    } else {
        None
    };

    Ok(RunSummary {
        output,
        stats,
        train_n: train.n(),
        train_d: train.d(),
        task: train.task,
        test: Some(test),
        final_eval,
        final_eval_xla,
        residency: None,
    })
}

/// The bounded-memory path behind [`run_experiment`]: a `cache:` dataset
/// with `train_frac = 1` trains through [`Trainer::fit_source`] off a
/// double-buffered [`PrefetchSource`] over the shard cache. At most one
/// shard is in use and one in flight at any time; nothing in the run
/// holds the full CSR.
///
/// [`Trainer::fit_source`]: crate::train::Trainer::fit_source
fn run_streaming(cfg: &ExperimentConfig, dir: &str) -> Result<RunSummary> {
    let cache = ShardCacheSource::open(dir).context("open shard cache")?;
    let src = PrefetchSource::new(Arc::new(cache));
    let part = src
        .native_plan()
        .expect("a shard cache always carries its native plan");
    let (train_n, train_d, task) = (src.n(), src.d(), src.task());

    let trainer = cfg.trainer.build(cfg);
    let mut csv = match &cfg.trace_path {
        Some(path) => Some(CsvStreamer::create(path)?),
        None => None,
    };
    let fit_result = {
        let mut obs = Observers::new();
        if let Some(c) = csv.as_mut() {
            obs.push(c);
        }
        trainer.fit_source(&src, &mut obs)
    };
    let output = match fit_result {
        Ok(out) => out,
        Err(e) => {
            if let Some(c) = csv {
                c.abort();
            }
            return Err(e);
        }
    };
    if let Some(c) = csv {
        c.finish().context("stream trace CSV")?;
    }
    let stats = trainer.stats();

    // Final metrics shard by shard over the cached training rows (a
    // streaming run has no held-out set) — bitwise identical to
    // `evaluate` on the materialized dataset.
    let final_eval = crate::train::streaming_eval(&src, &part, &output.model)?;

    let residency = Some(ResidencyReport {
        peak_resident_shards: src.peak_resident_shards(),
        peak_resident_bytes: src.peak_resident_bytes(),
        prefetch_hits: src.prefetch_hits(),
        prefetch_misses: src.prefetch_misses(),
    });

    Ok(RunSummary {
        output,
        stats,
        train_n,
        train_d,
        task,
        test: None,
        final_eval,
        final_eval_xla: None,
        residency,
    })
}

/// Loads the score artifact for `ds`'s shape **if the runtime manifest
/// lists one** and evaluates through it. A missing artifact is the
/// expected state on most runs (`Ok(None)`); an artifact that is listed
/// but fails to load, shape-check or execute is a real error and
/// propagates — it must not be silently reported as "no artifact".
fn xla_eval_if_artifact(
    artifacts_dir: &str,
    ds: &Dataset,
    model: &FmModel,
) -> Result<Option<EvalMetrics>> {
    let rt = Runtime::new(artifacts_dir)?;
    let name = artifact_name_for(ds);
    if !rt
        .manifest()
        .entries()
        .iter()
        .any(|e| e.name == name && e.entry == "score")
    {
        return Ok(None);
    }
    let eval = Evaluator::for_dataset(artifacts_dir, ds)
        .with_context(|| format!("score artifact {name:?} is listed but unusable"))?;
    Ok(Some(eval.evaluate(model, ds)?))
}

/// Writes a convergence trace as CSV (the Fig 4/5 series format) after the
/// fact. Runs driven through [`run_on`] stream the same format live via
/// [`CsvStreamer`]; this helper serves callers that hold a finished
/// [`TrainOutput`].
pub fn write_trace_csv(path: &str, out: &TrainOutput) -> Result<()> {
    let mut w = CsvWriter::create(path, &TRACE_COLUMNS)?;
    for pt in &out.trace {
        w.row(&trace_row(pt))?;
    }
    w.flush()
}

/// XLA-backed evaluator: scores held-out data through the AOT artifact.
/// For a serving-shaped interface over the same executable, see
/// [`crate::train::XlaPredictor`] (obtainable via
/// [`Evaluator::into_predictor`]).
pub struct Evaluator {
    exec: FmExecutable,
}

impl Evaluator {
    /// Loads the score artifact matching the dataset's shape.
    pub fn for_dataset(artifacts_dir: &str, ds: &Dataset) -> Result<Evaluator> {
        let rt = Runtime::new(artifacts_dir)?;
        let name = artifact_name_for(ds);
        let exec = rt.load(&name, "score")?;
        anyhow::ensure!(
            exec.spec.d == ds.d(),
            "artifact {} d={} != dataset d={}",
            name,
            exec.spec.d,
            ds.d()
        );
        Ok(Evaluator { exec })
    }

    /// Evaluates through the artifact (batched, padded).
    pub fn evaluate(&self, model: &FmModel, ds: &Dataset) -> Result<EvalMetrics> {
        let scores = self.exec.score_dataset(model, ds)?;
        Ok(evaluate_scores(&scores, &ds.labels, ds.task))
    }

    /// Binds the executable to a model as a [`crate::train::Predictor`].
    pub fn into_predictor(self, model: FmModel) -> Result<crate::train::XlaPredictor> {
        crate::train::XlaPredictor::new(self.exec, model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetSpec, TrainerKind};

    #[test]
    fn cache_dataset_with_partial_train_frac_is_rejected() {
        // The error must fire before the cache is even opened: a bogus
        // directory with train_frac < 1 reports the contract, not ENOENT.
        let cfg = ExperimentConfig {
            dataset: DatasetSpec::Cache {
                dir: "/nonexistent/dsfacto-cache".into(),
            },
            train_frac: 0.5,
            ..Default::default()
        };
        let err = format!("{:#}", run_experiment(&cfg).unwrap_err());
        assert!(err.contains("train_frac = 1"), "{err}");
        assert!(err.contains("pre-split at ingest"), "{err}");
    }

    #[test]
    fn run_experiment_with_each_cpu_trainer() {
        for trainer in [
            TrainerKind::Nomad,
            TrainerKind::Libfm,
            TrainerKind::Dsgd,
            TrainerKind::BulkSync,
        ] {
            // Distributed engines take batch-GD-scale steps; libFM takes
            // per-example SGD steps.
            let eta = match trainer {
                TrainerKind::Libfm => crate::optim::LrSchedule::Constant(0.02),
                _ => crate::optim::LrSchedule::Constant(0.5),
            };
            let cfg = ExperimentConfig {
                dataset: DatasetSpec::Table2("housing".into()),
                trainer,
                eta,
                outer_iters: 5,
                workers: 2,
                ..Default::default()
            };
            let sum = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{trainer:?}: {e:#}"));
            assert_eq!(sum.output.trace.len(), 6, "{trainer:?}");
            assert!(
                sum.output.trace[5].objective < sum.output.trace[0].objective,
                "{trainer:?} did not descend"
            );
            assert!(sum.final_eval.rmse.is_finite());
            // Engine counters surface exactly for the engine that has them.
            assert_eq!(sum.stats.is_some(), trainer == TrainerKind::Nomad, "{trainer:?}");
        }
    }

    #[test]
    fn trace_csv_is_written() {
        let dir = std::env::temp_dir().join("dsfacto_coord_test");
        let path = dir.join("trace.csv").to_str().unwrap().to_string();
        let cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("housing".into()),
            trainer: TrainerKind::Libfm,
            outer_iters: 3,
            trace_path: Some(path.clone()),
            ..Default::default()
        };
        run_experiment(&cfg).unwrap();
        let (hdr, rows) = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(hdr[0], "iter");
        assert_eq!(rows.len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nomad_runs_through_simnet_transport_from_config() {
        // The former `main.rs` special case, now plain config.
        let mut cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("housing".into()),
            outer_iters: 4,
            workers: 2,
            ..Default::default()
        };
        cfg.set("transport", "simnet:20us,1e9,1").unwrap();
        let sum = run_experiment(&cfg).unwrap();
        let stats = sum.stats.expect("nomad stats");
        assert!(stats.bytes > 0, "simnet hops must serialize");
    }
}
