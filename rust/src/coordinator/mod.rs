//! Experiment driver: config -> data -> trainer -> trace/eval/persist.
//!
//! This is the layer the CLI, the examples and the benches call. It owns
//! the trainer dispatch (DS-FACTO, the baselines, the XLA dense trainer)
//! and the XLA-backed held-out evaluator.

use anyhow::{Context, Result};

use crate::baseline::{bulksync_train, dsgd_train, libfm_train, DsgdConfig, LibfmConfig};
use crate::config::{ExperimentConfig, TrainerKind};
use crate::data::Dataset;
use crate::fm::FmModel;
use crate::metrics::{evaluate_scores, EvalMetrics, TraceRecorder, TrainOutput};
use crate::nomad::{self, EngineStats, NomadConfig};
use crate::runtime::{artifact_name_for, FmExecutable, Runtime};
use crate::util::csv::CsvWriter;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Everything a finished run reports.
pub struct RunSummary {
    pub output: TrainOutput,
    /// Engine counters (DS-FACTO runs only).
    pub stats: Option<EngineStats>,
    pub train: Dataset,
    pub test: Dataset,
    /// Final held-out metrics via the Rust scorer.
    pub final_eval: EvalMetrics,
    /// Final held-out metrics via the XLA artifact (when available): the
    /// request-path number. Tests assert it agrees with `final_eval`.
    pub final_eval_xla: Option<EvalMetrics>,
}

/// Runs one experiment end to end.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunSummary> {
    let ds = cfg.dataset.load(cfg.seed).context("load dataset")?;
    let (train, test) = ds.split(cfg.train_frac, cfg.seed.wrapping_add(1));
    run_on(cfg, train, test)
}

/// Runs one experiment on a pre-split dataset pair.
pub fn run_on(cfg: &ExperimentConfig, train: Dataset, test: Dataset) -> Result<RunSummary> {
    let (output, stats) = match cfg.trainer {
        TrainerKind::Nomad => {
            let ncfg = NomadConfig {
                workers: cfg.workers,
                outer_iters: cfg.outer_iters,
                eta: cfg.eta,
                seed: cfg.seed,
                eval_every: cfg.eval_every,
                transport: nomad::TransportKind::Local,
                update_mode: nomad::UpdateMode::MeanGradient,
                cols_per_token: 0,
            };
            let (out, st) = nomad::train_with_stats(&train, Some(&test), &cfg.fm, &ncfg)?;
            (out, Some(st))
        }
        TrainerKind::Libfm => {
            let lcfg = LibfmConfig {
                epochs: cfg.outer_iters,
                eta: cfg.eta,
                seed: cfg.seed,
                eval_every: cfg.eval_every,
                shuffle: true,
            };
            (libfm_train(&train, Some(&test), &cfg.fm, &lcfg), None)
        }
        TrainerKind::Dsgd => {
            let dcfg = DsgdConfig {
                epochs: cfg.outer_iters,
                eta: cfg.eta,
                workers: cfg.workers,
                seed: cfg.seed,
                eval_every: cfg.eval_every,
            };
            (dsgd_train(&train, Some(&test), &cfg.fm, &dcfg), None)
        }
        TrainerKind::BulkSync => (
            bulksync_train(
                &train,
                Some(&test),
                &cfg.fm,
                cfg.outer_iters,
                cfg.eta,
                cfg.workers,
                cfg.seed,
            ),
            None,
        ),
        TrainerKind::XlaDense => (xla_dense_train(cfg, &train, &test)?, None),
    };

    // Held-out evaluation, Rust path + (optionally) the XLA request path.
    let final_eval = crate::metrics::evaluate(&output.model, &test);
    let final_eval_xla = if cfg.xla_eval && Runtime::available(&cfg.artifacts_dir) {
        match Evaluator::for_dataset(&cfg.artifacts_dir, &test) {
            Ok(eval) => Some(eval.evaluate(&output.model, &test)?),
            Err(_) => None, // no artifact for this shape
        }
    } else {
        None
    };

    if let Some(path) = &cfg.trace_path {
        write_trace_csv(path, &output)?;
    }

    Ok(RunSummary {
        output,
        stats,
        train,
        test,
        final_eval,
        final_eval_xla,
    })
}

/// Writes a convergence trace as CSV (the Fig 4/5 series format).
pub fn write_trace_csv(path: &str, out: &TrainOutput) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &["iter", "secs", "objective", "train_loss", "test_loss", "test_metric"],
    )?;
    for pt in &out.trace {
        let (tl, tm) = match &pt.test {
            Some(m) => (
                format!("{}", m.loss),
                format!(
                    "{}",
                    if m.rmse.is_nan() { m.accuracy } else { m.rmse }
                ),
            ),
            None => (String::new(), String::new()),
        };
        w.row(&[
            pt.iter.to_string(),
            format!("{:.6}", pt.secs),
            format!("{}", pt.objective),
            format!("{}", pt.train_loss),
            tl,
            tm,
        ])?;
    }
    w.flush()
}

/// XLA-backed evaluator: scores held-out data through the AOT artifact.
pub struct Evaluator {
    exec: FmExecutable,
}

impl Evaluator {
    /// Loads the score artifact matching the dataset's shape.
    pub fn for_dataset(artifacts_dir: &str, ds: &Dataset) -> Result<Evaluator> {
        let rt = Runtime::new(artifacts_dir)?;
        let name = artifact_name_for(ds);
        let exec = rt.load(&name, "score")?;
        anyhow::ensure!(
            exec.spec.d == ds.d(),
            "artifact {} d={} != dataset d={}",
            name,
            exec.spec.d,
            ds.d()
        );
        Ok(Evaluator { exec })
    }

    /// Evaluates through the artifact (batched, padded).
    pub fn evaluate(&self, model: &FmModel, ds: &Dataset) -> Result<EvalMetrics> {
        let scores = self.exec.score_dataset(model, ds)?;
        Ok(evaluate_scores(&scores, &ds.labels, ds.task))
    }
}

/// Dense-minibatch SGD through the AOT `step` artifact: the trainer variant
/// that runs the paper's update entirely inside XLA (demonstrates the
/// L3->L2->L1 training path; used by quickstart and integration tests).
pub fn xla_dense_train(
    cfg: &ExperimentConfig,
    train: &Dataset,
    test: &Dataset,
) -> Result<TrainOutput> {
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    let name = artifact_name_for(train);
    let step = rt.load(&name, "step")?;
    anyhow::ensure!(step.spec.d == train.d(), "artifact/dataset shape mismatch");
    let (b, k) = (step.spec.b, step.spec.k);
    anyhow::ensure!(
        k == cfg.fm.k,
        "artifact k={k} != config k={} (dense XLA trainer is shape-specialized)",
        cfg.fm.k
    );

    let mut rng = Pcg64::new(cfg.seed, 0x71a);
    let mut model = FmModel::init(train.d(), k, cfg.fm.init_std, &mut rng);
    let mut recorder =
        TraceRecorder::new(train, Some(test), cfg.fm.lambda_w, cfg.fm.lambda_v, cfg.eval_every);

    let mut xbuf = vec![0f32; b * train.d()];
    let mut ybuf = vec![0f32; b];
    let mut sw = Stopwatch::start();
    let mut clock = 0f64;
    recorder.record(0, 0.0, &model);
    sw.lap();

    let n_batches = train.n().div_ceil(b);
    for epoch in 0..cfg.outer_iters {
        let eta = cfg.eta.at(epoch);
        for bi in 0..n_batches {
            let start = bi * b;
            let real = train.densify_batch(start, b, &mut xbuf);
            train.labels_batch(start, b, &mut ybuf);
            // Padding rows have x=0, y=0: their squared-loss gradient
            // contribution is w0-only; rescale eta by real/b to keep the
            // batch-mean semantics approximately right on the tail batch.
            let eff_eta = eta * (real as f32 / b as f32);
            step.step_batch(&mut model, &xbuf, &ybuf, eff_eta, cfg.fm.lambda_w, cfg.fm.lambda_v)?;
        }
        clock += sw.lap();
        recorder.record(epoch + 1, clock, &model);
        sw.lap();
    }

    Ok(TrainOutput {
        model,
        trace: recorder.into_trace(),
        wall_secs: clock,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;

    #[test]
    fn run_experiment_with_each_cpu_trainer() {
        for trainer in [
            TrainerKind::Nomad,
            TrainerKind::Libfm,
            TrainerKind::Dsgd,
            TrainerKind::BulkSync,
        ] {
            // Distributed engines take batch-GD-scale steps; libFM takes
            // per-example SGD steps.
            let eta = match trainer {
                TrainerKind::Libfm => crate::optim::LrSchedule::Constant(0.02),
                _ => crate::optim::LrSchedule::Constant(0.5),
            };
            let cfg = ExperimentConfig {
                dataset: DatasetSpec::Table2("housing".into()),
                trainer,
                eta,
                outer_iters: 5,
                workers: 2,
                ..Default::default()
            };
            let sum = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{trainer:?}: {e:#}"));
            assert_eq!(sum.output.trace.len(), 6, "{trainer:?}");
            assert!(
                sum.output.trace[5].objective < sum.output.trace[0].objective,
                "{trainer:?} did not descend"
            );
            assert!(sum.final_eval.rmse.is_finite());
        }
    }

    #[test]
    fn trace_csv_is_written() {
        let dir = std::env::temp_dir().join("dsfacto_coord_test");
        let path = dir.join("trace.csv").to_str().unwrap().to_string();
        let cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("housing".into()),
            trainer: TrainerKind::Libfm,
            outer_iters: 3,
            trace_path: Some(path.clone()),
            ..Default::default()
        };
        run_experiment(&cfg).unwrap();
        let (hdr, rows) = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(hdr[0], "iter");
        assert_eq!(rows.len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
