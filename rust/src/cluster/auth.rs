//! Frame authentication primitives: a std-only SHA-256 and HMAC-SHA256.
//!
//! The cluster has no external crypto dependency, so the control plane
//! authenticates frames with this hand-rolled FIPS 180-4 SHA-256 plus
//! RFC 2104 HMAC (verified against the RFC 4231 test vectors below).
//! This is *authentication*, not encryption: a shared `cluster_secret`
//! keys an HMAC tag over every frame so stray or hostile traffic on the
//! control/ring ports is dropped at the wire, but payloads stay
//! plaintext. Rotate the secret out-of-band; it never transits the wire.

/// FIPS 180-4 initial hash values (fractional parts of sqrt of the
/// first eight primes).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// FIPS 180-4 round constants (fractional parts of cbrt of the first
/// sixty-four primes).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Incremental SHA-256.
pub struct Sha256 {
    h: [u32; 8],
    block: [u8; 64],
    block_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            h: H0,
            block: [0u8; 64],
            block_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        while !data.is_empty() {
            let take = (64 - self.block_len).min(data.len());
            self.block[self.block_len..self.block_len + take].copy_from_slice(&data[..take]);
            self.block_len += take;
            data = &data[take..];
            if self.block_len == 64 {
                let block = self.block;
                self.compress(&block);
                self.block_len = 0;
            }
        }
    }

    pub fn finish(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.block_len != 56 {
            self.update(&[0x00]);
        }
        self.update(&bit_len.to_be_bytes());
        debug_assert_eq!(self.block_len, 0);
        let mut out = [0u8; 32];
        for (chunk, word) in out.chunks_exact_mut(4).zip(self.h.iter()) {
            chunk.copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (hv, v) in self.h.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *hv = hv.wrapping_add(v);
        }
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finish()
}

/// RFC 2104 HMAC-SHA256 over the concatenation of `parts`.
pub fn hmac_sha256(key: &[u8], parts: &[&[u8]]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0x36u8; 64];
    let mut opad = [0x5cu8; 64];
    for i in 0..64 {
        ipad[i] ^= k[i];
        opad[i] ^= k[i];
    }
    let mut inner = Sha256::new();
    inner.update(&ipad);
    for part in parts {
        inner.update(part);
    }
    let mut outer = Sha256::new();
    outer.update(&opad);
    outer.update(&inner.finish());
    outer.finish()
}

/// Derives the 32-byte frame-tag key from the operator-supplied secret
/// string. Hashing (rather than truncating/padding the raw bytes) gives
/// every secret the full key width.
pub fn derive_key(secret: &str) -> [u8; 32] {
    sha256(secret.as_bytes())
}

/// Constant-time-ish tag comparison. The cluster threat model is stray
/// traffic, not a timing-oracle adversary, but there is no reason to
/// hand out an early-exit comparison either.
pub fn tags_equal(a: &[u8; 32], b: &[u8; 32]) -> bool {
    a.iter().zip(b.iter()).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn sha256_matches_fips_examples() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_incremental_matches_one_shot() {
        let data: Vec<u8> = (0u32..1000).map(|i| (i % 251) as u8).collect();
        let mut inc = Sha256::new();
        for chunk in data.chunks(7) {
            inc.update(chunk);
        }
        assert_eq!(inc.finish(), sha256(&data));
    }

    #[test]
    fn sha256_million_a() {
        // FIPS 180-4 long-message example: one million repetitions of 'a'.
        let mut h = Sha256::new();
        let block = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&block);
        }
        assert_eq!(
            hex(&h.finish()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn hmac_rfc4231_case_1() {
        let key = [0x0b; 20];
        let tag = hmac_sha256(&key, &[b"Hi There"]);
        assert_eq!(
            hex(&tag),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn hmac_rfc4231_case_2() {
        let tag = hmac_sha256(b"Jefe", &[b"what do ya want ", b"for nothing?"]);
        assert_eq!(
            hex(&tag),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn hmac_rfc4231_case_3() {
        let key = [0xaa; 20];
        let data = [0xdd; 50];
        let tag = hmac_sha256(&key, &[&data]);
        assert_eq!(
            hex(&tag),
            "773ea91e36800e46854db8ebd09181a72959098b3ef8c122d9635514ced565fe"
        );
    }

    #[test]
    fn hmac_rfc4231_case_6_key_longer_than_block() {
        let key = [0xaa; 131];
        let tag = hmac_sha256(&key, &[b"Test Using Larger Than Block-Size Key - Hash Key First"]);
        assert_eq!(
            hex(&tag),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hmac_rfc4231_case_7_key_and_data_longer_than_block() {
        let key = [0xaa; 131];
        let tag = hmac_sha256(
            &key,
            &[
                b"This is a test using a larger than block-size key and a larger t".as_slice(),
                b"han block-size data. The key needs to be hashed before being use".as_slice(),
                b"d by the HMAC algorithm.".as_slice(),
            ],
        );
        assert_eq!(
            hex(&tag),
            "9b09ffa71b942fcb27635fbcd5b0e944bfdc63644f0713938a7f51535c3a35e2"
        );
    }

    #[test]
    fn derived_keys_differ_and_tags_compare() {
        let a = derive_key("alpha");
        let b = derive_key("beta");
        assert_ne!(a, b);
        assert!(tags_equal(&a, &a));
        assert!(!tags_equal(&hmac_sha256(&a, &[b"x"]), &hmac_sha256(&b, &[b"x"])));
    }
}
