//! Worker-to-worker communication substrates.
//!
//! The NOMAD engine sends parameter tokens through a [`Transport`]:
//!
//! * [`LocalTransport`] — in-process queues (the paper's multi-threaded
//!   mode): tokens move by pointer, no serialization.
//! * [`SimNetTransport`] — the *simulated multi-machine* mode (DESIGN.md
//!   §2): every token is serialized through the wire codec and delivered
//!   after a modeled per-link latency + bandwidth delay. This reproduces
//!   the paper's multi-core/multi-machine axis on a single host with an
//!   explicit, configurable network model.
//! * [`tcp`] — a real TCP loopback transport over the same codec (used by
//!   the multi-process integration test and available to the CLI).
//! * [`runtime`] — the multi-process cluster runtime: a driver control
//!   plane (membership, epoch bookkeeping, checkpoint-restart) plus the
//!   worker process that hosts one engine worker over a remote TCP ring.
//!
//! Robustness support shared by the real-wire paths: [`auth`] (std-only
//! SHA-256/HMAC for frame tags), [`chaos`] (deterministic fault
//! injection behind `DSFACTO_CHAOS`), and [`retry`] (the one jittered
//! backoff policy every reconnect path uses).

pub mod auth;
pub mod chaos;
pub mod codec;
pub mod retry;
pub mod runtime;
pub mod tcp;

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::nomad::token::Token;

/// Cumulative transport counters (Fig. 6 analysis).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TransportStats {
    /// Tokens delivered.
    pub messages: u64,
    /// Serialized bytes moved (0 for the in-process transport).
    pub bytes: u64,
}

/// Token delivery between workers.
pub trait Transport: Send + Sync {
    /// Enqueues a token for worker `dst`.
    fn send(&self, dst: usize, tok: Token);
    /// Blocking pop for worker `worker` with a timeout; `None` on timeout
    /// or shutdown.
    fn recv_timeout(&self, worker: usize, timeout: Duration) -> Option<Token>;
    /// Wakes all blocked receivers and stops delivery threads.
    fn shutdown(&self);
    /// Counters.
    fn stats(&self) -> TransportStats;
}

/// Per-worker mpsc inboxes; zero-copy token movement.
pub struct LocalTransport {
    senders: Vec<Sender<Token>>,
    receivers: Vec<Mutex<Receiver<Token>>>,
    messages: AtomicU64,
}

impl LocalTransport {
    /// Builds inboxes for `p` workers.
    pub fn new(p: usize) -> Self {
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        LocalTransport {
            senders,
            receivers,
            messages: AtomicU64::new(0),
        }
    }
}

impl Transport for LocalTransport {
    fn send(&self, dst: usize, tok: Token) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        // A send after shutdown (receiver dropped) is a no-op.
        let _ = self.senders[dst].send(tok);
    }

    fn recv_timeout(&self, worker: usize, timeout: Duration) -> Option<Token> {
        let rx = self.receivers[worker].lock().unwrap();
        match rx.recv_timeout(timeout) {
            Ok(tok) => Some(tok),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    fn shutdown(&self) {}

    fn stats(&self) -> TransportStats {
        TransportStats {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: 0,
        }
    }
}

/// Network model for the simulated multi-machine transport.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// One-way per-message latency.
    pub latency: Duration,
    /// Link bandwidth in bytes/second (per destination NIC).
    pub bandwidth_bps: f64,
    /// Workers per machine: token hops *within* a machine skip the network
    /// model entirely (the paper's threads-on-one-node case).
    pub workers_per_machine: usize,
}

impl Default for NetModel {
    fn default() -> Self {
        // Commodity-cluster-ish defaults: 100us latency, 10 Gbit/s links.
        NetModel {
            latency: Duration::from_micros(100),
            bandwidth_bps: 10e9 / 8.0,
            workers_per_machine: 1,
        }
    }
}

/// A token scheduled for future delivery.
struct Scheduled {
    deliver_at: Instant,
    seq: u64,
    dst: usize,
    tok: Token,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.deliver_at == other.deliver_at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse: BinaryHeap is a max-heap, we need earliest-first.
        other
            .deliver_at
            .cmp(&self.deliver_at)
            .then(other.seq.cmp(&self.seq))
    }
}

struct SimState {
    heap: BinaryHeap<Scheduled>,
    /// Next free time of each destination link (bandwidth serialization).
    link_free: Vec<Instant>,
    seq: u64,
    down: bool,
}

/// Simulated-network transport: serialize, delay, deliver.
pub struct SimNetTransport {
    inner: LocalTransport,
    model: NetModel,
    /// `Some(k)` when the engine circulates lane-padded token payloads:
    /// byte accounting then uses the K-strided wire size (the padding
    /// never crosses the wire). `None` = payloads are already K-strided.
    wire_k: Option<usize>,
    state: Arc<(Mutex<SimState>, Condvar)>,
    pump: Mutex<Option<std::thread::JoinHandle<()>>>,
    messages: AtomicU64,
    bytes: AtomicU64,
    down: AtomicBool,
}

impl SimNetTransport {
    /// Builds the transport and starts its delivery pump thread.
    /// `wire_k` declares the circulating tokens' payload layout:
    /// `Some(k)` for the engine's lane-padded payloads (wire bytes are
    /// accounted in the K-strided form), `None` for K-strided payloads.
    pub fn new(p: usize, model: NetModel, wire_k: Option<usize>) -> Arc<Self> {
        let now = Instant::now();
        let state = Arc::new((
            Mutex::new(SimState {
                heap: BinaryHeap::new(),
                link_free: vec![now; p],
                seq: 0,
                down: false,
            }),
            Condvar::new(),
        ));
        let t = Arc::new(SimNetTransport {
            inner: LocalTransport::new(p),
            model,
            wire_k,
            state,
            pump: Mutex::new(None),
            messages: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            down: AtomicBool::new(false),
        });
        let pump_t = Arc::clone(&t);
        let handle = std::thread::Builder::new()
            .name("simnet-pump".into())
            .spawn(move || pump_t.pump_loop())
            .expect("spawn simnet pump");
        *t.pump.lock().unwrap() = Some(handle);
        t
    }

    fn machine_of(&self, worker: usize) -> usize {
        worker / self.model.workers_per_machine.max(1)
    }

    fn pump_loop(&self) {
        let (lock, cvar) = &*self.state;
        let mut st = lock.lock().unwrap();
        loop {
            if st.down {
                return;
            }
            let now = Instant::now();
            // Deliver everything due.
            while st.heap.peek().is_some_and(|s| s.deliver_at <= now) {
                let s = st.heap.pop().unwrap();
                self.inner.send(s.dst, s.tok);
            }
            // Sleep until the next deadline (or a new message arrives).
            st = match st.heap.peek().map(|s| s.deliver_at) {
                Some(at) => {
                    let wait = at.saturating_duration_since(Instant::now());
                    cvar.wait_timeout(st, wait).unwrap().0
                }
                None => cvar.wait(st).unwrap(),
            };
        }
    }
}

impl Transport for SimNetTransport {
    fn send(&self, dst: usize, tok: Token) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        // Intra-machine hop: no network model (thread-to-thread pass).
        // Determining the source from the token's ring position: tokens
        // always move src -> src+1, so src = dst-1 mod P.
        let p = self.inner.senders.len();
        let src = (dst + p - 1) % p;
        if self.machine_of(src) == self.machine_of(dst) {
            self.inner.send(dst, tok);
            return;
        }
        let size = match self.wire_k {
            Some(k) => codec::padded_token_wire_size(&tok, k),
            None => codec::token_wire_size(&tok),
        };
        self.bytes.fetch_add(size as u64, Ordering::Relaxed);
        let (lock, cvar) = &*self.state;
        let mut st = lock.lock().unwrap();
        let now = Instant::now();
        let xmit = Duration::from_secs_f64(size as f64 / self.model.bandwidth_bps);
        let start = st.link_free[dst].max(now);
        let deliver_at = start + xmit + self.model.latency;
        st.link_free[dst] = start + xmit;
        let seq = st.seq;
        st.seq += 1;
        st.heap.push(Scheduled {
            deliver_at,
            seq,
            dst,
            tok,
        });
        cvar.notify_one();
    }

    fn recv_timeout(&self, worker: usize, timeout: Duration) -> Option<Token> {
        self.inner.recv_timeout(worker, timeout)
    }

    fn shutdown(&self) {
        if self.down.swap(true, Ordering::SeqCst) {
            return;
        }
        let (lock, cvar) = &*self.state;
        lock.lock().unwrap().down = true;
        cvar.notify_all();
        if let Some(h) = self.pump.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

impl Drop for SimNetTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nomad::token::{Phase, Token};

    fn tok(j: u32) -> Token {
        Token {
            j,
            iter: 0,
            phase: Phase::Update,
            visits: 0,
            w: Box::from([1.5f32]),
            v: vec![0.1, 0.2].into_boxed_slice(),
        }
    }

    #[test]
    fn local_transport_delivers_in_order() {
        let t = LocalTransport::new(2);
        t.send(1, tok(1));
        t.send(1, tok(2));
        assert_eq!(t.recv_timeout(1, Duration::from_millis(50)).unwrap().j, 1);
        assert_eq!(t.recv_timeout(1, Duration::from_millis(50)).unwrap().j, 2);
        assert!(t.recv_timeout(0, Duration::from_millis(10)).is_none());
        assert_eq!(t.stats().messages, 2);
    }

    #[test]
    fn simnet_delivers_with_delay() {
        let model = NetModel {
            latency: Duration::from_millis(20),
            bandwidth_bps: 1e9,
            workers_per_machine: 1,
        };
        let t = SimNetTransport::new(2, model, None);
        let start = Instant::now();
        t.send(1, tok(7));
        let got = t.recv_timeout(1, Duration::from_secs(2)).expect("delivery");
        assert_eq!(got.j, 7);
        let elapsed = start.elapsed();
        assert!(elapsed >= Duration::from_millis(18), "{elapsed:?}");
        assert!(t.stats().bytes > 0);
        t.shutdown();
    }

    #[test]
    fn simnet_intra_machine_is_immediate() {
        let model = NetModel {
            latency: Duration::from_secs(5), // would time the test out
            bandwidth_bps: 1e9,
            workers_per_machine: 2,          // workers 0,1 share a machine
        };
        let t = SimNetTransport::new(2, model, None);
        t.send(1, tok(3)); // src 0 -> dst 1: same machine
        let got = t.recv_timeout(1, Duration::from_millis(100)).expect("fast path");
        assert_eq!(got.j, 3);
        assert_eq!(t.stats().bytes, 0, "intra-machine hop must not serialize");
        t.shutdown();
    }

    #[test]
    fn simnet_orders_by_deadline() {
        // Two sends to the same dst: bandwidth serialization keeps order.
        let model = NetModel {
            latency: Duration::from_millis(5),
            bandwidth_bps: 1e6,
            workers_per_machine: 1,
        };
        let t = SimNetTransport::new(3, model, None);
        t.send(1, tok(1));
        t.send(1, tok(2));
        assert_eq!(t.recv_timeout(1, Duration::from_secs(2)).unwrap().j, 1);
        assert_eq!(t.recv_timeout(1, Duration::from_secs(2)).unwrap().j, 2);
        t.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let t = SimNetTransport::new(1, NetModel::default(), None);
        t.shutdown();
        t.shutdown();
    }

    #[test]
    fn simnet_accounts_stripped_bytes_for_padded_payloads() {
        // k = 3 pads to 8 lanes in memory; the modeled wire must charge
        // for the 3 real entries only (Fig. 6 byte counts are unchanged
        // by the in-memory layout).
        let k = 3usize;
        let kp = crate::kernel::padded_k(k);
        let mut v = vec![0f32; kp];
        v[..k].copy_from_slice(&[0.1, 0.2, 0.3]);
        let padded = Token {
            j: 0,
            iter: 0,
            phase: Phase::Update,
            visits: 0,
            w: Box::from([1.0f32]),
            v: v.into_boxed_slice(),
        };
        let model = NetModel {
            latency: Duration::from_micros(1),
            bandwidth_bps: 1e9,
            workers_per_machine: 1,
        };
        let t = SimNetTransport::new(2, model, Some(k));
        t.send(1, padded.clone());
        let got = t.recv_timeout(1, Duration::from_secs(2)).expect("delivery");
        assert_eq!(got, padded, "local delivery must preserve the payload");
        assert_eq!(
            t.stats().bytes,
            codec::padded_token_wire_size(&padded, k) as u64
        );
        assert!(t.stats().bytes < codec::token_wire_size(&padded) as u64);
        t.shutdown();
    }
}
