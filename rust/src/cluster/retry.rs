//! One retry policy for every reconnect path in the cluster stack.
//!
//! Before this module existed the repo had three divergent hand-rolled
//! backoff loops (worker control connect, `TcpTransport` ring connect,
//! prefetch fall-back). They disagreed on caps, jitter (none had any —
//! synchronized retry storms), and deadline handling. `RetryPolicy` is
//! the single implementation: jittered exponential backoff under a hard
//! deadline, injectable clock so the unit tests never sleep.

use std::time::{Duration, Instant};

use crate::util::rng::Pcg64;

/// Clock seam: production code uses [`SystemClock`]; tests drive a
/// [`FakeClock`] so backoff schedules are asserted without real sleeps.
pub trait Clock {
    fn now(&self) -> Instant;
    fn sleep(&mut self, d: Duration);
}

/// The real wall clock.
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }

    fn sleep(&mut self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// A manually advanced clock recording every sleep it was asked for.
pub struct FakeClock {
    origin: Instant,
    elapsed: Duration,
    pub sleeps: Vec<Duration>,
}

impl FakeClock {
    pub fn new() -> FakeClock {
        FakeClock {
            origin: Instant::now(),
            elapsed: Duration::ZERO,
            sleeps: Vec::new(),
        }
    }
}

impl Default for FakeClock {
    fn default() -> Self {
        FakeClock::new()
    }
}

impl Clock for FakeClock {
    fn now(&self) -> Instant {
        self.origin + self.elapsed
    }

    fn sleep(&mut self, d: Duration) {
        self.sleeps.push(d);
        self.elapsed += d;
    }
}

/// Outcome of one attempt: retry after backoff, or abort immediately
/// (e.g. the transport was shut down — waiting longer cannot help).
pub enum Attempt<E> {
    Retry(E),
    Abort(E),
}

/// Jittered exponential backoff bounded by a hard deadline.
///
/// Attempt `i` sleeps `min(max, initial * 2^i)` scaled by a uniform
/// factor in `[0.5, 1.0)` drawn from a seeded PCG stream, so a fleet of
/// workers reconnecting to a restarted driver never stampedes in phase.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub initial: Duration,
    pub max: Duration,
    pub deadline: Duration,
    pub jitter_seed: u64,
}

impl RetryPolicy {
    pub fn new(initial: Duration, max: Duration, deadline: Duration) -> RetryPolicy {
        RetryPolicy {
            initial,
            max,
            deadline,
            jitter_seed: 0x9e3779b97f4a7c15,
        }
    }

    pub fn with_jitter_seed(mut self, seed: u64) -> RetryPolicy {
        self.jitter_seed = seed;
        self
    }

    /// The raw (pre-jitter) backoff for attempt `i`.
    fn base_backoff(&self, attempt: u32) -> Duration {
        let exp = attempt.min(24); // 2^24 * initial is already >> any max we use
        self.initial
            .saturating_mul(1u32 << exp)
            .min(self.max)
    }

    /// Runs `f(attempt)` until it succeeds, aborts, or the deadline
    /// (measured from the first call) would pass during the next sleep.
    /// On give-up the last error is returned.
    pub fn run<T, E>(
        &self,
        clock: &mut impl Clock,
        mut f: impl FnMut(u32) -> Result<T, Attempt<E>>,
    ) -> Result<T, E> {
        let start = clock.now();
        let mut rng = Pcg64::new(self.jitter_seed, 0x7e7b);
        let mut attempt = 0u32;
        loop {
            let err = match f(attempt) {
                Ok(v) => return Ok(v),
                Err(Attempt::Abort(e)) => return Err(e),
                Err(Attempt::Retry(e)) => e,
            };
            let jitter = 0.5 + 0.5 * rng.f64();
            let backoff = self.base_backoff(attempt).mul_f64(jitter);
            if clock.now().duration_since(start) + backoff >= self.deadline {
                return Err(err);
            }
            clock.sleep(backoff);
            attempt += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetryPolicy {
        RetryPolicy::new(
            Duration::from_millis(10),
            Duration::from_millis(200),
            Duration::from_secs(2),
        )
    }

    #[test]
    fn first_success_needs_no_sleep() {
        let mut clock = FakeClock::new();
        let got: Result<u32, &str> = policy().run(&mut clock, |_| Ok(7));
        assert_eq!(got, Ok(7));
        assert!(clock.sleeps.is_empty());
    }

    #[test]
    fn backoff_doubles_up_to_the_cap_with_jitter() {
        let mut clock = FakeClock::new();
        let mut calls = 0u32;
        let got: Result<(), &str> = policy().run(&mut clock, |attempt| {
            assert_eq!(attempt, calls);
            calls += 1;
            if calls == 8 {
                Ok(())
            } else {
                Err(Attempt::Retry("nope"))
            }
        });
        assert_eq!(got, Ok(()));
        assert_eq!(clock.sleeps.len(), 7);
        for (i, slept) in clock.sleeps.iter().enumerate() {
            let base = Duration::from_millis(10)
                .saturating_mul(1 << i as u32)
                .min(Duration::from_millis(200));
            assert!(
                *slept >= base.mul_f64(0.5) && *slept < base,
                "sleep {i} = {slept:?} outside [{:?}, {base:?})",
                base.mul_f64(0.5)
            );
        }
    }

    #[test]
    fn deadline_returns_the_last_error_without_overshooting() {
        let mut clock = FakeClock::new();
        let mut calls = 0u32;
        let got: Result<(), String> = RetryPolicy::new(
            Duration::from_millis(100),
            Duration::from_millis(100),
            Duration::from_millis(350),
        )
        .run(&mut clock, |a| {
            calls += 1;
            Err(Attempt::Retry(format!("fail {a}")))
        });
        let err = got.unwrap_err();
        assert!(err.starts_with("fail"), "unexpected error: {err}");
        assert_eq!(format!("fail {}", calls - 1), err);
        // Never slept past the deadline.
        let total: Duration = clock.sleeps.iter().sum();
        assert!(total < Duration::from_millis(350), "overslept: {total:?}");
        assert!(calls >= 3, "deadline gave up too early after {calls} calls");
    }

    #[test]
    fn abort_short_circuits_immediately() {
        let mut clock = FakeClock::new();
        let mut calls = 0u32;
        let got: Result<(), &str> = policy().run(&mut clock, |_| {
            calls += 1;
            Err(Attempt::Abort("shut down"))
        });
        assert_eq!(got, Err("shut down"));
        assert_eq!(calls, 1);
        assert!(clock.sleeps.is_empty());
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let mut a = FakeClock::new();
        let mut b = FakeClock::new();
        let mut c = FakeClock::new();
        let run = |clock: &mut FakeClock, seed: u64| {
            let _: Result<(), &str> = policy().with_jitter_seed(seed).run(clock, |a| {
                if a < 4 {
                    Err(Attempt::Retry("x"))
                } else {
                    Ok(())
                }
            });
        };
        run(&mut a, 1);
        run(&mut b, 1);
        run(&mut c, 2);
        assert_eq!(a.sleeps, b.sleeps);
        assert_ne!(a.sleeps, c.sleeps);
    }
}
