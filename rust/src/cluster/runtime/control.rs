//! Control-plane wire protocol between the cluster driver and its
//! workers.
//!
//! Same framing discipline as the token codec ([`crate::cluster::codec`]):
//! every frame travels as `len u32 (LE) | body`, and every body starts
//! `magic u16 | kind u8` followed by kind-specific fields (all
//! little-endian; strings and byte blobs are u32-length-prefixed). The
//! magic (`0xD5FB`) is distinct from the token codec's (`0xD5FA`) so a
//! crossed wire fails loudly instead of decoding garbage.
//!
//! Frame vocabulary (driver ⇄ worker):
//!
//! | frame        | direction | meaning                                     |
//! |--------------|-----------|---------------------------------------------|
//! | `Join`       | w → d     | membership: ring address + wire precision   |
//! | `Assign`     | d → w     | rank + peer ring addresses + config + start |
//! | `Ready`      | w → d     | shard loaded, ring listener live            |
//! | `Start`      | d → w     | barrier release: deal tokens and run        |
//! | `Epoch`      | w → d     | one worker's finalize report for an iter    |
//! | `Progress`   | d → w     | iterations fully aggregated (pipeline gate) |
//! | `Stop`       | d → w     | collect tokens at this iteration            |
//! | `Heartbeat`  | w → d     | liveness (driver tracks last-heard times)   |
//! | `Abort`      | d → w     | generation failed: tear down and re-`Join`  |
//! | `FinalBlock` | w → d     | one collected token (K-strided wire bytes)  |
//! | `Done`       | w → d     | all collected tokens sent + transport stats |
//! | `Shutdown`   | d → w     | run complete: exit cleanly                  |
//! | `Reject`     | d → w     | membership refused (precision mismatch)     |

//!
//! On the socket every body travels inside the stream envelope of
//! [`crate::cluster::codec::FrameSealer`] (sequence numbers + optional
//! HMAC tag), sent through [`CtrlLink`] and opened by a per-connection
//! [`FrameOpener`] on the receive side.

use std::io::Read;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::cluster::chaos::{ChaosPlan, Scope, SendFate};
use crate::cluster::codec::{FrameOpener, FrameSealer, Opened, WirePrecision};

const MAGIC: u16 = 0xD5FB;

/// Upper bound on a control frame body. `FinalBlock` carries one token's
/// wire frame, bounded by the token codec's own size caps.
const MAX_FRAME: usize = 1 << 26;

/// Envelope header + tag headroom on top of [`MAX_FRAME`] for the
/// on-wire length check.
const MAX_ENVELOPE: usize = MAX_FRAME + 64;

/// A control-plane message (see the module table for direction/meaning).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Worker announces itself; `ring_addr` is where its token-ring
    /// listener accepts peer connections, `wire_precision` the token
    /// payload format its ring transport will speak. The driver admits
    /// the worker only if the precision matches its own config — a
    /// mismatched ring would corrupt every circulating token.
    Join {
        ring_addr: String,
        wire_precision: WirePrecision,
    },
    /// Driver assigns a rank, the full ring (rank-ordered peer addresses),
    /// the experiment config (its `dump()` text), and the iteration to
    /// start or resume from.
    Assign {
        rank: u32,
        p: u32,
        start_iter: u32,
        peers: Vec<String>,
        config: String,
    },
    /// Worker finished loading its shard and seeding its arenas.
    Ready,
    /// Barrier release: every worker is `Ready`, start the ring.
    Start,
    /// One worker's end-of-recompute report for iteration `iter`.
    Epoch {
        rank: u32,
        iter: u32,
        loss_sum: f64,
        reg_w: f64,
        reg_v: f64,
    },
    /// Absolute count of iterations the driver has fully aggregated
    /// (feeds the engine's bounded-pipelining gate).
    Progress { iters_done: u32 },
    /// Collect tokens at iteration `at` (monotone: workers `fetch_min`).
    Stop { at: u32 },
    /// Worker liveness signal.
    Heartbeat,
    /// Generation failed (a worker died): tear down the ring, re-`Join`.
    Abort,
    /// One collected token, already in the K-strided wire form of
    /// [`crate::cluster::codec::encode_token_padded`].
    FinalBlock { frame: Vec<u8> },
    /// All of this worker's collected tokens were sent; transport totals.
    Done { messages: u64, bytes: u64 },
    /// Run complete; worker exits.
    Shutdown,
    /// Driver refuses a `Join` outright (e.g. wire-precision mismatch).
    /// Unlike [`Frame::Abort`] — which tells a worker to tear down and
    /// re-`Join` — `Reject` means the worker's configuration can never
    /// be admitted, so it must exit with the reason.
    Reject { reason: String },
}

// The put_*/Reader framing primitives are shared with every other body
// codec that frames `magic u16 | kind u8 | fields` (see
// [`crate::cluster::codec::wire`]); only the frame vocabulary below is
// control-plane specific.
use crate::cluster::codec::wire::{put_bytes, put_f64, put_str, put_u32, put_u64, Reader};

/// Serializes a frame body (no length prefix — the stream writer adds it).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    match frame {
        Frame::Join {
            ring_addr,
            wire_precision,
        } => {
            out.push(1);
            put_str(&mut out, ring_addr);
            out.push(wire_precision.to_byte());
        }
        Frame::Assign {
            rank,
            p,
            start_iter,
            peers,
            config,
        } => {
            out.push(2);
            put_u32(&mut out, *rank);
            put_u32(&mut out, *p);
            put_u32(&mut out, *start_iter);
            put_u32(&mut out, peers.len() as u32);
            for peer in peers {
                put_str(&mut out, peer);
            }
            put_str(&mut out, config);
        }
        Frame::Ready => out.push(3),
        Frame::Start => out.push(4),
        Frame::Epoch {
            rank,
            iter,
            loss_sum,
            reg_w,
            reg_v,
        } => {
            out.push(5);
            put_u32(&mut out, *rank);
            put_u32(&mut out, *iter);
            put_f64(&mut out, *loss_sum);
            put_f64(&mut out, *reg_w);
            put_f64(&mut out, *reg_v);
        }
        Frame::Progress { iters_done } => {
            out.push(6);
            put_u32(&mut out, *iters_done);
        }
        Frame::Stop { at } => {
            out.push(7);
            put_u32(&mut out, *at);
        }
        Frame::Heartbeat => out.push(8),
        Frame::Abort => out.push(9),
        Frame::FinalBlock { frame } => {
            out.push(10);
            put_bytes(&mut out, frame);
        }
        Frame::Done { messages, bytes } => {
            out.push(11);
            put_u64(&mut out, *messages);
            put_u64(&mut out, *bytes);
        }
        Frame::Shutdown => out.push(12),
        Frame::Reject { reason } => {
            out.push(13);
            put_str(&mut out, reason);
        }
    }
    out
}

/// Deserializes a frame body.
pub fn decode(buf: &[u8]) -> Result<Frame> {
    let mut r = Reader::new(buf);
    let magic = r.u16()?;
    ensure!(magic == MAGIC, "bad control magic {magic:#06x}");
    let frame = match r.u8()? {
        1 => Frame::Join {
            ring_addr: r.string(MAX_FRAME)?,
            wire_precision: WirePrecision::from_byte(r.u8()?)?,
        },
        2 => {
            let rank = r.u32()?;
            let p = r.u32()?;
            let start_iter = r.u32()?;
            let npeers = r.u32()? as usize;
            ensure!(npeers <= 4096, "implausible peer count {npeers}");
            let mut peers = Vec::with_capacity(npeers);
            for _ in 0..npeers {
                peers.push(r.string(MAX_FRAME)?);
            }
            Frame::Assign {
                rank,
                p,
                start_iter,
                peers,
                config: r.string(MAX_FRAME)?,
            }
        }
        3 => Frame::Ready,
        4 => Frame::Start,
        5 => Frame::Epoch {
            rank: r.u32()?,
            iter: r.u32()?,
            loss_sum: r.f64()?,
            reg_w: r.f64()?,
            reg_v: r.f64()?,
        },
        6 => Frame::Progress {
            iters_done: r.u32()?,
        },
        7 => Frame::Stop { at: r.u32()? },
        8 => Frame::Heartbeat,
        9 => Frame::Abort,
        10 => Frame::FinalBlock { frame: r.bytes(MAX_FRAME)? },
        11 => Frame::Done {
            messages: r.u64()?,
            bytes: r.u64()?,
        },
        12 => Frame::Shutdown,
        13 => Frame::Reject {
            reason: r.string(MAX_FRAME)?,
        },
        other => bail!("unknown control frame kind {other}"),
    };
    r.finish()?;
    Ok(frame)
}

/// The writable half of one control connection: the shared stream plus
/// its per-connection envelope sealer and the process's chaos seam. The
/// stream lives behind a mutex because heartbeats, epoch reports and
/// final blocks come from different threads of a worker process.
pub struct CtrlLink {
    stream: Mutex<TcpStream>,
    sealer: FrameSealer,
    chaos: Option<Arc<ChaosPlan>>,
}

impl CtrlLink {
    pub fn new(
        stream: TcpStream,
        key: Option<[u8; 32]>,
        chaos: Option<Arc<ChaosPlan>>,
    ) -> CtrlLink {
        CtrlLink {
            stream: Mutex::new(stream),
            sealer: FrameSealer::new(key),
            chaos,
        }
    }

    /// Writes one length-prefixed, enveloped frame — subject to the
    /// chaos plan's scripted drop/dup/delay schedule when one is live.
    pub fn send(&self, frame: &Frame) -> Result<()> {
        use std::io::Write;
        let body = encode(frame);
        let mut env = Vec::with_capacity(body.len() + self.sealer.overhead());
        self.sealer.seal(&body, &mut env);
        let fate = match &self.chaos {
            Some(c) => c.on_send(Scope::Ctrl),
            None => SendFate::Deliver,
        };
        if fate == SendFate::Drop {
            // The network "ate" the frame; its sequence number goes with
            // it, so the receiver sees a gap, never a desync.
            return Ok(());
        }
        let mut msg = Vec::with_capacity(env.len() + 4);
        msg.extend_from_slice(&(env.len() as u32).to_le_bytes());
        msg.extend_from_slice(&env);
        let mut s = self.stream.lock().unwrap();
        s.write_all(&msg).context("control write")?;
        if fate == SendFate::Duplicate {
            s.write_all(&msg).context("control write (chaos dup)")?;
        }
        s.flush().context("control flush")
    }
}

/// Reads one length-prefixed frame from a stream that has a read timeout
/// set. Returns `Ok(None)` if the timeout elapsed *between* frames (the
/// caller loops and re-checks its flags) or the envelope was an exact
/// duplicate (already-seen sequence number); a timeout mid-frame keeps
/// reading. Errors on EOF, shutdown (`down`), a malformed frame, or an
/// envelope `opener` rejects (bad magic/flags/tag — drop the connection).
pub fn recv_frame(
    stream: &mut TcpStream,
    opener: &mut FrameOpener,
    down: &AtomicBool,
) -> Result<Option<Frame>> {
    let mut len4 = [0u8; 4];
    let mut off = 0usize;
    while off < 4 {
        if down.load(Ordering::Relaxed) {
            bail!("control channel shut down");
        }
        match stream.read(&mut len4[off..]) {
            Ok(0) => bail!("control connection closed"),
            Ok(n) => off += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if off == 0 {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("control read"),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(len <= MAX_ENVELOPE, "control frame too large: {len} bytes");
    let mut body = vec![0u8; len];
    let mut off = 0usize;
    while off < len {
        if down.load(Ordering::Relaxed) {
            bail!("control channel shut down");
        }
        match stream.read(&mut body[off..]) {
            Ok(0) => bail!("control connection closed mid-frame"),
            Ok(n) => off += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e).context("control read body"),
        }
    }
    match opener.open(&body)? {
        Opened::Duplicate => Ok(None),
        Opened::Body(b) => decode(b).map(Some),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_frames() -> Vec<Frame> {
        vec![
            Frame::Join {
                ring_addr: "127.0.0.1:4001".into(),
                wire_precision: WirePrecision::F32,
            },
            Frame::Join {
                ring_addr: "127.0.0.1:4002".into(),
                wire_precision: WirePrecision::Bf16,
            },
            Frame::Assign {
                rank: 1,
                p: 3,
                start_iter: 7,
                peers: vec!["127.0.0.1:4000".into(), "127.0.0.1:4001".into()],
                config: "dataset = housing\nworkers = 3\n".into(),
            },
            Frame::Ready,
            Frame::Start,
            Frame::Epoch {
                rank: 2,
                iter: 41,
                loss_sum: 123.456,
                reg_w: 0.25,
                reg_v: -1.5e-9,
            },
            Frame::Progress { iters_done: 40 },
            Frame::Stop { at: 50 },
            Frame::Heartbeat,
            Frame::Abort,
            Frame::FinalBlock {
                frame: vec![0xD5, 0xFA, 1, 2, 3],
            },
            Frame::Done {
                messages: 9_999,
                bytes: u64::MAX / 3,
            },
            Frame::Shutdown,
            Frame::Reject {
                reason: "wire_precision mismatch: driver f32, worker bf16".into(),
            },
        ]
    }

    #[test]
    fn every_frame_round_trips() {
        for f in all_frames() {
            let buf = encode(&f);
            let back = decode(&buf).unwrap_or_else(|e| panic!("{f:?}: {e}"));
            assert_eq!(back, f);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[0xFA, 0xD5, 1]).is_err()); // token magic, not control
        let mut buf = encode(&Frame::Heartbeat);
        buf[2] = 200; // unknown kind
        assert!(decode(&buf).is_err());
        let mut buf = encode(&Frame::Join {
            ring_addr: "x".into(),
            wire_precision: WirePrecision::F32,
        });
        buf.truncate(buf.len() - 1); // truncated (precision byte missing)
        assert!(decode(&buf).is_err());
        let mut buf = encode(&Frame::Join {
            ring_addr: "x".into(),
            wire_precision: WirePrecision::Bf16,
        });
        let last = buf.len() - 1;
        buf[last] = 7; // not a known precision tag
        assert!(decode(&buf).is_err());
        let mut buf = encode(&Frame::Stop { at: 3 });
        buf.push(0); // trailing byte
        assert!(decode(&buf).is_err());
    }

    fn stream_pair() -> (TcpStream, TcpStream) {
        use std::net::TcpListener;
        use std::time::Duration;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server
            .set_read_timeout(Some(Duration::from_millis(50)))
            .unwrap();
        (client, server)
    }

    #[test]
    fn frames_survive_a_tcp_stream() {
        use std::sync::atomic::AtomicBool;

        let (client, mut server) = stream_pair();
        let link = CtrlLink::new(client, None, None);
        let mut opener = FrameOpener::new(None, "test");
        let down = AtomicBool::new(false);

        // Timeout between frames surfaces as None, not an error.
        assert!(recv_frame(&mut server, &mut opener, &down)
            .unwrap()
            .is_none());

        for f in all_frames() {
            link.send(&f).unwrap();
        }
        for f in all_frames() {
            let got = loop {
                if let Some(g) = recv_frame(&mut server, &mut opener, &down).unwrap() {
                    break g;
                }
            };
            assert_eq!(got, f);
        }

        // A dropped peer surfaces as an error.
        drop(link);
        let mut saw_err = false;
        for _ in 0..100 {
            if recv_frame(&mut server, &mut opener, &down).is_err() {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "EOF did not surface as an error");
    }

    #[test]
    fn authed_frames_survive_and_garbage_is_rejected() {
        use std::io::Write;
        use std::sync::atomic::AtomicBool;

        let key = crate::cluster::auth::derive_key("cluster-pw");
        let (client, mut server) = stream_pair();
        let link = CtrlLink::new(client, Some(key), None);
        let mut opener = FrameOpener::new(Some(key), "test");
        let down = AtomicBool::new(false);

        for f in all_frames() {
            link.send(&f).unwrap();
        }
        for f in all_frames() {
            let got = loop {
                if let Some(g) = recv_frame(&mut server, &mut opener, &down).unwrap() {
                    break g;
                }
            };
            assert_eq!(got, f);
        }

        // An unauthenticated client knocking on a keyed port: its bytes
        // must be rejected (counted), never decoded into a frame.
        let (mut knock, mut server2) = stream_pair();
        let mut opener2 = FrameOpener::new(Some(key), "test");
        let junk = [12u8, 0, 0, 0, 0xfb, 0xd5, 8, 0, 0, 0, 0, 0, 0, 0, 0, 0];
        knock.write_all(&junk).unwrap();
        knock.flush().unwrap();
        let mut rejected = false;
        for _ in 0..100 {
            if recv_frame(&mut server2, &mut opener2, &down).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "garbage was not rejected");
        assert_eq!(opener2.rejected(), 1);
    }

    #[test]
    fn chaos_dup_and_drop_on_the_control_wire() {
        use crate::cluster::chaos::ChaosPlan;
        use std::sync::atomic::AtomicBool;
        use std::time::{Duration, Instant};

        // Frame #1 dropped, frame #2 duplicated.
        let plan = Arc::new(ChaosPlan::parse("drop:ctrl:1;dup:ctrl:2").unwrap());
        let (client, mut server) = stream_pair();
        let link = CtrlLink::new(client, None, Some(plan));
        let mut opener = FrameOpener::new(None, "test");
        let down = AtomicBool::new(false);

        link.send(&Frame::Ready).unwrap(); // #0 delivered
        link.send(&Frame::Start).unwrap(); // #1 dropped on the floor
        link.send(&Frame::Heartbeat).unwrap(); // #2 written twice
        link.send(&Frame::Shutdown).unwrap(); // #3 delivered

        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        while got.len() < 3 && Instant::now() < deadline {
            if let Some(f) = recv_frame(&mut server, &mut opener, &down).unwrap() {
                got.push(f);
            }
        }
        // The duplicate was swallowed by the opener, the drop shows up
        // only as a sequence gap.
        assert_eq!(got, vec![Frame::Ready, Frame::Heartbeat, Frame::Shutdown]);
        assert_eq!(opener.gaps(), 1);
        assert_eq!(opener.rejected(), 0);
    }
}
