//! The cluster control plane.
//!
//! `run_driver` owns everything the paper's decentralized design leaves
//! *outside* the token ring: membership (wait for the expected P workers
//! to `Join`), rank/shard assignment off the shared
//! [`crate::partition::RowPartition`] plan, the per-epoch objective fold,
//! heartbeat-based failure detection, and the final exact model assembly
//! from collected tokens. Parameters never pass through the driver while
//! training runs — workers exchange tokens peer-to-peer.
//!
//! ## Generations
//!
//! A *generation* is one attempt at running the ring to completion. When
//! a worker dies (its control connection drops, or it goes silent past
//! the heartbeat timeout), the driver broadcasts [`Frame::Abort`],
//! survivors tear down their ring and re-`Join`, and the next generation
//! restarts every worker from the newest complete block checkpoint (the
//! largest epoch tag for which all P per-rank files exist — see
//! [`crate::train::Checkpointer::latest_block_epoch`]). Survivors keep
//! their ranks across generations; freed ranks go to fresh joiners in
//! join order, so a replacement process slots into the dead worker's
//! shard.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::control::{self, CtrlLink, Frame};
use super::journal::{self, DriverJournal};
use super::{col_plan_for, ClusterSpec};
use crate::cluster::auth;
use crate::cluster::chaos::ChaosPlan;
use crate::cluster::codec::{self, FrameOpener, WirePrecision};
use crate::config::{DatasetSpec, ExperimentConfig};
use crate::data::cache::ShardCacheSource;
use crate::data::{DataSource, PrefetchSource};
use crate::fm::FmModel;
use crate::metrics::TracePoint;
use crate::nomad::engine::assemble_model;
use crate::nomad::token::Token;
use crate::train::Checkpointer;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Everything `dsfacto driver` needs to run one cluster training job.
pub struct DriverOptions {
    /// The experiment; `cfg.cluster` must be `ClusterSpec::Driver`, and
    /// the dataset must resolve to a shard cache directory every worker
    /// can open.
    pub cfg: ExperimentConfig,
    /// Directory for per-epoch block checkpoints; `None` disables
    /// checkpoint-restart (a failed generation then restarts from iter 0).
    pub ckpt_dir: Option<PathBuf>,
    /// Checkpoint every this many completed outer iterations.
    pub ckpt_every: u32,
    /// How long to wait for the expected P workers to join (and later to
    /// report `Ready`) before giving up on a generation.
    pub join_timeout: Duration,
    /// A running worker silent for longer than this is presumed dead.
    pub heartbeat_timeout: Duration,
    /// No *progress* (aggregated iterations, final blocks, done frames)
    /// for longer than this aborts the generation even while heartbeats
    /// keep flowing — the recovery path for a token lost on the ring,
    /// which stalls the ring without killing anyone.
    pub stall_timeout: Duration,
    /// Upper bound on generations (1 = no fault tolerance).
    pub max_generations: u32,
    /// Resume a crashed driver from its `driver.dsfj` journal (requires
    /// `ckpt_dir`): restores the trace, skips the iter-0 probe, and
    /// refuses to resume a different experiment.
    pub resume: bool,
    /// Scripted fault-injection plan for this process (tests/benches).
    pub chaos: Option<Arc<ChaosPlan>>,
    /// Suppress per-iteration progress lines.
    pub quiet: bool,
}

/// What a completed cluster run produced.
pub struct DriverReport {
    /// The final model, assembled exactly from the collected tokens
    /// (engine invariant 4).
    pub model: FmModel,
    /// Convergence trace: iter 0 plus one point per aggregated iteration.
    pub trace: Vec<TracePoint>,
    /// Generations used (1 = no failures).
    pub generations: u32,
    /// Sum of the workers' transport message counts.
    pub messages: u64,
    /// Sum of the workers' transport byte counts.
    pub bytes: u64,
    /// Wall-clock seconds from listener-up to model assembly.
    pub wall_secs: f64,
}

/// One control connection as the driver sees it.
struct Conn {
    writer: Arc<CtrlLink>,
    alive: bool,
    last_heard: Instant,
    ring_addr: Option<String>,
    rank: Option<usize>,
    /// The generation this connection's latest `Join` belongs to —
    /// distinguishes a current-membership worker from stale frames of an
    /// aborted generation still draining out of the socket.
    joined_gen: Option<u32>,
}

/// Reader-thread events funneled into the driver's single event loop.
enum Ev {
    /// A new control connection was accepted.
    Accepted(TcpStream),
    /// A frame arrived on connection `idx`.
    Frame(usize, Frame),
    /// Connection `idx` closed or errored.
    Dead(usize),
}

/// How one generation ended.
enum GenOutcome {
    /// Training ran to `t_max`: the full token set plus summed transport
    /// stats from every worker's `Done`.
    Finished {
        tokens: Vec<Token>,
        messages: u64,
        bytes: u64,
    },
    /// A worker died; survivors were told to abort and re-join.
    Aborted,
}

/// Sends a frame to connection `i`; on failure the connection is marked
/// dead (its rank freed) and `false` is returned.
fn send_to(conns: &mut [Conn], i: usize, frame: &Frame) -> bool {
    if conns[i].writer.send(frame).is_ok() {
        true
    } else {
        conns[i].alive = false;
        conns[i].rank = None;
        conns[i].joined_gen = None;
        false
    }
}

/// Broadcasts to every ranked member of generation `gen`; returns whether
/// all sends landed.
fn broadcast(conns: &mut [Conn], gen: u32, frame: &Frame) -> bool {
    let mut ok = true;
    for i in 0..conns.len() {
        if conns[i].alive && conns[i].joined_gen == Some(gen) && conns[i].rank.is_some() {
            ok &= send_to(conns, i, frame);
        }
    }
    ok
}

/// Tells every live connection (ranked or not) the generation failed.
fn abort_all(conns: &mut [Conn]) {
    for i in 0..conns.len() {
        if conns[i].alive {
            // Best-effort: a failed send already marks the conn dead.
            send_to(conns, i, &Frame::Abort);
        }
    }
}

/// Registers a freshly accepted control connection and spawns its reader
/// thread (frames and death notices flow into the shared event channel).
/// Socket-option failures are no longer swallowed: a connection whose
/// timeouts cannot be set could block the driver forever, so it is
/// rejected with a log line instead of registered broken.
fn register_conn(
    conns: &mut Vec<Conn>,
    stream: TcpStream,
    ev_tx: &Sender<Ev>,
    down: &Arc<AtomicBool>,
    key: Option<[u8; 32]>,
    chaos: Option<&Arc<ChaosPlan>>,
) {
    let idx = conns.len();
    if let Err(e) = stream.set_nodelay(true) {
        // Latency-only concern; the connection still works.
        eprintln!("dsfacto driver: set_nodelay failed on a control conn: {e}");
    }
    if let Err(e) = stream.set_write_timeout(Some(Duration::from_secs(10))) {
        eprintln!("dsfacto driver: rejecting control conn (set_write_timeout failed: {e})");
        return;
    }
    let reader = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => return, // stillborn connection; nothing to track
    };
    if let Err(e) = reader.set_read_timeout(Some(Duration::from_millis(250))) {
        // The reader polls `down` between timeouts; without a timeout it
        // would block forever and never notice shutdown.
        eprintln!("dsfacto driver: rejecting control conn (set_read_timeout failed: {e})");
        return;
    }
    let tx = ev_tx.clone();
    let down = Arc::clone(down);
    let spawned = std::thread::Builder::new()
        .name(format!("ctrl-read-{idx}"))
        .spawn(move || {
            let mut reader = reader;
            let mut opener = FrameOpener::new(key, "driver control");
            loop {
                match control::recv_frame(&mut reader, &mut opener, &down) {
                    Ok(Some(f)) => {
                        if tx.send(Ev::Frame(idx, f)).is_err() {
                            return;
                        }
                    }
                    Ok(None) => {
                        if down.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                    Err(_) => {
                        let _ = tx.send(Ev::Dead(idx));
                        return;
                    }
                }
            }
        });
    if spawned.is_err() {
        return;
    }
    conns.push(Conn {
        writer: Arc::new(CtrlLink::new(stream, key, chaos.cloned())),
        alive: true,
        last_heard: Instant::now(),
        ring_addr: None,
        rank: None,
        joined_gen: None,
    });
}

/// Marks connection `i` dead and frees its rank.
fn mark_dead(conns: &mut [Conn], i: usize) {
    conns[i].alive = false;
    conns[i].rank = None;
    conns[i].joined_gen = None;
}

/// Runs the cluster control plane to completion and returns the final
/// model (bitwise the in-process engine's under `update_mode =
/// mean_gradient` at a matched schedule) plus the convergence trace.
pub fn run_driver(opts: &DriverOptions) -> Result<DriverReport> {
    let cfg = &opts.cfg;
    let Some(ClusterSpec::Driver { addr, p }) = cfg.cluster.clone() else {
        bail!("run_driver needs `cluster = driver:<addr>,p=<P>` in the config");
    };
    ensure!(opts.max_generations >= 1, "max_generations must be >= 1");
    // A cluster run cannot split: workers train on the shard files as
    // ingested, so a fractional split would silently train on different
    // rows than the probe evaluates. Reject instead of ignoring the key.
    ensure!(
        cfg.train_frac >= 1.0,
        "cluster runs require train_frac = 1 (pre-split at ingest): got train_frac = {}",
        cfg.train_frac
    );

    // The dataset must live in a shard cache both the driver (for the
    // streaming probe) and every worker (for its shard) can open.
    let cache_dir = match (&cfg.dataset, &cfg.data_cache) {
        (DatasetSpec::Cache { dir }, _) => dir.clone(),
        (_, Some(dir)) => dir.clone(),
        _ => bail!(
            "cluster driver needs `dataset = cache:<dir>` (or `data_cache = <dir>`): \
             workers resolve their shards from the shared ingest cache"
        ),
    };
    // Double-buffer the driver's own shard sweeps (the iter-0 probe and
    // any later folds): one shard in use, the next in flight.
    let src = PrefetchSource::new(Arc::new(
        ShardCacheSource::open(&cache_dir)
            .with_context(|| format!("opening shard cache {cache_dir:?}"))?,
    ));
    let n = src.n();
    let d = src.d();
    let k = cfg.fm.k;
    ensure!(n > 0 && d > 0, "empty dataset in shard cache {cache_dir:?}");
    let row_plan = src.plan(cfg.row_partition, p)?;
    let col_plan = col_plan_for(cfg.cols_per_token, d, p);
    let ntok = col_plan.n_blocks() + 1;
    let t_max = cfg.outer_iters as u32;

    // What ships to workers: the same experiment pinned to this ring
    // width, with the dataset pointing at the cache. The cluster key and
    // the secret are stripped — each worker's role *and its key* come
    // from its own command line; the secret never transits the wire. The
    // wire precision is stripped too: each worker declares its own
    // `--wire-precision` in its Join, and the driver *verifies* the match
    // instead of silently overwriting what the operator launched.
    let ship_cfg = {
        let mut ship = cfg.clone();
        ship.workers = p;
        ship.dataset = DatasetSpec::Cache {
            dir: cache_dir.clone(),
        };
        ship.data_cache = None;
        ship.cluster = None;
        ship.cluster_secret = None;
        ship.wire_precision = WirePrecision::F32;
        ship.dump()
    };
    let key = cfg.cluster_secret.as_deref().map(auth::derive_key);
    let config_sha = journal::config_sha(&ship_cfg);

    let mut gen_base = 0u32;
    let mut trace;
    if opts.resume {
        // Crashed-driver rejoin: restore the control state the journal
        // captured instead of re-probing iteration 0.
        let dir = opts.ckpt_dir.as_deref().context(
            "--resume requires --ckpt-dir (the journal lives next to the block checkpoints)",
        )?;
        let j = DriverJournal::load(dir)?.with_context(|| {
            format!("--resume: no {} found in {dir:?}", DriverJournal::FILE)
        })?;
        ensure!(
            j.p == p,
            "--resume: journal was written for p = {}, this driver expects p = {p}",
            j.p
        );
        ensure!(
            j.config_sha == config_sha,
            "--resume: journal belongs to a different experiment (config hash mismatch)"
        );
        ensure!(!j.trace.is_empty(), "--resume: journal has an empty trace");
        gen_base = j.generations;
        trace = j.trace;
        if !opts.quiet {
            println!(
                "dsfacto driver: resuming from journal ({} generation(s) used, {} trace points)",
                gen_base,
                trace.len()
            );
        }
    } else {
        // Iter-0 probe: the exact initial objective, folded shard-by-shard
        // so the driver never materializes the full matrix.
        let init = {
            let mut rng = Pcg64::new(cfg.seed, 0x0ad);
            FmModel::init(d, k, cfg.fm.init_std, &mut rng)
        };
        let (objective, train_loss) = crate::train::streaming_objective(
            &src,
            &row_plan,
            &init,
            cfg.fm.lambda_w,
            cfg.fm.lambda_v,
        )?;
        trace = vec![TracePoint {
            iter: 0,
            secs: 0.0,
            objective,
            train_loss,
            test: None,
        }];
        if !opts.quiet {
            print_point(&trace[0]);
        }
    }

    // Control listener. The `control on <addr>` line is parsed by tests
    // and scripts that bind port 0 — keep its shape stable.
    let listener = TcpListener::bind(&addr).with_context(|| format!("binding driver on {addr}"))?;
    let local = listener.local_addr()?;
    println!("dsfacto driver: control on {local}");
    if cfg.wire_precision != WirePrecision::F32 {
        println!(
            "dsfacto driver: token wire precision {}",
            cfg.wire_precision.name()
        );
    }
    {
        use std::io::Write;
        let _ = std::io::stdout().flush();
    }
    listener.set_nonblocking(true)?;

    let (ev_tx, ev_rx) = channel::<Ev>();
    let down = Arc::new(AtomicBool::new(false));
    let acceptor = {
        let tx = ev_tx.clone();
        let down = Arc::clone(&down);
        let chaos = opts.chaos.clone();
        std::thread::Builder::new()
            .name("ctrl-accept".into())
            .spawn(move || loop {
                if down.load(Ordering::Relaxed) {
                    return;
                }
                match listener.accept() {
                    Ok((s, _)) => {
                        if chaos.as_ref().is_some_and(|c| c.refusing()) {
                            // Scripted refusal window: reset the conn so
                            // workers exercise their retry policy.
                            drop(s);
                            continue;
                        }
                        if tx.send(Ev::Accepted(s)).is_err() {
                            return;
                        }
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(20)),
                }
            })
            .context("spawning acceptor")?
    };

    let sw = Stopwatch::start();
    let mut conns: Vec<Conn> = Vec::new();
    let mut generations = 0u32;
    let jsink = JournalSink {
        dir: opts.ckpt_dir.as_deref(),
        p,
        config_sha: &config_sha,
    };

    let run = (|| -> Result<(Vec<Token>, u64, u64)> {
        for gen in gen_base..gen_base.saturating_add(opts.max_generations) {
            generations = gen + 1;
            let start_iter = match &opts.ckpt_dir {
                Some(dir) => Checkpointer::latest_block_epoch(dir, p)?.unwrap_or(0).min(t_max),
                None => 0,
            };
            if gen > 0 {
                // Drop trace points the aborted (or journaled-past-the-
                // checkpoint) run recorded past the restart iteration —
                // they'll be re-aggregated.
                trace.retain(|pt| pt.iter <= start_iter as usize);
                jsink.save(generations, &trace);
                if !opts.quiet {
                    println!(
                        "dsfacto driver: generation {} restarting from iteration {start_iter}",
                        gen + 1
                    );
                }
            }
            match run_generation(
                opts,
                &ev_rx,
                &ev_tx,
                &down,
                &mut conns,
                gen,
                p,
                start_iter,
                t_max,
                n,
                ntok,
                &ship_cfg,
                key,
                &jsink,
                &sw,
                &mut trace,
            )? {
                GenOutcome::Finished {
                    tokens,
                    messages,
                    bytes,
                } => return Ok((tokens, messages, bytes)),
                GenOutcome::Aborted => continue,
            }
        }
        bail!(
            "cluster run failed: {} generation(s) exhausted without completing",
            opts.max_generations
        )
    })();

    down.store(true, Ordering::SeqCst);
    drop(ev_tx);
    let _ = acceptor.join();

    let (tokens, messages, bytes) = run?;
    let model = assemble_model(tokens, &col_plan, d, k, t_max)?;
    Ok(DriverReport {
        model,
        trace,
        generations,
        messages,
        bytes,
        wall_secs: sw.secs(),
    })
}

fn print_point(pt: &TracePoint) {
    println!(
        "iter {:>4} t={:>8.3}s objective={:.6} train_loss={:.6}",
        pt.iter, pt.secs, pt.objective, pt.train_loss
    );
}

/// Best-effort journal writer: a failed save is logged, never fatal —
/// journal durability must not take down a healthy run.
struct JournalSink<'a> {
    dir: Option<&'a Path>,
    p: usize,
    config_sha: &'a str,
}

impl JournalSink<'_> {
    fn save(&self, generations: u32, trace: &[TracePoint]) {
        let Some(dir) = self.dir else { return };
        let j = DriverJournal {
            p: self.p,
            config_sha: self.config_sha.to_string(),
            generations,
            trace: trace.to_vec(),
        };
        if let Err(e) = j.save(dir) {
            eprintln!("dsfacto driver: journal write failed: {e:#}");
        }
    }
}

/// One generation: membership, assignment, barrier, epoch aggregation,
/// token drain. Returns `Aborted` (after telling everyone) on any worker
/// failure; hard errors (join timeout, malformed state) bubble up.
#[allow(clippy::too_many_arguments)]
fn run_generation(
    opts: &DriverOptions,
    ev_rx: &Receiver<Ev>,
    ev_tx: &Sender<Ev>,
    down: &Arc<AtomicBool>,
    conns: &mut Vec<Conn>,
    gen: u32,
    p: usize,
    start_iter: u32,
    t_max: u32,
    n: usize,
    ntok: usize,
    ship_cfg: &str,
    key: Option<[u8; 32]>,
    jsink: &JournalSink,
    sw: &Stopwatch,
    trace: &mut Vec<TracePoint>,
) -> Result<GenOutcome> {
    let cfg = &opts.cfg;

    // ---- Membership: wait for P live `Join`s tagged with this generation.
    let deadline = Instant::now() + opts.join_timeout;
    loop {
        let joined = conns
            .iter()
            .filter(|c| c.alive && c.joined_gen == Some(gen))
            .count();
        if joined >= p {
            break;
        }
        ensure!(
            Instant::now() < deadline,
            "only {joined}/{p} workers joined within {:?}",
            opts.join_timeout
        );
        match ev_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(Ev::Accepted(s)) => register_conn(conns, s, ev_tx, down, key, opts.chaos.as_ref()),
            Ok(Ev::Frame(i, f)) => {
                conns[i].last_heard = Instant::now();
                if let Frame::Join {
                    ring_addr,
                    wire_precision,
                } = f
                {
                    if wire_precision != cfg.wire_precision {
                        // A mixed-precision ring would corrupt every
                        // circulating token, and an Abort would just make
                        // the worker re-Join forever — refuse outright so
                        // it exits with the reason.
                        let reason = format!(
                            "wire_precision mismatch: driver runs {}, worker announced {}",
                            cfg.wire_precision.name(),
                            wire_precision.name()
                        );
                        eprintln!("dsfacto driver: rejecting worker: {reason}");
                        send_to(conns, i, &Frame::Reject { reason });
                        continue;
                    }
                    // A conn marked dead by a missed heartbeat can come
                    // back here; it lost its rank, not its socket.
                    conns[i].alive = true;
                    conns[i].ring_addr = Some(ring_addr);
                    conns[i].joined_gen = Some(gen);
                }
                // Anything else is a stale frame from an aborted
                // generation still draining: ignore it.
            }
            Ok(Ev::Dead(i)) => mark_dead(conns, i),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => bail!("driver event channel closed"),
        }
    }

    // ---- Rank assignment: survivors keep their ranks, freed ranks go to
    // fresh joiners in join (= accept) order.
    let members: Vec<usize> = conns
        .iter()
        .enumerate()
        .filter(|(_, c)| c.alive && c.joined_gen == Some(gen))
        .map(|(i, _)| i)
        .take(p)
        .collect();
    let mut used = vec![false; p];
    for &i in &members {
        match conns[i].rank {
            Some(r) if r < p && !used[r] => used[r] = true,
            _ => conns[i].rank = None,
        }
    }
    let mut free: Vec<usize> = (0..p).rev().filter(|&r| !used[r]).collect();
    for &i in &members {
        if conns[i].rank.is_none() {
            conns[i].rank = free.pop();
        }
    }
    let mut peers = vec![String::new(); p];
    for &i in &members {
        let (Some(r), Some(a)) = (conns[i].rank, conns[i].ring_addr.clone()) else {
            bail!("membership bookkeeping lost a rank or ring address");
        };
        peers[r] = a;
    }
    ensure!(
        peers.iter().all(|a| !a.is_empty()),
        "ring address table has holes"
    );

    for &i in &members {
        let assign = Frame::Assign {
            rank: conns[i].rank.unwrap() as u32,
            p: p as u32,
            start_iter,
            peers: peers.clone(),
            config: ship_cfg.to_string(),
        };
        if !send_to(conns, i, &assign) {
            abort_all(conns);
            return Ok(GenOutcome::Aborted);
        }
    }

    // ---- Barrier: every worker loads its shard and reports Ready.
    let deadline = Instant::now() + opts.join_timeout;
    let mut ready = 0usize;
    while ready < p {
        ensure!(
            Instant::now() < deadline,
            "only {ready}/{p} workers became ready within {:?}",
            opts.join_timeout
        );
        match ev_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(Ev::Accepted(s)) => register_conn(conns, s, ev_tx, down, key, opts.chaos.as_ref()),
            Ok(Ev::Frame(i, f)) => {
                conns[i].last_heard = Instant::now();
                if matches!(f, Frame::Ready)
                    && conns[i].joined_gen == Some(gen)
                    && conns[i].rank.is_some()
                {
                    ready += 1;
                }
            }
            Ok(Ev::Dead(i)) => {
                let ranked = conns[i].rank.is_some() && conns[i].joined_gen == Some(gen);
                mark_dead(conns, i);
                if ranked {
                    abort_all(conns);
                    return Ok(GenOutcome::Aborted);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => bail!("driver event channel closed"),
        }
    }

    if !broadcast(conns, gen, &Frame::Start) {
        abort_all(conns);
        return Ok(GenOutcome::Aborted);
    }

    // ---- Epoch aggregation + token drain. Per-connection frame order
    // means a worker's FinalBlocks can arrive while a slower peer's Epoch
    // reports are still pending, so both phases share one event loop.
    let target = t_max - start_iter;
    let mut completions = 0u32;
    let mut slots: BTreeMap<u32, Vec<Option<(f64, f64, f64)>>> = BTreeMap::new();
    let mut final_frames: Vec<Vec<u8>> = Vec::with_capacity(ntok);
    let mut dones = 0usize;
    let mut messages = 0u64;
    let mut bytes = 0u64;
    // Once aggregation is done the remaining drain is bounded work; give
    // it its own generous deadline instead of the heartbeat cadence.
    let mut drain_deadline: Option<Instant> = None;
    // Stall detection: heartbeats prove workers are *alive*, not that the
    // ring is *moving*. A token frame lost on the wire stalls every
    // worker at a barrier while heartbeats keep flowing — only a lack of
    // progress (aggregated iterations, final blocks, done frames) reveals
    // it, and the fix is the same checkpoint restart a death gets.
    let mut last_progress = Instant::now();

    loop {
        if completions >= target && final_frames.len() == ntok && dones == p {
            break;
        }
        let now = Instant::now();
        if completions >= target && drain_deadline.is_none() {
            drain_deadline = Some(now + Duration::from_secs(120));
        }
        if let Some(dl) = drain_deadline {
            if now >= dl {
                eprintln!(
                    "dsfacto driver: token drain timed out ({}/{ntok} blocks, {dones}/{p} done \
                     frames); aborting generation",
                    final_frames.len()
                );
                abort_all(conns);
                return Ok(GenOutcome::Aborted);
            }
        }
        if now.duration_since(last_progress) > opts.stall_timeout {
            eprintln!(
                "dsfacto driver: no progress for {:?} (ring stalled or frames lost); \
                 aborting generation",
                opts.stall_timeout
            );
            abort_all(conns);
            return Ok(GenOutcome::Aborted);
        }
        // Failure detection: a ranked worker silent past the heartbeat
        // timeout is presumed dead.
        for i in 0..conns.len() {
            if conns[i].alive
                && conns[i].joined_gen == Some(gen)
                && conns[i].rank.is_some()
                && now.duration_since(conns[i].last_heard) > opts.heartbeat_timeout
            {
                mark_dead(conns, i);
                abort_all(conns);
                return Ok(GenOutcome::Aborted);
            }
        }
        match ev_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(Ev::Accepted(s)) => register_conn(conns, s, ev_tx, down, key, opts.chaos.as_ref()),
            Ok(Ev::Frame(i, f)) => {
                conns[i].last_heard = Instant::now();
                if conns[i].joined_gen != Some(gen) || conns[i].rank.is_none() {
                    continue; // stale traffic from an aborted generation
                }
                match f {
                    Frame::Epoch {
                        rank,
                        iter,
                        loss_sum,
                        reg_w,
                        reg_v,
                    } => {
                        ensure!((rank as usize) < p, "epoch report from rank {rank} >= {p}");
                        let slot = slots.entry(iter).or_insert_with(|| vec![None; p]);
                        slot[rank as usize] = Some((loss_sum, reg_w, reg_v));
                        if slot.iter().all(|s| s.is_some()) {
                            let vals = slots.remove(&iter).unwrap();
                            // Rank-ordered fold: deterministic across
                            // arrival orders (the in-process driver folds
                            // in arrival order, which can differ in final
                            // ULPs of the *trace* — the model equality
                            // guarantee is unaffected).
                            let (mut ls, mut rw, mut rv) = (0.0f64, 0.0f64, 0.0f64);
                            for v in vals {
                                let (l, w, vv) = v.unwrap();
                                ls += l;
                                rw += w;
                                rv += vv;
                            }
                            let train_loss = ls / n as f64;
                            let objective = train_loss
                                + 0.5 * cfg.fm.lambda_w as f64 * rw
                                + 0.5 * cfg.fm.lambda_v as f64 * rv;
                            completions += 1;
                            // Publish progress before anything slow: the
                            // workers' pipelining gate rides on this.
                            if !broadcast(
                                conns,
                                gen,
                                &Frame::Progress {
                                    iters_done: start_iter + completions,
                                },
                            ) {
                                abort_all(conns);
                                return Ok(GenOutcome::Aborted);
                            }
                            let pt = TracePoint {
                                iter: iter as usize + 1,
                                secs: sw.secs(),
                                objective,
                                train_loss,
                                test: None,
                            };
                            if !opts.quiet {
                                print_point(&pt);
                            }
                            trace.push(pt);
                            // Journal after every aggregated iteration —
                            // the state a `--resume` driver restores.
                            jsink.save(gen + 1, trace);
                            last_progress = Instant::now();
                            if let Some(chaos) = &opts.chaos {
                                chaos.kill_if_due(start_iter + completions, "driver");
                            }
                        }
                    }
                    Frame::FinalBlock { frame } => {
                        ensure!(
                            final_frames.len() < ntok,
                            "more than {ntok} final blocks arrived"
                        );
                        final_frames.push(frame);
                        last_progress = Instant::now();
                    }
                    Frame::Done {
                        messages: m,
                        bytes: b,
                    } => {
                        dones += 1;
                        messages += m;
                        bytes += b;
                        last_progress = Instant::now();
                    }
                    // Heartbeats already refreshed last_heard; a stray
                    // Join here belongs to the next generation's loop.
                    _ => {}
                }
            }
            Ok(Ev::Dead(i)) => {
                let ranked = conns[i].rank.is_some() && conns[i].joined_gen == Some(gen);
                mark_dead(conns, i);
                if ranked {
                    abort_all(conns);
                    return Ok(GenOutcome::Aborted);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => bail!("driver event channel closed"),
        }
    }

    let mut tokens = Vec::with_capacity(ntok);
    for frame in &final_frames {
        tokens.push(codec::decode_token_padded(frame).context("decoding a final block")?);
    }
    broadcast(conns, gen, &Frame::Shutdown);
    Ok(GenOutcome::Finished {
        tokens,
        messages,
        bytes,
    })
}
