//! One engine worker hosted in its own OS process.
//!
//! `run_worker` is the body of `dsfacto worker`: connect to the driver's
//! control plane (with retry — workers may start before the driver),
//! `Join` with a freshly bound token-ring listener, and on `Assign`
//! resolve the assigned shard from the shared cache, rebuild the token
//! deal from `(seed, p)`, and run one [`crate::nomad::engine::Worker`]
//! over a cross-process [`TcpTransport`] ring.
//!
//! While the engine thread runs, a relay loop on the main thread:
//!
//! * forwards the engine's finalize reports to the driver as
//!   [`Frame::Epoch`],
//! * persists the per-epoch block checkpoint stream through
//!   [`Checkpointer::save_blocks`],
//! * applies `Progress` / `Stop` frames to the engine's pipelining gate,
//! * heartbeats, and
//! * detects completion: the engine has finalized its last iteration
//!   *and* all of this rank's dealt tokens returned (a token always
//!   drains back to its deal rank — each phase is exactly P hops around
//!   the ring, so a token ends every phase where it started it). The
//!   explicit finalize condition matters for ranks dealt zero tokens:
//!   their collector count is trivially complete from the start, but the
//!   process must keep forwarding ring traffic until the run ends.
//!
//! On `Abort` the worker tears the ring down and re-`Join`s with a fresh
//! listener; the driver's next `Assign` carries the restart iteration,
//! and the worker reloads the model from all P per-rank checkpoint files
//! (every worker reassembles the same global model, then keeps only its
//! own dealt tokens and its own shard's arenas).
//!
//! A *lost control connection* is survivable too: the driver may have
//! crashed and be on its way back via `dsfacto driver --resume`, so the
//! worker re-dials (bounded by `connect_timeout` per attempt) and
//! re-joins instead of dying with it.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use super::col_plan_for;
use super::control::{self, CtrlLink, Frame};
use crate::cluster::auth;
use crate::cluster::chaos::ChaosPlan;
use crate::cluster::codec::{self, FrameOpener, WirePrecision};
use crate::cluster::retry::{Attempt, RetryPolicy, SystemClock};
use crate::cluster::tcp::TcpTransport;
use crate::cluster::Transport;
use crate::config::{DatasetSpec, ExperimentConfig};
use crate::data::cache::ShardCacheSource;
use crate::data::DataSource;
use crate::fm::FmModel;
use crate::kernel::{padded_k, FmKernel};
use crate::nomad::engine::{
    assemble_model, deal_ranks, deal_tokens, seed_arenas, CkptHook, CkptMsg, FinalizePost, Shared,
    Worker,
};
use crate::nomad::token::Token;
use crate::partition;
use crate::train::Checkpointer;
use crate::util::rng::Pcg64;

/// Everything `dsfacto worker` needs to serve one cluster.
pub struct WorkerOptions {
    /// The driver's control-plane address.
    pub driver_addr: String,
    /// Shard cache override; by default the worker uses the cache
    /// directory named in the driver-shipped config.
    pub data_cache: Option<String>,
    /// Where to write per-epoch block checkpoints (and read them back on
    /// a restart `Assign`). `None` disables checkpointing.
    pub ckpt_dir: Option<PathBuf>,
    /// Checkpoint every this many completed outer iterations.
    pub ckpt_every: u32,
    /// How long to keep retrying each control connection (the initial
    /// dial, and every re-dial after the driver drops).
    pub connect_timeout: Duration,
    /// Shared secret for frame authentication; must match the driver's
    /// `--cluster-secret` (or both sides run unauthenticated).
    pub cluster_secret: Option<String>,
    /// Token payload format this worker's ring transport speaks
    /// (`--wire-precision`). Declared in every `Join`; the driver rejects
    /// workers whose precision differs from its own config, so a ring
    /// can never mix formats.
    pub wire_precision: WirePrecision,
    /// Scripted fault-injection plan for this process (tests/benches).
    pub chaos: Option<Arc<ChaosPlan>>,
}

/// Control-channel events funneled from the reader thread.
enum CtrlEv {
    Frame(Frame),
    Dead,
}

/// Why the relay loop stopped.
enum RelayEnd {
    /// Training finished; tokens are in the collector.
    Completed,
    /// Driver aborted the generation: tear down and re-join.
    Aborted,
    /// Driver shut the cluster down mid-run (stale but final).
    Shutdown,
    /// The control connection died: re-dial the driver.
    ControlLost,
}

/// How one control-connection session ended.
enum LoopEnd {
    /// Driver sent `Shutdown`: the cluster run is over.
    Shutdown,
    /// The control connection died mid-session: reconnect and re-join.
    ControlLost,
}

/// Dials the driver under the shared retry policy (workers may start
/// before the driver, and a `--resume` driver takes a moment to return).
fn connect_control(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let policy = RetryPolicy::new(
        Duration::from_millis(50),
        Duration::from_millis(500),
        timeout,
    );
    policy
        .run(&mut SystemClock, |_| {
            TcpStream::connect(addr)
                .map_err(|e| Attempt::Retry(anyhow::Error::new(e).context("connect")))
        })
        .with_context(|| format!("connecting to driver {addr} (gave up after {timeout:?})"))
}

/// Sends a heartbeat if the cadence interval elapsed.
fn maybe_heartbeat(ctrl: &CtrlLink, last: &mut Instant) -> Result<()> {
    if last.elapsed() >= Duration::from_millis(500) {
        ctrl.send(&Frame::Heartbeat).context("heartbeat")?;
        *last = Instant::now();
    }
    Ok(())
}

/// Persists one completed checkpoint epoch (best-effort: a failed write
/// costs restart depth, not the run), then prunes superseded epochs so a
/// long run does not accumulate unbounded checkpoint files.
fn save_epoch(
    ckpt_dir: &Option<PathBuf>,
    rank: usize,
    p: usize,
    tag: u32,
    pending: &mut HashMap<u32, Vec<Token>>,
    k: usize,
) {
    let blocks = pending.remove(&tag).unwrap_or_default();
    if let Some(dir) = ckpt_dir {
        if let Err(e) = Checkpointer::save_blocks(dir, rank, tag, &blocks, k) {
            eprintln!("dsfacto worker: checkpoint write failed at epoch {tag}: {e:#}");
        } else if let Err(e) = Checkpointer::prune_block_epochs(dir, p, 2) {
            eprintln!("dsfacto worker: checkpoint GC failed: {e:#}");
        }
    }
}

/// Runs the worker process until the driver shuts the cluster down (or
/// the control channel stays unreachable / a generation cannot be
/// served). Each pass of the session loop is one control connection; a
/// `ControlLost` end re-dials and re-joins (checkpoint rejoin after a
/// driver crash + `--resume`).
pub fn run_worker(opts: &WorkerOptions) -> Result<()> {
    let key = opts.cluster_secret.as_deref().map(auth::derive_key);
    let mut session = 0u64;
    loop {
        if session > 0 {
            eprintln!(
                "dsfacto worker: control connection lost; redialing {} (session {})",
                opts.driver_addr,
                session + 1
            );
        }
        let ctrl_raw = connect_control(&opts.driver_addr, opts.connect_timeout)?;
        if let Err(e) = ctrl_raw.set_nodelay(true) {
            // Latency-only concern; the connection still works.
            eprintln!("dsfacto worker: set_nodelay failed on the control conn: {e}");
        }
        // A silently unset write timeout would let a wedged driver block
        // this process forever — propagate instead of shrugging.
        ctrl_raw
            .set_write_timeout(Some(Duration::from_secs(10)))
            .context("setting control write timeout")?;
        // The IP the driver (and thus the other workers) can reach us on
        // is whatever interface this control connection went out of.
        let local_ip = ctrl_raw.local_addr()?.ip();

        let (ctrl_tx, ctrl_rx) = channel::<CtrlEv>();
        let ctrl_down = Arc::new(AtomicBool::new(false));
        {
            let mut reader = ctrl_raw.try_clone().context("cloning control stream")?;
            reader
                .set_read_timeout(Some(Duration::from_millis(250)))
                .context("setting control read timeout")?;
            let tx = ctrl_tx.clone();
            let down = Arc::clone(&ctrl_down);
            std::thread::Builder::new()
                .name("ctrl-read".into())
                .spawn(move || {
                    let mut opener = FrameOpener::new(key, "worker control");
                    loop {
                        match control::recv_frame(&mut reader, &mut opener, &down) {
                            Ok(Some(f)) => {
                                if tx.send(CtrlEv::Frame(f)).is_err() {
                                    return;
                                }
                            }
                            Ok(None) => {
                                if down.load(Ordering::Relaxed) {
                                    return;
                                }
                            }
                            Err(_) => {
                                let _ = tx.send(CtrlEv::Dead);
                                return;
                            }
                        }
                    }
                })
                .context("spawning control reader")?;
        }
        drop(ctrl_tx);
        let ctrl = CtrlLink::new(ctrl_raw, key, opts.chaos.clone());

        let result = worker_loop(opts, key, &ctrl, &ctrl_rx, local_ip);
        ctrl_down.store(true, Ordering::SeqCst);
        match result? {
            LoopEnd::Shutdown => return Ok(()),
            LoopEnd::ControlLost => session += 1,
        }
    }
}

/// The generation loop: join, serve, and either exit on `Shutdown`,
/// re-join after `Abort`, or report `ControlLost` for a re-dial.
fn worker_loop(
    opts: &WorkerOptions,
    key: Option<[u8; 32]>,
    ctrl: &CtrlLink,
    ctrl_rx: &Receiver<CtrlEv>,
    local_ip: std::net::IpAddr,
) -> Result<LoopEnd> {
    loop {
        // Fresh ring listener per generation: the old ring's peers may
        // still be flushing frames at the old port.
        let ring_listener = TcpListener::bind((local_ip, 0))
            .or_else(|_| TcpListener::bind("0.0.0.0:0"))
            .context("binding ring listener")?;
        let ring_addr = format!("{}:{}", local_ip, ring_listener.local_addr()?.port());
        if ctrl
            .send(&Frame::Join {
                ring_addr: ring_addr.clone(),
                wire_precision: opts.wire_precision,
            })
            .is_err()
        {
            return Ok(LoopEnd::ControlLost);
        }

        // ---- Await Assign (tolerating one full generation of delay: a
        // replacement worker can join while the old generation is mid-run).
        // The Join is re-sent every couple of seconds: a Join that lands
        // while the driver's *previous* generation is still aborting gets
        // discarded as stale traffic, so keep knocking until a membership
        // round actually hears us (the driver handles repeats
        // idempotently).
        let assign_deadline = Instant::now() + opts.connect_timeout + Duration::from_secs(60);
        let mut last_hb = Instant::now();
        let mut last_join = Instant::now();
        let (rank, p, start_iter, peers, config) = loop {
            ensure!(
                Instant::now() < assign_deadline,
                "no assignment from driver within the join window"
            );
            if maybe_heartbeat(ctrl, &mut last_hb).is_err() {
                return Ok(LoopEnd::ControlLost);
            }
            if last_join.elapsed() >= Duration::from_secs(2) {
                if ctrl
                    .send(&Frame::Join {
                        ring_addr: ring_addr.clone(),
                        wire_precision: opts.wire_precision,
                    })
                    .is_err()
                {
                    return Ok(LoopEnd::ControlLost);
                }
                last_join = Instant::now();
            }
            match ctrl_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(CtrlEv::Frame(Frame::Assign {
                    rank,
                    p,
                    start_iter,
                    peers,
                    config,
                })) => break (rank as usize, p as usize, start_iter, peers, config),
                Ok(CtrlEv::Frame(Frame::Shutdown)) => return Ok(LoopEnd::Shutdown),
                Ok(CtrlEv::Frame(Frame::Reject { reason })) => {
                    // The driver will never admit this configuration;
                    // re-joining forever would just spin.
                    anyhow::bail!("driver rejected this worker: {reason}");
                }
                Ok(CtrlEv::Frame(_)) | Err(RecvTimeoutError::Timeout) => {}
                Ok(CtrlEv::Dead) | Err(RecvTimeoutError::Disconnected) => {
                    return Ok(LoopEnd::ControlLost)
                }
            }
        };
        ensure!(rank < p && p >= 1, "bad assignment: rank {rank} of {p}");
        ensure!(peers.len() == p, "assignment names {} peers, want {p}", peers.len());

        // ---- Resolve the experiment and this rank's shard.
        let cfg = ExperimentConfig::parse_str(&config).context("parsing shipped config")?;
        let cache_dir = opts
            .data_cache
            .clone()
            .or_else(|| cfg.data_cache.clone())
            .or_else(|| match &cfg.dataset {
                DatasetSpec::Cache { dir } => Some(dir.clone()),
                _ => None,
            })
            .context("no shard cache: pass --data-cache or ship dataset = cache:<dir>")?;
        let src = ShardCacheSource::open(&cache_dir)
            .with_context(|| format!("opening shard cache {cache_dir:?}"))?;
        let n = src.n();
        let d = src.d();
        let k = cfg.fm.k;
        let kp = padded_k(k);
        let row_plan = src.plan(cfg.row_partition, p)?;
        let shard = src.shard(&row_plan, rank)?;
        let col_plan = col_plan_for(cfg.cols_per_token, d, p);
        let ntok = col_plan.n_blocks() + 1;
        let t_max = cfg.outer_iters as u32;
        ensure!(start_iter <= t_max, "assigned start {start_iter} > t_max {t_max}");

        // ---- Reproduce the deal; restore or initialize the model.
        let ranks = deal_ranks(ntok, cfg.seed, p);
        let expected_local = ranks.iter().filter(|&&r| r == rank).count();
        let (model, my_tokens) = if start_iter == 0 {
            let mut rng = Pcg64::new(cfg.seed, 0x0ad);
            let init = FmModel::init(d, k, cfg.fm.init_std, &mut rng);
            let kern = FmKernel::from_model(&init);
            let mine: Vec<Token> = deal_tokens(&init, &kern, &col_plan, 0)
                .into_iter()
                .zip(&ranks)
                .filter(|&(_, &r)| r == rank)
                .map(|(t, _)| t)
                .collect();
            (init, mine)
        } else {
            let dir = opts
                .ckpt_dir
                .as_ref()
                .context("driver assigned a checkpoint restart but --ckpt-dir is not set")?;
            let mut all: Vec<Token> = Vec::with_capacity(ntok);
            for r in 0..p {
                let path = dir.join(Checkpointer::block_file_name(r, start_iter));
                let (_, iter, toks) = Checkpointer::load_blocks(&path)
                    .with_context(|| format!("loading checkpoint {path:?}"))?;
                ensure!(iter == start_iter, "checkpoint {path:?} is for epoch {iter}");
                all.extend(toks);
            }
            let mine: Vec<Token> = all
                .iter()
                .filter(|t| {
                    let idx = if t.is_bias() { ntok - 1 } else { t.j as usize };
                    ranks[idx] == rank
                })
                .cloned()
                .collect();
            let model = assemble_model(all, &col_plan, d, k, start_iter)?;
            (model, mine)
        };

        // ---- Ring transport over the assigned peer table.
        let mut peer_addrs = Vec::with_capacity(p);
        for peer in &peers {
            let addr = peer
                .to_socket_addrs()
                .with_context(|| format!("resolving ring peer {peer}"))?
                .next()
                .with_context(|| format!("ring peer {peer} resolved to nothing"))?;
            peer_addrs.push(addr);
        }
        let transport = TcpTransport::remote(
            rank,
            ring_listener,
            peer_addrs,
            Some(k),
            opts.wire_precision,
            Duration::from_secs(30),
            key,
            opts.chaos.clone(),
        )?;

        // ---- Arenas seeded from the (initial or restored) model.
        let kern = FmKernel::from_model(&model);
        let (arenas, scratch) = seed_arenas(&shard, &kern, k);
        let partition::Shard { task, cols, labels, .. } = shard;

        let (post_tx, post_rx) = channel::<FinalizePost>();
        let (ckpt_tx, ckpt_rx) = channel::<CkptMsg>();
        let shared = Shared {
            transport: &*transport,
            mirror: None,
            collector: Mutex::new(Vec::with_capacity(ntok)),
            collected: AtomicUsize::new(0),
            done: AtomicBool::new(false),
            update_visits: AtomicU64::new(0),
            coordinate_updates: AtomicU64::new(0),
            holdback_peak: AtomicUsize::new(0),
            busy_secs: Mutex::new(vec![0.0; p]),
            stop_at: AtomicU32::new(u32::MAX),
            driver_iters: AtomicU32::new(start_iter),
        };
        let mut engine = Worker {
            id: rank,
            p,
            ntok,
            n_total: n,
            t_max,
            k,
            kp,
            col_plan,
            task,
            eta: cfg.eta,
            lambda_w: cfg.fm.lambda_w,
            lambda_v: cfg.fm.lambda_v,
            labels,
            cols,
            nloc: arenas.g.len(),
            g: arenas.g,
            aa: arenas.aa,
            acc_xw: arenas.acc_xw,
            acc_a: arenas.acc_a,
            acc_s2: arenas.acc_s2,
            w0: model.w0,
            seq: 2 * start_iter as u64,
            seen: 0,
            holdback: Vec::new(),
            reg_w: 0.0,
            reg_v: 0.0,
            post_tx,
            shared: &shared,
            visits_processed: 0,
            coords_applied: 0,
            update_mode: cfg.update_mode,
            rng: Pcg64::new(cfg.seed, 0x3a17 + rank as u64),
            scratch,
            def_idx: Vec::new(),
            def_w: Vec::new(),
            def_v: Vec::new(),
            ckpt: opts.ckpt_dir.is_some().then(|| CkptHook {
                every: opts.ckpt_every.max(1),
                tx: ckpt_tx.clone(),
            }),
        };
        drop(ckpt_tx);

        if ctrl.send(&Frame::Ready).is_err() {
            transport.shutdown();
            return Ok(LoopEnd::ControlLost);
        }

        // ---- Await the Start barrier.
        let start_deadline = Instant::now() + opts.connect_timeout + Duration::from_secs(60);
        let mut rejoin = false;
        loop {
            ensure!(
                Instant::now() < start_deadline,
                "driver never released the Start barrier"
            );
            if maybe_heartbeat(ctrl, &mut last_hb).is_err() {
                transport.shutdown();
                return Ok(LoopEnd::ControlLost);
            }
            match ctrl_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(CtrlEv::Frame(Frame::Start)) => break,
                Ok(CtrlEv::Frame(Frame::Abort)) => {
                    rejoin = true;
                    break;
                }
                Ok(CtrlEv::Frame(Frame::Shutdown)) => {
                    transport.shutdown();
                    return Ok(LoopEnd::Shutdown);
                }
                Ok(CtrlEv::Frame(_)) | Err(RecvTimeoutError::Timeout) => {}
                Ok(CtrlEv::Dead) | Err(RecvTimeoutError::Disconnected) => {
                    transport.shutdown();
                    return Ok(LoopEnd::ControlLost);
                }
            }
        }
        if rejoin {
            transport.shutdown();
            continue;
        }

        // ---- Deal this rank's tokens into its own inbox and run.
        for tok in my_tokens {
            transport.send(rank, tok);
        }
        let mut pending: HashMap<u32, Vec<Token>> = HashMap::new();
        let end = std::thread::scope(|scope| {
            let eng = scope.spawn(move || engine.run());
            let end = relay_loop(
                ctrl,
                ctrl_rx,
                &post_rx,
                &ckpt_rx,
                &shared,
                opts,
                rank,
                p,
                k,
                t_max,
                start_iter,
                expected_local,
                &mut pending,
                &mut last_hb,
            );
            // The engine thread must exit before the scope can close,
            // whatever the relay decided (including errors).
            shared.done.store(true, Ordering::SeqCst);
            let _ = eng.join();
            end
        })?;

        match end {
            RelayEnd::Completed => {
                // The engine is quiesced: flush any checkpoint epochs that
                // completed in its final moments, then hand every
                // collected token to the driver.
                while let Ok(msg) = ckpt_rx.try_recv() {
                    match msg {
                        CkptMsg::Block(tok) => pending.entry(tok.iter).or_default().push(tok),
                        CkptMsg::EpochDone(tag) => {
                            save_epoch(&opts.ckpt_dir, rank, p, tag, &mut pending, k)
                        }
                    }
                }
                let tokens = std::mem::take(&mut *shared.collector.lock().unwrap());
                let mut buf = Vec::new();
                let mut lost = false;
                for tok in &tokens {
                    codec::encode_token_padded(tok, k, &mut buf);
                    if ctrl.send(&Frame::FinalBlock { frame: buf.clone() }).is_err() {
                        lost = true;
                        break;
                    }
                }
                let stats = transport.stats();
                if lost
                    || ctrl
                        .send(&Frame::Done {
                            messages: stats.messages,
                            bytes: stats.bytes,
                        })
                        .is_err()
                {
                    transport.shutdown();
                    return Ok(LoopEnd::ControlLost);
                }

                // Keep the ring alive until the driver confirms: peers may
                // still be pulling their last tokens through us.
                let bye_deadline = Instant::now() + Duration::from_secs(120);
                loop {
                    ensure!(
                        Instant::now() < bye_deadline,
                        "driver never acknowledged completion"
                    );
                    if maybe_heartbeat(ctrl, &mut last_hb).is_err() {
                        transport.shutdown();
                        return Ok(LoopEnd::ControlLost);
                    }
                    match ctrl_rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(CtrlEv::Frame(Frame::Shutdown)) => {
                            transport.shutdown();
                            return Ok(LoopEnd::Shutdown);
                        }
                        Ok(CtrlEv::Frame(Frame::Abort)) => {
                            transport.shutdown();
                            break; // re-join: a peer died during its drain
                        }
                        Ok(CtrlEv::Frame(_)) | Err(RecvTimeoutError::Timeout) => {}
                        Ok(CtrlEv::Dead) | Err(RecvTimeoutError::Disconnected) => {
                            transport.shutdown();
                            return Ok(LoopEnd::ControlLost);
                        }
                    }
                }
            }
            RelayEnd::Aborted => {
                transport.shutdown();
            }
            RelayEnd::Shutdown => {
                transport.shutdown();
                return Ok(LoopEnd::Shutdown);
            }
            RelayEnd::ControlLost => {
                transport.shutdown();
                return Ok(LoopEnd::ControlLost);
            }
        }
    }
}

/// The mid-training relay between engine, checkpoint stream and driver.
#[allow(clippy::too_many_arguments)]
fn relay_loop(
    ctrl: &CtrlLink,
    ctrl_rx: &Receiver<CtrlEv>,
    post_rx: &Receiver<FinalizePost>,
    ckpt_rx: &Receiver<CkptMsg>,
    shared: &Shared<'_>,
    opts: &WorkerOptions,
    rank: usize,
    p: usize,
    k: usize,
    t_max: u32,
    start_iter: u32,
    expected_local: usize,
    pending: &mut HashMap<u32, Vec<Token>>,
    last_hb: &mut Instant,
) -> Result<RelayEnd> {
    // Iterations this engine worker has fully finalized (posts arrive in
    // increasing order).
    let mut finished_iters = start_iter;
    loop {
        // (An Err here is a timeout, or the engine thread quiescing.)
        if let Ok(post) = post_rx.recv_timeout(Duration::from_millis(5)) {
            finished_iters = post.iter + 1;
            if let Some(chaos) = &opts.chaos {
                // Scripted mid-epoch death: exit before this epoch's
                // report reaches the driver, so recovery must come from
                // block checkpoints, not from a graceful handoff.
                chaos.kill_if_due(finished_iters, "worker");
            }
            if ctrl
                .send(&Frame::Epoch {
                    rank: rank as u32,
                    iter: post.iter,
                    loss_sum: post.loss_sum,
                    reg_w: post.reg_w,
                    reg_v: post.reg_v,
                })
                .is_err()
            {
                return Ok(RelayEnd::ControlLost);
            }
        }
        while let Ok(msg) = ckpt_rx.try_recv() {
            match msg {
                CkptMsg::Block(tok) => pending.entry(tok.iter).or_default().push(tok),
                CkptMsg::EpochDone(tag) => save_epoch(&opts.ckpt_dir, rank, p, tag, pending, k),
            }
        }
        loop {
            match ctrl_rx.try_recv() {
                Ok(CtrlEv::Frame(Frame::Progress { iters_done })) => {
                    shared.driver_iters.fetch_max(iters_done, Ordering::Release);
                }
                Ok(CtrlEv::Frame(Frame::Stop { at })) => {
                    shared.stop_at.fetch_min(at, Ordering::SeqCst);
                }
                Ok(CtrlEv::Frame(Frame::Abort)) => return Ok(RelayEnd::Aborted),
                Ok(CtrlEv::Frame(Frame::Shutdown)) => return Ok(RelayEnd::Shutdown),
                Ok(CtrlEv::Frame(_)) => {}
                Ok(CtrlEv::Dead) | Err(TryRecvError::Disconnected) => {
                    return Ok(RelayEnd::ControlLost)
                }
                Err(TryRecvError::Empty) => break,
            }
        }
        if maybe_heartbeat(ctrl, last_hb).is_err() {
            return Ok(RelayEnd::ControlLost);
        }
        // Completion: this engine finalized its last iteration AND every
        // token this rank dealt came home (tokens return to their deal
        // rank; `expected_local` can be 0, hence the finalize condition).
        let stop = t_max.min(shared.stop_at.load(Ordering::SeqCst));
        if finished_iters >= stop && shared.collected.load(Ordering::SeqCst) >= expected_local {
            return Ok(RelayEnd::Completed);
        }
    }
}
