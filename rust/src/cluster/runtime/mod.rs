//! The multi-process cluster runtime: `dsfacto driver` + P x `dsfacto
//! worker` running the NOMAD token ring across OS processes.
//!
//! The division of labor mirrors the paper's no-parameter-server design
//! (Figs. 1-2, Algorithm 2): the driver is a *control plane only* —
//! membership, rank assignment, epoch bookkeeping, the convergence probe,
//! and final model assembly from collected tokens. Parameters never pass
//! through it during training; they circulate worker-to-worker on a
//! cross-process [`crate::cluster::TcpTransport`] ring, exactly the
//! in-process engine's data path over a different medium.
//!
//! * [`control`] — the length-prefixed control-frame codec (join/assign/
//!   barrier/epoch/stop frames) and its blocking stream IO.
//! * [`driver`] — the control plane: expected-P membership with a join
//!   timeout, rank/shard assignment from the shared
//!   [`crate::partition::RowPartition`] plan, per-epoch objective
//!   aggregation, heartbeat-based failure detection, and
//!   checkpoint-restart generations.
//! * [`worker`] — one engine [`crate::nomad`] worker hosted in its own
//!   process: resolves its shard from a `cache:<dir>` via
//!   [`crate::data::cache::ShardCacheSource`], reproduces the token deal
//!   from `(seed, p)`, and streams per-epoch block checkpoints through
//!   [`crate::train::Checkpointer`].
//!
//! Determinism: with the engine's deferred-sorted recompute fold, a
//! MeanGradient ring is bitwise deterministic at any P given identical
//! shards, seed, and column plan — so a P-process ring reproduces the
//! in-process P-worker model exactly (pinned by `rust/tests/
//! cluster_e2e.rs`). `update_mode = stochastic` remains timing-sensitive
//! (its RNG draw order depends on token arrival order) and carries no
//! cross-process equality guarantee.

pub mod control;
pub mod driver;
pub mod journal;
pub mod worker;

pub use driver::{run_driver, DriverOptions, DriverReport};
pub use worker::{run_worker, WorkerOptions};

use anyhow::{bail, ensure, Result};

use crate::partition::ColPartition;

/// The `cluster = ...` config key: which role this process plays in a
/// multi-process run. `None` (the default) runs everything in-process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterSpec {
    /// `driver:<addr>,p=<P>` — bind the control plane on `addr` and wait
    /// for `p` workers to join.
    Driver {
        /// Control-plane bind address (`host:port`; port 0 = ephemeral).
        addr: String,
        /// Expected worker count.
        p: usize,
    },
    /// `worker:<addr>` — join the driver listening on `addr`.
    Worker {
        /// The driver's control-plane address.
        driver: String,
    },
}

impl ClusterSpec {
    /// Parses `driver:<addr>,p=<P>` / `worker:<addr>`.
    pub fn parse(s: &str) -> Result<ClusterSpec> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("driver:") {
            let Some((addr, p)) = rest.rsplit_once(",p=") else {
                bail!("cluster driver spec needs `,p=<P>`: {s:?} (want driver:<addr>,p=<P>)");
            };
            ensure!(!addr.is_empty(), "cluster driver spec has empty address: {s:?}");
            let p: usize = p
                .parse()
                .map_err(|_| anyhow::anyhow!("bad worker count in cluster spec {s:?}"))?;
            ensure!(p >= 1, "cluster driver needs p >= 1: {s:?}");
            Ok(ClusterSpec::Driver {
                addr: addr.to_string(),
                p,
            })
        } else if let Some(addr) = s.strip_prefix("worker:") {
            ensure!(!addr.is_empty(), "cluster worker spec has empty address: {s:?}");
            Ok(ClusterSpec::Worker {
                driver: addr.to_string(),
            })
        } else {
            bail!("unknown cluster role in {s:?} (want driver:<addr>,p=<P> or worker:<addr>)")
        }
    }

    /// Canonical spec string (round-trips through [`ClusterSpec::parse`]).
    pub fn spec(&self) -> String {
        match self {
            ClusterSpec::Driver { addr, p } => format!("driver:{addr},p={p}"),
            ClusterSpec::Worker { driver } => format!("worker:{driver}"),
        }
    }
}

/// The column-block grid for a run, from the config knob: `0` = the auto
/// heuristic, otherwise a fixed block size. Driver and workers must agree
/// on this (both derive it from the same shipped config), and it must
/// match what the in-process engine would pick for the equality guarantee
/// to hold.
pub(crate) fn col_plan_for(cols_per_token: usize, d: usize, p: usize) -> ColPartition {
    if cols_per_token == 0 {
        ColPartition::auto(d, p)
    } else {
        ColPartition::with_block_size(d, cols_per_token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_spec_parses_and_round_trips() {
        let d = ClusterSpec::parse("driver:0.0.0.0:4700,p=8").unwrap();
        assert_eq!(
            d,
            ClusterSpec::Driver {
                addr: "0.0.0.0:4700".into(),
                p: 8
            }
        );
        assert_eq!(ClusterSpec::parse(&d.spec()).unwrap(), d);
        let w = ClusterSpec::parse("worker:10.1.2.3:4700").unwrap();
        assert_eq!(
            w,
            ClusterSpec::Worker {
                driver: "10.1.2.3:4700".into()
            }
        );
        assert_eq!(ClusterSpec::parse(&w.spec()).unwrap(), w);
    }

    #[test]
    fn cluster_spec_rejects_malformed_input() {
        for bad in [
            "",
            "driver:",
            "driver:127.0.0.1:4700",      // missing ,p=
            "driver:,p=2",                // empty address
            "driver:127.0.0.1:4700,p=0",  // zero workers
            "driver:127.0.0.1:4700,p=xy", // non-numeric count
            "worker:",
            "peer:127.0.0.1:4700", // unknown role
        ] {
            assert!(ClusterSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn col_plan_matches_engine_choice() {
        // 0 = auto heuristic (what the in-process engine picks); nonzero
        // pins the block size exactly.
        let auto = col_plan_for(0, 13, 2);
        assert_eq!(auto.d(), 13);
        let fixed = col_plan_for(5, 13, 2);
        assert_eq!(fixed.block_size(), 5);
        assert_eq!(fixed.n_blocks(), 3); // 5 + 5 + 3
    }
}
