//! Driver control-state journal: the small file that kills the last
//! single point of failure.
//!
//! Block checkpoints already make *workers* restartable; this journal
//! makes the *driver* restartable. After every aggregated iteration the
//! driver writes its control state — ring width, a hash of the shipped
//! config, cumulative generation count, and the convergence trace — to
//! `driver.dsfj` next to the block checkpoints (same atomic tmp+rename
//! discipline as `Checkpointer::save_blocks`). A restarted
//! `dsfacto driver --resume` loads it, refuses a mismatched experiment
//! (config hash), re-opens membership, and resumes from
//! `Checkpointer::latest_block_epoch` with the trace intact.
//!
//! Format: versioned plain text. Floats are written with Rust's `{}`
//! formatting, whose shortest-round-trip representation parses back to
//! the identical bits — the resumed trace is exact, not approximate.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::cluster::auth::sha256;
use crate::metrics::TracePoint;

const VERSION_LINE: &str = "dsfj v1";

/// The driver's journaled control state.
#[derive(Debug, Clone, PartialEq)]
pub struct DriverJournal {
    /// Ring width the run was started with.
    pub p: usize,
    /// Hex SHA-256 of the shipped config text (`ship_cfg`): a resumed
    /// driver must be running the *same experiment*.
    pub config_sha: String,
    /// Cumulative generations used (across driver restarts).
    pub generations: u32,
    /// Convergence trace up to the last fully aggregated iteration.
    pub trace: Vec<TracePoint>,
}

/// Hex SHA-256 of the shipped config text.
pub fn config_sha(ship_cfg: &str) -> String {
    sha256(ship_cfg.as_bytes())
        .iter()
        .map(|b| format!("{b:02x}"))
        .collect()
}

impl DriverJournal {
    /// File name inside the checkpoint directory.
    pub const FILE: &'static str = "driver.dsfj";

    pub fn path(dir: &Path) -> PathBuf {
        dir.join(Self::FILE)
    }

    /// Atomically writes the journal into `dir` (tmp + rename + sync).
    pub fn save(&self, dir: &Path) -> Result<()> {
        use std::io::Write;
        let mut text = String::new();
        text.push_str(VERSION_LINE);
        text.push('\n');
        text.push_str(&format!("p {}\n", self.p));
        text.push_str(&format!("config_sha {}\n", self.config_sha));
        text.push_str(&format!("generations {}\n", self.generations));
        text.push_str(&format!("trace {}\n", self.trace.len()));
        for pt in &self.trace {
            // The held-out column is never populated in cluster runs.
            text.push_str(&format!(
                "{} {} {} {}\n",
                pt.iter, pt.secs, pt.objective, pt.train_loss
            ));
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {dir:?}"))?;
        let tmp = dir.join(format!(".{}.tmp", Self::FILE));
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(text.as_bytes()).context("writing journal")?;
            f.sync_all().context("syncing journal")?;
        }
        std::fs::rename(&tmp, Self::path(dir)).context("publishing journal")
    }

    /// Loads the journal from `dir`; `Ok(None)` when none was written.
    pub fn load(dir: &Path) -> Result<Option<DriverJournal>> {
        let path = Self::path(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading {path:?}")),
        };
        let mut lines = text.lines();
        ensure!(
            lines.next() == Some(VERSION_LINE),
            "{path:?} is not a {VERSION_LINE} journal"
        );
        let mut field = |name: &str| -> Result<String> {
            let line = lines
                .next()
                .with_context(|| format!("{path:?}: missing `{name}` line"))?;
            let Some(v) = line.strip_prefix(name).map(str::trim) else {
                bail!("{path:?}: expected `{name} ...`, found {line:?}");
            };
            Ok(v.to_string())
        };
        let p: usize = field("p")?.parse().context("journal p")?;
        let config_sha = field("config_sha")?;
        let generations: u32 = field("generations")?.parse().context("journal generations")?;
        let ntrace: usize = field("trace")?.parse().context("journal trace count")?;
        ensure!(ntrace <= 1 << 24, "implausible trace length {ntrace}");
        let mut trace = Vec::with_capacity(ntrace);
        for _ in 0..ntrace {
            let line = lines
                .next()
                .with_context(|| format!("{path:?}: trace truncated"))?;
            let cols: Vec<&str> = line.split_whitespace().collect();
            ensure!(cols.len() == 4, "{path:?}: bad trace line {line:?}");
            trace.push(TracePoint {
                iter: cols[0].parse().context("trace iter")?,
                secs: cols[1].parse().context("trace secs")?,
                objective: cols[2].parse().context("trace objective")?,
                train_loss: cols[3].parse().context("trace train_loss")?,
                test: None,
            });
        }
        ensure!(
            lines.next().is_none(),
            "{path:?} has trailing content past the trace"
        );
        Ok(Some(DriverJournal {
            p,
            config_sha,
            generations,
            trace,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DriverJournal {
        DriverJournal {
            p: 3,
            config_sha: config_sha("dataset = cache:/x\nworkers = 3\n"),
            generations: 2,
            trace: vec![
                TracePoint {
                    iter: 0,
                    secs: 0.0,
                    objective: 0.123456789012345678,
                    train_loss: 0.1,
                    test: None,
                },
                TracePoint {
                    iter: 1,
                    secs: 1.5e-3,
                    objective: f64::MIN_POSITIVE,
                    train_loss: 1.0 / 3.0,
                    test: None,
                },
            ],
        }
    }

    #[test]
    fn journal_round_trips_bitwise() {
        let dir = std::env::temp_dir().join("dsfacto_journal_rt");
        std::fs::remove_dir_all(&dir).ok();
        let j = sample();
        assert_eq!(DriverJournal::load(&dir).unwrap(), None);
        j.save(&dir).unwrap();
        let back = DriverJournal::load(&dir).unwrap().expect("journal exists");
        assert_eq!(back, j, "trace floats must round-trip exactly");
        assert_eq!(back.trace[1].objective.to_bits(), f64::MIN_POSITIVE.to_bits());
        // Overwrite is atomic-in-place: a second save fully replaces it.
        let mut j2 = j.clone();
        j2.generations = 5;
        j2.trace.truncate(1);
        j2.save(&dir).unwrap();
        assert_eq!(DriverJournal::load(&dir).unwrap().unwrap(), j2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_rejects_corruption() {
        let dir = std::env::temp_dir().join("dsfacto_journal_bad");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = DriverJournal::path(&dir);
        for bad in [
            "",
            "dsfj v999\np 2\n",
            "dsfj v1\np 2\nconfig_sha x\ngenerations 1\ntrace 2\n0 0 0 0\n", // truncated trace
            "dsfj v1\np 2\nconfig_sha x\ngenerations 1\ntrace 1\n0 0 0\n",   // short line
            "dsfj v1\np 2\nconfig_sha x\ngenerations 1\ntrace 0\nextra\n",   // trailing
        ] {
            std::fs::write(&path, bad).unwrap();
            assert!(DriverJournal::load(&dir).is_err(), "accepted: {bad:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_sha_discriminates() {
        assert_ne!(config_sha("a"), config_sha("b"));
        assert_eq!(config_sha("same"), config_sha("same"));
        assert_eq!(config_sha("x").len(), 64);
    }
}
